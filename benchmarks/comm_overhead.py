"""Fig. 3 reproduction: AR / A2A communication overhead.

Left subfigure: operator latency vs parallel degree for the paper's two
models on the 910B cluster (intra-node d<=8, inter-node d>8 inflection).
Right subfigure: intra- vs inter-node latency vs data size (the inflection
point arrives later intra-node because of the higher bandwidth).

Theoretical model (Eqs. 1-3) on the paper's cluster specs — the same curves
the analyzer uses for strategy selection.
"""

from __future__ import annotations

from repro.configs.paper_models import DEEPSEEK_R1, QWEN3_235B
from repro.core import cost_model as cm
from repro.core.topology import ASCEND_910B_CLUSTER as CL


def left_subfigure(rows: list) -> None:
    for model in (DEEPSEEK_R1, QWEN3_235B):
        size = 16 * 1024 * model.d_model * cm.BYTES        # b=16, s=1024
        for d in (2, 4, 8, 16, 32):
            inter = d > CL.n_proc
            bw, al = CL.bw(inter), CL.latency(inter)
            ar = cm.ar_cost(size, d, bw, al)
            a2a = cm.a2a_cost(size * model.top_k, d, bw, al)
            rows.append((f"fig3L/{model.name}/d{d}/AR", ar * 1e6,
                         f"inter={inter}"))
            rows.append((f"fig3L/{model.name}/d{d}/A2A", a2a * 1e6,
                         f"inter={inter}"))


def right_subfigure(rows: list) -> None:
    for mb in (1, 4, 16, 64, 256):
        size = mb * 1e6
        intra = cm.a2a_cost(size, 4, CL.intra_node_bw, CL.intra_node_latency)
        inter = cm.a2a_cost(size, 4, CL.inter_node_bw, CL.inter_node_latency)
        rows.append((f"fig3R/intra/{mb}MB", intra * 1e6, ""))
        rows.append((f"fig3R/inter/{mb}MB", inter * 1e6,
                     f"ratio={inter / max(intra, 1e-12):.1f}x"))


def run() -> list:
    rows: list = []
    left_subfigure(rows)
    right_subfigure(rows)
    # headline observations the paper draws from Fig. 3
    d32_ar = next(v for n, v, _ in rows
                  if n == f"fig3L/{DEEPSEEK_R1.name}/d32/AR")
    d8_ar = next(v for n, v, _ in rows
                 if n == f"fig3L/{DEEPSEEK_R1.name}/d8/AR")
    rows.append(("fig3/check/inter_node_cliff", 0.0,
                 f"AR d=32 is {d32_ar / d8_ar:.1f}x AR d=8 (paper: sharp "
                 "increase past d=8)"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")
