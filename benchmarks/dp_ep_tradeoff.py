"""Fig. 11 reproduction: the DP/EP trade-off ablation (§III-B3, §IV-C1).

Three representative settings on each cluster:
  (1) d_DP = d_EP   (balanced)
  (2) d_DP > d_EP   (expert-weight redundancy, more DP throughput)
  (3) d_DP < d_EP   (hidden-state redundancy + drop strategy)

The paper finds the balanced case wins on the 910B cluster while d_DP<d_EP
wins TTFT on H20 — both orderings emerge from the same Eq. 5 trade-off model.
"""

from __future__ import annotations

from repro.configs.paper_models import DEEPSEEK_R1, QWEN3_235B
from repro.core import cost_model as cm
from repro.core.topology import ASCEND_910B_CLUSTER, H20_CLUSTER

BATCH, L_IN, L_OUT = 16, 4096 - 256, 256


def run() -> list:
    rows = []
    # (name, attn_tp, attn_dp, moe_tp, moe_ep) per §IV-C1
    settings_910b = [("dp_eq_ep", 8, 4, 8, 4),
                     ("dp_gt_ep", 4, 8, 8, 4),
                     ("dp_lt_ep", 8, 4, 4, 8)]
    settings_h20 = [("dp_eq_ep", 8, 2, 8, 2),
                    ("dp_gt_ep", 4, 4, 8, 2),
                    ("dp_lt_ep", 8, 2, 4, 4)]
    for model in (DEEPSEEK_R1, QWEN3_235B):
        for cluster, settings in ((ASCEND_910B_CLUSTER, settings_910b),
                                  (H20_CLUSTER, settings_h20)):
            best = None
            for name, atp, adp, mtp, mep in settings:
                s = cm.Strategy(attn_tp=atp, attn_dp=adp, moe_tp=mtp,
                                moe_ep=mep, comm_algo="fused",
                                ep_inter_node=mep > cluster.n_proc // mtp)
                ind = cm.indicators(model, s, cluster, batch=BATCH,
                                    l_in=L_IN, l_out=L_OUT)
                rows.append((f"fig11/{model.name}/{cluster.name}/{name}",
                             ind.ttft * 1e6,
                             f"itl={ind.itl*1e3:.2f}ms "
                             f"thr={ind.throughput:.1f}tok/s"))
                if best is None or ind.ttft < best[1]:
                    best = (name, ind.ttft)
            rows.append((f"fig11/{model.name}/{cluster.name}/winner", 0.0,
                         best[0]))
    return rows


if __name__ == "__main__":
    for name, v, derived in run():
        print(f"{name},{v:.1f},{derived}")
