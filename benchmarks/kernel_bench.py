"""Pallas kernel micro-bench: interpret-mode correctness-scale timings plus
the jnp-oracle timings on matched shapes (CPU walltime; the TPU story is the
BlockSpec structure, not these numbers)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.kernels import ops


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)

    e, c, h, d = 8, 256, 512, 512
    x = jax.random.normal(key, (e, c, h), jnp.float32)
    w = jax.random.normal(key, (e, h, d), jnp.float32)
    us_ref = time_fn(jax.jit(ops.moe_gemm_ref), x, w)
    flops = 2 * e * c * h * d
    rows.append((f"kernel/moe_gemm_ref/{e}x{c}x{h}x{d}", us_ref,
                 f"{flops / us_ref / 1e3:.1f}GFLOP/s(cpu)"))

    t, ne, k = 4096, 160, 6
    logits = jax.random.normal(key, (t, ne), jnp.float32)
    us = time_fn(jax.jit(lambda l: ops.topk_gate_ref(l, k)), logits)
    rows.append((f"kernel/topk_gate_ref/{t}x{ne}k{k}", us, ""))

    b, nq, nkv, hd, s = 8, 32, 8, 128, 4096
    q = jax.random.normal(key, (b, nq, hd), jnp.float32)
    kk = jax.random.normal(key, (b, s, nkv, hd), jnp.float32)
    vv = jax.random.normal(key, (b, s, nkv, hd), jnp.float32)
    lens = jnp.full((b,), s, jnp.int32)
    us = time_fn(jax.jit(ops.flash_decode_ref), q, kk, vv, lens)
    bytes_read = b * s * nkv * hd * 2 * 4
    rows.append((f"kernel/flash_decode_ref/b{b}s{s}", us,
                 f"{bytes_read / us / 1e3:.1f}GB/s(cpu)"))
    return rows


if __name__ == "__main__":
    for name, v, derived in run():
        print(f"{name},{v:.1f},{derived}")
