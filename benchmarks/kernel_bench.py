"""Pallas kernel micro-bench: matched-shape pairs of the jnp oracle and the
actual Pallas kernel (interpret mode on CPU — correctness-scale timings; the
TPU story is the BlockSpec structure, not these numbers).

Rows come in ``<op>_ref`` / ``<op>_pallas`` pairs so the CSV/JSON output can
be diffed shape-for-shape, including the fused permute/unpermute dispatch
kernels against the repeat+scatter-add / gather+reduce jnp bodies they
replace.  Block sizes go through the autotune selection cache exactly like
the serving path."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.kernels import autotune, ops
from repro.models import moe as M


def _pair(rows, name, us_ref, us_krn, derived=""):
    rows.append((f"kernel/{name}_ref", us_ref, derived))
    rows.append((f"kernel/{name}_pallas", us_krn,
                 f"interp x{us_krn / max(us_ref, 1e-9):.1f} vs ref"))


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)

    # ---- grouped expert GEMM --------------------------------------------
    e, c, h, d = 4, 128, 256, 256
    x = jax.random.normal(key, (e, c, h), jnp.float32)
    w = jax.random.normal(key, (e, h, d), jnp.float32)
    us_ref = time_fn(jax.jit(ops.moe_gemm_ref), x, w)
    us_krn = time_fn(functools.partial(ops.moe_gemm, x, w))
    flops = 2 * e * c * h * d
    _pair(rows, f"moe_gemm/{e}x{c}x{h}x{d}", us_ref, us_krn,
          f"{flops / us_ref / 1e3:.1f}GFLOP/s(cpu)")

    # ---- dropless segment GEMM (ragged, group-offset grid) --------------
    # same FLOP volume as the capacity pair above (N = E*C rows) so the two
    # grouped-GEMM schemes diff directly; offsets from a skewed multinomial
    # routing draw, so segment boundaries straddle row tiles.
    n = e * c
    xs = jax.random.normal(key, (n, h), jnp.float32)
    wg = jax.random.normal(key, (e, h, d), jnp.float32)
    probs = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(3), (e,)))
    counts = jnp.floor(probs * n).astype(jnp.int32)
    counts = counts.at[0].add(n - counts.sum())          # exact total
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(counts).astype(jnp.int32)])
    us_ref = time_fn(jax.jit(ops.grouped_gemm_ref), xs, wg, offs)
    us_krn = time_fn(functools.partial(ops.grouped_gemm, xs, wg, offs))
    _pair(rows, f"grouped_gemm/{n}x{h}x{d}e{e}", us_ref, us_krn,
          f"{2 * n * h * d / us_ref / 1e3:.1f}GFLOP/s(cpu)")

    # ---- fused router gate ----------------------------------------------
    t, ne, k = 1024, 64, 4
    logits = jax.random.normal(key, (t, ne), jnp.float32)
    us_ref = time_fn(jax.jit(lambda l: ops.topk_gate_ref(l, k)), logits)
    us_krn = time_fn(functools.partial(ops.topk_gate, logits, k))
    _pair(rows, f"topk_gate/{t}x{ne}k{k}", us_ref, us_krn)

    # ---- flash decode ---------------------------------------------------
    b, nq, nkv, hd, s = 4, 16, 4, 64, 1024
    q = jax.random.normal(key, (b, nq, hd), jnp.float32)
    kk = jax.random.normal(key, (b, s, nkv, hd), jnp.float32)
    vv = jax.random.normal(key, (b, s, nkv, hd), jnp.float32)
    lens = jnp.full((b,), s, jnp.int32)
    us_ref = time_fn(jax.jit(ops.flash_decode_ref), q, kk, vv, lens)
    us_krn = time_fn(functools.partial(ops.flash_decode, q, kk, vv, lens))
    bytes_read = b * s * nkv * hd * 2 * 4
    _pair(rows, f"flash_decode/b{b}s{s}", us_ref, us_krn,
          f"{bytes_read / us_ref / 1e3:.1f}GB/s(cpu)")

    # ---- ragged mixed-chunk flash attention (unified-step shape) --------
    # a token-budget (B, chunk) iteration: one full prefill chunk, one
    # decode slot, one short chunk, one idle slot — per-slot offsets deep
    # into the cache so the kernel's frontier tile-skipping has tiles to
    # skip (the jnp ref walks every (b, s) score column regardless).
    b, sq, nq, nkv, hd, s = 4, 16, 16, 4, 64, 1024
    qc = jax.random.normal(key, (b, sq, nq, hd), jnp.float32)
    kc = jax.random.normal(key, (b, s, nkv, hd), jnp.float32)
    vc = jax.random.normal(key, (b, s, nkv, hd), jnp.float32)
    qlen = jnp.asarray([sq, 1, 5, 0], jnp.int32)
    off = jnp.asarray([256, 900, 64, 0], jnp.int32)
    kvlen = off + qlen
    us_ref = time_fn(jax.jit(ops.flash_chunk_ref), qc, kc, vc, off, qlen,
                     kvlen)
    us_krn = time_fn(functools.partial(ops.flash_chunk, qc, kc, vc, off,
                                       qlen, kvlen))
    _pair(rows, f"flash_chunk/b{b}sq{sq}s{s}", us_ref, us_krn,
          f"q_lens={[int(x) for x in qlen]} (ragged mixed batch)")

    # ---- fused token permute / unpermute+combine ------------------------
    tt, hh, ee, topk, cf = 512, 256, 32, 2, 2.0
    xx = jax.random.normal(key, (tt, hh), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(1), (tt, topk), 0, ee)
    wts = jax.random.uniform(jax.random.PRNGKey(2), (tt, topk), jnp.float32)
    cap = M.capacity_for(tt, topk, ee, cf)

    def _dispatch(flat_e, pos, keep, w):
        return M.DispatchInfo(flat_e=flat_e, pos=pos, keep=keep, weights=w,
                              capacity=cap)

    d0 = M.make_dispatch(idx, wts, ee, cap)
    args = (d0.flat_e, d0.pos, d0.keep, d0.weights)

    scat_ref = jax.jit(lambda xv, fe, po, kp, w: M.scatter_to_buffers(
        xv, _dispatch(fe, po, kp, w), ee))
    scat_krn = jax.jit(lambda xv, fe, po, kp, w: M.scatter_to_buffers(
        xv, _dispatch(fe, po, kp, w), ee, use_kernel=True))
    us_ref = time_fn(scat_ref, xx, *args)
    us_krn = time_fn(scat_krn, xx, *args)
    _pair(rows, f"permute/{tt}x{hh}e{ee}c{cap}", us_ref, us_krn,
          "scatter_to_buffers")

    buf = scat_ref(xx, *args)
    gath_ref = jax.jit(lambda bv, fe, po, kp, w: M.gather_from_buffers(
        bv, _dispatch(fe, po, kp, w), tt))
    gath_krn = jax.jit(lambda bv, fe, po, kp, w: M.gather_from_buffers(
        bv, _dispatch(fe, po, kp, w), tt, use_kernel=True))
    us_ref = time_fn(gath_ref, buf, *args)
    us_krn = time_fn(gath_krn, buf, *args)
    _pair(rows, f"unpermute/{tt}x{hh}e{ee}c{cap}", us_ref, us_krn,
          "gather_from_buffers")

    # ---- segment-aware ragged permute (dropless EP exchange shape) ------
    # worst-case-sized exchange buffer, 1/8 populated: the ragged kernel
    # skips the empty tail tiles that the plain gather still walks.
    nbuf, fill = 4096, 512
    src = jnp.where(jnp.arange(nbuf) < fill,
                    jax.random.randint(jax.random.PRNGKey(4), (nbuf,), 0, tt),
                    -1).astype(jnp.int32)
    us_ref = time_fn(jax.jit(ops.permute_tokens_ref), xx, src)
    us_krn = time_fn(functools.partial(ops.permute_tokens_ragged, xx, src,
                                       fill))
    _pair(rows, f"permute_ragged/{nbuf}x{hh}fill{fill}", us_ref, us_krn,
          "dropless EP exchange gather")

    # ---- paged-KV page-size sweep (the resolver-tier "kv_page" tune) ----
    # times the paged ragged-attention kernel across page sizes for one
    # slot envelope and registers the winner under the SAME key the
    # ServeSpec resolver's ``auto_kv`` looks up (resolve.kv_page_key) — a
    # subsequent resolve on this machine reports the page as
    # ``autotune:measured`` instead of the analytic default.
    import repro.configs as C
    from repro.core import resolve as R

    kv_cfg = C.get_reduced("smollm-360m")
    b, sq, max_len = 4, 8, 512
    nqp, nkvp, hdp = 8, 4, 64
    qp = jax.random.normal(key, (b, sq, nqp, hdp), jnp.float32)
    offp = jnp.asarray([256, 120, 64, 0], jnp.int32)
    qlenp = jnp.asarray([sq, 1, 5, 0], jnp.int32)
    best_page, best_us = None, float("inf")
    for page in (8, 16, 32, 64):
        n_pages = b * max_len // page
        kp = jax.random.normal(key, (n_pages, page, nkvp, hdp), jnp.float32)
        vp = jax.random.normal(key, (n_pages, page, nkvp, hdp), jnp.float32)
        bt = jnp.arange(n_pages, dtype=jnp.int32).reshape(b, -1)
        us = time_fn(functools.partial(ops.flash_chunk_paged, qp, kp, vp,
                                       bt, offp, qlenp, offp + qlenp))
        rows.append((f"kernel/kv_page/page{page}", us,
                     f"flash_chunk_paged b{b}sq{sq}len{max_len}"))
        if us < best_us:
            best_page, best_us = page, us
    key_shape = R.kv_page_key(kv_cfg, max_len)
    autotune.register("kv_page", key_shape, "bfloat16",
                      {"page": best_page})
    rows.append(("kernel/kv_page/tuned", float(best_page),
                 f"registered for key {key_shape} ({kv_cfg.name}) -> "
                 "auto_kv resolves it as autotune:measured"))

    # ---- resolver-constants calibration (the per-host "resolver" tune) --
    # measures the two host-dependent constants the ServeSpec resolver
    # otherwise takes as analytic defaults (resolve.AUTO_BATCH_CAP /
    # resolve.ITL_SLACK): the engine-slot sanity cap is the largest batch
    # at which a single-row decode step still amortizes on THIS host
    # (per-step throughput keeps improving), and the auto-chunk slack is
    # the ITL inflation a mid-size prefill chunk actually costs a decode
    # step here.  Registered under resolve.resolver_key() so the next
    # resolve on this machine reports both as ``autotune:measured``.
    from repro.core.partitioner import NULL_PLAN
    from repro.models.model import forward, init_params
    from repro.serving.kv_cache import make_batched_cache

    r_cfg = C.get_reduced("smollm-360m")
    r_params = init_params(jax.random.PRNGKey(0), r_cfg, jnp.float32)
    chunk, r_len, r_off = 16, 128, 64

    def _step(params, toks, q, cache):
        return forward(params, r_cfg, NULL_PLAN, tokens=toks, cache=cache,
                       q_lens=q, last_only=True).logits

    def _step_us(bsz, q_lens):
        cache = make_batched_cache(r_cfg, bsz, r_len, jnp.float32)
        cache = {**cache, "length": jnp.full((bsz,), r_off, jnp.int32)}
        toks = jnp.zeros((bsz, chunk), jnp.int32)
        return time_fn(jax.jit(_step), r_params, toks,
                       jnp.asarray(q_lens, jnp.int32), cache)

    cap, prev_tok_us = 2, float("inf")
    for bsz in (2, 4, 8, 16):
        us = _step_us(bsz, [1] * bsz)
        tok_us = us / bsz
        rows.append((f"kernel/resolver/decode_b{bsz}", us,
                     f"{tok_us:.1f}us/token (unified decode step)"))
        if tok_us < 0.9 * prev_tok_us:     # batching still amortizes
            cap, prev_tok_us = bsz, tok_us
        else:
            break

    t_dec = _step_us(4, [1, 1, 1, 1])
    t_mix = _step_us(4, [chunk, 1, 1, 1])
    infl = max(t_mix / max(t_dec, 1e-9) - 1.0, 0.0)
    pct = int(min(max(round(infl * 100), 25), 100))
    rows.append((f"kernel/resolver/itl_inflation_chunk{chunk}",
                 infl * 100,
                 f"mixed {t_mix:.0f}us vs decode {t_dec:.0f}us -> "
                 f"slack {pct}%"))

    autotune.register("resolver", R.resolver_key(), "host",
                      {"batch_cap": cap, "itl_slack_pct": pct})
    rows.append(("kernel/resolver/tuned", float(cap),
                 f"batch_cap={cap} itl_slack_pct={pct} registered for key "
                 f"{R.resolver_key()} -> auto_max_batch/auto_chunk resolve "
                 "them as autotune:measured"))

    rows.append(("kernel/autotune_cache_entries", float(
        len(autotune.cache_info())), "shape-keyed block selections"))
    return rows


if __name__ == "__main__":
    for name, v, derived in run():
        print(f"{name},{v:.1f},{derived}")
