"""Fig. 12 reproduction + the micro-chunked EP-exchange ablation.

(a) Gantt decomposition: per-phase times of the fused RS-Combine and fused
    AG-Dispatch schedules, sync (back-to-back) vs async (overlapped).
(b) End-to-end indicator impact on DeepSeek-R1 @ Ascend 910B, matching the
    paper's ablation cluster.
(c) Micro-chunk sweep: the count-bounded, C-chunked dispatch/compute/
    combine pipeline (docs/dispatch.md "Hiding the EP exchange") priced
    against the monolithic exchange — per-chunk alpha rounds bound the
    useful chunk count from above, so the sweep has a real optimum.
(d) The A2A byte ledger: count-bounded extent vs the monolithic
    worst-case buffers, for a prefill-shaped and a decode-shaped step.

``run_quick`` (the ``benchmarks.run --quick`` ``overlap`` suite) GATES on
the two acceptance invariants and on the analyzer flip:

  - the best chunked estimate never prices above the monolithic exchange
    (C=1 is in the sweep — a regression here means the overlap pricing
    changed sign);
  - the count-bounded extent moves strictly fewer bytes than worst case
    at realistic chunk sizes;
  - pricing the exchange as overlapped changes ``analyzer.select``'s
    preferred strategy on at least one paper cluster configuration.

The measured bit-identity of the chunked pipeline runs in tier-1
(tests/sharded/run_overlap_equivalence.py); this suite is the pricing +
ledger side.
"""

from __future__ import annotations

from repro.configs.paper_models import DEEPSEEK_R1
from repro.core import analyzer
from repro.core import cost_model as cm
from repro.core.topology import ASCEND_910B_CLUSTER as CL
from repro.core.topology import CLUSTERS

BATCH, L_IN, L_OUT = 16, 4096 - 256, 256
CHUNKS = (1, 2, 4, 8)


def _fig12_rows() -> list:
    rows = []
    model = DEEPSEEK_R1
    work = cm.Workload(batch=BATCH, seq_len=1)      # decode-phase ablation
    for algo in ("sync", "fused"):
        s = cm.Strategy(attn_tp=8, attn_dp=4, moe_tp=8, moe_ep=4,
                        comm_algo=algo, ep_inter_node=True)
        lam = cm.comm_latency(model, s, work, CL)
        ind = cm.indicators(model, s, CL, batch=BATCH, l_in=L_IN,
                            l_out=L_OUT)
        rows.append((f"fig12/{algo}/comm_per_layer", lam * 1e6,
                     f"ttft={ind.ttft*1e3:.1f}ms itl={ind.itl*1e3:.2f}ms "
                     f"thr={ind.throughput:.1f}tok/s"))
    sync = next(v for n, v, _ in rows if n.startswith("fig12/sync"))
    fused = next(v for n, v, _ in rows if n.startswith("fig12/fused"))
    # the overlap hides min(intra, inter) per phase; paper says the gain is
    # ~ the inter-node overhead of one phase
    size = BATCH * 1 * model.d_model * cm.BYTES * model.top_k / 8
    inter_phase = cm.a2a_cost(size, 4, CL.bw(True), CL.latency(True))
    rows.append(("fig12/async_gain", sync - fused,
                 f"~inter_node_phase={inter_phase*1e6:.1f}us (paper: gain "
                 "slightly > inter-node overhead)"))
    return rows


def _chunk_sweep_rows() -> list:
    """(c) the C-sweep on the paper's ablation config: per-layer MoE comm
    under the micro-chunked pipeline estimate, for a prefill-shaped and a
    decode-shaped step.  GATE (per phase): the best chunked price <= the
    monolithic price — C=1 is in the sweep, so a violation means the
    overlap pricing changed sign.  Decode at batch 16 correctly picks C=1
    (the per-chunk alpha rounds outweigh the hidable wire time); prefill
    has a real interior optimum."""
    rows = []
    model = DEEPSEEK_R1
    s = cm.Strategy(attn_tp=8, attn_dp=4, moe_tp=8, moe_ep=4,
                    comm_algo="fused", ep_inter_node=True)
    for phase, seq in (("prefill", 512), ("decode", 1)):
        work = cm.Workload(batch=BATCH, seq_len=seq)
        priced = {}
        for c in CHUNKS:
            ovl = cm.EpOverlap(chunks=c)
            lam = cm.comm_latency(model, s, work, CL, ep_overlap=ovl)
            priced[c] = lam
            rows.append((f"overlap/chunked/{phase}/C{c}/comm_per_layer",
                         lam * 1e6, f"cap={ovl.describe()}"))
        best_c = min(priced, key=priced.get)
        mono = priced[1]
        if priced[best_c] > mono:
            raise RuntimeError(
                f"micro-chunk sweep regression ({phase}): best chunked "
                f"estimate C={best_c} ({priced[best_c]*1e6:.1f}us) prices "
                f"ABOVE the monolithic exchange ({mono*1e6:.1f}us)")
        rows.append((f"overlap/chunked/{phase}/best_gain",
                     (mono - priced[best_c]) * 1e6,
                     f"best C={best_c}: hides "
                     f"{(1 - priced[best_c]/max(mono, 1e-30))*100:.0f}% of "
                     "the monolithic per-layer MoE comm"))
    return rows


def _ledger_rows() -> list:
    """(d) count-bounded extent vs worst-case buffers, in rows per rank.
    GATE: strictly fewer bytes at realistic (prefill and decode) chunk
    sizes — the soft cap must actually shrink the exchange."""
    rows = []
    model = DEEPSEEK_R1
    ep, c = 4, 4
    for phase, tokens in (("prefill", 256), ("decode", BATCH)):
        n = tokens * model.top_k                 # routed slots per rank
        ovl = cm.EpOverlap(chunks=c)
        n_chunk = (n // c) if n % c == 0 else n  # per-chunk slots
        cap = cm.cap_rows_for(n_chunk, ep, ovl)
        moved = ep * c * cap if n % c == 0 else ep * n
        worst = ep * n
        rows.append((f"overlap/ledger/{phase}/rows_moved", float(moved),
                     f"worst={worst} cap={cap}/chunk "
                     f"(-{(1 - moved/worst)*100:.0f}% vs worst-case, "
                     f"ep={ep} C={c} tokens={tokens})"))
        if phase == "prefill" and moved >= worst:
            raise RuntimeError(
                "count-bounded extent regression: the soft cap moved "
                f"{moved} rows vs {worst} worst-case at prefill shape "
                f"(tokens={tokens}, ep={ep}, C={c})")
    return rows


def _flip_rows() -> list:
    """Acceptance: pricing the exchange as overlapped flips the analyzer's
    preferred strategy on >= 1 paper cluster configuration."""
    model_id, cluster_id = "phi3.5-moe-42b", "v5e-pod-256"
    import repro.configs as C
    model = C.get(model_id)
    cluster = CLUSTERS[cluster_id]
    kw = dict(batch=16, l_in=1024, l_out=256)
    base = analyzer.select(model, cluster, **kw)
    ovl = analyzer.select(model, cluster, ep_overlap=cm.EpOverlap(chunks=4),
                          **kw)
    flipped = base.best.strategy != ovl.best.strategy
    if not flipped:
        raise RuntimeError(
            "overlap pricing no longer changes the analyzer's pick on "
            f"{model_id}@{cluster_id} b=16: both select "
            f"{base.best.strategy.describe()}")
    return [(f"overlap/flip/{model_id}@{cluster_id}", 1.0,
             f"monolithic -> {base.best.strategy.describe()} | "
             f"overlapped(C=4) -> {ovl.best.strategy.describe()}")]


def run_quick():
    """The ``overlap`` quick suite: sweep + ledger + flip, all gated."""
    rows = _chunk_sweep_rows() + _ledger_rows() + _flip_rows()
    return {"rows": rows,
            "meta": {"gates": ["best chunked price <= monolithic",
                               "count-bounded rows < worst-case rows",
                               "analyzer flip on a paper cluster"]}}


def run() -> list:
    return _fig12_rows() + _chunk_sweep_rows() + _ledger_rows() \
        + _flip_rows()


if __name__ == "__main__":
    for name, v, derived in run():
        print(f"{name},{v:.1f},{derived}")
