"""Fig. 12 reproduction: sync vs async (fused) AR-A2A communication.

(a) Gantt decomposition: per-phase times of the fused RS-Combine and fused
    AG-Dispatch schedules, sync (back-to-back) vs async (overlapped).
(b) End-to-end indicator impact on DeepSeek-R1 @ Ascend 910B, matching the
    paper's ablation cluster.

The paper's observation: the async gain is "approximately slightly greater
than inter-node communication overhead" — we report exactly that delta.
"""

from __future__ import annotations

from repro.configs.paper_models import DEEPSEEK_R1
from repro.core import cost_model as cm
from repro.core.topology import ASCEND_910B_CLUSTER as CL

BATCH, L_IN, L_OUT = 16, 4096 - 256, 256


def run() -> list:
    rows = []
    model = DEEPSEEK_R1
    work = cm.Workload(batch=BATCH, seq_len=1)      # decode-phase ablation
    for algo in ("sync", "fused"):
        s = cm.Strategy(attn_tp=8, attn_dp=4, moe_tp=8, moe_ep=4,
                        comm_algo=algo, ep_inter_node=True)
        lam = cm.comm_latency(model, s, work, CL)
        ind = cm.indicators(model, s, CL, batch=BATCH, l_in=L_IN,
                            l_out=L_OUT)
        rows.append((f"fig12/{algo}/comm_per_layer", lam * 1e6,
                     f"ttft={ind.ttft*1e3:.1f}ms itl={ind.itl*1e3:.2f}ms "
                     f"thr={ind.throughput:.1f}tok/s"))
    sync = next(v for n, v, _ in rows if n.startswith("fig12/sync"))
    fused = next(v for n, v, _ in rows if n.startswith("fig12/fused"))
    # the overlap hides min(intra, inter) per phase; paper says the gain is
    # ~ the inter-node overhead of one phase
    size = BATCH * 1 * model.d_model * cm.BYTES * model.top_k / 8
    inter_phase = cm.a2a_cost(size, 4, CL.bw(True), CL.latency(True))
    rows.append(("fig12/async_gain", sync - fused,
                 f"~inter_node_phase={inter_phase*1e6:.1f}us (paper: gain "
                 "slightly > inter-node overhead)"))
    return rows


if __name__ == "__main__":
    for name, v, derived in run():
        print(f"{name},{v:.1f},{derived}")
