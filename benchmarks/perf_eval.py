"""Fig. 10 reproduction: TTFT / ITL / throughput of MixServe vs baselines.

The paper measures DeepSeek-R1 and Qwen3-235B on two clusters against the
Table II baselines.  We reproduce the comparison with the theoretical
indicator model (Eqs. 9-11) driven by the same model hyperparameters and
cluster specs, then report speedups in the paper's format.

Paper's reported ranges for reference: TTFT 1.08-3.80x, ITL 1.03-1.66x,
throughput +5.2%..+50.3%.
"""

from __future__ import annotations

from repro.configs.paper_models import DEEPSEEK_R1, QWEN3_235B
from repro.core import cost_model as cm
from repro.core.strategy import preset
from repro.core.topology import ASCEND_910B_CLUSTER, H20_CLUSTER

# benchmark setup from §IV-B: max batch 16, max seq 4096; ShareGPT-like
# prompt/output lengths (the 4096 is a cap, not the mean request size)
BATCH, L_IN, L_OUT = 16, 1024, 256
RATE = 4.0     # req/s midpoint of {2, 4, 8}


def indicators_for(model, cluster, strat):
    return cm.indicators(model, strat, cluster, batch=BATCH, l_in=L_IN,
                         l_out=L_OUT, arrival_rate=RATE)


def run() -> list:
    rows = []
    cases = [(ASCEND_910B_CLUSTER, ("vllm_tp_pp", "vllm_dp_ep",
                                    "vllm_dp_ep_tp4")),
             (H20_CLUSTER, ("vllm_tp_pp", "vllm_dp_ep", "tutel_tp_ep"))]
    for model in (DEEPSEEK_R1, QWEN3_235B):
        for cluster, baselines in cases:
            mix = indicators_for(model, cluster, preset("mixserve", cluster))
            rows.append((f"fig10/{model.name}/{cluster.name}/mixserve/ttft",
                         mix.ttft * 1e6, f"itl={mix.itl*1e3:.2f}ms "
                         f"thr={mix.throughput:.1f}tok/s"))
            for bl in baselines:
                ind = indicators_for(model, cluster, preset(bl, cluster))
                rows.append((
                    f"fig10/{model.name}/{cluster.name}/{bl}/speedup",
                    ind.ttft * 1e6,
                    f"ttft_x={ind.ttft / mix.ttft:.2f} "
                    f"itl_x={ind.itl / mix.itl:.2f} "
                    f"thr_gain={(mix.throughput / ind.throughput - 1) * 100:.1f}%"))
    return rows


if __name__ == "__main__":
    for name, v, derived in run():
        print(f"{name},{v:.1f},{derived}")
