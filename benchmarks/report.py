"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run JSON records.

  PYTHONPATH=src:. python -m benchmarks.report > /tmp/tables.md
"""

from __future__ import annotations

import json

import repro.configs as C
from benchmarks.roofline import analyze, load_records
from repro.configs.base import INPUT_SHAPES


def dryrun_table(recs) -> str:
    lines = ["| arch | shape | mesh | devs | args GB | temp GB | compile s | "
             "HLO GFLOP/dev | coll MB/dev |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        m, c = r["memory"], r["costs"]
        coll = c["collectives"]["bytes"].get("total", 0)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['n_devices']} "
            f"| {m['argument_bytes']/1e9:.2f} | {m['temp_bytes']/1e9:.2f} "
            f"| {r['compile_s']:.0f} | {c['flops']/1e9:.1f} "
            f"| {coll/1e6:.1f} |")
    return "\n".join(lines)


def skips_table() -> str:
    lines = ["| arch | shape | reason |", "|---|---|---|"]
    for a in C.ARCH_IDS:
        cfg = C.get(a)
        for s in INPUT_SHAPES.values():
            if not C.shape_supported(cfg, s):
                lines.append(f"| {a} | {s.name} | full quadratic attention — "
                             "long_500k needs sub-quadratic (DESIGN.md §4) |")
    return "\n".join(lines)


def roofline_table(recs, mesh: str = "single") -> str:
    lines = ["| arch | shape | compute ms | memory ms | collective ms | "
             "dominant | useful ratio | HBM GB |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        a = analyze(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {a['t_compute']*1e3:.2f} "
            f"| {a['t_memory']*1e3:.2f} | {a['t_collective']*1e3:.3f} "
            f"| **{a['dominant']}** | {a['useful_ratio']:.2f} "
            f"| {a['hbm_gb_per_dev']:.1f} |")
    return "\n".join(lines)


def main():
    recs = load_records()
    recs.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print("### Dry-run records (strategy=mixserve)\n")
    print(dryrun_table(recs))
    print("\n### Shape skips\n")
    print(skips_table())
    print("\n### Roofline (single pod, 256 chips)\n")
    print(roofline_table(recs, "single"))
    print("\n### Roofline (multi pod, 512 chips)\n")
    print(roofline_table(recs, "multi"))


if __name__ == "__main__":
    main()
