"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run JSON records (experiments/dryrun/*.json) and derives:

  compute term    = HLO_FLOPs / peak_FLOP/s          (per chip, s)
  memory term     = HLO_bytes / HBM_bw               (per chip, s)
  collective term = collective_bytes / link_bw       (per chip, s)

Hardware constants (task spec): TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.  cost_analysis() and the HLO parse are post-SPMD, i.e.
already per-device, so no further division by chip count is needed.

Also reports MODEL_FLOPS / HLO_FLOPS ("useful-compute ratio"): MODEL_FLOPS =
6*N*D for training (fwd+bwd) and 2*N_active*D for inference, with D = tokens
processed per step globally.
"""

from __future__ import annotations

import json
import os
import sys

import repro.configs as C
from repro.configs.base import INPUT_SHAPES
from repro.models.model import count_params

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                           "dryrun")


def model_flops(cfg, shape) -> float:
    """Global useful FLOPs per step (6ND train, 2ND inference)."""
    n_total = count_params(cfg)
    if cfg.is_moe:
        n_moe_layers = cfg.n_layers - cfg.first_dense_layers
        inactive = (n_moe_layers * (cfg.n_experts - cfg.top_k)
                    * 3 * cfg.d_model * cfg.d_expert)
        n_active = n_total - inactive
    else:
        n_active = n_total
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n_active * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n_active * d
    d = shape.global_batch * 1          # decode: one token per request
    return 2.0 * n_active * d


def analyze(rec: dict) -> dict:
    """Three roofline terms per chip per step.

    XLA's HloCostAnalysis multiplies single-level while bodies by their trip
    counts but UNDER-counts nested scans (grad-accum/prefill-chunk loops
    around the layer scan), so the compute term takes the max of HLO FLOPs
    and the MODEL_FLOPS floor; ``flops_src`` records which bound.  The
    collective term comes from our own trip-count-aware HLO parse.
    """
    cfg = C.get(rec["arch"])
    shape = INPUT_SHAPES[rec["shape"]]
    c = rec["costs"]
    mf = model_flops(cfg, shape)
    hlo = c["flops"]
    floor = mf / rec["n_devices"]
    eff_flops = max(hlo, floor)
    t_compute = eff_flops / PEAK_FLOPS
    t_memory = c["bytes_accessed"] / HBM_BW
    coll = c["collectives"]["bytes"].get("total", 0)
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    return {
        **{f"t_{k}": v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "flops_src": "hlo" if hlo >= floor else "model-floor",
        "useful_ratio": min(mf / (eff_flops * rec["n_devices"]), 1.0)
        if eff_flops else 0.0,
        "coll_bytes": coll,
        "hbm_gb_per_dev": (rec["memory"]["argument_bytes"]
                           + rec["memory"]["temp_bytes"]
                           + rec["memory"]["output_bytes"]) / 1e9,
    }


def load_records(results_dir: str = RESULTS_DIR, strategy: str = "mixserve"):
    recs = []
    if not os.path.isdir(results_dir):
        return recs
    for fn in sorted(os.listdir(results_dir)):
        if not fn.endswith(".json") or not fn.endswith(
                f"_{strategy}.json"):
            continue
        with open(os.path.join(results_dir, fn)) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def run() -> list:
    rows = []
    for rec in load_records():
        a = analyze(rec)
        name = f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        total_us = (a["t_compute"] + a["t_memory"] + a["t_collective"]) * 1e6
        rows.append((name, total_us,
                     f"comp={a['t_compute']*1e3:.2f}ms "
                     f"mem={a['t_memory']*1e3:.2f}ms "
                     f"coll={a['t_collective']*1e3:.2f}ms "
                     f"dom={a['dominant']} "
                     f"useful={a['useful_ratio']:.2f} "
                     f"hbm={a['hbm_gb_per_dev']:.1f}GB"))
    if not rows:
        rows.append(("roofline/NO_DRYRUN_RECORDS", 0.0,
                     "run `python -m repro.launch.dryrun --all` first"))
    return rows


if __name__ == "__main__":
    for name, v, derived in run():
        print(f"{name},{v:.1f},{derived}")
