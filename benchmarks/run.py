"""Benchmark driver — one harness per paper table/figure.

  python -m benchmarks.run            # all
  python -m benchmarks.run fig10      # one
  python -m benchmarks.run --quick    # fast smoke gate: kernel micro-bench
                                      # + the KERNELIZED serve path (fails
                                      # if the Pallas kernels don't trace)

Output: ``name,value,derived`` CSV rows (value in us unless noted), plus a
``BENCH_<suite>.json`` per completed suite in the CWD (machine-readable
mirror of the same rows, for the report tooling / CI diffing).
"""

from __future__ import annotations

import json
import sys
import time
import traceback

from benchmarks import (comm_overhead, dp_ep_tradeoff, kernel_bench,
                        overlap_ablation, perf_eval, roofline, serve_micro,
                        spec_decode, table1)

SUITES = {
    "fig3": comm_overhead,       # AR/A2A overhead vs degree & size
    "table1": table1,            # collective volumes
    "fig10": perf_eval,          # TTFT/ITL/throughput vs baselines
    "fig11": dp_ep_tradeoff,     # DP/EP trade-off ablation
    "fig12": overlap_ablation,   # sync vs fused overlap ablation
    "roofline": roofline,        # dry-run roofline terms (deliverable g)
    "serve": serve_micro,        # measured engine indicators (reduced)
    "kernels": kernel_bench,     # pallas kernel micro-bench (ref vs pallas)
}

# --quick: the smoke gate — kernel pairs + the kernelized engine loop + the
# blocking-prefill vs unified-step mixed-workload comparison (the BENCH
# artifact that tracks the TTFT/ITL trajectory across PRs)
QUICK = {
    "kernels": kernel_bench.run,
    "serve_quick": serve_micro.run_quick,
    "serve_mixed": serve_micro.run_mixed_quick,
    # paged-KV shared-prefix gate: prefix hits + paged==dense bit-identity
    # + warm-TTFT and pool-footprint wins (docs/kv_cache.md)
    "serve_prefix": serve_micro.run_prefix,
    # micro-chunked EP-exchange gate: chunked price <= monolithic,
    # count-bounded rows < worst-case, analyzer flip (docs/dispatch.md)
    "overlap": overlap_ablation.run_quick,
    # speculative-decoding gate: accepted streams bit-identical to the
    # non-speculative greedy run, acceptance counters nonzero, committed
    # tokens/slot-step > 1.0 on a decode-heavy workload (docs/serving.md)
    "spec": spec_decode.run_quick,
}


def _emit_json(name: str, rows: list, meta: dict | None = None) -> None:
    payload = {"suite": name,
               "rows": [{"name": r, "value_us": v, "derived": d}
                        for r, v, d in rows]}
    if meta:
        # e.g. the ServeSpec resolver's provenance report — which
        # auto-chosen knobs produced these numbers (serve_micro.run_mixed)
        payload["meta"] = meta
    with open(f"BENCH_{name}.json", "w") as f:
        json.dump(payload, f, indent=1)


def _check_serve_mixed_meta(meta: dict | None) -> None:
    """The serve_mixed artifact must carry the graceful-degradation
    counters for every scenario — a missing block means the robustness
    layer got disconnected from the benchmark gate."""
    rb = (meta or {}).get("robustness")
    if not rb:
        raise RuntimeError(
            "BENCH_serve_mixed meta has no 'robustness' block "
            "(shed/preempt/cancel/deadline-miss/fault counters)")
    for scen, counters in rb.items():
        missing = set(serve_micro.ROBUSTNESS_KEYS) - set(counters)
        if missing:
            raise RuntimeError(
                f"BENCH_serve_mixed meta: scenario {scen!r} is missing "
                f"robustness counters {sorted(missing)}")


def main() -> int:
    argv = sys.argv[1:]
    quick = "--quick" in argv
    argv = [a for a in argv if a != "--quick"]
    runners = ({n: QUICK[n] for n in (argv or QUICK)} if quick
               else {n: SUITES[n].run for n in (argv or SUITES)})
    failed = []
    print("name,value,derived")
    for name, runner in runners.items():
        t0 = time.time()
        try:
            out = runner()
            rows, meta = ((out["rows"], out.get("meta"))
                          if isinstance(out, dict) else (list(out), None))
            if name == "serve_mixed":
                _check_serve_mixed_meta(meta)
            for row, v, derived in rows:
                print(f"{row},{v:.1f},{derived}")
            _emit_json(name, rows, meta)
        except Exception:
            failed.append(name)
            traceback.print_exc()
        print(f"# suite {name} done in {time.time() - t0:.1f}s")
    if failed:
        print(f"# FAILED suites: {failed}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
