"""Benchmark driver — one harness per paper table/figure.

  python -m benchmarks.run            # all
  python -m benchmarks.run fig10      # one

Output: ``name,value,derived`` CSV rows (value in us unless noted).
"""

from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (comm_overhead, dp_ep_tradeoff, kernel_bench,
                        overlap_ablation, perf_eval, roofline, serve_micro,
                        table1)

SUITES = {
    "fig3": comm_overhead,       # AR/A2A overhead vs degree & size
    "table1": table1,            # collective volumes
    "fig10": perf_eval,          # TTFT/ITL/throughput vs baselines
    "fig11": dp_ep_tradeoff,     # DP/EP trade-off ablation
    "fig12": overlap_ablation,   # sync vs fused overlap ablation
    "roofline": roofline,        # dry-run roofline terms (deliverable g)
    "serve": serve_micro,        # measured engine indicators (reduced)
    "kernels": kernel_bench,     # pallas kernel micro-bench
}


def main() -> int:
    picks = sys.argv[1:] or list(SUITES)
    failed = []
    print("name,value,derived")
    for name in picks:
        mod = SUITES[name]
        t0 = time.time()
        try:
            for row, v, derived in mod.run():
                print(f"{row},{v:.1f},{derived}")
        except Exception:
            failed.append(name)
            traceback.print_exc()
        print(f"# suite {name} done in {time.time() - t0:.1f}s")
    if failed:
        print(f"# FAILED suites: {failed}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
