"""Measured serving microbenchmark (reduced configs, this CPU host).

Complements the theoretical Fig. 10 reproduction with REAL engine numbers:
continuous-batching TTFT/ITL/throughput for a MoE and a dense arch.  CPU
walltimes are not TPU predictions — the point is exercising the production
engine loop end-to-end under load and reporting the same indicators.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models.model import init_params
from repro.serving.engine import Engine
from repro.serving.scheduler import Scheduler, synthetic_workload


def run_quick() -> list:
    """Smoke gate for the kernelized serve path (``benchmarks.run --quick``).

    Forces ``KernelPolicy.all_on()`` through a tiny MoE engine run and FAILS
    unless the jitted prefill/decode graphs actually traced every hot-path
    kernel — under the default (dropless) dispatch that is flash_decode,
    topk_gate, the grouped segment GEMM and the fused permute/unpermute
    pair; a second engine run pins capacity mode and checks its moe_gemm
    path still traces too."""
    from repro.kernels import ops
    from repro.kernels.policy import KernelPolicy

    cfg = C.get_reduced("phi3.5-moe-42b")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rows = []
    for dispatch, gemm in (("dropless", "grouped_gemm"),
                           ("capacity", "moe_gemm")):
        ops.reset_counters()
        eng = Engine(cfg, params, max_batch=2, max_len=64,
                     kernel_policy=KernelPolicy.all_on(),
                     dispatch_mode=dispatch)
        sched = Scheduler(eng)
        for r in synthetic_workload(3, prompt_len=8, max_new_tokens=4,
                                    vocab=cfg.vocab_size, arrival_rate=16.0):
            sched.submit(r)
        done = sched.run()
        assert len(done) == 3, f"quick serve gate: {len(done)}/3 completed"
        required = {"flash_decode", "topk_gate", gemm,
                    "permute_tokens", "unpermute_tokens"}
        missing = required - {k for k, v in ops.counters.items() if v > 0}
        if missing:
            raise RuntimeError(
                f"kernelized serve path ({dispatch}) did not trace "
                f"{sorted(missing)} (counters: {dict(ops.counters)})")
        m = sched.metrics()
        rows.append((f"serve_quick/{cfg.name}/{dispatch}/kernels",
                     float(sum(ops.counters[k] for k in required)),
                     f"traced={sorted(required)} "
                     f"thr={m.throughput_tok_s:.1f}tok/s"))
    return rows


def run() -> list:
    rows = []
    for arch in ("smollm-360m", "phi3.5-moe-42b"):
        cfg = C.get_reduced(arch)
        params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        eng = Engine(cfg, params, max_batch=4, max_len=128)
        sched = Scheduler(eng)
        for r in synthetic_workload(10, prompt_len=24, max_new_tokens=8,
                                    vocab=cfg.vocab_size, arrival_rate=8.0):
            sched.submit(r)
        sched.run()
        m = sched.metrics()
        rows.append((f"serve/{arch}/itl", m.itl_mean * 1e6,
                     f"ttft={m.ttft_mean*1e3:.1f}ms "
                     f"thr={m.throughput_tok_s:.1f}tok/s n={m.n_requests}"))
    return rows


if __name__ == "__main__":
    for name, v, derived in run():
        print(f"{name},{v:.1f},{derived}")
