"""Measured serving microbenchmark (reduced configs, this CPU host).

Complements the theoretical Fig. 10 reproduction with REAL engine numbers:
continuous-batching TTFT/ITL/throughput for a MoE and a dense arch.  CPU
walltimes are not TPU predictions — the point is exercising the production
engine loop end-to-end under load and reporting the same indicators.

Two engine paths are compared head-to-head:
  unified   the default one-program token-budget mixed step (chunked
            prefill co-scheduled with decode)
  legacy    the pre-unified blocking-prefill engine (escape hatch)
``run_mixed`` is the scenario the unified step exists for: long prompts
landing mid-decode, where blocking prefill spikes every queued TTFT and
active ITL.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models.model import init_params
from repro.serving.engine import Engine
from repro.serving.scheduler import (Scheduler, mixed_workload,
                                     synthetic_workload)


def run_quick() -> list:
    """Smoke gate for the kernelized serve path (``benchmarks.run --quick``).

    Forces ``KernelPolicy.all_on()`` through a tiny MoE engine and FAILS
    unless the jitted graphs actually traced every hot-path kernel.  Three
    runs:
      unified/dropless + unified/capacity — the ONE-program mixed step must
        trace topk_gate, the expert GEMM (grouped under dropless, batched
        under capacity) and the fused permute/unpermute pair (attention in
        the mixed chunk runs the masked chunked-softmax body — flash_decode
        is a chunk==1 specialization);
      legacy/dropless — the escape-hatch decode program must still trace
        flash_decode (regression bisect path).
    """
    from repro.kernels import ops
    from repro.kernels.policy import KernelPolicy

    cfg = C.get_reduced("phi3.5-moe-42b")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rows = []
    cases = [("unified", "dropless", "grouped_gemm", None),
             ("unified", "capacity", "moe_gemm", None),
             ("legacy", "dropless", "grouped_gemm", "flash_decode")]
    for mode, dispatch, gemm, extra in cases:
        ops.reset_counters()
        eng = Engine(cfg, params, max_batch=2, max_len=64,
                     kernel_policy=KernelPolicy.all_on(),
                     dispatch_mode=dispatch, chunk=4,
                     legacy=(mode == "legacy"))
        sched = Scheduler(eng)
        for r in synthetic_workload(3, prompt_len=8, max_new_tokens=4,
                                    vocab=cfg.vocab_size, arrival_rate=16.0):
            sched.submit(r)
        done = sched.run()
        assert len(done) == 3, f"quick serve gate: {len(done)}/3 completed"
        required = {"topk_gate", gemm, "permute_tokens", "unpermute_tokens"}
        if extra:
            required.add(extra)
        missing = required - {k for k, v in ops.counters.items() if v > 0}
        if missing:
            raise RuntimeError(
                f"kernelized serve path ({mode}/{dispatch}) did not trace "
                f"{sorted(missing)} (counters: {dict(ops.counters)})")
        m = sched.metrics()
        rows.append((f"serve_quick/{cfg.name}/{mode}-{dispatch}/kernels",
                     float(sum(ops.counters[k] for k in required)),
                     f"traced={sorted(required)} "
                     f"thr={m.throughput_tok_s:.1f}tok/s"))
    return rows


def _run_one(cfg, params, reqs, *, legacy: bool, max_batch=4, max_len=192,
             chunk=16):
    eng = Engine(cfg, params, max_batch=max_batch, max_len=max_len,
                 chunk=chunk, legacy=legacy)
    sched = Scheduler(eng)
    for r in reqs:
        sched.submit(r)
    sched.run()
    return sched.metrics()


def run_mixed(quick: bool = False) -> list:
    """Mixed workload: long prompts arriving mid-decode, blocking-prefill vs
    unified-step.  TTFT p99 is the headline (queued shorts wait behind the
    long blocking prefill; the unified step streams it in chunks); the
    decode-only scenario guards ITL against regression."""
    rows = []
    arch = "smollm-360m"
    cfg = C.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    n_short, long_len = (5, 64) if quick else (10, 96)
    scenarios = {
        "mixed": lambda: mixed_workload(
            n_short, short_len=12, n_long=2, long_len=long_len,
            max_new_tokens=6 if quick else 8, vocab=cfg.vocab_size,
            arrival_rate=24.0, seed=0),
        "decode": lambda: synthetic_workload(
            4 if quick else 8, prompt_len=8,
            max_new_tokens=8 if quick else 16, vocab=cfg.vocab_size,
            arrival_rate=64.0, seed=0),
    }
    for scen, mk in scenarios.items():
        ms = {}
        for mode in ("legacy", "unified"):
            ms[mode] = _run_one(cfg, params, list(mk()),
                                legacy=(mode == "legacy"),
                                chunk=8 if quick else 16)
        for mode, m in ms.items():
            other = ms["unified" if mode == "legacy" else "legacy"]
            rows.append((
                f"serve_mixed/{arch}/{scen}/{mode}/ttft_p99",
                m.ttft_p99 * 1e6,
                f"itl_p99={m.itl_p99*1e3:.2f}ms "
                f"ttft_p99_vs_other={m.ttft_p99/max(other.ttft_p99,1e-9):.2f}x "
                f"n={m.n_requests} incomplete={m.n_incomplete}"))
    return rows


def run_mixed_quick() -> list:
    return run_mixed(quick=True)


def run() -> list:
    rows = []
    for arch in ("smollm-360m", "phi3.5-moe-42b"):
        cfg = C.get_reduced(arch)
        params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        eng = Engine(cfg, params, max_batch=4, max_len=128)
        sched = Scheduler(eng)
        for r in synthetic_workload(10, prompt_len=24, max_new_tokens=8,
                                    vocab=cfg.vocab_size, arrival_rate=8.0):
            sched.submit(r)
        sched.run()
        m = sched.metrics()
        rows.append((f"serve/{arch}/itl", m.itl_mean * 1e6,
                     f"ttft={m.ttft_mean*1e3:.1f}ms "
                     f"thr={m.throughput_tok_s:.1f}tok/s n={m.n_requests}"))
    rows.extend(run_mixed())
    return rows


if __name__ == "__main__":
    for name, v, derived in run():
        print(f"{name},{v:.1f},{derived}")
