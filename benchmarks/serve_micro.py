"""Measured serving microbenchmark (reduced configs, this CPU host).

Complements the theoretical Fig. 10 reproduction with REAL engine numbers:
continuous-batching TTFT/ITL/throughput for a MoE and a dense arch.  CPU
walltimes are not TPU predictions — the point is exercising the production
engine loop end-to-end under load and reporting the same indicators.

Every engine here is built through the declarative ``ServeSpec`` API
(docs/api.md) — the benchmark gate therefore exercises the resolver on
every run, and ``run_mixed`` records the resolver's provenance report in
the ``BENCH_serve_mixed`` metadata so the perf trajectory says which
auto-chosen knobs produced each number.

Everything runs the unified token-budget mixed prefill/decode engine (the
pre-unified blocking-prefill engine is no longer publicly reachable — it
survives only as the internal auto-fallback for ssm/hybrid/frontend
families).  ``run_mixed`` is the scenario the unified step exists for:
long prompts landing mid-decode, streamed in chunks co-scheduled with the
decode traffic.  Both ``run_quick`` and ``run_mixed`` record the kernel
invocation counters of a ``KernelPolicy.all_on()`` engine and FAIL if the
jitted mixed step did not trace the ragged ``flash_chunk`` attention
kernel — no silent jnp fallback on the hot path.

``run_mixed`` additionally records per-scenario ROBUSTNESS counters
(shed/preempt/cancel/deadline-miss/fault, ``ServeMetrics.robustness()``)
in the artifact meta, including a chaos scenario with injected NaN and
straggler faults — ``benchmarks.run --quick`` fails if the counters are
missing, so the graceful-degradation path cannot silently rot.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models.model import init_params
from repro.serving.api import LLM, ServeSpec
from repro.serving.faults import Fault
from repro.serving.scheduler import (mixed_workload, synthetic_workload,
                                     tiered_workload)

# every scenario's meta must carry these graceful-degradation counters —
# ``benchmarks.run --quick`` FAILS if they go missing from the artifact
ROBUSTNESS_KEYS = ("n_shed", "n_preempted", "n_cancelled",
                   "n_deadline_miss", "n_faults", "deadline_miss_p99",
                   # KV-cache efficiency (paged backend; docs/kv_cache.md)
                   "kv_occupancy", "n_prefix_hits", "prefix_hit_tokens",
                   "n_evictions",
                   # expert-load skew + EP-exchange byte ledger (MoE;
                   # dense archs report zeros — docs/dispatch.md)
                   "ep_rank_max_tokens", "ep_rank_mean_tokens",
                   "a2a_bytes_moved", "a2a_bytes_worst",
                   # speculative-decoding counters (docs/serving.md);
                   # all-zero when speculation="off" but must be PRESENT
                   "n_spec_steps", "n_spec_drafted", "n_spec_accepted",
                   "spec_accept_rate", "spec_tokens_per_step")


def run_quick() -> list:
    """Smoke gate for the kernelized serve path (``benchmarks.run --quick``).

    Builds every engine through ``ServeSpec`` (explicit chunk/dispatch, the
    rest resolved), forces ``KernelPolicy.all_on()`` through a tiny MoE
    engine and FAILS unless the jitted graphs actually traced every
    hot-path kernel.  Four runs of the ONE-program unified mixed step:
      chunk=4 / dropless + chunk=4 / capacity (kv auto -> paged) — the
        mixed ragged batch must trace topk_gate, the expert GEMM (grouped
        under dropless, batched under capacity), the fused
        permute/unpermute pair AND the paged ragged attention kernel
        ``flash_chunk_paged`` (the block-table path is the default);
      chunk=4 / dropless / kv=dense — the dense cache keeps the original
        ``flash_chunk`` routing alive;
      chunk=1 / dropless / kv=dense — a pure-decode-shaped budget
        degenerates the dense program to sq == 1, whose attention is the
        ``flash_decode`` specialization of the same kernel family.
    """
    from repro.kernels import ops
    from repro.kernels.policy import KernelPolicy

    arch = "phi3.5-moe-42b"
    cfg = C.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rows = []
    cases = [("chunk4", "dropless", 4, "auto",
              {"grouped_gemm", "flash_chunk_paged"}),
             ("chunk4", "capacity", 4, "auto",
              {"moe_gemm", "flash_chunk_paged"}),
             ("chunk4-dense", "dropless", 4, "dense",
              {"grouped_gemm", "flash_chunk"}),
             ("chunk1-dense", "dropless", 1, "dense",
              {"grouped_gemm", "flash_decode"})]
    for mode, dispatch, chunk, kv, extras in cases:
        ops.reset_counters()
        resolved = ServeSpec(
            arch=arch, kernels=KernelPolicy.all_on(), dispatch=dispatch,
            chunk=chunk, max_batch=2, max_len=64, prompt_len=8,
            max_new_tokens=4, kv=kv).resolve()
        llm = LLM.from_spec(resolved, cfg=cfg, params=params)
        sched = llm.serve(synthetic_workload(
            3, prompt_len=8, max_new_tokens=4, vocab=cfg.vocab_size,
            arrival_rate=16.0))
        done = sched.finished
        assert len(done) == 3, f"quick serve gate: {len(done)}/3 completed"
        required = {"topk_gate", "permute_tokens", "unpermute_tokens"} \
            | extras
        missing = required - {k for k, v in ops.counters.items() if v > 0}
        if missing:
            raise RuntimeError(
                f"kernelized serve path ({mode}/{dispatch}) did not trace "
                f"{sorted(missing)} (counters: {dict(ops.counters)})")
        m = sched.metrics()
        # the MoE engine must surface expert-load observability: routed
        # slots landed somewhere, and the EP ledger priced the exchange
        if m.ep_rank_max_tokens <= 0:
            raise RuntimeError(
                f"MoE serve gate ({mode}/{dispatch}): expert-load counters "
                f"stayed zero ({m.robustness()})")
        rows.append((f"serve_quick/{cfg.name}/{mode}-{dispatch}/kernels",
                     float(sum(ops.counters[k] for k in required)),
                     f"traced={sorted(required)} "
                     f"thr={m.throughput_tok_s:.1f}tok/s"))
    return rows


def _spec_llm(arch, cfg, params, *, max_batch=4, max_len=192, chunk=16,
              kernel_policy=None, prompt_len=96, max_new_tokens=8,
              faults=()):
    """One engine through the ServeSpec door; returns (llm, resolved)."""
    spec = ServeSpec(arch=arch, kernels=kernel_policy or "auto",
                     chunk=chunk, max_batch=max_batch, max_len=max_len,
                     prompt_len=prompt_len, max_new_tokens=max_new_tokens,
                     faults=faults)
    resolved = spec.resolve(C.get(arch))
    return LLM.from_spec(resolved, cfg=cfg, params=params), resolved


def run_mixed(quick: bool = False):
    """Mixed workload: long prompts arriving mid-decode, streamed through
    the unified step.  TTFT p99 is the headline (the chunked prefill keeps
    queued shorts from waiting behind a long blocking prefill); the
    decode-only scenario guards ITL against regression.  A second pass with
    ``KernelPolicy.all_on()`` records the kernel invocation counters and
    fails if the mixed step silently fell back to the jnp attention body.

    Returns ``{"rows": ..., "meta": ...}`` — the meta block carries the
    resolver's provenance report (which knob came from where) into the
    ``BENCH_serve_mixed.json`` artifact.
    """
    from repro.kernels import ops
    from repro.kernels.policy import KernelPolicy

    rows = []
    arch = "smollm-360m"
    cfg = C.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    n_short, long_len = (5, 64) if quick else (10, 96)
    scenarios = {
        "mixed": lambda: mixed_workload(
            n_short, short_len=12, n_long=2, long_len=long_len,
            max_new_tokens=6 if quick else 8, vocab=cfg.vocab_size,
            arrival_rate=24.0, seed=0),
        "decode": lambda: synthetic_workload(
            4 if quick else 8, prompt_len=8,
            max_new_tokens=8 if quick else 16, vocab=cfg.vocab_size,
            arrival_rate=64.0, seed=0),
    }
    provenance = {}
    robustness = {}
    for scen, mk in scenarios.items():
        llm, resolved = _spec_llm(arch, cfg, params,
                                  chunk=8 if quick else 16,
                                  prompt_len=long_len)
        provenance[scen] = resolved.as_meta()
        m = llm.serve(list(mk())).metrics()
        robustness[scen] = m.robustness()
        rows.append((
            f"serve_mixed/{arch}/{scen}/unified/ttft_p99",
            m.ttft_p99 * 1e6,
            f"itl_p99={m.itl_p99*1e3:.2f}ms "
            f"n={m.n_requests} incomplete={m.n_incomplete}"))
        if scen == "mixed":
            # retrace regression gate (jax.log_compiles): the unified step
            # runs on fixed (B, chunk) buffers, so re-serving the SAME
            # workload on the warm engine must compile NOTHING — a single
            # recompile here means a shape/dtype/static-arg leak that
            # shows up in production as a per-request latency cliff
            from repro.analysis.compile_watch import CompileWatch
            with CompileWatch(match="_unified_impl") as watch:
                llm.serve(list(mk()))
            if watch.count > 0:
                raise RuntimeError(
                    f"unified step retraced on a warm engine: "
                    f"{watch.count} compile(s) re-serving an identical "
                    f"workload — {watch.matching()[:2]}")
            rows.append((f"serve_mixed/{arch}/{scen}/unified/retraces",
                         float(watch.count),
                         "compiles re-serving identical workload "
                         "(gate: must be 0)"))

    # chaos scenario: a priority/deadline-tiered workload under injected
    # NaN and straggler faults — the robustness counters in the artifact
    # must show the degradation machinery actually firing, not just exist
    llm, resolved = _spec_llm(arch, cfg, params, max_batch=2, max_len=96,
                              chunk=8, prompt_len=24, max_new_tokens=6,
                              faults=(Fault(kind="nan", rid=1, every=1,
                                            n_max=1),
                                      Fault(kind="latency", every=8, ms=2.0)))
    provenance["chaos"] = resolved.as_meta()
    m = llm.serve(list(tiered_workload(
        8 if quick else 14, prompt_len=14, max_new_tokens=6,
        vocab=cfg.vocab_size, arrival_rate=300.0, seed=2,
        hi_every=3, hi_priority=5, hi_deadline_s=2.0))).metrics()
    robustness["chaos"] = m.robustness()
    if m.n_faults < 1:
        raise RuntimeError(
            "chaos scenario: injected NaN fault did not surface in the "
            f"robustness counters ({m.robustness()})")
    rows.append((f"serve_mixed/{arch}/chaos/faults", float(m.n_faults),
                 m.row()))

    # kernelized gate: the same mixed shape with every Pallas kernel on
    # (interpret mode on CPU — a small workload, the counters are the point)
    ops.reset_counters()
    llm, resolved = _spec_llm(arch, cfg, params, max_batch=2, max_len=96,
                              chunk=8, kernel_policy=KernelPolicy.all_on(),
                              prompt_len=24, max_new_tokens=4)
    provenance["kernels"] = resolved.as_meta()
    m = llm.serve(list(mixed_workload(
        3, short_len=10, n_long=1, long_len=24, max_new_tokens=4,
        vocab=cfg.vocab_size, arrival_rate=32.0, seed=1))).metrics()
    n_flash = ops.counters["flash_chunk_paged"]
    if n_flash <= 0:
        raise RuntimeError(
            "unified mixed step (paged KV default) did not trace "
            "flash_chunk_paged — silent jnp attention fallback "
            f"(counters: {dict(ops.counters)})")
    rows.append((f"serve_mixed/{arch}/kernels/flash_chunk_paged",
                 float(n_flash),
                 f"traced call sites (all_on engine) "
                 f"incomplete={m.n_incomplete}"))
    return {"rows": rows,
            "meta": {"serve_spec": provenance, "robustness": robustness}}


def run_mixed_quick():
    return run_mixed(quick=True)


def run_prefix():
    """Shared-prefix scenario: the paged KV cache's radix index must turn a
    common system prompt into measured wins (``benchmarks.run --quick``).

    One cold request seeds the prefix index with the system prompt's full
    pages; a warm batch sharing that prompt then admits with most of its
    prefill already cached.  FAILS unless (a) the warm batch scores prefix
    hits, (b) the paged engine's greedy token streams are bit-identical to
    a dense-backend twin, (c) warm TTFT p50 < 0.2x the cold TTFT, and
    (d) the paged pool is strictly smaller than the dense footprint.
    """
    import numpy as np

    from repro.serving.engine import Request

    arch = "smollm-360m"
    cfg = C.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(7)
    system = rng.integers(0, cfg.vocab_size, size=176).astype(np.int32)
    tails = [rng.integers(0, cfg.vocab_size, size=7).astype(np.int32)
             for _ in range(5)]
    prompts = [np.concatenate([system, t]) for t in tails]
    warmup = rng.integers(0, cfg.vocab_size, size=183).astype(np.int32)

    def mk(rid, prompt):
        return Request(rid=rid, prompt=prompt, max_new_tokens=8, arrival=0.0)

    outs = {}
    llms = {}
    for kv in ("paged", "dense"):
        # budget 32 lets every warm prefill tail land in ONE step (4 slots
        # x 7 uncached tokens); the cold prefill stays chunk-capped at 8
        resolved = ServeSpec(
            arch=arch, kv=kv, chunk=8, token_budget=32, max_batch=4,
            max_len=256, prompt_len=183, max_new_tokens=8).resolve()
        llm = LLM.from_spec(resolved, cfg=cfg, params=params)
        llms[kv] = llm
        llm.generate([warmup], max_new_tokens=2)       # absorb compile time
        cold = llm.serve([mk(0, prompts[0])]).finished
        warm = llm.serve([mk(i, p) for i, p in
                          enumerate(prompts[1:], start=1)]).finished
        assert len(cold) == 1 and len(warm) == 4, \
            f"prefix scenario ({kv}): {len(cold)}+{len(warm)} completed"
        outs[kv] = ({r.rid: list(r.out_tokens) for r in cold + warm},
                    cold[0].ttft,
                    float(np.median([r.ttft for r in warm])))

    if outs["paged"][0] != outs["dense"][0]:
        raise RuntimeError(
            "paged and dense greedy token streams diverged: "
            f"{outs['paged'][0]} vs {outs['dense'][0]}")
    stats = llms["paged"].engine.kv.stats
    if stats.n_prefix_hits < 4 or stats.prefix_hit_tokens < 4 * len(system):
        raise RuntimeError(
            f"shared system prompt scored no prefix reuse: {stats}")
    cold_ttft, warm_p50 = outs["paged"][1], outs["paged"][2]
    ratio = warm_p50 / max(cold_ttft, 1e-9)
    if ratio >= 0.2:
        raise RuntimeError(
            f"warm TTFT p50 {warm_p50*1e3:.1f}ms is not < 0.2x the cold "
            f"TTFT {cold_ttft*1e3:.1f}ms (ratio {ratio:.2f})")
    paged_b = llms["paged"].engine.kv.kv_bytes()
    dense_b = llms["dense"].engine.kv.kv_bytes()
    if paged_b >= dense_b:
        raise RuntimeError(
            f"paged pool ({paged_b}B) is not below the dense (B, max_len) "
            f"footprint ({dense_b}B)")
    return [(f"serve_prefix/{arch}/cold_ttft", cold_ttft * 1e6,
             f"{len(system)}-token shared system prompt, chunk=8"),
            (f"serve_prefix/{arch}/warm_ttft_p50", warm_p50 * 1e6,
             f"ratio={ratio:.2f} hits={stats.n_prefix_hits} "
             f"hit_tokens={stats.prefix_hit_tokens} "
             f"kv_bytes={paged_b}/{dense_b} (paged/dense)")]


def run():
    rows = []
    for arch in ("smollm-360m", "phi3.5-moe-42b"):
        cfg = C.get_reduced(arch)
        params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        llm, _ = _spec_llm(arch, cfg, params, max_batch=4, max_len=128,
                           prompt_len=24)
        m = llm.serve(synthetic_workload(
            10, prompt_len=24, max_new_tokens=8, vocab=cfg.vocab_size,
            arrival_rate=8.0)).metrics()
        rows.append((f"serve/{arch}/itl", m.itl_mean * 1e6,
                     f"ttft={m.ttft_mean*1e3:.1f}ms "
                     f"thr={m.throughput_tok_s:.1f}tok/s n={m.n_requests}"))
    mixed = run_mixed()
    rows.extend(mixed["rows"])
    rows.extend(run_prefix())
    return {"rows": rows, "meta": mixed["meta"]}


if __name__ == "__main__":
    out = run()
    for name, v, derived in out["rows"]:
        print(f"{name},{v:.1f},{derived}")
