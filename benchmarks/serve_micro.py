"""Measured serving microbenchmark (reduced configs, this CPU host).

Complements the theoretical Fig. 10 reproduction with REAL engine numbers:
continuous-batching TTFT/ITL/throughput for a MoE and a dense arch.  CPU
walltimes are not TPU predictions — the point is exercising the production
engine loop end-to-end under load and reporting the same indicators.

Every engine here is built through the declarative ``ServeSpec`` API
(docs/api.md) — the benchmark gate therefore exercises the resolver on
every run, and ``run_mixed`` records the resolver's provenance report in
the ``BENCH_serve_mixed`` metadata so the perf trajectory says which
auto-chosen knobs produced each number.

Everything runs the unified token-budget mixed prefill/decode engine (the
pre-unified blocking-prefill engine is no longer publicly reachable — it
survives only as the internal auto-fallback for ssm/hybrid/frontend
families).  ``run_mixed`` is the scenario the unified step exists for:
long prompts landing mid-decode, streamed in chunks co-scheduled with the
decode traffic.  Both ``run_quick`` and ``run_mixed`` record the kernel
invocation counters of a ``KernelPolicy.all_on()`` engine and FAIL if the
jitted mixed step did not trace the ragged ``flash_chunk`` attention
kernel — no silent jnp fallback on the hot path.

``run_mixed`` additionally records per-scenario ROBUSTNESS counters
(shed/preempt/cancel/deadline-miss/fault, ``ServeMetrics.robustness()``)
in the artifact meta, including a chaos scenario with injected NaN and
straggler faults — ``benchmarks.run --quick`` fails if the counters are
missing, so the graceful-degradation path cannot silently rot.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models.model import init_params
from repro.serving.api import LLM, ServeSpec
from repro.serving.faults import Fault
from repro.serving.scheduler import (mixed_workload, synthetic_workload,
                                     tiered_workload)

# every scenario's meta must carry these graceful-degradation counters —
# ``benchmarks.run --quick`` FAILS if they go missing from the artifact
ROBUSTNESS_KEYS = ("n_shed", "n_preempted", "n_cancelled",
                   "n_deadline_miss", "n_faults", "deadline_miss_p99")


def run_quick() -> list:
    """Smoke gate for the kernelized serve path (``benchmarks.run --quick``).

    Builds every engine through ``ServeSpec`` (explicit chunk/dispatch, the
    rest resolved), forces ``KernelPolicy.all_on()`` through a tiny MoE
    engine and FAILS unless the jitted graphs actually traced every
    hot-path kernel.  Three runs of the ONE-program unified mixed step:
      chunk=4 / dropless + chunk=4 / capacity — the mixed ragged batch must
        trace topk_gate, the expert GEMM (grouped under dropless, batched
        under capacity), the fused permute/unpermute pair AND the ragged
        ``flash_chunk`` attention kernel;
      chunk=1 / dropless — a pure-decode-shaped budget degenerates the
        program to sq == 1, whose attention is the ``flash_decode``
        specialization of the same kernel family.
    """
    from repro.kernels import ops
    from repro.kernels.policy import KernelPolicy

    arch = "phi3.5-moe-42b"
    cfg = C.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rows = []
    cases = [("chunk4", "dropless", 4, {"grouped_gemm", "flash_chunk"}),
             ("chunk4", "capacity", 4, {"moe_gemm", "flash_chunk"}),
             ("chunk1", "dropless", 1, {"grouped_gemm", "flash_decode"})]
    for mode, dispatch, chunk, extras in cases:
        ops.reset_counters()
        resolved = ServeSpec(
            arch=arch, kernels=KernelPolicy.all_on(), dispatch=dispatch,
            chunk=chunk, max_batch=2, max_len=64, prompt_len=8,
            max_new_tokens=4).resolve()
        llm = LLM.from_spec(resolved, cfg=cfg, params=params)
        sched = llm.serve(synthetic_workload(
            3, prompt_len=8, max_new_tokens=4, vocab=cfg.vocab_size,
            arrival_rate=16.0))
        done = sched.finished
        assert len(done) == 3, f"quick serve gate: {len(done)}/3 completed"
        required = {"topk_gate", "permute_tokens", "unpermute_tokens"} \
            | extras
        missing = required - {k for k, v in ops.counters.items() if v > 0}
        if missing:
            raise RuntimeError(
                f"kernelized serve path ({mode}/{dispatch}) did not trace "
                f"{sorted(missing)} (counters: {dict(ops.counters)})")
        m = sched.metrics()
        rows.append((f"serve_quick/{cfg.name}/{mode}-{dispatch}/kernels",
                     float(sum(ops.counters[k] for k in required)),
                     f"traced={sorted(required)} "
                     f"thr={m.throughput_tok_s:.1f}tok/s"))
    return rows


def _spec_llm(arch, cfg, params, *, max_batch=4, max_len=192, chunk=16,
              kernel_policy=None, prompt_len=96, max_new_tokens=8,
              faults=()):
    """One engine through the ServeSpec door; returns (llm, resolved)."""
    spec = ServeSpec(arch=arch, kernels=kernel_policy or "auto",
                     chunk=chunk, max_batch=max_batch, max_len=max_len,
                     prompt_len=prompt_len, max_new_tokens=max_new_tokens,
                     faults=faults)
    resolved = spec.resolve(C.get(arch))
    return LLM.from_spec(resolved, cfg=cfg, params=params), resolved


def run_mixed(quick: bool = False):
    """Mixed workload: long prompts arriving mid-decode, streamed through
    the unified step.  TTFT p99 is the headline (the chunked prefill keeps
    queued shorts from waiting behind a long blocking prefill); the
    decode-only scenario guards ITL against regression.  A second pass with
    ``KernelPolicy.all_on()`` records the kernel invocation counters and
    fails if the mixed step silently fell back to the jnp attention body.

    Returns ``{"rows": ..., "meta": ...}`` — the meta block carries the
    resolver's provenance report (which knob came from where) into the
    ``BENCH_serve_mixed.json`` artifact.
    """
    from repro.kernels import ops
    from repro.kernels.policy import KernelPolicy

    rows = []
    arch = "smollm-360m"
    cfg = C.get_reduced(arch)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    n_short, long_len = (5, 64) if quick else (10, 96)
    scenarios = {
        "mixed": lambda: mixed_workload(
            n_short, short_len=12, n_long=2, long_len=long_len,
            max_new_tokens=6 if quick else 8, vocab=cfg.vocab_size,
            arrival_rate=24.0, seed=0),
        "decode": lambda: synthetic_workload(
            4 if quick else 8, prompt_len=8,
            max_new_tokens=8 if quick else 16, vocab=cfg.vocab_size,
            arrival_rate=64.0, seed=0),
    }
    provenance = {}
    robustness = {}
    for scen, mk in scenarios.items():
        llm, resolved = _spec_llm(arch, cfg, params,
                                  chunk=8 if quick else 16,
                                  prompt_len=long_len)
        provenance[scen] = resolved.as_meta()
        m = llm.serve(list(mk())).metrics()
        robustness[scen] = m.robustness()
        rows.append((
            f"serve_mixed/{arch}/{scen}/unified/ttft_p99",
            m.ttft_p99 * 1e6,
            f"itl_p99={m.itl_p99*1e3:.2f}ms "
            f"n={m.n_requests} incomplete={m.n_incomplete}"))

    # chaos scenario: a priority/deadline-tiered workload under injected
    # NaN and straggler faults — the robustness counters in the artifact
    # must show the degradation machinery actually firing, not just exist
    llm, resolved = _spec_llm(arch, cfg, params, max_batch=2, max_len=96,
                              chunk=8, prompt_len=24, max_new_tokens=6,
                              faults=(Fault(kind="nan", rid=1, every=1,
                                            n_max=1),
                                      Fault(kind="latency", every=8, ms=2.0)))
    provenance["chaos"] = resolved.as_meta()
    m = llm.serve(list(tiered_workload(
        8 if quick else 14, prompt_len=14, max_new_tokens=6,
        vocab=cfg.vocab_size, arrival_rate=300.0, seed=2,
        hi_every=3, hi_priority=5, hi_deadline_s=2.0))).metrics()
    robustness["chaos"] = m.robustness()
    if m.n_faults < 1:
        raise RuntimeError(
            "chaos scenario: injected NaN fault did not surface in the "
            f"robustness counters ({m.robustness()})")
    rows.append((f"serve_mixed/{arch}/chaos/faults", float(m.n_faults),
                 m.row()))

    # kernelized gate: the same mixed shape with every Pallas kernel on
    # (interpret mode on CPU — a small workload, the counters are the point)
    ops.reset_counters()
    llm, resolved = _spec_llm(arch, cfg, params, max_batch=2, max_len=96,
                              chunk=8, kernel_policy=KernelPolicy.all_on(),
                              prompt_len=24, max_new_tokens=4)
    provenance["kernels"] = resolved.as_meta()
    m = llm.serve(list(mixed_workload(
        3, short_len=10, n_long=1, long_len=24, max_new_tokens=4,
        vocab=cfg.vocab_size, arrival_rate=32.0, seed=1))).metrics()
    n_flash = ops.counters["flash_chunk"]
    if n_flash <= 0:
        raise RuntimeError(
            "unified mixed step did not trace flash_chunk — silent jnp "
            f"attention fallback (counters: {dict(ops.counters)})")
    rows.append((f"serve_mixed/{arch}/kernels/flash_chunk", float(n_flash),
                 f"traced call sites (all_on engine) "
                 f"incomplete={m.n_incomplete}"))
    return {"rows": rows,
            "meta": {"serve_spec": provenance, "robustness": robustness}}


def run_mixed_quick():
    return run_mixed(quick=True)


def run():
    rows = []
    for arch in ("smollm-360m", "phi3.5-moe-42b"):
        cfg = C.get_reduced(arch)
        params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
        llm, _ = _spec_llm(arch, cfg, params, max_batch=4, max_len=128,
                           prompt_len=24)
        m = llm.serve(synthetic_workload(
            10, prompt_len=24, max_new_tokens=8, vocab=cfg.vocab_size,
            arrival_rate=8.0)).metrics()
        rows.append((f"serve/{arch}/itl", m.itl_mean * 1e6,
                     f"ttft={m.ttft_mean*1e3:.1f}ms "
                     f"thr={m.throughput_tok_s:.1f}tok/s n={m.n_requests}"))
    mixed = run_mixed()
    rows.extend(mixed["rows"])
    return {"rows": rows, "meta": mixed["meta"]}


if __name__ == "__main__":
    out = run()
    for name, v, derived in out["rows"]:
        print(f"{name},{v:.1f},{derived}")
