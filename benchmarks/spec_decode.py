"""Speculative-decoding quick suite (``benchmarks.run --quick`` -> spec).

Decode-heavy synthetic workload (short prompts, long generations — the
regime speculation exists for) through the SAME unified engine twice:
``speculation="off"`` and ``SpeculationConfig(k=4, draft="self")``.  The
self-draft is the greedy acceptance-1.0 oracle, so the suite gates the
three properties docs/serving.md promises:

  (a) the accepted token streams are BIT-IDENTICAL to the non-speculative
      greedy run (speculation must never change output);
  (b) the acceptance counters (``n_spec_steps``/``n_spec_drafted``/
      ``n_spec_accepted``) surface in ``ServeMetrics`` and are nonzero —
      the observability path cannot silently rot;
  (c) committed tokens per speculating slot-step > 1.0 — the mechanism
      actually amortizes steps, not just avoids breaking them.

An ``NGramDraft`` row rides along bit-exactness-gated only (its
acceptance is workload-dependent; random-token prompts rarely match), and
the artifact meta records the resolver provenance for both engines plus a
``speculation="auto"`` resolution demo so ``BENCH_spec.json`` says WHY a
draft length was (or wasn't) chosen on this host.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.models.model import init_params
from repro.serving.api import LLM, ServeSpec, SpeculationConfig

ARCH = "smollm-360m"
SPEC_COUNTER_KEYS = ("n_spec_steps", "n_spec_drafted", "n_spec_accepted",
                     "spec_accept_rate", "spec_tokens_per_step")


def _workload(cfg, n=6, prompt_len=8, max_new=24, seed=0):
    from repro.serving.engine import Request

    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=prompt_len)
                    .astype(np.int32),
                    max_new_tokens=max_new, arrival=0.0)
            for i in range(n)]


def _serve(cfg, params, speculation, *, max_new=24):
    resolved = ServeSpec(
        arch=ARCH, chunk=8, max_batch=4, max_len=64, prompt_len=8,
        max_new_tokens=max_new, speculation=speculation).resolve()
    llm = LLM.from_spec(resolved, cfg=cfg, params=params)
    llm.generate([np.zeros(4, np.int32)], max_new_tokens=2)  # compile
    t0 = time.perf_counter()
    sched = llm.serve(_workload(cfg, max_new=max_new))
    wall = time.perf_counter() - t0
    streams = {r.rid: list(r.out_tokens) for r in sched.finished}
    return resolved, sched.metrics(), streams, wall


def run_quick():
    cfg = C.get_reduced(ARCH)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    rows, meta = [], {"serve_spec": {}}

    base_r, base_m, base_streams, base_wall = _serve(cfg, params, "off")
    meta["serve_spec"]["off"] = base_r.as_meta()
    if base_m.n_spec_steps != 0:
        raise RuntimeError(
            f"speculation='off' reported spec steps: {base_m.robustness()}")

    for label, speculation, gated in (
            ("self_k4", SpeculationConfig(k=4, draft="self"), True),
            ("ngram_k4", SpeculationConfig(k=4, draft="ngram"), False)):
        resolved, m, streams, wall = _serve(cfg, params, speculation)
        meta["serve_spec"][label] = resolved.as_meta()
        rb = m.robustness()
        missing = [k for k in SPEC_COUNTER_KEYS if k not in rb]
        if missing:
            raise RuntimeError(
                f"spec gate ({label}): acceptance counters {missing} "
                f"missing from ServeMetrics.robustness()")
        if streams != base_streams:
            raise RuntimeError(
                f"spec gate ({label}): accepted streams diverged from the "
                f"non-speculative greedy run\n  off : {base_streams}\n"
                f"  spec: {streams}")
        if gated:
            if m.n_spec_steps <= 0 or m.n_spec_accepted <= 0:
                raise RuntimeError(
                    f"spec gate ({label}): acceptance counters stayed zero "
                    f"on a decode-heavy workload ({rb})")
            if m.spec_tokens_per_step <= 1.0:
                raise RuntimeError(
                    f"spec gate ({label}): {m.spec_tokens_per_step:.2f} "
                    "committed tokens/slot-step is not > 1.0")
        rows.append((
            f"spec/{ARCH}/{label}/tokens_per_step",
            m.spec_tokens_per_step,
            f"accept={m.spec_accept_rate:.2f} "
            f"drafted={m.n_spec_drafted} steps={m.n_spec_steps} "
            f"wall x{wall / max(base_wall, 1e-9):.2f} vs off "
            "(bit-identical streams)"))

    # auto-resolution demo: the cost model prices draft lengths against
    # the verify step and explains its pick in the provenance report
    auto_r = ServeSpec(arch=ARCH, chunk=8, max_batch=4, max_len=64,
                       prompt_len=8, max_new_tokens=24,
                       speculation="auto").resolve()
    meta["serve_spec"]["auto"] = auto_r.as_meta()
    prov = auto_r.provenance.get("speculation", "?")
    if not prov.startswith(("auto:", "explicit")):
        raise RuntimeError(
            f"speculation='auto' resolution has no provenance: {prov!r}")
    rows.append((f"spec/{ARCH}/auto/k",
                 float(auto_r.speculation.k if auto_r.speculation else 0),
                 prov))
    return {"rows": rows, "meta": meta}


if __name__ == "__main__":
    out = run_quick()
    for name, v, derived in out["rows"]:
        print(f"{name},{v:.1f},{derived}")
