"""Table I reproduction: per-round communication volume of each collective.

Validates the closed forms O(bs*h/d) for AR's RS/AG and O(bs/d * h*k) for
A2A Dispatch/Combine against a direct count of bytes moved by the reference
implementations (simulated rank buffers, numpy)."""

from __future__ import annotations

import numpy as np

from repro.core import cost_model as cm


def simulate_rs_bytes(b, s, h, d) -> int:
    """Reduce-scatter: each rank sends (d-1) shards of size bs*h/d."""
    return (d - 1) * (b * s * h // d) * cm.BYTES


def simulate_a2a_bytes(b, s, h, k, d) -> int:
    """Pairwise A2A: each rank holds bs/d tokens x k copies, sends to d-1
    peers a 1/d slice each round."""
    payload = (b * s // d) * h * k * cm.BYTES
    return (d - 1) * (payload // d) * cm.BYTES // cm.BYTES


def run() -> list:
    rows = []
    b, s, h, k = 16, 1024, 7168, 8
    for d in (2, 4, 8, 16):
        # closed-form seconds at unit bandwidth == bytes moved
        rs_model = cm.rs_cost(b * s * h * cm.BYTES, d, 1.0, 0.0)
        rs_sim = simulate_rs_bytes(b, s, h, d)
        a2a_model = cm.a2a_cost((b * s // d) * h * k * d * cm.BYTES / d,
                                d, 1.0, 0.0)
        rows.append((f"table1/RS/d{d}", rs_model,
                     f"sim={rs_sim} rel_err="
                     f"{abs(rs_model - rs_sim) / rs_sim:.3f}"))
        # Table I scaling checks
        rows.append((f"table1/AR_per_round/d{d}", b * s * h / d,
                     "O(bs*h/d) per Table I"))
        rows.append((f"table1/A2A_per_round/d{d}", b * s / d * h * k,
                     "O(bs/d*h*k) per Table I"))
    # rounds: AR 1 full-duplex round (broadcast), A2A pairwise d-1
    rows.append(("table1/rounds/AR", 1, "Broadcast, full-duplex"))
    rows.append(("table1/rounds/A2A_d8", 7, "Pairwise d-1"))
    return rows


if __name__ == "__main__":
    for name, v, derived in run():
        print(f"{name},{v:.1f},{derived}")
