"""The automatic analyzer as a standalone tool (paper §III-B).

For every assigned architecture, rank parallel strategies on a chosen
cluster and print the top-3 with their theoretical TTFT/ITL/throughput —
the offline stage MixServe runs before loading any weights.

Run:  PYTHONPATH=src python examples/autotune_strategy.py \
          [--cluster v5e-pod-256] [--objective throughput]
"""

import argparse

import repro.configs as C
from repro.core import analyzer
from repro.core.topology import CLUSTERS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", default="v5e-pod-256",
                    choices=list(CLUSTERS))
    ap.add_argument("--objective", default="balanced",
                    choices=["ttft", "itl", "throughput", "balanced"])
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--l-in", type=int, default=1024)
    ap.add_argument("--l-out", type=int, default=256)
    args = ap.parse_args()
    cluster = CLUSTERS[args.cluster]

    for arch in C.ARCH_IDS:
        cfg = C.get(arch)
        rep = analyzer.select(cfg, cluster, batch=args.batch,
                              l_in=args.l_in, l_out=args.l_out,
                              objective=args.objective)
        print(f"\n=== {arch} on {cluster.name} "
              f"(objective={args.objective}) ===")
        print(rep.describe(top=3))


if __name__ == "__main__":
    main()
