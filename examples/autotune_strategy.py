"""The automatic resolver as a standalone tool (paper §III-A/B).

For every assigned architecture, resolve a full ``ServeSpec`` on a chosen
cluster: the analyzer ranks parallel strategies (top-3 printed with their
theoretical TTFT/ITL/throughput) and the cost model prices every serving
knob — the offline stage MixServe runs before loading any weights, with
the provenance report showing which value came from where.

Run:  PYTHONPATH=src python examples/autotune_strategy.py \
          [--cluster v5e-pod-256] [--objective throughput]
"""

import argparse

import repro.configs as C
from repro.core.topology import CLUSTERS
from repro.serving.api import ServeSpec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", default="v5e-pod-256",
                    choices=list(CLUSTERS))
    ap.add_argument("--objective", default="balanced",
                    choices=["ttft", "itl", "throughput", "balanced"])
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--l-in", type=int, default=1024)
    ap.add_argument("--l-out", type=int, default=256)
    args = ap.parse_args()

    for arch in C.ARCH_IDS:
        spec = ServeSpec(arch=arch, cluster=args.cluster,
                         max_batch=args.batch, prompt_len=args.l_in,
                         max_new_tokens=args.l_out,
                         objective=args.objective)
        resolved = spec.resolve()
        print(f"\n=== {arch} on {resolved.cluster} "
              f"(objective={args.objective}) ===")
        print(resolved.report.describe(top=3))
        print(resolved.describe())


if __name__ == "__main__":
    main()
