"""Quickstart: the MixServe pipeline end-to-end through ONE API.

1. declare — a ``ServeSpec`` for DeepSeek-V2-236B with every knob "auto";
2. resolve — the offline stage: the automatic analyzer picks the parallel
   strategy on a TPU v5e pod and the cost model prices the serving knobs
   (prefill chunk, token budget, batch envelope); the provenance report
   says which field came from where;
3. serve — the ``LLM`` facade builds the engine from the resolved spec
   (a reduced same-family model on this host) and generates.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

import repro.configs as C
from repro.models.model import count_params
from repro.serving.api import LLM, ServeSpec

ARCH = "deepseek-v2-236b"


def main():
    full_cfg = C.get(ARCH)
    print(f"model: {full_cfg.name}  ({count_params(full_cfg):,} params, "
          f"{full_cfg.n_experts} experts top-{full_cfg.top_k})")

    # ---------------- declare + resolve (offline stage) ----------------
    spec = ServeSpec(arch=ARCH, cluster="v5e-pod-256", prompt_len=16,
                     max_new_tokens=8, arrival_rate=4.0,
                     objective="balanced")
    resolved = spec.resolve()
    print("\n== offline stage: strategy ranking (theoretical) ==")
    print(resolved.report.describe(top=5))
    print("\n== resolved serving spec (provenance) ==")
    print(resolved.describe())

    # ---------------- serve (online stage, reduced config) ----------------
    llm = LLM.from_spec(resolved)
    prompts = [np.arange(10 + rid, dtype=np.int32) % llm.cfg.vocab_size
               for rid in range(3)]
    outs = llm.generate(prompts, max_new_tokens=8)
    print("\n== online stage: served requests (reduced config, CPU) ==")
    for rid, (p, toks) in enumerate(zip(prompts, outs)):
        print(f"  req {rid}: prompt[{len(p)}] -> {toks}")


if __name__ == "__main__":
    main()
