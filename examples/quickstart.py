"""Quickstart: the MixServe pipeline end-to-end in one script.

1. offline stage — the automatic analyzer picks a parallel strategy for
   DeepSeek-V2-236B on a TPU v5e pod from the theoretical cost model;
2. online stage — a reduced same-family model is built, partitioned by the
   resulting plan semantics, and serves a couple of requests on this host.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.core import analyzer
from repro.core.topology import TPU_V5E_POD
from repro.models.model import count_params, init_params
from repro.serving.engine import Engine, Request
from repro.serving.scheduler import Scheduler

ARCH = "deepseek-v2-236b"


def main():
    # ---------------- offline: automatic analyzer ----------------
    full_cfg = C.get(ARCH)
    print(f"model: {full_cfg.name}  ({count_params(full_cfg):,} params, "
          f"{full_cfg.n_experts} experts top-{full_cfg.top_k})")
    report = analyzer.select(full_cfg, TPU_V5E_POD, batch=16, l_in=1024,
                             l_out=256, arrival_rate=4.0,
                             objective="balanced")
    print("\n== offline stage: strategy ranking (theoretical) ==")
    print(report.describe(top=5))
    best = report.best.strategy
    print(f"\nselected: {best.describe()}")

    # ---------------- online: serve a reduced variant ----------------
    cfg = C.get_reduced(ARCH)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    engine = Engine(cfg, params, max_batch=2, max_len=96)
    sched = Scheduler(engine)
    import numpy as np
    for rid in range(3):
        prompt = np.arange(10 + rid, dtype=np.int32) % cfg.vocab_size
        sched.submit(Request(rid=rid, prompt=prompt, max_new_tokens=8))
    done = sched.run()
    print("\n== online stage: served requests (reduced config, CPU) ==")
    for r in done:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")
    print(sched.metrics().row())


if __name__ == "__main__":
    main()
