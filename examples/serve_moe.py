"""End-to-end serving driver: batched requests through the continuous-
batching engine on an MoE model (the paper's serving scenario).

The offline stage resolves a full ``ServeSpec`` on each of the paper's two
evaluation clusters (H20 x16, Ascend 910B x32) — strategy from the
analyzer, chunk/token-budget/batch from the cost model — then the ``LLM``
facade replays a Poisson request stream against the resolved configuration
on this host and reports measured TTFT / ITL / throughput next to the
theoretical estimates.

Run:  PYTHONPATH=src python examples/serve_moe.py [--arch phi3.5-moe-42b]
"""

import argparse

import repro.configs as C
from repro.core.topology import ASCEND_910B_CLUSTER, H20_CLUSTER
from repro.serving.api import LLM, ServeSpec
from repro.serving.scheduler import synthetic_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3.5-moe-42b",
                    choices=[a for a in C.ARCH_IDS])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0)
    args = ap.parse_args()

    spec = ServeSpec(arch=args.arch, prompt_len=32, max_new_tokens=12,
                     arrival_rate=args.rate)

    print("== offline stage: the spec resolved on the paper's clusters ==")
    for cl in (H20_CLUSTER, ASCEND_910B_CLUSTER):
        r = spec.resolve(cluster=cl)
        best = r.report.best
        print(f"[{cl.name}] {r.strategy} ({r.strategy_detail})  "
              f"chunk={r.chunk} budget={r.token_budget} "
              f"b={r.max_batch}  ttft={best.ind.ttft*1e3:.0f}ms "
              f"itl={best.ind.itl*1e3:.1f}ms "
              f"thr={best.ind.throughput:.0f}tok/s")

    # online stage: serve the default-cluster resolution on this host
    resolved = spec.resolve()
    print("\n== resolved serving spec (provenance) ==")
    print(resolved.describe())
    llm = LLM.from_spec(resolved)
    sched = llm.serve(synthetic_workload(
        args.requests, prompt_len=32, max_new_tokens=12,
        vocab=llm.cfg.vocab_size, arrival_rate=args.rate))
    m = sched.metrics()
    print(f"\n== measured on this host (reduced {llm.cfg.name}) ==")
    print(m.row())
    assert len(sched.finished) == args.requests


if __name__ == "__main__":
    main()
