"""End-to-end serving driver: batched requests through the continuous-
batching engine on an MoE model (the paper's serving scenario).

A Poisson request stream replays in real time against the engine; per-request
TTFT / ITL and aggregate throughput are reported next to the analyzer's
theoretical estimates for the paper's two clusters.

Run:  PYTHONPATH=src python examples/serve_moe.py [--arch phi3.5-moe-42b]
"""

import argparse

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.core import analyzer
from repro.core.topology import ASCEND_910B_CLUSTER, H20_CLUSTER
from repro.models.model import init_params
from repro.serving.engine import Engine
from repro.serving.scheduler import Scheduler, synthetic_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3.5-moe-42b",
                    choices=[a for a in C.ARCH_IDS])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0)
    args = ap.parse_args()

    full = C.get(args.arch)
    print("== offline analyzer on the paper's clusters ==")
    for cl in (H20_CLUSTER, ASCEND_910B_CLUSTER):
        rep = analyzer.select(full, cl, batch=16, l_in=1024, l_out=256,
                              arrival_rate=args.rate)
        print(f"[{cl.name}] best: {rep.best.strategy.describe()}  "
              f"ttft={rep.best.ind.ttft*1e3:.0f}ms "
              f"itl={rep.best.ind.itl*1e3:.1f}ms "
              f"thr={rep.best.ind.throughput:.0f}tok/s")

    cfg = C.get_reduced(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    embeds_fn = None
    if cfg.frontend == "audio_stub":
        e = cfg.encoder
        embeds_fn = lambda b: {"frames": jnp.full(
            (b, e.n_frames, e.d_model), 0.01, jnp.float32)}
    engine = Engine(cfg, params, max_batch=4, max_len=128,
                    embeds_fn=embeds_fn)
    sched = Scheduler(engine)
    for r in synthetic_workload(args.requests, prompt_len=32,
                                max_new_tokens=12, vocab=cfg.vocab_size,
                                arrival_rate=args.rate):
        sched.submit(r)
    done = sched.run()
    m = sched.metrics()
    print(f"\n== measured on this host (reduced {cfg.name}) ==")
    print(m.row())
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
