"""End-to-end serving driver: batched requests through the continuous-
batching engine on an MoE model (the paper's serving scenario).

The offline stage resolves a full ``ServeSpec`` on each of the paper's two
evaluation clusters (H20 x16, Ascend 910B x32) — strategy from the
analyzer, chunk/token-budget/batch from the cost model — then the ``LLM``
facade replays a request stream against the resolved configuration on
this host and reports measured TTFT / ITL / throughput next to the
theoretical estimates.

The online stream is PRIORITY/DEADLINE-TIERED (every third request is
high-priority with a deadline, the rest best-effort), so the run also
exercises the robustness tier — priority preemption, deadline
enforcement, overload shedding — and prints the shed/preempt/deadline
counters next to the latency indicators (docs/serving.md, "Robustness &
degradation").  Every request additionally shares a SYSTEM PROMPT, so the
paged KV cache serves its pages once and re-matches them from the prefix
index on later admissions — the cache stats printed at the end show the
reuse (docs/kv_cache.md).

Run:  PYTHONPATH=src python examples/serve_moe.py [--arch phi3.5-moe-42b]
"""

import argparse

import numpy as np

import repro.configs as C
from repro.core.topology import ASCEND_910B_CLUSTER, H20_CLUSTER
from repro.serving.api import LLM, ServeSpec
from repro.serving.scheduler import tiered_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3.5-moe-42b",
                    choices=[a for a in C.ARCH_IDS])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--deadline", type=float, default=5.0,
                    help="deadline (s) carried by the high-priority tier")
    ap.add_argument("--system-len", type=int, default=48,
                    help="length of the shared system prompt every request "
                         "carries (paged-KV prefix-reuse showcase)")
    args = ap.parse_args()

    spec = ServeSpec(arch=args.arch, prompt_len=args.system_len + 32,
                     max_new_tokens=12, arrival_rate=args.rate)

    print("== offline stage: the spec resolved on the paper's clusters ==")
    for cl in (H20_CLUSTER, ASCEND_910B_CLUSTER):
        r = spec.resolve(cluster=cl)
        best = r.report.best
        print(f"[{cl.name}] {r.strategy} ({r.strategy_detail})  "
              f"chunk={r.chunk} budget={r.token_budget} "
              f"b={r.max_batch}  ttft={best.ind.ttft*1e3:.0f}ms "
              f"itl={best.ind.itl*1e3:.1f}ms "
              f"thr={best.ind.throughput:.0f}tok/s")

    # online stage: serve the default-cluster resolution on this host with
    # a two-tier workload — every third request is high-priority with a
    # deadline, the rest best-effort (preemptible, sheddable under load)
    resolved = spec.resolve()
    print("\n== resolved serving spec (provenance) ==")
    print(resolved.describe())
    llm = LLM.from_spec(resolved)
    system = np.random.default_rng(7).integers(
        0, llm.cfg.vocab_size, args.system_len).astype(np.int32)
    reqs = list(tiered_workload(
        args.requests, prompt_len=32, max_new_tokens=12,
        vocab=llm.cfg.vocab_size, arrival_rate=args.rate,
        hi_every=3, hi_priority=10, hi_deadline_s=args.deadline,
        system=system))
    n_hi = sum(1 for r in reqs if r.priority > 0)
    print(f"\n== online stage: {len(reqs)} requests "
          f"({n_hi} high-priority w/ {args.deadline:.1f}s deadline, "
          f"{len(reqs) - n_hi} best-effort; shared {args.system_len}-token "
          "system prompt) ==")
    sched = llm.serve(reqs)
    m = sched.metrics()
    print(f"\n== measured on this host (reduced {llm.cfg.name}) ==")
    print(m.row())
    rb = m.robustness()
    print("robustness: " + " ".join(f"{k}={v}" for k, v in rb.items()))
    kv = llm.engine.kv
    print(f"kv cache: backend={kv.backend} peak_occupancy="
          f"{m.kv_occupancy:.0%} prefix_hits={m.n_prefix_hits} "
          f"({m.prefix_hit_tokens} tok reused) evictions={m.n_evictions} "
          f"pool_bytes={kv.kv_bytes()}")
    assert m.n_incomplete == 0, "every request must reach a terminal state"
    # the index seeds at retirement, so reuse needs a second admission wave
    if kv.backend == "paged" and len(reqs) > llm.engine.max_batch:
        assert m.n_prefix_hits > 0, "shared system prompt must re-match"


if __name__ == "__main__":
    main()
