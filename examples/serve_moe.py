"""End-to-end serving driver: batched requests through the continuous-
batching engine on an MoE model (the paper's serving scenario).

The offline stage resolves a full ``ServeSpec`` on each of the paper's two
evaluation clusters (H20 x16, Ascend 910B x32) — strategy from the
analyzer, chunk/token-budget/batch from the cost model — then the ``LLM``
facade replays a request stream against the resolved configuration on
this host and reports measured TTFT / ITL / throughput next to the
theoretical estimates.

The online stream is PRIORITY/DEADLINE-TIERED (every third request is
high-priority with a deadline, the rest best-effort), so the run also
exercises the robustness tier — priority preemption, deadline
enforcement, overload shedding — and prints the shed/preempt/deadline
counters next to the latency indicators (docs/serving.md, "Robustness &
degradation").

Run:  PYTHONPATH=src python examples/serve_moe.py [--arch phi3.5-moe-42b]
"""

import argparse

import repro.configs as C
from repro.core.topology import ASCEND_910B_CLUSTER, H20_CLUSTER
from repro.serving.api import LLM, ServeSpec
from repro.serving.scheduler import tiered_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3.5-moe-42b",
                    choices=[a for a in C.ARCH_IDS])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--deadline", type=float, default=5.0,
                    help="deadline (s) carried by the high-priority tier")
    args = ap.parse_args()

    spec = ServeSpec(arch=args.arch, prompt_len=32, max_new_tokens=12,
                     arrival_rate=args.rate)

    print("== offline stage: the spec resolved on the paper's clusters ==")
    for cl in (H20_CLUSTER, ASCEND_910B_CLUSTER):
        r = spec.resolve(cluster=cl)
        best = r.report.best
        print(f"[{cl.name}] {r.strategy} ({r.strategy_detail})  "
              f"chunk={r.chunk} budget={r.token_budget} "
              f"b={r.max_batch}  ttft={best.ind.ttft*1e3:.0f}ms "
              f"itl={best.ind.itl*1e3:.1f}ms "
              f"thr={best.ind.throughput:.0f}tok/s")

    # online stage: serve the default-cluster resolution on this host with
    # a two-tier workload — every third request is high-priority with a
    # deadline, the rest best-effort (preemptible, sheddable under load)
    resolved = spec.resolve()
    print("\n== resolved serving spec (provenance) ==")
    print(resolved.describe())
    llm = LLM.from_spec(resolved)
    reqs = list(tiered_workload(
        args.requests, prompt_len=32, max_new_tokens=12,
        vocab=llm.cfg.vocab_size, arrival_rate=args.rate,
        hi_every=3, hi_priority=10, hi_deadline_s=args.deadline))
    n_hi = sum(1 for r in reqs if r.priority > 0)
    print(f"\n== online stage: {len(reqs)} requests "
          f"({n_hi} high-priority w/ {args.deadline:.1f}s deadline, "
          f"{len(reqs) - n_hi} best-effort) ==")
    sched = llm.serve(reqs)
    m = sched.metrics()
    print(f"\n== measured on this host (reduced {llm.cfg.name}) ==")
    print(m.row())
    rb = m.robustness()
    print("robustness: " + " ".join(f"{k}={v}" for k, v in rb.items()))
    assert m.n_incomplete == 0, "every request must reach a terminal state"


if __name__ == "__main__":
    main()
