"""Train a ~100M-parameter MoE for a few hundred steps (deliverable b).

A scaled phi3.5-family model (8 experts top-2, ~100M params) trains on the
synthetic Markov/Zipf pipeline; loss is expected to drop well below the
uniform floor log(V).  Runs on CPU in a few minutes.

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import count_params, init_params
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step

CFG_100M = ModelConfig(
    name="phi-mini-100m", family="moe", n_layers=6, d_model=512,
    n_heads=8, n_kv_heads=4, d_ff=1024, vocab_size=8192,
    n_experts=8, top_k=2, d_expert=1024, activation="swiglu",
    source="scaled phi3.5-moe family (hf:microsoft/Phi-3.5-MoE-instruct)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = CFG_100M
    print(f"{cfg.name}: {count_params(cfg):,} params "
          f"({cfg.n_experts} experts top-{cfg.top_k})")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(
        cfg, opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=20,
                                 total_steps=args.steps)))
    data = SyntheticLM(cfg, DataConfig(batch=args.batch, seq_len=args.seq))
    t0, first = time.time(), None
    for i, b in enumerate(data.batches(args.steps)):
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt, m = step(params, opt, b)
        loss = float(m["loss"])
        first = first or loss
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={loss:.4f} aux={float(m['aux']):.3f} "
                  f"({time.time()-t0:.0f}s)")
    print(f"\nloss {first:.3f} -> {loss:.3f} "
          f"(uniform floor would be {jnp.log(cfg.vocab_size):.2f})")
    assert loss < first - 0.5, "expected clear loss descent"


if __name__ == "__main__":
    main()
