"""repro-lint + trace-contract static analysis (docs/analysis.md).

Two layers keep the hot-path invariants machine-checked instead of
ROADMAP folklore:

* ``repro.analysis.lint`` — an AST linter over ``src/`` with the
  repo-specific rules R1-R5 (``python -m repro.analysis.lint src/``).
* ``repro.analysis.trace_contract`` — traces the lowered program on a
  CPU mesh and cross-checks it against ``cost_model.comm_census``:
  collective census, retrace detector, host-callback/dynamic-shape scan
  (``python -m repro.analysis.trace_contract``).
"""

# Submodules import lazily so `python -m repro.analysis.lint` does not
# re-import the module it is executing (runpy double-import warning).
__all__ = ["Finding", "lint_paths", "lint_sources", "CompileWatch"]


def __getattr__(name):
    if name in ("Finding", "lint_paths", "lint_sources"):
        from repro.analysis import lint
        return getattr(lint, name)
    if name == "CompileWatch":
        from repro.analysis.compile_watch import CompileWatch
        return CompileWatch
    raise AttributeError(name)
