"""Shared AST infrastructure for the repro-lint rules.

Builds a best-effort, name-based view of the source tree:

* per-module import maps and function tables,
* a call graph across ``repro.*`` modules,
* the *traced roots* — functions handed to ``jax.jit`` /
  ``shard_map`` / ``jax.lax.cond``-family transforms (directly, via
  ``functools.partial``, or through a local alias), from which the
  jit-reachable and shard_map-reachable sets are computed,
* ``# repro-lint: disable=R1[,R2]`` comment extraction.

The resolution is deliberately approximate (pure-AST, no imports are
executed): calls that cannot be resolved by name are ignored, which can
only make the reachable sets *smaller*.  Rules are tuned so the shipped
tree is clean; the escape hatch covers intentional exceptions.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Iterable, Optional

DISABLE_RE = re.compile(r"#\s*repro-lint:\s*disable=([A-Za-z0-9_,\s]+)")

# transforms whose function arguments run under a jax trace
_JIT_WRAPPERS = {"jax.jit", "jit", "jax.make_jaxpr", "make_jaxpr",
                 "jax.pmap", "pmap", "jax.checkpoint", "jax.remat"}
_SHARD_WRAPPERS = {"shard_map", "_shard_map", "jax.shard_map",
                   "jax.experimental.shard_map.shard_map"}
# (callee suffix, positions of function-valued args); None = all args
_BRANCH_WRAPPERS = {
    "lax.cond": (1, 2), "lax.switch": None, "lax.scan": (1,),
    "lax.while_loop": (0, 1), "lax.fori_loop": (2,), "lax.map": (0,),
    "lax.associative_scan": (0,),
}


@dataclasses.dataclass
class FuncInfo:
    """One function (or lambda) definition with its lexical context."""

    module: "ModuleInfo"
    qualname: str                      # e.g. "Engine._unified_impl"
    node: ast.AST                      # FunctionDef | AsyncFunctionDef | Lambda

    @property
    def key(self) -> str:
        return f"{self.module.name}:{self.qualname}"

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    @property
    def lineno(self) -> int:
        return self.node.lineno

    def positional_params(self) -> list[str]:
        """Positional parameter names with no default (the traced-arg
        convention: static config rides keyword-only / defaulted params)."""
        a = self.node.args
        pos = list(a.posonlyargs) + list(a.args)
        n_default = len(a.defaults)
        if n_default:
            pos = pos[:-n_default]
        names = [p.arg for p in pos]
        return [n for n in names if n not in ("self", "cls")]


@dataclasses.dataclass
class ModuleInfo:
    name: str                          # dotted module name
    path: Path
    source: str
    tree: ast.Module
    imports: dict = dataclasses.field(default_factory=dict)
    functions: dict = dataclasses.field(default_factory=dict)  # qual -> FuncInfo
    disables: dict = dataclasses.field(default_factory=dict)   # line -> {rule}


def module_name_for(path: Path) -> str:
    """Dotted module name, rooted at the nearest ``src`` dir if present."""
    parts = list(path.with_suffix("").parts)
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    else:
        parts = parts[-1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) or path.stem


def _collect_disables(source: str) -> dict:
    out: dict[int, set] = {}
    try:
        toks = tokenize.generate_tokens(io.StringIO(source).readline)
        for tok in toks:
            if tok.type != tokenize.COMMENT:
                continue
            m = DISABLE_RE.search(tok.string)
            if m:
                rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
                out.setdefault(tok.start[0], set()).update(rules)
    except tokenize.TokenError:
        pass
    return out


def _collect_imports(tree: ast.Module) -> dict:
    imp: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                imp[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                imp[a.asname or a.name] = f"{node.module}.{a.name}"
    return imp


def _collect_functions(mod: ModuleInfo) -> None:
    def visit(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                mod.functions[qual] = FuncInfo(mod, qual, child)
                visit(child, f"{qual}.")
            elif isinstance(child, ast.ClassDef):
                visit(child, f"{prefix}{child.name}.")
            else:
                visit(child, prefix)
    visit(mod.tree, "")


def parse_module(path: Path, name: Optional[str] = None,
                 source: Optional[str] = None) -> ModuleInfo:
    src = source if source is not None else path.read_text()
    tree = ast.parse(src, filename=str(path))
    mod = ModuleInfo(name=name or module_name_for(path), path=path,
                     source=src, tree=tree)
    mod.imports = _collect_imports(tree)
    mod.disables = _collect_disables(src)
    _collect_functions(mod)
    return mod


def dotted(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def resolve(mod: ModuleInfo, name: str) -> str:
    """Expand the first segment of a dotted name through the import map."""
    head, _, rest = name.partition(".")
    target = mod.imports.get(head)
    if target is None:
        return name
    return f"{target}.{rest}" if rest else target


_NORMALIZE = {"jax.numpy": "jnp", "numpy": "np", "jax.lax": "lax"}


def normalized(mod: ModuleInfo, node: ast.AST) -> Optional[str]:
    """Resolved dotted callee with jnp/np/lax spelled canonically,
    e.g. ``jnp.argsort`` whatever the local import alias was."""
    d = dotted(node)
    if d is None:
        return None
    r = resolve(mod, d)
    for full, short in _NORMALIZE.items():
        if r == full:
            return short
        if r.startswith(full + "."):
            return short + r[len(full):]
    return r


class Index:
    """Cross-module function table + call graph + traced roots."""

    def __init__(self, modules: Iterable[ModuleInfo]):
        self.modules = {m.name: m for m in modules}
        self.by_fq: dict[str, FuncInfo] = {}
        self.by_key: dict[str, FuncInfo] = {}
        for m in self.modules.values():
            for qual, fi in m.functions.items():
                self.by_fq[f"{m.name}.{qual}"] = fi
                self.by_key[fi.key] = fi
        self._edges: dict[str, set] = {}
        self.jit_roots: list[FuncInfo] = []
        self.shard_roots: list[FuncInfo] = []
        self.branch_roots: list[FuncInfo] = []
        self._lambda_n = 0
        for m in self.modules.values():
            self._scan_module(m)

    # -- resolution ---------------------------------------------------------

    def _enclosing_class(self, fi: FuncInfo) -> Optional[str]:
        parts = fi.qualname.split(".")
        return parts[-2] if len(parts) >= 2 else None

    def resolve_func(self, mod: ModuleInfo, expr: ast.AST,
                     enclosing: Optional[FuncInfo] = None,
                     localmap: Optional[dict] = None) -> Optional[FuncInfo]:
        """Resolve an expression to a FuncInfo in the index, best-effort."""
        if isinstance(expr, ast.Lambda):
            self._lambda_n += 1
            fi = FuncInfo(mod, f"<lambda#{self._lambda_n}>", expr)
            return fi
        if isinstance(expr, ast.Call):
            callee = dotted(expr.func)
            if callee and callee.split(".")[-1] == "partial" and expr.args:
                return self.resolve_func(mod, expr.args[0], enclosing, localmap)
            return None
        d = dotted(expr)
        if d is None:
            return None
        if localmap and d in localmap:
            return self.resolve_func(mod, localmap[d], enclosing, localmap)
        # self.method -> method of the enclosing class
        if d.startswith("self.") and enclosing is not None:
            cls = self._enclosing_class(enclosing)
            if cls:
                fi = mod.functions.get(f"{cls}.{d[5:]}")
                if fi:
                    return fi
        # local / nested name within the enclosing function's scope
        if enclosing is not None:
            fi = mod.functions.get(f"{enclosing.qualname}.{d}")
            if fi:
                return fi
        if d in mod.functions:
            return mod.functions[d]
        fq = resolve(mod, d)
        if fq in self.by_fq:
            return self.by_fq[fq]
        # module attribute reference: repro.models.moe.moe_block
        tail = fq.rsplit(".", 1)
        if len(tail) == 2 and tail[0] in self.modules:
            return self.modules[tail[0]].functions.get(tail[1])
        return None

    # -- scanning -----------------------------------------------------------

    def _scan_module(self, mod: ModuleInfo) -> None:
        # local "name = functools.partial(fn, ...)" / "name = fn" aliases,
        # per enclosing function
        for qual, fi in list(mod.functions.items()):
            localmap: dict[str, ast.AST] = {}
            for node in ast.walk(fi.node):
                if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                        and isinstance(node.targets[0], ast.Name):
                    localmap[node.targets[0].id] = node.value
            fi._localmap = localmap  # type: ignore[attr-defined]
        for qual, fi in list(mod.functions.items()):
            edges = self._edges.setdefault(fi.key, set())
            localmap = getattr(fi, "_localmap", {})
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                callee = self.resolve_func(mod, node.func, fi, localmap)
                if callee is not None and not isinstance(callee.node, ast.Lambda):
                    edges.add(callee.key)
                self._scan_call_for_roots(mod, node, fi, localmap, edges)
        # module-level calls (e.g. decorators / module body jit calls)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                self._scan_call_for_roots(mod, node, None, {}, None)

    def _scan_call_for_roots(self, mod, call, enclosing, localmap, edges):
        callee = dotted(call.func)
        if callee is None:
            return
        resolved = resolve(mod, callee)
        last = callee.split(".")[-1]

        def grab(exprs, bucket):
            for e in exprs:
                fi = self.resolve_func(mod, e, enclosing, localmap)
                if fi is not None:
                    bucket.append(fi)
                    if edges is not None and not isinstance(fi.node, ast.Lambda):
                        edges.add(fi.key)

        if resolved in _JIT_WRAPPERS or last == "jit":
            args = list(call.args[:1])
            args += [k.value for k in call.keywords if k.arg in ("fun", "f")]
            grab(args, self.jit_roots)
        elif last in _SHARD_WRAPPERS or resolved in _SHARD_WRAPPERS:
            args = list(call.args[:1])
            args += [k.value for k in call.keywords if k.arg in ("f", "fn")]
            grab(args, self.shard_roots)
        else:
            for suffix, positions in _BRANCH_WRAPPERS.items():
                if resolved.endswith(suffix) or callee.endswith(suffix):
                    if positions is None:
                        args = list(call.args)
                    else:
                        args = [call.args[i] for i in positions
                                if i < len(call.args)]
                    grab(args, self.branch_roots)
                    break

    # -- reachability -------------------------------------------------------

    def reachable(self, roots: Iterable[FuncInfo]) -> dict[str, FuncInfo]:
        """BFS over the call graph from ``roots`` (named functions only;
        lambdas contribute their body calls via the enclosing function)."""
        seen: dict[str, FuncInfo] = {}
        queue = []
        for r in roots:
            if isinstance(r.node, ast.Lambda):
                continue
            if r.key not in seen:
                seen[r.key] = r
                queue.append(r)
        while queue:
            fi = queue.pop()
            for key in self._edges.get(fi.key, ()):
                if key not in seen and key in self.by_key:
                    seen[key] = self.by_key[key]
                    queue.append(self.by_key[key])
            # nested defs run under the same trace
            for qual, sub in fi.module.functions.items():
                if qual.startswith(fi.qualname + ".") and sub.key not in seen:
                    seen[sub.key] = sub
                    queue.append(sub)
        return seen


__all__ = ["FuncInfo", "ModuleInfo", "Index", "parse_module", "dotted",
           "resolve", "normalized", "module_name_for", "DISABLE_RE"]
