"""Capture XLA compile events via ``jax.log_compiles``.

The retrace detector (trace contract check (b) and the
``benchmarks/serve_micro.py`` regression gate) needs to count how many
times the unified step actually compiles across a workload sweep — a
silent retrace otherwise only shows up as a latency cliff.  jax logs
"Compiling <fn> ..." at WARNING level on the ``jax._src`` logger tree
whenever ``jax_log_compiles`` is on; this context manager flips the
flag, attaches a capturing handler to the ``jax`` root logger, and
restores everything on exit.
"""

from __future__ import annotations

import logging
from typing import Optional


class _Capture(logging.Handler):
    def __init__(self, events: list):
        super().__init__(level=logging.DEBUG)
        self.events = events
        self._seen: set = set()

    def emit(self, record: logging.LogRecord) -> None:
        try:
            msg = record.getMessage()
        except Exception:
            return
        # the same record propagates through every ancestor logger the
        # handler is attached to — count it once
        if id(record) in self._seen:
            return
        self._seen.add(id(record))
        if msg.startswith("Compiling "):
            self.events.append(msg)


class CompileWatch:
    """``with CompileWatch() as w: ... ; w.count`` — XLA compiles seen.

    ``match``: only count compile events whose message contains the
    substring (e.g. ``"_unified_impl"`` to isolate the serving step from
    draft-model or helper compiles).
    """

    def __init__(self, match: str = ""):
        self.match = match
        self.events: list[str] = []
        self._handler: Optional[_Capture] = None
        self._prev_flag = None

    @property
    def count(self) -> int:
        return sum(1 for e in self.events if self.match in e)

    def matching(self) -> list[str]:
        return [e for e in self.events if self.match in e]

    # "jax" alone would suffice while child loggers propagate (the
    # default); the explicit children keep the watch working if a logging
    # config flips propagate off — the id(record) dedup in _Capture makes
    # the overlap harmless.
    _LOGGERS = ("jax", "jax._src.interpreters.pxla", "jax._src.dispatch")

    def __enter__(self) -> "CompileWatch":
        import jax
        self._prev_flag = jax.config.jax_log_compiles
        jax.config.update("jax_log_compiles", True)
        self._handler = _Capture(self.events)
        for name in self._LOGGERS:
            logging.getLogger(name).addHandler(self._handler)
        return self

    def __exit__(self, *exc) -> None:
        import jax
        for name in self._LOGGERS:
            logging.getLogger(name).removeHandler(self._handler)
        jax.config.update("jax_log_compiles", self._prev_flag)


__all__ = ["CompileWatch"]
