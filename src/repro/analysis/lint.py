"""repro-lint: the repo-specific AST linter (Layer 1 of docs/analysis.md).

Usage::

    python -m repro.analysis.lint src/ [--json out.json] [--rules R1,R2]
    python -m repro.analysis.lint --self-test

Exit code 0 = clean, 1 = findings, 2 = usage/internal error.  Findings
are suppressed by a ``# repro-lint: disable=R1[,R2]`` comment on the
flagged line or the line directly above it (pair it with a justification
comment — the escape hatch is for *audited* exceptions).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from pathlib import Path
from typing import Iterable, Optional

from repro.analysis.astutils import Index, ModuleInfo, parse_module


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    symbol: str = ""

    def as_json(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule}{sym} {self.message}"


class LintContext:
    """Parsed modules + the cross-module index, shared by all rules."""

    def __init__(self, modules: Iterable[ModuleInfo]):
        self.modules = list(modules)
        self.index = Index(self.modules)

    def module(self, name: str) -> Optional[ModuleInfo]:
        for m in self.modules:
            if m.name == name or m.name.endswith("." + name):
                return m
        return None


def _suppressed(mod: ModuleInfo, rule: str, line: int) -> bool:
    for ln in (line, line - 1):
        if rule in mod.disables.get(ln, ()):
            return True
    return False


def all_rules() -> list:
    from repro.analysis.rules import RULES
    return list(RULES)


def run_rules(ctx: LintContext, rules: Optional[Iterable] = None,
              respect_disables: bool = True) -> list[Finding]:
    mods_by_path = {str(m.path): m for m in ctx.modules}
    findings: list[Finding] = []
    for rule in (rules if rules is not None else all_rules()):
        for f in rule.check(ctx):
            mod = mods_by_path.get(f.path)
            if respect_disables and mod is not None \
                    and _suppressed(mod, f.rule, f.line):
                continue
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def discover(paths: Iterable[str]) -> list[ModuleInfo]:
    mods = []
    for p in paths:
        path = Path(p)
        files = sorted(path.rglob("*.py")) if path.is_dir() else [path]
        for f in files:
            mods.append(parse_module(f))
    return mods


def lint_paths(paths: Iterable[str], rule_ids: Optional[set] = None,
               respect_disables: bool = True) -> list[Finding]:
    ctx = LintContext(discover(paths))
    rules = all_rules()
    if rule_ids:
        rules = [r for r in rules if r.id in rule_ids]
    return run_rules(ctx, rules, respect_disables)


def lint_sources(sources: dict, rule_ids: Optional[set] = None,
                 respect_disables: bool = True) -> list[Finding]:
    """Lint in-memory ``{module_name: source}`` dicts (test fixtures)."""
    mods = [parse_module(Path(f"{name.replace('.', '/')}.py"), name=name,
                         source=src)
            for name, src in sources.items()]
    ctx = LintContext(mods)
    rules = all_rules()
    if rule_ids:
        rules = [r for r in rules if r.id in rule_ids]
    return run_rules(ctx, rules, respect_disables)


def self_test() -> int:
    """Each rule must catch its bad fixture and pass its good fixture.

    This is the CI red-on-seeded-violation proof: a rule that stops
    firing on its own fixture fails the lane.
    """
    ok = True
    for rule in all_rules():
        bad = lint_sources({f"fixture_bad_{rule.id.lower()}": rule.FIXTURE_BAD},
                           rule_ids={rule.id})
        good = lint_sources(
            {f"fixture_good_{rule.id.lower()}": rule.FIXTURE_GOOD},
            rule_ids={rule.id})
        if not bad:
            print(f"SELF-TEST FAIL: {rule.id} missed its seeded violation")
            ok = False
        if good:
            print(f"SELF-TEST FAIL: {rule.id} false-positives on its clean "
                  f"fixture: {[str(f) for f in good]}")
            ok = False
    print("self-test:", "OK" if ok else "FAILED",
          f"({len(all_rules())} rules)")
    return 0 if ok else 1


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis.lint",
                                 description=__doc__)
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    ap.add_argument("--json", metavar="FILE",
                    help="write machine-readable findings to FILE")
    ap.add_argument("--rules", help="comma-separated rule ids (default: all)")
    ap.add_argument("--no-disables", action="store_true",
                    help="ignore # repro-lint: disable= comments")
    ap.add_argument("--self-test", action="store_true",
                    help="verify every rule catches its seeded violation")
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test()
    if not args.paths:
        ap.print_usage()
        return 2
    rule_ids = {r.strip() for r in args.rules.split(",")} if args.rules else None
    findings = lint_paths(args.paths, rule_ids,
                          respect_disables=not args.no_disables)
    for f in findings:
        print(f)
    if args.json:
        payload = {"tool": "repro-lint", "findings": [f.as_json() for f in findings],
                   "count": len(findings),
                   "rules": [r.id for r in all_rules()]}
        Path(args.json).write_text(json.dumps(payload, indent=2) + "\n")
    print(f"repro-lint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
