"""repro-lint rule registry.

Each rule module exports a ``RULE`` instance with:

* ``id`` / ``title`` — the stable identifier and one-line summary,
* a class docstring carrying the rationale and the incident that
  motivated the rule (moved here from ROADMAP prose so the invariant is
  machine-checked, not folklore),
* ``check(ctx)`` yielding ``Finding``s,
* ``FIXTURE_BAD`` / ``FIXTURE_GOOD`` — the seeded-violation and clean
  snippets used by ``--self-test`` and ``tests/test_analysis.py``.

Suppression: ``# repro-lint: disable=<ID>`` on the flagged line or the
line above, with a justification comment.
"""

from repro.analysis.rules.r1_sort_in_shard_map import RULE as R1
from repro.analysis.rules.r2_host_sync import RULE as R2
from repro.analysis.rules.r3_traced_branch import RULE as R3
from repro.analysis.rules.r4_kernel_contract import RULE as R4
from repro.analysis.rules.r5_serving_determinism import RULE as R5

RULES = (R1, R2, R3, R4, R5)

__all__ = ["RULES", "R1", "R2", "R3", "R4", "R5"]
