"""R1: no sorts inside shard_map bodies."""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.astutils import normalized
from repro.analysis.lint import Finding

_SORT_FNS = {"argsort", "sort", "searchsorted", "lexsort"}
_SORT_MODULES = {"jnp", "np", "lax", "jax"}


class SortInShardMapRule:
    """No ``sort``/``argsort``/``searchsorted`` inside shard_map bodies.

    Incident (ROADMAP "Notes for kernel/shard_map work"): XLA:CPU
    miscompiled an ``argsort`` whose consumers included interpret-mode
    pallas calls inside ``shard_map`` — wrong only when the sort was
    fusion-duplicated, so no unit test caught it until the sharded
    end-to-end lane diverged.  The EP regroup permutation is therefore
    computed in closed form from count prefix-sums (see
    ``models.moe._moe_shard_dropless_fn.expert_phase``); prefer index
    arithmetic over sorts in shard_map bodies.

    The rule flags calls to ``jnp.argsort`` / ``jnp.sort`` /
    ``jnp.searchsorted`` / ``jnp.lexsort`` / ``lax.sort`` in any function
    passed to ``shard_map`` or reachable from one through the repo call
    graph.  Audited survivors (e.g. the per-chunk local token sort whose
    single consumer chain is regression-tested bitwise against the
    oracle) carry ``# repro-lint: disable=R1`` with a justification.
    """

    id = "R1"
    title = "no sort/argsort/searchsorted inside shard_map bodies"

    def check(self, ctx) -> Iterable[Finding]:
        idx = ctx.index
        scope = idx.reachable(idx.shard_roots)
        for fi in scope.values():
            mod = fi.module
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                name = normalized(mod, node.func)
                if name is None:
                    continue
                head, _, last = name.rpartition(".")
                if last in _SORT_FNS and head.split(".")[0] in _SORT_MODULES:
                    yield Finding(
                        self.id, str(mod.path), node.lineno, node.col_offset,
                        f"`{name}` inside a shard_map body ({fi.qualname}): "
                        "XLA:CPU has miscompiled fusion-duplicated sorts "
                        "here — use closed-form index arithmetic from count "
                        "prefix-sums (see docs/analysis.md#r1)",
                        symbol=fi.qualname)

    # The historical pattern, verbatim shape: an argsort computed inside a
    # shard_map body whose consumer is a (pallas-backed) gather.
    FIXTURE_BAD = '''
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map


def _regroup(x, flat_e):
    order = jnp.argsort(flat_e, stable=True)   # the miscompiled pattern
    return x[order]


def _body(x, e):
    return _regroup(x, e)


def run(mesh, x, e):
    return shard_map(_body, mesh=mesh, in_specs=None, out_specs=None)(x, e)
'''

    FIXTURE_GOOD = '''
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map


def _regroup(x, counts):
    # closed form from count prefix-sums -- no sort
    offsets = jnp.cumsum(counts)
    return x, offsets


def _body(x, counts):
    return _regroup(x, counts)


def host_side_sort(v):
    return jnp.argsort(v)   # fine: not reachable from a shard_map body


def run(mesh, x, counts):
    return shard_map(_body, mesh=mesh, in_specs=None, out_specs=None)(x, counts)
'''


RULE = SortInShardMapRule()
