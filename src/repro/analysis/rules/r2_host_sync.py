"""R2: no host-sync calls reachable from jitted code."""

from __future__ import annotations

import ast
from typing import Iterable

from repro.analysis.astutils import normalized
from repro.analysis.lint import Finding

# attribute calls that force a device->host sync on a jax array
_SYNC_METHODS = {"item", "block_until_ready", "tolist", "copy_to_host_async"}
# fully-resolved callables that materialize device values on the host
_SYNC_FNS = {"np.asarray", "np.array", "jax.device_get", "np.copy"}
# builtins that concretize a traced value when fed a device expression
_CONCRETIZERS = {"float", "int", "bool"}


def _contains_device_call(node: ast.AST, mod) -> bool:
    """True when the expression contains a jnp./jax. call — the
    unambiguous `float(jnp.mean(x))` host-sync smell."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = normalized(mod, sub.func)
            if name and name.split(".")[0] in ("jnp", "jax", "lax"):
                return True
    return False


class HostSyncRule:
    """No host-sync calls reachable from the jitted unified step, the
    draft sources, or the kernel wrappers.

    The serving engine runs ONE jitted ``(B, chunk)`` unified step; its
    host control loop (``Engine.unified_step``) intentionally syncs
    (``np.asarray`` on sampled ids, wall-clock timestamps) AFTER the
    jitted program returns — that separation is the whole latency story.
    A ``.item()`` / ``np.asarray`` / ``jax.device_get`` /
    ``float(jnp...)`` *inside* the traced region either fails at trace
    time (ConcretizationTypeError at best) or, via callbacks, silently
    serializes the device stream mid-step.

    The rule flags, in any function passed to ``jax.jit`` / ``shard_map``
    or reachable from one (plus the ``kernels/ops.py`` wrappers, which
    always run under an outer jit): ``.item()``, ``.tolist()``,
    ``.block_until_ready()``, ``jax.device_get``, ``np.asarray`` /
    ``np.array``, and ``float()``/``int()``/``bool()`` whose argument
    contains a ``jnp.``/``jax.`` call.  Trace-time shape arithmetic
    (``int(math.ceil(...))``, ``x.shape``) is deliberately not flagged.
    """

    id = "R2"
    title = "no host-sync calls reachable from jitted code"

    def _roots(self, idx):
        roots = list(idx.jit_roots) + list(idx.shard_roots)
        # kernel wrappers run under the caller's jit
        for key, fi in idx.by_key.items():
            if fi.module.name.endswith("kernels.ops") \
                    and "." not in fi.qualname \
                    and not fi.qualname.startswith("_"):
                roots.append(fi)
        return roots

    def check(self, ctx) -> Iterable[Finding]:
        idx = ctx.index
        scope = idx.reachable(self._roots(idx))
        for fi in scope.values():
            mod = fi.module
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                # .item() / .block_until_ready() / .tolist()
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _SYNC_METHODS:
                    yield Finding(
                        self.id, str(mod.path), node.lineno, node.col_offset,
                        f"`.{node.func.attr}()` in jit-reachable "
                        f"{fi.qualname}: device->host sync inside the traced "
                        "region (move it to the host control loop)",
                        symbol=fi.qualname)
                    continue
                name = normalized(mod, node.func)
                if name in _SYNC_FNS:
                    yield Finding(
                        self.id, str(mod.path), node.lineno, node.col_offset,
                        f"`{name}` in jit-reachable {fi.qualname}: "
                        "materializes device values on the host inside the "
                        "traced region",
                        symbol=fi.qualname)
                elif name in _CONCRETIZERS and node.args \
                        and _contains_device_call(node.args[0], mod):
                    yield Finding(
                        self.id, str(mod.path), node.lineno, node.col_offset,
                        f"`{name}(jnp...)` in jit-reachable {fi.qualname}: "
                        "concretizes a traced value (host sync / "
                        "ConcretizationTypeError)",
                        symbol=fi.qualname)

    FIXTURE_BAD = '''
import jax
import jax.numpy as jnp
import numpy as np


def _step_impl(params, tokens):
    logits = params @ tokens
    best = float(jnp.max(logits))      # concretizes a traced value
    arr = np.asarray(logits)           # host materialization in the trace
    return logits.item()               # device->host sync


def make_step():
    return jax.jit(_step_impl)
'''

    FIXTURE_GOOD = '''
import jax
import jax.numpy as jnp
import numpy as np
import math


def _step_impl(params, tokens):
    cap = int(math.ceil(tokens.shape[0] * 1.25))   # trace-time shape math
    return (params @ tokens)[:cap]


def make_step():
    return jax.jit(_step_impl)


def host_loop(step, params, tokens):
    out = step(params, tokens)
    return np.asarray(out)             # fine: host side, after the jit
'''


RULE = HostSyncRule()
