"""R3: no Python branching on traced values in jitted functions."""

from __future__ import annotations

import ast
import re
from typing import Iterable

from repro.analysis.astutils import FuncInfo, normalized
from repro.analysis.lint import Finding

# attribute reads that stay device-valued (everything else on a traced
# name — .shape, .ndim, .dtype, config fields — is trace-time static)
_DEVICE_ATTRS = {"T", "mT", "at", "real", "imag"}
_STATIC_CALLS = {"len", "isinstance", "getattr", "hasattr", "callable",
                 "int", "float", "bool", "str", "repr", "type", "id",
                 # shape/dtype inspection: host ints even on traced args
                 "ndim", "shape", "size", "result_type", "issubdtype",
                 "can_cast"}
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "itemsize", "nbytes"}

# dtype literals: `x.dtype == jnp.int32` is trace-time static
_DTYPE_RE = re.compile(
    r"^(jnp|np)\.(u?int\d+|float\d+|bfloat16|float0|bool_?|complex\d+)$")

# parameter annotations naming only host-level Python types — such an
# argument cannot be a traced array, so it does not seed the taint set
_STATIC_ANN_NAMES = {
    "int", "str", "bool", "float", "bytes", "tuple", "list", "dict", "set",
    "frozenset", "type", "None", "NoneType", "Optional", "Union", "Tuple",
    "List", "Dict", "Set", "FrozenSet", "Mapping", "Sequence", "Iterable",
    "Collection", "Literal",
}


def _ann_static(node) -> bool:
    """True when an annotation names only host-level types (``kind: str``,
    ``shape: tuple``, ``mode: Optional[str]``) — conservative on anything
    array-ish, unknown, or absent."""
    if node is None:
        return False
    if isinstance(node, ast.Constant):
        if isinstance(node.value, str):   # forward-ref string annotation
            try:
                return _ann_static(ast.parse(node.value, mode="eval").body)
            except SyntaxError:
                return False
        return True   # None / Literal member / Ellipsis
    if isinstance(node, ast.Name):
        return node.id in _STATIC_ANN_NAMES
    if isinstance(node, ast.Attribute):   # typing.Optional, t.Sequence
        return node.attr in _STATIC_ANN_NAMES
    if isinstance(node, ast.Subscript):
        return _ann_static(node.value) and _ann_static(node.slice)
    if isinstance(node, ast.Tuple):
        return all(_ann_static(e) for e in node.elts)
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
        return _ann_static(node.left) and _ann_static(node.right)
    return False


class _Taint:
    """Single-function forward taint: positional parameters without
    defaults seed the traced set (the repo convention — static config
    rides keyword-only or defaulted params); assignments propagate."""

    def __init__(self, fi: FuncInfo):
        self.mod = fi.module
        seeds = set(fi.positional_params())
        args = getattr(fi.node, "args", None)
        if args is not None:
            for arg in list(getattr(args, "posonlyargs", [])) + list(args.args):
                if arg.arg in seeds and _ann_static(arg.annotation):
                    seeds.discard(arg.arg)   # annotated host-level type
        self.tainted: set = seeds
        body = getattr(fi.node, "body", None)
        stmts = body if isinstance(body, list) else []
        for _ in range(3):   # small fixpoint for forward references
            before = set(self.tainted)
            self._scan(stmts)
            if self.tainted == before:
                break

    def _scan(self, stmts) -> None:
        for node in stmts:
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                continue   # nested defs get their own pass
            if isinstance(node, ast.Assign):
                val_tainted = not self.static(node.value)
                for tgt in node.targets:
                    self._mark(tgt, val_tainted)
            elif isinstance(node, ast.AugAssign):
                if not self.static(node.value) or not self.static(node.target):
                    self._mark(node.target, True)
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                self._mark(node.target, not self.static(node.value))
            self._scan([c for c in ast.iter_child_nodes(node)
                        if isinstance(c, ast.stmt)])

    @staticmethod
    def _mark_names(target: ast.AST, out: set) -> None:
        for n in ast.walk(target):
            if isinstance(n, ast.Name):
                out.add(n.id)

    def _mark(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._mark(el, tainted)
        elif isinstance(target, ast.Starred):
            self._mark(target.value, tainted)

    # -- expression classification -----------------------------------------

    def static(self, node: ast.AST) -> bool:
        """True when the expression is trace-time static (safe to branch
        on); False when it may hold a traced array value."""
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.Name):
            return node.id not in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return True
            if node.attr in _DEVICE_ATTRS:
                return self.static(node.value)
            # cfg.field / self.field: config attribute access is static
            return True
        if isinstance(node, ast.Subscript):
            return self.static(node.value)
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return True   # identity tests are python-level, trace-safe
            if all(isinstance(op, (ast.In, ast.NotIn)) for op in node.ops) \
                    and isinstance(node.left, ast.Constant) \
                    and isinstance(node.left.value, str):
                return True   # `"w_gate" in p`: pytree-structure membership
            if node.comparators and all(
                    _DTYPE_RE.match(normalized(self.mod, c) or "")
                    for c in node.comparators):
                return True   # dtype-literal comparison is trace-static
            return self.static(node.left) and all(
                self.static(c) for c in node.comparators)
        if isinstance(node, (ast.BoolOp,)):
            return all(self.static(v) for v in node.values)
        if isinstance(node, ast.BinOp):
            return self.static(node.left) and self.static(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.static(node.operand)
        if isinstance(node, ast.IfExp):
            return all(self.static(v) for v in (node.test, node.body,
                                                node.orelse))
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return all(self.static(v) for v in node.elts)
        if isinstance(node, ast.Call):
            name = normalized(self.mod, node.func) or ""
            last = name.rpartition(".")[-1]
            if last in _STATIC_CALLS or name.startswith("math."):
                return True
            if name.split(".")[0] in ("jnp", "jax", "lax"):
                return False   # device-valued result
            # any call fed a tainted argument may return a traced value
            args = list(node.args) + [k.value for k in node.keywords]
            return all(self.static(a) for a in args)
        if isinstance(node, (ast.JoinedStr, ast.FormattedValue)):
            return True
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            if not all(self.static(g.iter) for g in node.generators):
                return False
            # iterables are static -> comp targets are static names too
            targets: set = set()
            for g in node.generators:
                self._mark_names(g.target, targets)
            saved = self.tainted
            self.tainted = saved - targets
            try:
                ok = all(self.static(i)
                         for g in node.generators for i in g.ifs)
                if ok and isinstance(node, ast.DictComp):
                    ok = self.static(node.key) and self.static(node.value)
                elif ok:
                    ok = self.static(node.elt)
            finally:
                self.tainted = saved
            return ok
        if isinstance(node, ast.Starred):
            return self.static(node.value)
        return False


def _own_branches(func_node):
    """If/While statements lexically inside ``func_node`` but NOT inside
    a nested def (nested defs are analyzed with their own taint seeds)."""
    out = []

    def visit(node):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.Lambda)) and child is not func_node:
                continue
            if isinstance(child, (ast.If, ast.While)):
                out.append(child)
            visit(child)

    visit(func_node)
    return out


class TracedBranchRule:
    """No Python ``if``/``while`` on traced values in functions passed to
    ``jax.jit`` / ``shard_map`` / ``lax.cond``-family transforms.

    A Python branch on a traced value either raises
    ``ConcretizationTypeError`` at trace time or — worse, when the value
    happens to be concrete on some call paths — silently bakes ONE branch
    into the compiled program and retraces per distinct value, which on
    the serving hot path shows up only as a latency cliff (the recompile
    hazard the trace contract's retrace detector measures end-to-end).
    Branch on trace-time statics (shapes, config) or use ``lax.cond`` /
    ``jnp.where``.

    Detection: inside the jit/shard_map-reachable set, positional
    parameters without defaults are assumed traced (the repo convention:
    static config rides keyword-only params — see
    ``_moe_shard_dropless_fn``) UNLESS annotated with a host-level type
    (``kind: str``, ``shape: tuple``, ``mode: Optional[str]``); taint
    propagates through assignments, and ``.shape``/``len()``/
    ``jnp.ndim()``/config-attribute/dtype-literal/string-key-membership
    derivations untaint.  An ``if``/``while`` whose test may hold a
    traced value is flagged.  Annotating a static parameter is therefore
    both documentation and lint compliance.
    """

    id = "R3"
    title = "no Python if/while on traced values in jitted functions"

    def check(self, ctx) -> Iterable[Finding]:
        idx = ctx.index
        roots = list(idx.jit_roots) + list(idx.shard_roots) \
            + list(idx.branch_roots)
        scope = idx.reachable(roots)
        for fi in scope.values():
            if isinstance(fi.node, ast.Lambda):
                continue
            taint = _Taint(fi)
            for node in _own_branches(fi.node):
                if not taint.static(node.test):
                    kw = "while" if isinstance(node, ast.While) else "if"
                    yield Finding(
                        self.id, str(fi.module.path), node.lineno,
                        node.col_offset,
                        f"Python `{kw}` on a possibly-traced value in jitted "
                        f"{fi.qualname}: retrace/ConcretizationTypeError "
                        "hazard — branch on shapes/config or use lax.cond",
                        symbol=fi.qualname)

    FIXTURE_BAD = '''
import jax
import jax.numpy as jnp


def _impl(params, x):
    y = x @ params
    if y.sum() > 0:            # traced-value branch
        y = y * 2
    while jnp.max(y) > 1.0:    # traced-value loop
        y = y / 2
    return y


def make():
    return jax.jit(_impl)
'''

    FIXTURE_GOOD = '''
import jax
import jax.numpy as jnp


def _impl(params, x, *, temperature=0.0, cfg=None):
    b, h = x.shape
    if b > 1:                          # shape branch: static
        x = x.reshape(b, h)
    if temperature > 0:                # defaulted knob: static
        x = x / temperature
    if cfg is not None and cfg.scale:  # config attribute: static
        x = x * cfg.scale
    y = jnp.where(x.sum() > 0, x * 2, x)   # traced select: fine
    return y @ params


def make():
    return jax.jit(_impl)
'''


RULE = TracedBranchRule()
