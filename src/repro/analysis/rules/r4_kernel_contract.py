"""R4: every kernel wrapper bumps its trace counter and has an oracle."""

from __future__ import annotations

import ast
from typing import Iterable, Optional

from repro.analysis.astutils import ModuleInfo, resolve
from repro.analysis.lint import Finding

_EXEMPT = {"reset_counters"}


def _is_counter_module(mod: ModuleInfo) -> bool:
    """A kernel-wrapper module declares `counters = collections.Counter()`
    at top level (the kernels/ops.py idiom)."""
    for node in mod.tree.body:
        if isinstance(node, (ast.Assign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Name) and t.id == "counters":
                    return True
    return False


def _counter_bump(fn: ast.FunctionDef) -> Optional[str]:
    """The string key of the first `counters[...] += 1` in the body."""
    for node in ast.walk(fn):
        if isinstance(node, ast.AugAssign) and isinstance(node.op, ast.Add) \
                and isinstance(node.target, ast.Subscript) \
                and isinstance(node.target.value, ast.Name) \
                and node.target.value.id == "counters":
            sl = node.target.slice
            if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
                return sl.value
    return None


def _calls_kernel(mod: ModuleInfo, fn: ast.FunctionDef) -> bool:
    """True when the wrapper dispatches to an imported `_kernel` impl."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
                and node.func.id.startswith("_") \
                and node.func.id in mod.imports:
            return True
    return False


def _module_bindings(mod: ModuleInfo) -> set:
    names = set()
    for node in mod.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(node, ast.AnnAssign) and isinstance(node.target,
                                                            ast.Name):
            names.add(node.target.id)
    return names


class KernelContractRule:
    """Every ``kernels/ops.py`` wrapper must bump its trace counter and
    have a ``ref.py`` oracle.

    This is the silent-fallback gate moved from benchmark-time to
    lint-time: the serving graph asserts kernels are *traced into* the
    jitted program by counting wrapper invocations at trace time
    (``ops.counters``), and every kernel's numerics are pinned by an
    allclose sweep against its pure-jnp ``<name>_ref`` oracle.  A wrapper
    added without the counter bump silently disappears from the
    kernels-lane coverage (the benchmark gate only notices when the whole
    policy falls back); one without an oracle has no independent source
    of truth for bit-exactness.

    Applies to modules that declare ``counters = Counter()`` at top
    level.  Each public top-level function dispatching to an imported
    ``_kernel`` implementation must (a) contain
    ``counters["<its own name>"] += 1`` and (b) have ``<name>_ref``
    bound in the module (the re-exported ``ref.py`` oracle) or defined
    in the sibling ``ref`` module.
    """

    id = "R4"
    title = "kernel wrappers bump their counter and have a ref.py oracle"

    def check(self, ctx) -> Iterable[Finding]:
        for mod in ctx.modules:
            if not _is_counter_module(mod):
                continue
            bindings = _module_bindings(mod)
            ref_mod = None
            for m in ctx.modules:
                if m.name.rsplit(".", 1)[-1] == "ref" \
                        and m.name.rsplit(".", 2)[0] == mod.name.rsplit(".", 2)[0]:
                    ref_mod = m
            ref_bindings = _module_bindings(ref_mod) if ref_mod else set()
            for node in mod.tree.body:
                if not isinstance(node, ast.FunctionDef):
                    continue
                name = node.name
                if name.startswith("_") or name in _EXEMPT \
                        or name.endswith("_ref"):
                    continue
                if not _calls_kernel(mod, node):
                    continue
                bump = _counter_bump(node)
                if bump is None:
                    yield Finding(
                        self.id, str(mod.path), node.lineno, node.col_offset,
                        f"kernel wrapper `{name}` does not bump "
                        f'`counters["{name}"]` — it is invisible to the '
                        "traced-into-the-graph assertions", symbol=name)
                elif bump != name:
                    yield Finding(
                        self.id, str(mod.path), node.lineno, node.col_offset,
                        f"kernel wrapper `{name}` bumps counter `{bump}` "
                        "instead of its own name", symbol=name)
                oracle = f"{name}_ref"
                if oracle not in bindings and oracle not in ref_bindings:
                    yield Finding(
                        self.id, str(mod.path), node.lineno, node.col_offset,
                        f"kernel wrapper `{name}` has no `{oracle}` oracle "
                        "(ref.py) — no independent source of truth for the "
                        "allclose sweep", symbol=name)

    FIXTURE_BAD = '''
import collections
from repro.kernels.moe_gemm import moe_gemm as _moe_gemm

counters = collections.Counter()


def moe_gemm(x, w, **kw):
    # missing counter bump, and no moe_gemm_ref oracle anywhere
    return _moe_gemm(x, w, **kw)
'''

    FIXTURE_GOOD = '''
import collections
from repro.kernels.moe_gemm import moe_gemm as _moe_gemm
from repro.kernels import ref

counters = collections.Counter()


def reset_counters():
    counters.clear()


def moe_gemm(x, w, **kw):
    counters["moe_gemm"] += 1
    return _moe_gemm(x, w, **kw)


moe_gemm_ref = ref.moe_gemm_ref
'''


RULE = KernelContractRule()
