"""R5: no nondeterminism in serving/ outside faults.py."""

from __future__ import annotations

import ast
from typing import Iterable, Optional

from repro.analysis.astutils import normalized
from repro.analysis.lint import Finding

# wall-clock entropy (time.perf_counter/monotonic/sleep are exempt:
# monotonic metrics timestamps and pacing, not decision entropy)
_CLOCK_FNS = {"time.time", "time.time_ns"}
# the stdlib global-state RNG and numpy's legacy global RNG
_GLOBAL_RNG_PREFIXES = ("random.",)
_NP_LEGACY = {"np.random." + f for f in
              ("rand", "randn", "randint", "random", "choice", "shuffle",
               "permutation", "seed", "uniform", "normal", "poisson",
               "exponential")}
_EXEMPT_FILES = ("faults.py",)


def _first_arg_entropy(call: ast.Call, mod) -> bool:
    """True when a seed argument is itself derived from a clock/RNG."""
    for node in ast.walk(call):
        if node is call or not isinstance(node, ast.Call):
            continue
        name = normalized(mod, node.func) or ""
        if name in _CLOCK_FNS or name.startswith(_GLOBAL_RNG_PREFIXES):
            return True
    return False


class ServingDeterminismRule:
    """No nondeterminism in ``serving/`` outside ``faults.py`` and
    metrics timestamps.

    Determinism is what makes fault replay and the bit-exactness tests
    meaningful: a serving trace (admissions, preemptions, speculation
    accept/reject, chaos schedules) must replay identically from a seed,
    and the chaos lane diffs replayed runs token-for-token.  One
    ``time.time()``-seeded decision or global-RNG draw anywhere in the
    scheduler/engine silently breaks that — it still passes every
    functional test and only shows up as an unreproducible incident.

    Flags, in ``serving/`` files other than ``faults.py`` (the fault
    injector owns its own seeded entropy): ``time.time``/``time_ns``,
    stdlib ``random.*``, numpy's legacy global RNG
    (``np.random.rand``...), ``np.random.default_rng()`` with NO seed,
    and ``jax.random.PRNGKey``/``key`` seeded from a clock or RNG.
    ``time.perf_counter``/``monotonic``/``sleep`` stay legal — monotonic
    metrics timestamps and pacing are not decision entropy.
    """

    id = "R5"
    title = "no nondeterminism in serving/ outside faults.py"

    def _applies(self, mod) -> bool:
        # fixture modules named fixture_*_r5 count as serving/ files so the
        # self-test and test fixtures exercise the rule without a src tree
        if mod.name.startswith("fixture_") and mod.name.endswith("_r5"):
            return True
        parts = mod.name.split(".")
        if "serving" not in parts:
            return False
        return mod.path.name not in _EXEMPT_FILES

    def check(self, ctx) -> Iterable[Finding]:
        for mod in ctx.modules:
            if not self._applies(mod):
                continue
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                name = normalized(mod, node.func)
                if name is None:
                    continue
                f: Optional[str] = None
                if name in _CLOCK_FNS:
                    f = (f"`{name}()` in serving/: wall-clock entropy breaks "
                         "fault replay — use time.perf_counter for metrics "
                         "timestamps or a seeded rng for decisions")
                elif name.startswith(_GLOBAL_RNG_PREFIXES):
                    f = (f"`{name}` in serving/: stdlib global RNG is "
                         "unseeded shared state — use "
                         "np.random.default_rng(seed)")
                elif name in _NP_LEGACY:
                    f = (f"`{name}` in serving/: numpy legacy global RNG — "
                         "use np.random.default_rng(seed)")
                elif name == "np.random.default_rng" and not node.args \
                        and not node.keywords:
                    f = ("`np.random.default_rng()` without a seed in "
                         "serving/: draws are unreproducible across runs")
                elif name in ("jax.random.PRNGKey", "jax.random.key") \
                        and _first_arg_entropy(node, mod):
                    f = (f"`{name}` seeded from a clock/RNG in serving/: "
                         "the key must derive from the spec seed")
                if f:
                    yield Finding(self.id, str(mod.path), node.lineno,
                                  node.col_offset, f)

    FIXTURE_BAD = '''
import time
import random
import numpy as np
import jax


def admit(queue):
    if random.random() < 0.5:            # global RNG decision
        return queue.pop()
    return None


def stamp():
    return time.time()                   # wall clock, not perf_counter


def make_rng():
    return np.random.default_rng()       # unseeded


def make_key():
    return jax.random.PRNGKey(int(time.time()))   # clock-seeded key
'''

    FIXTURE_GOOD = '''
import time
import numpy as np
import jax


def admit(queue, rng):
    if rng.random() < 0.5:               # caller-provided seeded rng
        return queue.pop()
    return None


def stamp():
    return time.perf_counter()           # monotonic metrics timestamp


def make_rng(seed):
    return np.random.default_rng(seed)


def make_key(seed):
    return jax.random.PRNGKey(seed)
'''


RULE = ServingDeterminismRule()
