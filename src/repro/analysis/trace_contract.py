"""Trace contract: the cost model priced the program XLA actually runs.

``cost_model.comm_census`` claims a per-layer collective list for each
strategy; this module traces the real lowered program on a CPU mesh and
demands the two agree — the MixServe analyzer's accounting, made
falsifiable (docs/analysis.md).  Three checks:

(a) **Collective census** — the shard_map jaxpr of
    ``models.moe.moe_block`` must contain exactly the census's
    ``traceable`` collectives: same kinds, same mesh-axis groups, same
    counts.  Collectives inside ``lax.cond`` (the count-bounded
    exchange's worst-case fallback) are matched against the census's
    ``conditional`` entries.  An extra all-reduce slipped into the block
    — or a census entry the implementation stopped emitting — fails.
(b) **Retrace detector** — across a declared set of shape signatures the
    step compiles exactly ONCE per signature (``jax.log_compiles`` via
    ``CompileWatch``); a silent retrace otherwise only shows up as a
    production latency cliff.
(c) **Purity** — the lowered StableHLO contains no host callbacks
    (``io_callback``/``pure_callback``/debug prints smuggle a host sync
    into the hot path) and no dynamic shapes.

CLI::

    python -m repro.analysis.trace_contract                 # tiny sweep
    python -m repro.analysis.trace_contract --spec qwen3-235b-a22b \\
        --strategies mixserve --mesh 2x4 --json report.json

jax is imported inside functions only, so the CLI can set
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before the first
import (the same subprocess idiom as ``tests/sharded/``).
"""

from __future__ import annotations

import dataclasses
import json
from collections import Counter
from typing import Optional

# jaxpr collective primitive -> census kind (jax 0.4.x names; note
# lax.psum_scatter lowers to the primitive named "reduce_scatter")
_PRIM_KIND = {
    "psum": "all_reduce",
    "pmax": "all_reduce",
    "pmin": "all_reduce",
    "all_to_all": "all_to_all",
    "all_gather": "all_gather",
    "reduce_scatter": "reduce_scatter",
}
# census logical axis -> mesh axis-name group (resolved per plan below)
_CALLBACK_MARKERS = ("callback", "CustomCall(\"xla_python",
                     "xla_python_cpu_callback", "xla_ffi_python")


def _axes_of(eqn) -> frozenset:
    p = eqn.params
    ax = p.get("axes", p.get("axis_name", ()))
    if isinstance(ax, str):
        ax = (ax,)
    return frozenset(ax)


def _sub_jaxprs(eqn):
    for v in eqn.params.values():
        vs = v if isinstance(v, (list, tuple)) else [v]
        for vv in vs:
            if hasattr(vv, "eqns"):            # plain Jaxpr (shard_map)
                yield vv
            elif hasattr(vv, "jaxpr"):         # ClosedJaxpr (pjit, scan)
                yield vv.jaxpr


def _walk(jaxpr, firm: Counter, cond: Counter, mult: int = 1) -> None:
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        kind = _PRIM_KIND.get(name)
        if kind is not None:
            firm[(kind, _axes_of(eqn))] += mult
            continue
        if name == "cond":
            per = []
            for br in eqn.params["branches"]:
                f, c = Counter(), Counter()
                _walk(br.jaxpr if hasattr(br, "jaxpr") else br, f, c)
                per.append((f, c))
            keys = set()
            for f, c in per:
                keys |= set(f) | set(c)
            for k in keys:
                lo = min(f[k] for f, _ in per)
                hi = max(f[k] + c[k] for f, c in per)
                firm[k] += lo * mult
                cond[k] += (hi - lo) * mult
            continue
        if name == "scan":
            length = int(eqn.params.get("length", 1))
            f, c = Counter(), Counter()
            _walk(eqn.params["jaxpr"].jaxpr, f, c)
            for k, v in f.items():
                firm[k] += v * length * mult
            for k, v in c.items():
                cond[k] += v * length * mult
            continue
        if name == "while":
            # data-dependent trip count: any collective inside is counted
            # as conditional (the census has no unconditional claim on it)
            f, c = Counter(), Counter()
            for sub in _sub_jaxprs(eqn):
                _walk(sub, f, c)
            for k, v in (f + c).items():
                cond[k] += v * mult
            continue
        for sub in _sub_jaxprs(eqn):
            _walk(sub, firm, cond, mult)


def jaxpr_census(closed_jaxpr) -> tuple[Counter, Counter]:
    """(firm, conditional) Counters keyed (kind, frozenset(mesh axes)).

    ``firm`` counts collectives on every execution; ``conditional`` the
    extra issues the worst-case ``lax.cond``/``while`` paths may add.
    """
    firm: Counter = Counter()
    cond: Counter = Counter()
    _walk(closed_jaxpr.jaxpr, firm, cond)
    return firm, cond


# ---------------------------------------------------------------------------
# Expected counters from the cost model census
# ---------------------------------------------------------------------------

def strategy_for_plan(plan):
    """The cost-model ``Strategy`` a ShardingPlan realizes (inverse of
    ``partitioner.make_plan``'s canonical layouts, pod-less meshes)."""
    from repro.core.cost_model import Strategy
    tp = plan.axis_size(plan.tp_axes)
    ep = plan.axis_size(plan.ep_axes)
    n = plan.mesh.devices.size if plan.mesh is not None else 1
    token_sliced = bool(set(plan.tp_axes) & set(plan.ep_axes))
    if ep <= 1:                                   # pure_tp
        return Strategy(attn_tp=tp, attn_dp=max(1, n // tp),
                        moe_tp=tp, moe_ep=1, comm_algo=plan.comm_algo)
    if token_sliced:                              # dp_ep (pure EP)
        return Strategy(attn_tp=tp, attn_dp=max(1, n // tp),
                        moe_tp=1, moe_ep=ep, comm_algo="unfused")
    return Strategy(attn_tp=tp, attn_dp=max(1, n // tp),   # mixserve
                    moe_tp=tp, moe_ep=ep, comm_algo=plan.comm_algo)


def tokens_local_for(plan, batch: int, seq: int) -> int:
    """Per-rank token count the shard body sees — pins the census's
    micro-chunk count C and cap decision to the traced program's."""
    r = plan.rules.get("batch") or ()
    r = r if isinstance(r, tuple) else (r,)
    b_local = max(1, batch // max(1, plan.axis_size(tuple(a for a in r if a))))
    t = b_local * seq
    if set(plan.tp_axes) & set(plan.ep_axes):     # token-sliced body
        tp = plan.axis_size(plan.tp_axes)
        t = (t + (-t) % tp) // tp                 # pad-to-tp then slice
    return max(1, t)


def expected_census(census, plan) -> tuple[Counter, Counter]:
    """Resolve the census's logical axes onto the plan's mesh axis names;
    returns (firm, conditional) Counters shaped like ``jaxpr_census``."""
    groups = {
        "tp": frozenset(plan.tp_axes),
        "ep": frozenset(plan.ep_axes),
        "guard": frozenset(plan.tp_axes) | frozenset(plan.ep_axes),
        "mesh": frozenset(plan.mesh.axis_names) if plan.mesh is not None
        else frozenset(),
    }
    firm: Counter = Counter()
    cond: Counter = Counter()
    for e in census.entries:
        if not e.traceable:
            continue
        key = (e.kind, groups[e.axis])
        (cond if e.conditional else firm)[key] += e.count
    return firm, cond


# ---------------------------------------------------------------------------
# Check (a): the MoE block's collective census
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ContractReport:
    name: str
    ok: bool
    expected: dict
    actual: dict
    mismatches: list

    def as_json(self) -> dict:
        return dataclasses.asdict(self)

    def __str__(self) -> str:
        head = f"[{'OK' if self.ok else 'FAIL'}] {self.name}"
        if self.ok:
            return head
        return head + "".join(f"\n  {m}" for m in self.mismatches)


def _fmt(key) -> str:
    kind, axes = key
    return f"{kind}({','.join(sorted(axes))})"


def _diff(name: str, expected: Counter, actual: Counter) -> list:
    out = []
    for k in sorted(set(expected) | set(actual), key=_fmt):
        e, a = expected.get(k, 0), actual.get(k, 0)
        if e != a:
            out.append(f"{name}: {_fmt(k)} expected {e}, traced {a}")
    return out


def check_moe_census(cfg, plan, *, batch: int = 4, seq: int = 8,
                     name: str = "moe_census") -> ContractReport:
    """Check (a): trace ``moe_block`` on the plan's mesh abstractly and
    compare its collective census against ``cost_model.comm_census``."""
    import jax
    import jax.numpy as jnp

    from repro.core.cost_model import Workload, comm_census
    from repro.models import moe as M
    from repro.models.param import init_tree

    strat = strategy_for_plan(plan)
    work = Workload(batch=batch, seq_len=seq)
    census = comm_census(cfg, strat, work, ep_overlap=plan.ep_overlap,
                         tokens_local=tokens_local_for(plan, batch, seq))
    exp_firm, exp_cond = expected_census(census, plan)

    # abstract trace: ShapeDtypeStructs cost no device memory, so the
    # contract runs on paper-size configs too
    p_shapes = jax.eval_shape(
        lambda k: init_tree(k, M.moe_spec(cfg), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    x_shape = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda p, xx: M.moe_block(p, xx, cfg, plan))(p_shapes, x_shape)
    act_firm, act_cond = jaxpr_census(jaxpr)

    mismatches = _diff("firm", exp_firm, act_firm) \
        + _diff("conditional", exp_cond, act_cond)
    to_d = lambda c: {_fmt(k): v for k, v in sorted(c.items(), key=lambda i:
                                                    _fmt(i[0]))}
    return ContractReport(
        name=name, ok=not mismatches,
        expected={"firm": to_d(exp_firm), "conditional": to_d(exp_cond)},
        actual={"firm": to_d(act_firm), "conditional": to_d(act_cond)},
        mismatches=mismatches)


# ---------------------------------------------------------------------------
# Check (b): retrace detector
# ---------------------------------------------------------------------------

def check_retrace(fn, arg_sets, *, match: str = "",
                  name: str = "retrace") -> ContractReport:
    """Check (b): running ``fn`` over ``arg_sets`` (one per declared shape
    signature, each repeated twice) must compile exactly once per set."""
    from repro.analysis.compile_watch import CompileWatch

    with CompileWatch(match=match) as w:
        for args in arg_sets:
            fn(*args)
            fn(*args)           # second call must hit the cache
    expected, actual = len(arg_sets), w.count
    ok = actual <= expected     # dedup'd signatures may share a compile
    return ContractReport(
        name=name, ok=ok, expected={"compiles": expected},
        actual={"compiles": actual},
        mismatches=[] if ok else [
            f"{actual} compiles for {expected} shape signatures — "
            "a shape/dtype/static-arg leak is retracing the step"])


# ---------------------------------------------------------------------------
# Check (c): purity of the lowered module
# ---------------------------------------------------------------------------

def purity_issues(stablehlo_text: str) -> list:
    issues = []
    for line in stablehlo_text.splitlines():
        if "custom_call" in line and any(m in line for m in
                                         _CALLBACK_MARKERS):
            issues.append(f"host callback in lowered module: {line.strip()[:120]}")
        if "tensor<?x" in line or "x?x" in line:
            issues.append(f"dynamic shape in lowered module: {line.strip()[:120]}")
    return issues


def check_purity(lowered_text: str, *, name: str = "purity") -> ContractReport:
    issues = purity_issues(lowered_text)
    return ContractReport(name=name, ok=not issues, expected={},
                          actual={}, mismatches=issues)


def check_moe_purity(cfg, plan, *, batch: int = 4, seq: int = 8,
                     name: str = "moe_purity") -> ContractReport:
    import jax
    import jax.numpy as jnp

    from repro.models import moe as M
    from repro.models.param import init_tree

    p_shapes = jax.eval_shape(
        lambda k: init_tree(k, M.moe_spec(cfg), jnp.float32),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    x_shape = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.float32)
    text = jax.jit(lambda p, xx: M.moe_block(p, xx, cfg, plan)).lower(
        p_shapes, x_shape).as_text()
    return check_purity(text, name=name)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="tiny-moe", family="moe", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab_size=256, n_experts=8, top_k=2, d_expert=96,
                       n_shared_experts=1)


def run_contract(arch: Optional[str], mesh_shape: tuple, strategies,
                 comm_algo: str, chunks: int, purity: bool,
                 cap_rows: int = -1) -> list:
    """Build the mesh, resolve each strategy to a plan, run the checks."""
    import jax

    from repro.core.cost_model import EpOverlap
    from repro.core.partitioner import make_plan

    ep_overlap = EpOverlap(chunks=max(1, chunks), cap_rows=cap_rows) \
        if chunks > 1 or cap_rows >= 0 else None
    mesh = jax.make_mesh(mesh_shape, ("data", "model"))

    if arch is None:
        cfg = _tiny_cfg()
    else:
        from repro.serving.api import ServeSpec
        import repro.configs as C
        spec = ServeSpec(arch=arch)
        resolved = spec.resolve(mesh=mesh)
        cfg = C.get(arch)
        if not strategies:
            strategies = [resolved.strategy]

    reports = []
    for strat in strategies or ["mixserve", "pure_tp", "dp_ep"]:
        plan = make_plan(strat, mesh, comm_algo=comm_algo,
                         dispatch="dropless", ep_overlap=ep_overlap)
        tag = f"{cfg.name}/{strat}/{comm_algo}" \
              + (f"/chunks={chunks}" if chunks > 1 else "")
        reports.append(check_moe_census(cfg, plan,
                                        name=f"moe_census[{tag}]"))
        if purity:
            reports.append(check_moe_purity(cfg, plan,
                                            name=f"moe_purity[{tag}]"))
    return reports


def main(argv=None) -> int:
    import argparse
    import os

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.trace_contract",
        description="cross-check cost_model.comm_census against the "
                    "lowered MoE program on a CPU mesh")
    ap.add_argument("--spec", default=None, metavar="ARCH",
                    help="registry arch id; resolves a ServeSpec for the "
                         "mesh (default: the tiny 8-expert test config)")
    ap.add_argument("--mesh", default="2x4",
                    help="data x model CPU mesh, e.g. 2x4 (default)")
    ap.add_argument("--strategies", default="",
                    help="comma list of mixserve,pure_tp,dp_ep "
                         "(default: all three; with --spec: the resolved one)")
    ap.add_argument("--algo", default="fused",
                    choices=["fused", "sync", "unfused"])
    ap.add_argument("--chunks", type=int, default=0,
                    help="micro-chunked EP overlap chunk count (0 = off)")
    ap.add_argument("--cap-rows", type=int, default=-1,
                    help="count-bound row cap (-1 = worst case, 0 = auto "
                         "rule, >0 explicit — small values exercise the "
                         "conditional overflow fallback)")
    ap.add_argument("--no-purity", action="store_true",
                    help="skip the lowered-StableHLO purity scan")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the reports as JSON")
    args = ap.parse_args(argv)

    shape = tuple(int(d) for d in args.mesh.lower().split("x"))
    if len(shape) != 2:
        ap.error("--mesh must be RxC (two axes: data x model)")
    n = shape[0] * shape[1]
    # must precede the first jax import (the tests/sharded idiom)
    os.environ.setdefault("XLA_FLAGS",
                          f"--xla_force_host_platform_device_count={n}")
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    strategies = [s for s in args.strategies.split(",") if s]
    reports = run_contract(args.spec, shape, strategies, args.algo,
                           args.chunks, purity=not args.no_purity,
                           cap_rows=args.cap_rows)
    for r in reports:
        print(r)
    if args.json:
        with open(args.json, "w") as f:
            json.dump([r.as_json() for r in reports], f, indent=2)
    return 0 if all(r.ok for r in reports) else 1


if __name__ == "__main__":
    raise SystemExit(main())


__all__ = ["jaxpr_census", "expected_census", "strategy_for_plan",
           "tokens_local_for", "check_moe_census", "check_retrace",
           "purity_issues", "check_purity", "check_moe_purity",
           "ContractReport", "run_contract"]
