"""Architecture registry: the 10 assigned configs (+ reduced smoke variants).

``get(arch_id)`` / ``get_reduced(arch_id)`` are what the launcher's ``--arch``
flag resolves through.
"""

from __future__ import annotations

from repro.configs import (deepseek_v2_236b, gemma_2b, minicpm3_4b,
                           minitron_8b, phi35_moe_42b, qwen2_vl_7b,
                           recurrentgemma_9b, rwkv6_1b6, smollm_360m,
                           whisper_tiny)
from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

_MODULES = {
    "qwen2-vl-7b": qwen2_vl_7b,
    "phi3.5-moe-42b": phi35_moe_42b,
    "gemma-2b": gemma_2b,
    "smollm-360m": smollm_360m,
    "whisper-tiny": whisper_tiny,
    "rwkv6-1.6b": rwkv6_1b6,
    "minicpm3-4b": minicpm3_4b,
    "minitron-8b": minitron_8b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "recurrentgemma-9b": recurrentgemma_9b,
}

ARCH_IDS = tuple(_MODULES)


def get(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].CONFIG


def get_reduced(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].REDUCED


def is_subquadratic(cfg: ModelConfig) -> bool:
    """True when the arch supports long_500k (SSM / hybrid local-attention)."""
    return cfg.family in ("ssm", "hybrid")


def shape_supported(cfg: ModelConfig, shape: InputShape) -> bool:
    """The DESIGN.md §4 shape-skip policy (long_500k needs sub-quadratic)."""
    if shape.name == "long_500k":
        return is_subquadratic(cfg)
    return True


__all__ = ["ARCH_IDS", "get", "get_reduced", "is_subquadratic",
           "shape_supported", "INPUT_SHAPES", "InputShape", "ModelConfig"]
