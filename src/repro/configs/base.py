"""Model configuration dataclass shared by models, cost model and launcher.

One ``ModelConfig`` describes any of the six assigned architecture families
(dense / moe / ssm / hybrid / vlm / audio).  Family-specific fields default to
"absent" so dense configs stay small.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder half of an enc-dec model (whisper).  The modality frontend
    (mel + conv) is a stub: the encoder consumes precomputed frame embeddings."""

    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    n_frames: int  # encoder sequence length (1500 for whisper 30s audio)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int          # query heads; 0 for attention-free (ssm)
    n_kv_heads: int       # kv heads (GQA); ==n_heads for MHA, 1 for MQA
    d_ff: int             # dense-FFN hidden dim (or per-expert dim when dense_ff absent)
    vocab_size: int
    head_dim: int = 0     # 0 -> d_model // n_heads

    # --- attention flavour ---
    attention: Literal["gqa", "mla", "none", "local"] = "gqa"
    rope_theta: float = 10000.0
    mrope: bool = False               # qwen2-vl multimodal 3D rope
    mrope_sections: tuple = (16, 24, 24)
    window_size: int = 0              # sliding window for local attention

    # --- MLA (deepseek-v2 / minicpm3) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64           # decoupled rope dims per head (MLA)
    v_head_dim: int = 0               # 0 -> head_dim

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0                 # per-expert FFN hidden dim
    first_dense_layers: int = 0       # deepseek: first layer(s) dense
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- recurrent (rwkv6 / recurrentgemma) ---
    # block_pattern: cyclic layer-type pattern, e.g. ("rec","rec","attn")
    block_pattern: tuple = ()
    lru_width: int = 0                # RG-LRU recurrence width (0 -> d_model)
    conv1d_width: int = 4             # temporal conv in recurrent block

    # --- frontend stubs ---
    frontend: Optional[Literal["vision_stub", "audio_stub"]] = None
    n_frontend_tokens: int = 0        # patch/frame embeddings per request
    encoder: Optional[EncoderConfig] = None

    # --- misc ---
    activation: Literal["swiglu", "geglu", "gelu"] = "swiglu"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # citation for the assigned config
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.v_head_dim == 0:
            object.__setattr__(self, "v_head_dim", self.head_dim)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # ------------------------------------------------------------------
    # Parameter accounting (used by the cost model, Eq. 4 / Eq. 8).
    # ------------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def padded_vocab(self) -> int:
        """Embedding/lm-head vocab dim padded to a multiple of 16 so the
        vocab axis always shards over the 16-wide TP mesh axis (whisper's
        51865 and minicpm3's 73448 would otherwise replicate the logits).
        ``vocab_size`` itself stays the exact assigned value; padded logit
        columns are masked to -inf in the forward pass."""
        return -(-self.vocab_size // 16) * 16

    def attn_params_per_layer(self) -> int:
        h, hd = self.d_model, self.head_dim
        if self.attention == "none":
            # rwkv6 time-mix: r,k,v,g,o projections + decay params ~ 5 h^2
            return 5 * h * h
        if self.attention == "mla":
            r = self.kv_lora_rank
            qr = self.q_lora_rank or h
            nh = self.n_heads
            # q down/up, kv down/up, o
            return (h * qr + qr * nh * (hd + self.rope_head_dim)
                    + h * (r + self.rope_head_dim)
                    + r * nh * (hd + self.v_head_dim)
                    + nh * self.v_head_dim * h)
        nq, nkv = self.n_heads, self.n_kv_heads
        return h * nq * hd + 2 * h * nkv * hd + nq * hd * h

    def dense_ffn_params_per_layer(self) -> int:
        mult = 3 if self.activation in ("swiglu", "geglu") else 2
        return mult * self.d_model * self.d_ff

    def expert_params(self) -> int:
        """Parameters of ONE routed expert."""
        if not self.is_moe:
            return 0
        mult = 3 if self.activation in ("swiglu", "geglu") else 2
        return mult * self.d_model * self.d_expert

    def moe_params_per_layer(self) -> int:
        if not self.is_moe:
            return 0
        shared = self.n_shared_experts * self.expert_params()
        router = self.d_model * self.n_experts
        return self.n_experts * self.expert_params() + shared + router

    def params_total(self) -> int:
        per_layer_attn = self.attn_params_per_layer()
        n_moe_layers = self.n_layers - self.first_dense_layers if self.is_moe else 0
        n_dense_layers = self.n_layers - n_moe_layers
        p = self.n_layers * per_layer_attn
        p += n_dense_layers * self.dense_ffn_params_per_layer()
        p += n_moe_layers * self.moe_params_per_layer()
        p += self.d_model * self.vocab_size * (1 if self.tie_embeddings else 2)
        if self.encoder is not None:
            e = self.encoder
            p += e.n_layers * (4 * e.d_model**2 + 2 * e.d_model * e.d_ff)
            # decoder cross-attention
            p += self.n_layers * 4 * self.d_model**2
        return p

    def params_active(self) -> int:
        """Activated parameters per token (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.params_total()
        n_moe_layers = self.n_layers - self.first_dense_layers
        inactive = n_moe_layers * (self.n_experts - self.top_k) * self.expert_params()
        return self.params_total() - inactive

    def kv_bytes_per_token_per_layer(self, bytes_per_el: int = 2) -> int:
        if self.attention == "none":
            return 0  # recurrent state is O(1) in sequence length
        if self.attention == "mla":
            return (self.kv_lora_rank + self.rope_head_dim) * bytes_per_el
        return 2 * self.n_kv_heads * self.head_dim * bytes_per_el


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One of the four assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

__all__ = ["ModelConfig", "EncoderConfig", "InputShape", "INPUT_SHAPES", "Family"]
