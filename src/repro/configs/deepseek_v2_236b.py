"""deepseek-v2-236b — [moe] 60L d_model=5120 128H d_ff=1536 (per-expert)
vocab=102400, MoE 160 routed experts top-6 + 2 shared, MLA kv_lora=512.

The paper's own model family (DeepSeek): this is the paper-representative
architecture for the hybrid TP-EP + fused AR-A2A technique.
[arXiv:2405.04434]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,                # dense FFN dim of the first (dense) layer
    vocab_size=102400,
    head_dim=128,              # qk nope head dim
    attention="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    v_head_dim=128,
    n_experts=160,
    top_k=6,
    d_expert=1536,
    n_shared_experts=2,
    first_dense_layers=1,
    activation="swiglu",
    source="arXiv:2405.04434",
)

REDUCED = ModelConfig(
    name="deepseek-v2-reduced",
    family="moe",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=512,
    head_dim=32,
    attention="mla",
    kv_lora_rank=64,
    q_lora_rank=96,
    rope_head_dim=16,
    v_head_dim=32,
    n_experts=4,
    top_k=2,
    d_expert=128,
    n_shared_experts=1,
    first_dense_layers=1,
    activation="swiglu",
    source="arXiv:2405.04434 (reduced)",
)
