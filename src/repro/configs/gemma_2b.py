"""gemma-2b — [dense] 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.

GeGLU activation, head_dim=256 (wider than d_model/n_heads), MQA on the 2b
size.  [arXiv:2403.08295]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=256000,
    head_dim=256,
    attention="gqa",
    rope_theta=10000.0,
    activation="geglu",
    tie_embeddings=True,
    source="arXiv:2403.08295",
)

REDUCED = ModelConfig(
    name="gemma-reduced",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=4,
    n_kv_heads=1,
    d_ff=512,
    vocab_size=512,
    head_dim=64,
    attention="gqa",
    activation="geglu",
    tie_embeddings=True,
    source="arXiv:2403.08295 (reduced)",
)
