"""minicpm3-4b — [dense] 62L d_model=2560 40H d_ff=6400 vocab=73448, MLA.

Multi-head Latent Attention: kv_lora_rank=256, q_lora_rank=768, decoupled
rope dims 32, nope head dim 64.  [hf:openbmb/MiniCPM3-4B]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    head_dim=64,               # qk nope head dim
    attention="mla",
    kv_lora_rank=256,
    q_lora_rank=768,
    rope_head_dim=32,
    v_head_dim=64,
    activation="swiglu",
    source="hf:openbmb/MiniCPM3-4B",
)

REDUCED = ModelConfig(
    name="minicpm3-reduced",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=512,
    head_dim=32,
    attention="mla",
    kv_lora_rank=64,
    q_lora_rank=96,
    rope_head_dim=16,
    v_head_dim=32,
    activation="swiglu",
    source="hf:openbmb/MiniCPM3-4B (reduced)",
)
