"""minitron-8b — [dense] 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000.  Pruned-and-distilled Nemotron-4.  [arXiv:2407.14679]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    attention="gqa",
    rope_theta=10000.0,
    activation="gelu",          # nemotron uses squared-relu; gelu-family MLP
    source="arXiv:2407.14679",
)

REDUCED = ModelConfig(
    name="minitron-reduced",
    family="dense",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    attention="gqa",
    activation="gelu",
    source="arXiv:2407.14679 (reduced)",
)
