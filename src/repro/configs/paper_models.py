"""The paper's own evaluation models (§IV-A), as cost-model configs.

These drive the analyzer/benchmark reproductions of Figs. 3/10/11/12 and
Table I on the paper's two clusters.  (The runnable end-to-end archs are the
10 assigned configs; these two exist so every paper figure has its exact
model hyperparameters behind it.)
"""

from repro.configs.base import ModelConfig

# DeepSeek-R1: 671B total / 37B activated, 256 routed + 1 shared expert,
# MLA kv_lora=512.  [arXiv:2501.12948 / arXiv:2412.19437]
DEEPSEEK_R1 = ModelConfig(
    name="deepseek-r1-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,
    vocab_size=129280,
    head_dim=128,
    attention="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    top_k=8,
    d_expert=2048,
    n_shared_experts=1,
    first_dense_layers=3,
    activation="swiglu",
    source="arXiv:2412.19437 (DeepSeek-V3 base; R1 shares the architecture)",
)

# Qwen3-235B-A22B: 128 experts top-8, GQA kv=4.  [arXiv:2505.09388]
QWEN3_235B = ModelConfig(
    name="qwen3-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=12288,
    vocab_size=151936,
    head_dim=128,
    attention="gqa",
    n_experts=128,
    top_k=8,
    d_expert=1536,
    n_shared_experts=0,
    activation="swiglu",
    source="arXiv:2505.09388",
)

PAPER_MODELS = {m.name: m for m in (DEEPSEEK_R1, QWEN3_235B)}

__all__ = ["DEEPSEEK_R1", "QWEN3_235B", "PAPER_MODELS"]
