"""phi3.5-moe-42b-a6.6b — [moe] 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct]

d_ff is the per-expert FFN hidden dim (each expert is a SwiGLU MLP).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    attention="gqa",
    rope_theta=10000.0,
    n_experts=16,
    top_k=2,
    d_expert=6400,
    n_shared_experts=0,
    activation="swiglu",
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)

REDUCED = ModelConfig(
    name="phi3.5-moe-reduced",
    family="moe",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    attention="gqa",
    n_experts=4,
    top_k=2,
    d_expert=512,
    n_shared_experts=0,
    activation="swiglu",
    source="hf:microsoft/Phi-3.5-MoE-instruct (reduced)",
)
