"""qwen2-vl-7b — [vlm] 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.

M-RoPE (3D multimodal rotary embedding, sections t/h/w), dynamic resolution.
The ViT vision encoder + projector is a STUB per the assignment: input_specs()
provides precomputed patch embeddings of the right shape.  [arXiv:2409.12191]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    attention="gqa",
    rope_theta=1e6,
    mrope=True,
    mrope_sections=(16, 24, 24),     # t/h/w frequency-pair sections (sum=hd/2)
    frontend="vision_stub",
    n_frontend_tokens=256,           # patch embeddings prepended per request
    activation="swiglu",
    source="arXiv:2409.12191",
)

# Reduced same-family variant for CPU smoke tests (2 layers, d_model<=512).
REDUCED = ModelConfig(
    name="qwen2-vl-reduced",
    family="vlm",
    n_layers=2,
    d_model=256,
    n_heads=8,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=512,
    attention="gqa",
    rope_theta=1e6,
    mrope=True,
    mrope_sections=(4, 6, 6),
    frontend="vision_stub",
    n_frontend_tokens=16,
    activation="swiglu",
    source="arXiv:2409.12191 (reduced)",
)
