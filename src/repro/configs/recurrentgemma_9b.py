"""recurrentgemma-9b — [hybrid] 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000.  RG-LRU recurrent blocks + local (sliding-window) attention in
a 2:1 pattern (rec, rec, attn).  Sub-quadratic: long_500k runs.
[arXiv:2402.19427]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,                   # 12 x (rec, rec, attn) + 2 trailing rec
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    attention="local",
    window_size=2048,
    block_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    conv1d_width=4,
    activation="geglu",
    tie_embeddings=True,
    source="arXiv:2402.19427",
)

REDUCED = ModelConfig(
    name="recurrentgemma-reduced",
    family="hybrid",
    n_layers=3,                    # one full (rec, rec, attn) pattern
    d_model=256,
    n_heads=4,
    n_kv_heads=1,
    d_ff=512,
    vocab_size=512,
    head_dim=64,
    attention="local",
    window_size=64,
    block_pattern=("rec", "rec", "attn"),
    lru_width=256,
    conv1d_width=4,
    activation="geglu",
    tie_embeddings=True,
    source="arXiv:2402.19427 (reduced)",
)
