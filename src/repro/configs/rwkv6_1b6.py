"""rwkv6-1.6b (Finch) — [ssm] 24L d_model=2048 attn-free d_ff=7168 vocab=65536.

Data-dependent decay (the Finch contribution).  Attention-free: O(1) state per
layer, so long_500k decode is supported.  [arXiv:2404.05892]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    attention="none",
    activation="gelu",          # rwkv channel-mix uses squared-relu internally
    source="arXiv:2404.05892",
)

REDUCED = ModelConfig(
    name="rwkv6-reduced",
    family="ssm",
    n_layers=2,
    d_model=256,
    n_heads=0,
    n_kv_heads=0,
    d_ff=512,
    vocab_size=512,
    attention="none",
    activation="gelu",
    source="arXiv:2404.05892 (reduced)",
)
