"""smollm-360m — [dense] 32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.

Llama-architecture small model.  The 15 query heads are NOT divisible by the
16-wide TP mesh axis — GSPMD handles this via padded (uneven) sharding, which
the dry-run exercises deliberately.  [hf:HuggingFaceTB/SmolLM-360M]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    attention="gqa",
    rope_theta=10000.0,
    activation="swiglu",
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-360M",
)

REDUCED = ModelConfig(
    name="smollm-reduced",
    family="dense",
    n_layers=2,
    d_model=240,      # keeps the 15-head / uneven-sharding character: hd=16
    n_heads=15,
    n_kv_heads=5,
    d_ff=512,
    vocab_size=512,
    attention="gqa",
    activation="swiglu",
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-360M (reduced)",
)
