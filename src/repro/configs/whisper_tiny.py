"""whisper-tiny — [audio] 4L d_model=384 6H (MHA kv=6) d_ff=1536 vocab=51865.

Encoder-decoder; the mel-spectrogram + conv frontend is a STUB per the
assignment: input_specs() provides precomputed frame embeddings (1500 frames
for 30 s of audio at 50 Hz after the conv stride-2).  [arXiv:2212.04356]
"""

from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                      # decoder layers
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    attention="gqa",
    activation="gelu",
    frontend="audio_stub",
    n_frontend_tokens=1500,
    encoder=EncoderConfig(n_layers=4, d_model=384, n_heads=6, d_ff=1536,
                          n_frames=1500),
    source="arXiv:2212.04356",
)

REDUCED = ModelConfig(
    name="whisper-reduced",
    family="audio",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    attention="gqa",
    activation="gelu",
    frontend="audio_stub",
    n_frontend_tokens=64,
    encoder=EncoderConfig(n_layers=2, d_model=128, n_heads=4, d_ff=256,
                          n_frames=64),
    source="arXiv:2212.04356 (reduced)",
)
