"""Automatic analyzer (paper §III-A offline stage, §III-B).

Consumes model hyperparameters + cluster spec (+ workload), enumerates the
strategy grammar, scores every feasible candidate with the theoretical cost
model, applies the Eq. 8 memory constraint, and returns the ranked list.

This is the "offline stage" of MixServe: its output feeds the partitioner
(weight sharding specs) and the launcher (mesh/axis layout).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal, Optional

from repro.configs.base import ModelConfig
from repro.core import cost_model as cm
from repro.core.strategy import enumerate_strategies
from repro.core.topology import ClusterSpec

Objective = Literal["ttft", "itl", "throughput", "balanced"]


@dataclasses.dataclass(frozen=True)
class Candidate:
    strategy: cm.Strategy
    ind: cm.Indicators
    mem_bytes: float
    feasible: bool

    def score(self, objective: Objective) -> float:
        """Lower is better.  Saturated-but-feasible candidates compete on
        their saturation indicators (stable=False only zeroes W_q)."""
        if not self.feasible:
            return math.inf
        if objective == "ttft":
            return self.ind.ttft
        if objective == "itl":
            return self.ind.itl
        if objective == "throughput":
            return -self.ind.throughput
        # balanced: normalized geometric blend, the default serving objective
        return self.ind.ttft * self.ind.itl / max(self.ind.throughput, 1e-9)


@dataclasses.dataclass(frozen=True)
class AnalyzerReport:
    best: Candidate
    ranked: tuple[Candidate, ...]
    objective: Objective

    def describe(self, top: int = 5) -> str:
        lines = [f"objective={self.objective}  candidates={len(self.ranked)}"]
        for c in self.ranked[:top]:
            lines.append(
                f"  {c.strategy.describe():<44s} ttft={c.ind.ttft*1e3:8.2f}ms "
                f"itl={c.ind.itl*1e3:7.2f}ms thr={c.ind.throughput:9.1f}tok/s "
                f"mem={c.mem_bytes/1e9:6.1f}GB {'OK' if c.feasible else 'OOM'}")
        return "\n".join(lines)


def evaluate(model: ModelConfig, strat: cm.Strategy, cluster: ClusterSpec, *,
             batch: int, l_in: int, l_out: int,
             arrival_rate: float = 0.0,
             ep_overlap: Optional[cm.EpOverlap] = None) -> Candidate:
    ind = cm.indicators(model, strat, cluster, batch=batch, l_in=l_in,
                        l_out=l_out, arrival_rate=arrival_rate,
                        ep_overlap=ep_overlap)
    mem = cm.memory_per_device(model, strat, batch=batch, seq_len=l_in + l_out)
    # MoE experts must be integrally assignable to EP ranks.
    feasible = mem < cluster.hbm_bytes
    if model.is_moe and strat.moe_ep > model.n_experts:
        feasible = False
    if model.n_heads and strat.attn_tp > max(model.n_heads, 1):
        feasible = False
    if strat.attn_dp > batch:      # DP ranks beyond in-flight requests idle
        feasible = False
    return Candidate(strategy=strat, ind=ind, mem_bytes=mem, feasible=feasible)


def select(model: ModelConfig, cluster: ClusterSpec, *,
           batch: int = 16, l_in: int = 1024, l_out: int = 256,
           arrival_rate: float = 0.0, objective: Objective = "balanced",
           max_pp: int = 8,
           comm_algos: tuple[cm.CommAlgo, ...] = ("fused", "unfused"),
           ep_overlap: Optional[cm.EpOverlap] = None,
           ) -> AnalyzerReport:
    """The automatic analyzer: enumerate, score, rank, pick.

    ``ep_overlap``: price every candidate's MoE exchange with the
    micro-chunked pipeline estimate (stops penalizing EP degrees whose
    A2A the overlapped schedule can hide behind expert compute).
    """
    cands = []
    for strat in enumerate_strategies(cluster, model_is_moe=model.is_moe,
                                      max_pp=max_pp, comm_algos=comm_algos):
        cands.append(evaluate(model, strat, cluster, batch=batch, l_in=l_in,
                              l_out=l_out, arrival_rate=arrival_rate,
                              ep_overlap=ep_overlap))
    if not cands:
        raise RuntimeError("strategy grammar produced no candidates")
    ranked = tuple(sorted(cands, key=lambda c: c.score(objective)))
    return AnalyzerReport(best=ranked[0], ranked=ranked, objective=objective)


def select_strategy(model: ModelConfig, cluster: ClusterSpec,
                    **kw) -> cm.Strategy:
    return select(model, cluster, **kw).best.strategy


__all__ = ["Candidate", "AnalyzerReport", "evaluate", "select",
           "select_strategy", "Objective"]
