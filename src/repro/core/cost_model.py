"""Theoretical communication / computation cost model (paper §III-B).

Implements Eqs. (1)-(13):

  Eq. 1-2   AR(size, d) = RS + AG, each moving O(size/d) per round
  Eq. 3     A2A(size, d) = (size/d) * (d-1) rounds  (Pairwise)
  Eq. 4     compute latency  tau ∝ activated-params * tokens / chips
  Eq. 5     per-layer comm latency lambda with the DP/EP trade-off
  Eq. 6     Delta t_svc = l*(tau + lambda) + (d_PP - 1) * P2P
  Eq. 7     M/M/1 queuing delay W_q
  Eq. 8     memory constraint (weights + KV cache < HBM)
  Eq. 9-11  TTFT / ITL / throughput estimators
  Eq. 12    lambda_EP   (pure-EP MoE block; DeepSeek-V3 deployment)
  Eq. 13    lambda_mix  (MixServe hybrid TP-EP, RS-A2A-AG)

All sizes are bytes, all times seconds.  The model is intentionally
alpha-beta style: ``time = latency_rounds * alpha + bytes_on_wire / bw``.

Divergence from the paper (documented in DESIGN.md §2): Eq. 4 is stated as a
proportionality; we instantiate it with the standard 2*N_active*D FLOP count
plus the attention O(s^2) term, which preserves the paper's scaling in
(d_TP, d_EP, d_DP) while giving absolute seconds for the roofline.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

from repro.configs.base import ModelConfig
from repro.core.topology import ClusterSpec

BYTES = 2  # bf16 activations/weights on the wire


# ---------------------------------------------------------------------------
# Collective primitives (Eqs. 1-3, Table I)
# ---------------------------------------------------------------------------

def rs_cost(size: float, degree: int, bw: float, alpha: float) -> float:
    """Reduce-scatter of a ``size``-byte tensor over ``degree`` ranks.

    Table I: communication per round O(size/d), Broadcast algorithm, one
    full-duplex round per peer -> (d-1) rounds of size/d on the wire.
    """
    if degree <= 1:
        return 0.0
    return alpha * (degree - 1) + (size / degree) * (degree - 1) / bw


def ag_cost(size: float, degree: int, bw: float, alpha: float) -> float:
    """All-gather; symmetric to RS (Eq. 1: RS == AG)."""
    return rs_cost(size, degree, bw, alpha)


def ar_cost(size: float, degree: int, bw: float, alpha: float) -> float:
    """All-reduce decomposed as RS + AG (Eq. 2)."""
    return rs_cost(size, degree, bw, alpha) + ag_cost(size, degree, bw, alpha)


def a2a_cost(size: float, degree: int, bw: float, alpha: float) -> float:
    """Pairwise all-to-all (Eq. 3): d-1 rounds, size/d bytes per round.

    ``size`` is the full per-rank payload (what one rank holds before the
    exchange); each of the d-1 remote peers receives size/d of it.
    """
    if degree <= 1:
        return 0.0
    return alpha * (degree - 1) + (size / degree) * (degree - 1) / bw


def p2p_cost(size: float, bw: float, alpha: float) -> float:
    """Point-to-point transfer (PP stage handoff, Eq. 6)."""
    return alpha + size / bw


def tp_link(cluster: ClusterSpec, degree: int) -> tuple[float, float]:
    """(bw, alpha) for a TP collective of the given degree.

    A TP group wider than one node is bottlenecked by the inter-node links —
    this is the Fig. 3 observation that AR-based TP 'generally fails to scale
    effectively across multiple nodes' (TP worse than EP at d=32).
    """
    inter = degree > cluster.n_proc
    return cluster.bw(inter), cluster.latency(inter)


# ---------------------------------------------------------------------------
# Parallel strategy
# ---------------------------------------------------------------------------

# "fused"   = RS-A2A-AG with async intra/inter overlap (the paper's Alg. 1-2)
# "sync"    = RS-A2A-AG executed back-to-back (Fig. 12 sync ablation)
# "unfused" = AR at full width, then full-volume A2A (Tutel-style baseline)
CommAlgo = Literal["fused", "sync", "unfused"]


@dataclasses.dataclass(frozen=True)
class Strategy:
    """A per-Decoder-layer parallel strategy (paper §III-B1 grammar).

    grammar:  strategy -> Decoder [| PP=degree]
              Decoder  -> Attention, MoE
              block    -> intra-node + inter-node | parallel
              parallel -> TP | EP (DP) = degree   (degree = 2^k)

    ``attn_tp``/``attn_dp``: attention block TP (intra) x DP (inter) degrees.
    ``moe_tp``/``moe_ep``:   MoE block TP (intra) x EP (inter) degrees.
    Pure strategies are expressed by setting the other degree to 1 (e.g. pure
    EP is moe_tp=1, moe_ep=n_devices).
    """

    attn_tp: int = 1
    attn_dp: int = 1
    moe_tp: int = 1
    moe_ep: int = 1
    d_pp: int = 1
    comm_algo: CommAlgo = "fused"
    # True when the moe_ep groups span nodes (the usual case we optimize).
    ep_inter_node: bool = True

    @property
    def devices_per_pp_stage(self) -> int:
        return self.attn_tp * self.attn_dp

    @property
    def n_devices(self) -> int:
        return self.devices_per_pp_stage * self.d_pp

    def validate(self) -> None:
        if self.attn_tp * self.attn_dp != self.moe_tp * self.moe_ep:
            raise ValueError(
                f"attention degrees ({self.attn_tp}x{self.attn_dp}) and MoE "
                f"degrees ({self.moe_tp}x{self.moe_ep}) must cover the same "
                "device set within a PP stage")
        for d in (self.attn_tp, self.attn_dp, self.moe_tp, self.moe_ep, self.d_pp):
            if d < 1 or (d & (d - 1)):
                raise ValueError(f"degrees must be powers of two, got {self}")

    def describe(self) -> str:
        parts = [f"TP={self.attn_tp} + DP={self.attn_dp}"]
        if self.moe_tp > 1:
            parts.append(f"TP={self.moe_tp} + EP={self.moe_ep}")
        else:
            parts.append(f"EP={self.moe_ep}")
        s = ", ".join(parts)
        if self.d_pp > 1:
            s += f" [PP={self.d_pp}]"
        return s + f" ({self.comm_algo})"


# ---------------------------------------------------------------------------
# EP-exchange overlap (micro-chunked dispatch/GEMM/combine pipeline)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EpOverlap:
    """Micro-chunked EP-exchange schedule for the dropless MoE path.

    The local token batch is split into ``chunks`` micro-chunks; chunk i's
    dispatch A2A is issued before chunk i-1's grouped GEMM so XLA's async
    scheduler can overlap wire and MXU time, and each chunk's exchange
    buffers are **count-bounded**: a soft per-rank row cap instead of the
    worst-case ``T_chunk*k`` extent, with a bit-exact recompute-at-worst-case
    fallback when a chunk overflows the cap (models.moe).

    ``cap_rows``: per-rank rows per chunk.  ``0`` = the statistical rule
    (mean + ``cap_sigma``·std of uniform multinomial routing, rounded up to
    a multiple of 8); ``-1`` = worst-case extent (count-bounding off);
    ``> 0`` = an explicit row cap.
    """

    chunks: int = 1            # C; 1 = monolithic schedule
    cap_rows: int = 0          # 0 = auto rule, -1 = worst case, >0 explicit
    cap_sigma: float = 4.0     # auto-rule margin over the routing mean

    def __post_init__(self):
        if self.chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")
        if self.cap_rows < -1:
            raise ValueError(f"cap_rows must be >= -1, got {self.cap_rows}")
        if self.cap_sigma <= 0:
            raise ValueError(f"cap_sigma must be > 0, got {self.cap_sigma}")

    @property
    def off(self) -> bool:
        """True for the monolithic worst-case-extent schedule."""
        return self.chunks <= 1 and self.cap_rows == -1

    def describe(self) -> str:
        if self.off:
            return "off (monolithic worst-case exchange)"
        if self.cap_rows == -1:
            cap = "cap=worst-case"
        elif self.cap_rows > 0:
            cap = f"cap={self.cap_rows} rows/rank"
        else:
            cap = f"cap=auto(mean+{self.cap_sigma:g}sigma)"
        return f"C={self.chunks}, {cap}"


# the monolithic schedule (what the pre-overlap exchange always ran)
EP_OVERLAP_OFF = EpOverlap(chunks=1, cap_rows=-1)


def cap_rows_for(n_chunk: int, ep: int, overlap: EpOverlap) -> int:
    """Static per-destination-rank row cap for one micro-chunk.

    ``n_chunk`` is the chunk's slot count (tokens * top_k).  The auto rule
    prices the cap from the routing distribution: near-uniform multinomial
    routing puts mean = n/ep rows on a rank with std sqrt(n/ep * (1-1/ep));
    ``cap_sigma`` standard deviations of headroom make overflow (and the
    worst-case-extent fallback recompute) rare without paying worst case.
    """
    if ep <= 1 or overlap.cap_rows == -1:
        return n_chunk
    if overlap.cap_rows > 0:
        return max(1, min(overlap.cap_rows, n_chunk))
    mean = n_chunk / ep
    cap = mean + overlap.cap_sigma * math.sqrt(mean * (1.0 - 1.0 / ep))
    cap = -(-int(math.ceil(cap)) // 8) * 8          # round up to 8 rows
    return max(1, min(cap, n_chunk))


# ---------------------------------------------------------------------------
# Workload
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Workload:
    batch: int          # global batch (requests in flight)
    seq_len: int        # tokens processed this step per request (prefill: L_in, decode: 1)
    kv_len: int = 0     # KV cache length (decode); 0 -> seq_len
    arrival_rate: float = 0.0  # requests/s for W_q (Eq. 7)

    @property
    def context(self) -> int:
        return self.kv_len or self.seq_len


# ---------------------------------------------------------------------------
# Eq. 4: computation latency per rank
# ---------------------------------------------------------------------------

MFU = 0.5  # assumed achievable fraction of peak in steady state
GEMM_RAMP_TOKENS = 64  # per-expert batch at which expert GEMMs hit 50% eff


def compute_latency(model: ModelConfig, strat: Strategy, work: Workload,
                    cluster: ClusterSpec) -> float:
    """tau(d_TP, d_EP, d_DP): per-rank per-layer compute latency (Eq. 4).

    Matmul FLOPs = 2 * params_active_per_layer * tokens_per_rank, sharded by
    d_TP (attention+shared) and d_TP*d_EP (routed experts); plus the
    attention score/value FLOPs 4 * tokens * ctx * n_heads * head_dim / d_TP.
    """
    tokens_per_rank = work.batch * work.seq_len / strat.attn_dp

    attn_p = model.attn_params_per_layer()
    attn_flops = 2 * attn_p * tokens_per_rank / strat.attn_tp
    if model.attention != "none":
        attn_flops += (4 * tokens_per_rank * work.context
                       * model.n_heads * model.head_dim / strat.attn_tp)

    if model.is_moe:
        # Routed expert FLOPs per chip (Eq. 4): balanced routing spreads the
        # global token*top_k work over ALL chips of the stage regardless of
        # how (moe_tp, moe_ep, replica groups) tile them — replication under
        # d_DP > d_EP copies WEIGHTS, not work; dropping under d_DP < d_EP
        # removes the redundant copies before compute (Fig. 6c).
        global_tokens = work.batch * work.seq_len
        n_stage = strat.attn_tp * strat.attn_dp
        ffn_flops = 2 * model.expert_params() * model.top_k * global_tokens \
            / n_stage
        shared = 2 * model.n_shared_experts * model.expert_params() * tokens_per_rank
        ffn_flops += shared / strat.moe_tp
        # Expert-GEMM efficiency ramps with per-expert-instance batch (the
        # DeepSeek-V3 argument for wide EP: "each expert processes a
        # sufficiently large batch").  Replicating experts (d_DP > d_EP)
        # halves per-instance tokens and costs efficiency.
        instances = max(1, n_stage // (strat.moe_ep * strat.moe_tp))
        tok_per_expert = global_tokens * model.top_k / (
            max(model.n_experts, 1) * instances)
        gemm_eff = tok_per_expert / (tok_per_expert + GEMM_RAMP_TOKENS)
        ffn_flops = ffn_flops / max(gemm_eff, 1e-2)
    else:
        ffn_flops = 2 * model.dense_ffn_params_per_layer() * tokens_per_rank / strat.attn_tp

    t_flops = (attn_flops + ffn_flops) / (cluster.peak_flops * MFU)

    # ---- memory-bound term (dominates decode): weight + KV-cache reads ----
    w_bytes = model.attn_params_per_layer() / strat.attn_tp * BYTES
    if model.is_moe:
        global_tokens = work.batch * work.seq_len
        local_experts = max(1, model.n_experts // strat.moe_ep)
        touched = min(local_experts,
                      max(1.0, global_tokens * model.top_k
                          / (strat.attn_dp * strat.moe_ep)))
        w_bytes += (touched + model.n_shared_experts) \
            * model.expert_params() / strat.moe_tp * BYTES
    else:
        w_bytes += model.dense_ffn_params_per_layer() / strat.attn_tp * BYTES
    kv_bytes = (work.batch / strat.attn_dp) * work.context \
        * model.kv_bytes_per_token_per_layer()
    t_mem = (w_bytes + kv_bytes) / cluster.hbm_bw

    return max(t_flops, t_mem)


# ---------------------------------------------------------------------------
# Eq. 5 / 12 / 13: communication latency per rank per layer
# ---------------------------------------------------------------------------

def _moe_lambda_hybrid(model: ModelConfig, strat: Strategy, work: Workload,
                       cluster: ClusterSpec) -> float:
    """MoE-block comm under hybrid TP-EP (Eq. 13), fused or unfused.

    unfused:  AR(bsh, tp)  then  2 x A2A(bshk, ep)   at FULL hidden width
    fused:    RS-A2A-AG — the A2A operates on hidden states already sharded
              1/tp, so inter-node volume drops by 1/tp (Eq. 13); with
              ``comm_algo == 'fused'`` intra- and inter-node rounds overlap
              (Fig. 9) so the wall time is max(intra, inter) + epilogue.
    """
    bw_intra, a_intra = tp_link(cluster, strat.moe_tp)
    inter = strat.ep_inter_node
    bw_ep = cluster.bw(inter)
    a_ep = cluster.latency(inter)
    # d_DP > d_EP: the dDP/dEP parallel A2A groups (Fig. 6b) CONTEND for the
    # same inter-node links — per-group bandwidth divides accordingly.
    if inter:
        n_groups = max(1, strat.attn_dp // max(strat.moe_ep, 1))
        bw_ep = bw_ep / n_groups

    # Intra-node fabric contention: moe_tp < n_proc means several MoE TP
    # groups share one node's NVLink/HCCS fabric.
    if strat.moe_tp < cluster.n_proc:
        bw_intra = bw_intra * strat.moe_tp / cluster.n_proc

    # Fig. 6c: d_DP < d_EP drops the redundant hidden-state copies — the A2A
    # carries b/d_EP tokens over d_DP-device groups (Eq. 5 else-branch).
    tokens = work.batch * work.seq_len / max(strat.attn_dp, strat.moe_ep)
    ep_degree = min(strat.moe_ep, strat.attn_dp) if strat.attn_dp > 1 \
        else strat.moe_ep
    size = tokens * model.d_model * BYTES          # hidden states per DP group
    k = max(1, model.top_k)

    if strat.moe_ep <= 1:
        # pure TP MoE block: just the AR (Eq. 12 degenerate)
        return ar_cost(size, strat.moe_tp, bw_intra, a_intra)

    if strat.moe_tp <= 1:
        # pure EP (vLLM DP+EP): "EP is essentially equivalent to DP among the
        # experts" — every device is its own token group, so the A2A runs at
        # degree d_EP on bs/d_EP tokens per rank (no Fig. 6c dropping).
        tok_ep = work.batch * work.seq_len / strat.moe_ep
        size_ep = tok_ep * model.d_model * BYTES
        return 2 * a2a_cost(size_ep * k, strat.moe_ep, bw_ep, a_ep)

    if strat.comm_algo == "unfused":
        # Tutel-style: synchronize TP at full width first, then full-volume
        # A2A across the EP group (Eq. 12's structure inside a TP-EP layout).
        return (ar_cost(size, strat.moe_tp, bw_intra, a_intra)
                + 2 * a2a_cost(size * k, ep_degree, bw_ep, a_ep))

    # ---- fused RS-A2A-AG (Eq. 13) ----
    # Hidden states ride the inter-node wire 1/tp-sharded, so the A2A volume
    # drops by 1/moe_tp relative to Eq. 12.
    a2a_sharded = a2a_cost(size * k / strat.moe_tp, ep_degree, bw_ep, a_ep)
    # dispatch epilogue: AG the received 1/tp-wide token shards back to full
    # width inside the node (Alg. 2); combine prologue: RS the partial expert
    # outputs (Alg. 1); combine epilogue: AG the weighted sum.
    ag_disp = ag_cost(size * k, strat.moe_tp, bw_intra, a_intra)
    rs_comb = rs_cost(size * k, strat.moe_tp, bw_intra, a_intra)
    ag_comb = ag_cost(size, strat.moe_tp, bw_intra, a_intra)
    if strat.comm_algo == "fused":
        # Fig. 9: pairwise inter-node rounds overlap the intra-node RS/AG
        # rounds; wall time ~ max(inter, intra) per phase + epilogue.
        dispatch = max(a2a_sharded, ag_disp)
        combine = max(a2a_sharded, rs_comb) + ag_comb
    else:
        dispatch = a2a_sharded + ag_disp
        combine = rs_comb + a2a_sharded + ag_comb
    return dispatch + combine


def _routed_expert_seconds(model: ModelConfig, strat: Strategy,
                           work: Workload, cluster: ClusterSpec) -> float:
    """Per-rank routed-expert GEMM seconds (the Eq. 4 routed term alone).

    This is the compute the micro-chunked pipeline can overlap against the
    EP exchange: same sharding/efficiency model as ``compute_latency``'s
    MoE branch, restricted to the routed experts (shared experts and
    attention run outside the dispatch-FFN-combine window).
    """
    if not model.is_moe or model.top_k < 1:
        return 0.0
    global_tokens = work.batch * work.seq_len
    n_stage = strat.attn_tp * strat.attn_dp
    ffn_flops = 2 * model.expert_params() * model.top_k * global_tokens \
        / n_stage
    instances = max(1, n_stage // (strat.moe_ep * strat.moe_tp))
    tok_per_expert = global_tokens * model.top_k / (
        max(model.n_experts, 1) * instances)
    gemm_eff = tok_per_expert / (tok_per_expert + GEMM_RAMP_TOKENS)
    return ffn_flops / max(gemm_eff, 1e-2) / (cluster.peak_flops * MFU)


def moe_overlap_lambda(lam_moe: float, tau_expert: float, overlap: EpOverlap,
                       chunk_alpha: float = 0.0) -> float:
    """Visible MoE comm under the micro-chunked pipeline.

    With C micro-chunks, chunk i's dispatch A2A runs concurrently with
    chunk i-1's grouped GEMM and chunk i-2's combine A2A, so the pipelined
    makespan is max(lam, tau) + (lam + tau)/C for the fill/drain chunks
    instead of lam + tau.  Expressed as an *effective comm* term (so
    ``service_latency`` keeps adding tau separately):

        lam_eff = lam - (1 - 1/C) * min(lam, tau) + (C - 1) * chunk_alpha

    C=1 reproduces the serial sum; comm-bound (lam > tau) hides tau of
    wire time behind compute; compute-bound hides (almost) all of lam.
    ``chunk_alpha`` is the fixed launch latency each extra chunk's pair of
    A2As pays (the Eq. 3 alpha rounds do not shrink with payload) — it is
    what bounds the useful chunk count from above.
    """
    C = overlap.chunks
    if C <= 1:
        return lam_moe
    return (lam_moe - (1.0 - 1.0 / C) * min(lam_moe, tau_expert)
            + (C - 1) * chunk_alpha)


def comm_latency(model: ModelConfig, strat: Strategy, work: Workload,
                 cluster: ClusterSpec, *,
                 ep_overlap: EpOverlap | None = None) -> float:
    """lambda(d_TP, d_EP, d_DP): per-rank per-layer comm latency (Eq. 5).

    ``ep_overlap``: price the micro-chunked dispatch/GEMM/combine pipeline
    (models.moe) instead of the serial sum-of-phases exchange.
    """
    bw_intra, a_intra = tp_link(cluster, strat.attn_tp)
    # fabric contention: attn_tp < n_proc -> several attention TP groups
    # share one node's NVLink/HCCS fabric
    if 1 < strat.attn_tp < cluster.n_proc:
        bw_intra = bw_intra * strat.attn_tp / cluster.n_proc

    tokens = work.batch * work.seq_len / strat.attn_dp
    size = tokens * model.d_model * BYTES

    # Attention block TP: 2 ARs per layer (attn out + [dense] ffn out share
    # the residual stream; Eq. 5 counts 2 x AR).
    lam = 2 * ar_cost(size, strat.attn_tp, bw_intra, a_intra) \
        if strat.attn_tp > 1 else 0.0

    if model.is_moe:
        lam_moe = _moe_lambda_hybrid(model, strat, work, cluster)
        if (ep_overlap is not None and ep_overlap.chunks > 1
                and strat.moe_ep > 1):
            tau_e = _routed_expert_seconds(model, strat, work, cluster)
            # each extra chunk pays the payload-independent alpha rounds of
            # its own dispatch + combine A2A (pairwise: ep_degree - 1 rounds)
            a_ep = cluster.latency(strat.ep_inter_node)
            ep_degree = min(strat.moe_ep, strat.attn_dp) \
                if strat.attn_dp > 1 else strat.moe_ep
            chunk_alpha = 2 * a_ep * max(ep_degree - 1, 1)
            lam_moe = moe_overlap_lambda(lam_moe, tau_e, ep_overlap,
                                         chunk_alpha)
        lam += lam_moe
        if strat.attn_tp != strat.moe_tp and strat.moe_tp > 1:
            # layout resync between the attention TP group and the MoE TP
            # group (hidden states re-gathered on entry + exit)
            lam += 2 * ag_cost(size, max(strat.attn_tp, strat.moe_tp),
                               cluster.intra_node_bw, a_intra)
    # dense models: the second AR above already covers the FFN TP sync.
    return lam


def lambda_pure_ep(model: ModelConfig, strat: Strategy, work: Workload,
                   cluster: ClusterSpec) -> float:
    """Eq. 12: DeepSeek-V3-style deployment, MoE block fully EP."""
    tokens = work.batch * work.seq_len / strat.attn_dp
    size = tokens * model.d_model * BYTES
    k = max(1, model.top_k)
    return (ar_cost(size, strat.attn_tp, cluster.intra_node_bw,
                    cluster.intra_node_latency)
            + 2 * a2a_cost(size * k, strat.moe_ep,
                           cluster.bw(True), cluster.latency(True)))


# ---------------------------------------------------------------------------
# Eqs. 6-11: service latency, queuing, indicators
# ---------------------------------------------------------------------------

def service_latency(model: ModelConfig, strat: Strategy, work: Workload,
                    cluster: ClusterSpec, *,
                    ep_overlap: EpOverlap | None = None) -> float:
    """Delta t_svc (Eq. 6)."""
    tau = compute_latency(model, strat, work, cluster)
    lam = comm_latency(model, strat, work, cluster, ep_overlap=ep_overlap)
    t = model.n_layers * (tau + lam)
    if strat.d_pp > 1:
        tokens = work.batch * work.seq_len / strat.attn_dp
        size = tokens * model.d_model * BYTES
        t += (strat.d_pp - 1) * p2p_cost(size, cluster.bw(True),
                                         cluster.latency(True))
    return t


def queuing_delay(service_time: float, arrival_rate: float) -> float:
    """M/M/1 W_q (Eq. 7).  Returns inf when unstable (rho >= 1)."""
    if arrival_rate <= 0:
        return 0.0
    mu = 1.0 / service_time
    if arrival_rate >= mu:
        return math.inf
    return arrival_rate / (mu * (mu - arrival_rate))


@dataclasses.dataclass(frozen=True)
class Indicators:
    ttft: float
    itl: float
    throughput: float  # tokens/s (Eq. 11)
    w_q: float
    stable: bool


def indicators(model: ModelConfig, strat: Strategy, cluster: ClusterSpec, *,
               batch: int, l_in: int, l_out: int,
               arrival_rate: float = 0.0,
               ep_overlap: EpOverlap | None = None) -> Indicators:
    """TTFT (Eq. 9), ITL (Eq. 10), throughput Theta (Eq. 11).

    The M/M/1 service rate is batch-level: one continuous-batching "wave"
    serves ``batch`` requests in (prefill + l_out decode steps).  When the
    arrival rate exceeds that (rho >= 1) the system runs SATURATED — we then
    report W_q = 0 and the saturation throughput (what a loadgen measures on
    an overdriven server, which is how Fig. 10's throughput is collected),
    flagging ``stable=False``.
    """
    prf = service_latency(model, strat,
                          Workload(batch=batch, seq_len=l_in), cluster,
                          ep_overlap=ep_overlap)
    dec = service_latency(model, strat,
                          Workload(batch=batch, seq_len=1, kv_len=l_in + l_out),
                          cluster, ep_overlap=ep_overlap)
    t_request = (prf + l_out * dec) / max(batch, 1)
    w_q = queuing_delay(t_request, arrival_rate)
    stable = math.isfinite(w_q)
    if not stable:
        w_q = 0.0
    ttft = w_q + prf
    denom = w_q + prf + l_out * dec
    thr = batch * (l_in + l_out) / denom
    return Indicators(ttft=ttft, itl=dec, throughput=thr, w_q=w_q,
                      stable=stable)


# ---------------------------------------------------------------------------
# Speculative decoding: expected committed tokens per verify step
# ---------------------------------------------------------------------------

def spec_tokens_per_step(k: int, accept: float) -> float:
    """E[committed tokens] of one k-draft greedy-verify step.

    Acceptance is modeled i.i.d. per draft position with rate ``accept``:
    the step commits 1 + X tokens where X ~ min(Geometric misses, k), so
    E = sum_{j=0..k} accept^j = (1 - accept^(k+1)) / (1 - accept).  k=0 is
    plain decode (exactly 1 token); accept=1 commits all k+1 rows."""
    if k <= 0:
        return 1.0
    a = min(max(accept, 0.0), 1.0)
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


def speculation_itl(t_verify: float, t_draft: float, k: int,
                    accept: float) -> float:
    """Effective inter-token latency of speculative decode: one verify step
    (Eq. 4-6 at seq_len = 1+k) plus k draft proposals, amortized over the
    expected committed tokens.  With k=0 this is just ``t_verify``."""
    return (t_verify + max(k, 0) * t_draft) / spec_tokens_per_step(k, accept)


# ---------------------------------------------------------------------------
# Eq. 8: memory constraint
# ---------------------------------------------------------------------------

def memory_per_device(model: ModelConfig, strat: Strategy, *,
                      batch: int, seq_len: int,
                      bytes_per_el: int = BYTES) -> float:
    """LHS of Eq. 8: weights + KV cache bytes on one chip."""
    attn_total = model.n_layers * model.attn_params_per_layer()
    n_moe_layers = (model.n_layers - model.first_dense_layers) if model.is_moe else 0
    n_dense = model.n_layers - n_moe_layers
    dense_total = n_dense * model.dense_ffn_params_per_layer()
    moe_routed = n_moe_layers * model.n_experts * model.expert_params()
    moe_shared = n_moe_layers * (model.n_shared_experts * model.expert_params()
                                 + model.d_model * model.n_experts)
    embed = model.d_model * model.vocab_size * (1 if model.tie_embeddings else 2)

    w = (attn_total + dense_total + moe_shared) / strat.attn_tp
    w += moe_routed / (strat.moe_ep * strat.moe_tp)
    w += embed / strat.attn_tp
    w /= strat.d_pp

    kv = (batch / strat.attn_dp) * seq_len * model.n_layers \
        * model.kv_bytes_per_token_per_layer(bytes_per_el) / strat.d_pp
    # MQA/GQA KV is replicated when n_kv_heads < attn_tp; approximate by not
    # dividing KV by TP (conservative — vLLM does the same for MQA).
    return (w * bytes_per_el) + kv


def fits_memory(model: ModelConfig, strat: Strategy, cluster: ClusterSpec, *,
                batch: int, seq_len: int) -> bool:
    return memory_per_device(model, strat, batch=batch, seq_len=seq_len) \
        < cluster.hbm_bytes


__all__ = [
    "BYTES", "MFU", "Strategy", "Workload", "Indicators",
    "EpOverlap", "EP_OVERLAP_OFF", "cap_rows_for", "moe_overlap_lambda",
    "rs_cost", "ag_cost", "ar_cost", "a2a_cost", "p2p_cost",
    "compute_latency", "comm_latency", "lambda_pure_ep",
    "service_latency", "queuing_delay", "indicators",
    "memory_per_device", "fits_memory",
    "spec_tokens_per_step", "speculation_itl",
]
