"""Theoretical communication / computation cost model (paper §III-B).

Implements Eqs. (1)-(13):

  Eq. 1-2   AR(size, d) = RS + AG, each moving O(size/d) per round
  Eq. 3     A2A(size, d) = (size/d) * (d-1) rounds  (Pairwise)
  Eq. 4     compute latency  tau ∝ activated-params * tokens / chips
  Eq. 5     per-layer comm latency lambda with the DP/EP trade-off
  Eq. 6     Delta t_svc = l*(tau + lambda) + (d_PP - 1) * P2P
  Eq. 7     M/M/1 queuing delay W_q
  Eq. 8     memory constraint (weights + KV cache < HBM)
  Eq. 9-11  TTFT / ITL / throughput estimators
  Eq. 12    lambda_EP   (pure-EP MoE block; DeepSeek-V3 deployment)
  Eq. 13    lambda_mix  (MixServe hybrid TP-EP, RS-A2A-AG)

All sizes are bytes, all times seconds.  The model is intentionally
alpha-beta style: ``time = latency_rounds * alpha + bytes_on_wire / bw``.

Divergence from the paper (documented in DESIGN.md §2): Eq. 4 is stated as a
proportionality; we instantiate it with the standard 2*N_active*D FLOP count
plus the attention O(s^2) term, which preserves the paper's scaling in
(d_TP, d_EP, d_DP) while giving absolute seconds for the roofline.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

from repro.configs.base import ModelConfig
from repro.core.topology import ClusterSpec

BYTES = 2  # bf16 activations/weights on the wire


# ---------------------------------------------------------------------------
# Collective primitives (Eqs. 1-3, Table I)
# ---------------------------------------------------------------------------

def rs_cost(size: float, degree: int, bw: float, alpha: float) -> float:
    """Reduce-scatter of a ``size``-byte tensor over ``degree`` ranks.

    Table I: communication per round O(size/d), Broadcast algorithm, one
    full-duplex round per peer -> (d-1) rounds of size/d on the wire.
    """
    if degree <= 1:
        return 0.0
    return alpha * (degree - 1) + (size / degree) * (degree - 1) / bw


def ag_cost(size: float, degree: int, bw: float, alpha: float) -> float:
    """All-gather; symmetric to RS (Eq. 1: RS == AG)."""
    return rs_cost(size, degree, bw, alpha)


def ar_cost(size: float, degree: int, bw: float, alpha: float) -> float:
    """All-reduce decomposed as RS + AG (Eq. 2)."""
    return rs_cost(size, degree, bw, alpha) + ag_cost(size, degree, bw, alpha)


def a2a_cost(size: float, degree: int, bw: float, alpha: float) -> float:
    """Pairwise all-to-all (Eq. 3): d-1 rounds, size/d bytes per round.

    ``size`` is the full per-rank payload (what one rank holds before the
    exchange); each of the d-1 remote peers receives size/d of it.
    """
    if degree <= 1:
        return 0.0
    return alpha * (degree - 1) + (size / degree) * (degree - 1) / bw


def p2p_cost(size: float, bw: float, alpha: float) -> float:
    """Point-to-point transfer (PP stage handoff, Eq. 6)."""
    return alpha + size / bw


def tp_link(cluster: ClusterSpec, degree: int) -> tuple[float, float]:
    """(bw, alpha) for a TP collective of the given degree.

    A TP group wider than one node is bottlenecked by the inter-node links —
    this is the Fig. 3 observation that AR-based TP 'generally fails to scale
    effectively across multiple nodes' (TP worse than EP at d=32).
    """
    inter = degree > cluster.n_proc
    return cluster.bw(inter), cluster.latency(inter)


# ---------------------------------------------------------------------------
# Parallel strategy
# ---------------------------------------------------------------------------

# "fused"   = RS-A2A-AG with async intra/inter overlap (the paper's Alg. 1-2)
# "sync"    = RS-A2A-AG executed back-to-back (Fig. 12 sync ablation)
# "unfused" = AR at full width, then full-volume A2A (Tutel-style baseline)
CommAlgo = Literal["fused", "sync", "unfused"]


@dataclasses.dataclass(frozen=True)
class Strategy:
    """A per-Decoder-layer parallel strategy (paper §III-B1 grammar).

    grammar:  strategy -> Decoder [| PP=degree]
              Decoder  -> Attention, MoE
              block    -> intra-node + inter-node | parallel
              parallel -> TP | EP (DP) = degree   (degree = 2^k)

    ``attn_tp``/``attn_dp``: attention block TP (intra) x DP (inter) degrees.
    ``moe_tp``/``moe_ep``:   MoE block TP (intra) x EP (inter) degrees.
    Pure strategies are expressed by setting the other degree to 1 (e.g. pure
    EP is moe_tp=1, moe_ep=n_devices).
    """

    attn_tp: int = 1
    attn_dp: int = 1
    moe_tp: int = 1
    moe_ep: int = 1
    d_pp: int = 1
    comm_algo: CommAlgo = "fused"
    # True when the moe_ep groups span nodes (the usual case we optimize).
    ep_inter_node: bool = True

    @property
    def devices_per_pp_stage(self) -> int:
        return self.attn_tp * self.attn_dp

    @property
    def n_devices(self) -> int:
        return self.devices_per_pp_stage * self.d_pp

    def validate(self) -> None:
        if self.attn_tp * self.attn_dp != self.moe_tp * self.moe_ep:
            raise ValueError(
                f"attention degrees ({self.attn_tp}x{self.attn_dp}) and MoE "
                f"degrees ({self.moe_tp}x{self.moe_ep}) must cover the same "
                "device set within a PP stage")
        for d in (self.attn_tp, self.attn_dp, self.moe_tp, self.moe_ep, self.d_pp):
            if d < 1 or (d & (d - 1)):
                raise ValueError(f"degrees must be powers of two, got {self}")

    def describe(self) -> str:
        parts = [f"TP={self.attn_tp} + DP={self.attn_dp}"]
        if self.moe_tp > 1:
            parts.append(f"TP={self.moe_tp} + EP={self.moe_ep}")
        else:
            parts.append(f"EP={self.moe_ep}")
        s = ", ".join(parts)
        if self.d_pp > 1:
            s += f" [PP={self.d_pp}]"
        return s + f" ({self.comm_algo})"


# ---------------------------------------------------------------------------
# EP-exchange overlap (micro-chunked dispatch/GEMM/combine pipeline)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EpOverlap:
    """Micro-chunked EP-exchange schedule for the dropless MoE path.

    The local token batch is split into ``chunks`` micro-chunks; chunk i's
    dispatch A2A is issued before chunk i-1's grouped GEMM so XLA's async
    scheduler can overlap wire and MXU time, and each chunk's exchange
    buffers are **count-bounded**: a soft per-rank row cap instead of the
    worst-case ``T_chunk*k`` extent, with a bit-exact recompute-at-worst-case
    fallback when a chunk overflows the cap (models.moe).

    ``cap_rows``: per-rank rows per chunk.  ``0`` = the statistical rule
    (mean + ``cap_sigma``·std of uniform multinomial routing, rounded up to
    a multiple of 8); ``-1`` = worst-case extent (count-bounding off);
    ``> 0`` = an explicit row cap.
    """

    chunks: int = 1            # C; 1 = monolithic schedule
    cap_rows: int = 0          # 0 = auto rule, -1 = worst case, >0 explicit
    cap_sigma: float = 4.0     # auto-rule margin over the routing mean

    def __post_init__(self):
        if self.chunks < 1:
            raise ValueError(f"chunks must be >= 1, got {self.chunks}")
        if self.cap_rows < -1:
            raise ValueError(f"cap_rows must be >= -1, got {self.cap_rows}")
        if self.cap_sigma <= 0:
            raise ValueError(f"cap_sigma must be > 0, got {self.cap_sigma}")

    @property
    def off(self) -> bool:
        """True for the monolithic worst-case-extent schedule."""
        return self.chunks <= 1 and self.cap_rows == -1

    def describe(self) -> str:
        if self.off:
            return "off (monolithic worst-case exchange)"
        if self.cap_rows == -1:
            cap = "cap=worst-case"
        elif self.cap_rows > 0:
            cap = f"cap={self.cap_rows} rows/rank"
        else:
            cap = f"cap=auto(mean+{self.cap_sigma:g}sigma)"
        return f"C={self.chunks}, {cap}"


# the monolithic schedule (what the pre-overlap exchange always ran)
EP_OVERLAP_OFF = EpOverlap(chunks=1, cap_rows=-1)


def cap_rows_for(n_chunk: int, ep: int, overlap: EpOverlap) -> int:
    """Static per-destination-rank row cap for one micro-chunk.

    ``n_chunk`` is the chunk's slot count (tokens * top_k).  The auto rule
    prices the cap from the routing distribution: near-uniform multinomial
    routing puts mean = n/ep rows on a rank with std sqrt(n/ep * (1-1/ep));
    ``cap_sigma`` standard deviations of headroom make overflow (and the
    worst-case-extent fallback recompute) rare without paying worst case.
    """
    if ep <= 1 or overlap.cap_rows == -1:
        return n_chunk
    if overlap.cap_rows > 0:
        return max(1, min(overlap.cap_rows, n_chunk))
    mean = n_chunk / ep
    cap = mean + overlap.cap_sigma * math.sqrt(mean * (1.0 - 1.0 / ep))
    cap = -(-int(math.ceil(cap)) // 8) * 8          # round up to 8 rows
    return max(1, min(cap, n_chunk))


# ---------------------------------------------------------------------------
# Workload
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Workload:
    batch: int          # global batch (requests in flight)
    seq_len: int        # tokens processed this step per request (prefill: L_in, decode: 1)
    kv_len: int = 0     # KV cache length (decode); 0 -> seq_len
    arrival_rate: float = 0.0  # requests/s for W_q (Eq. 7)

    @property
    def context(self) -> int:
        return self.kv_len or self.seq_len


# ---------------------------------------------------------------------------
# Eq. 4: computation latency per rank
# ---------------------------------------------------------------------------

MFU = 0.5  # assumed achievable fraction of peak in steady state
GEMM_RAMP_TOKENS = 64  # per-expert batch at which expert GEMMs hit 50% eff


def compute_latency(model: ModelConfig, strat: Strategy, work: Workload,
                    cluster: ClusterSpec) -> float:
    """tau(d_TP, d_EP, d_DP): per-rank per-layer compute latency (Eq. 4).

    Matmul FLOPs = 2 * params_active_per_layer * tokens_per_rank, sharded by
    d_TP (attention+shared) and d_TP*d_EP (routed experts); plus the
    attention score/value FLOPs 4 * tokens * ctx * n_heads * head_dim / d_TP.
    """
    tokens_per_rank = work.batch * work.seq_len / strat.attn_dp

    attn_p = model.attn_params_per_layer()
    attn_flops = 2 * attn_p * tokens_per_rank / strat.attn_tp
    if model.attention != "none":
        attn_flops += (4 * tokens_per_rank * work.context
                       * model.n_heads * model.head_dim / strat.attn_tp)

    if model.is_moe:
        # Routed expert FLOPs per chip (Eq. 4): balanced routing spreads the
        # global token*top_k work over ALL chips of the stage regardless of
        # how (moe_tp, moe_ep, replica groups) tile them — replication under
        # d_DP > d_EP copies WEIGHTS, not work; dropping under d_DP < d_EP
        # removes the redundant copies before compute (Fig. 6c).
        global_tokens = work.batch * work.seq_len
        n_stage = strat.attn_tp * strat.attn_dp
        ffn_flops = 2 * model.expert_params() * model.top_k * global_tokens \
            / n_stage
        shared = 2 * model.n_shared_experts * model.expert_params() * tokens_per_rank
        ffn_flops += shared / strat.moe_tp
        # Expert-GEMM efficiency ramps with per-expert-instance batch (the
        # DeepSeek-V3 argument for wide EP: "each expert processes a
        # sufficiently large batch").  Replicating experts (d_DP > d_EP)
        # halves per-instance tokens and costs efficiency.
        instances = max(1, n_stage // (strat.moe_ep * strat.moe_tp))
        tok_per_expert = global_tokens * model.top_k / (
            max(model.n_experts, 1) * instances)
        gemm_eff = tok_per_expert / (tok_per_expert + GEMM_RAMP_TOKENS)
        ffn_flops = ffn_flops / max(gemm_eff, 1e-2)
    else:
        ffn_flops = 2 * model.dense_ffn_params_per_layer() * tokens_per_rank / strat.attn_tp

    t_flops = (attn_flops + ffn_flops) / (cluster.peak_flops * MFU)

    # ---- memory-bound term (dominates decode): weight + KV-cache reads ----
    w_bytes = model.attn_params_per_layer() / strat.attn_tp * BYTES
    if model.is_moe:
        global_tokens = work.batch * work.seq_len
        local_experts = max(1, model.n_experts // strat.moe_ep)
        touched = min(local_experts,
                      max(1.0, global_tokens * model.top_k
                          / (strat.attn_dp * strat.moe_ep)))
        w_bytes += (touched + model.n_shared_experts) \
            * model.expert_params() / strat.moe_tp * BYTES
    else:
        w_bytes += model.dense_ffn_params_per_layer() / strat.attn_tp * BYTES
    kv_bytes = (work.batch / strat.attn_dp) * work.context \
        * model.kv_bytes_per_token_per_layer()
    t_mem = (w_bytes + kv_bytes) / cluster.hbm_bw

    return max(t_flops, t_mem)


# ---------------------------------------------------------------------------
# Eq. 5 / 12 / 13: communication latency per rank per layer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Collective:
    """One collective the strategy's per-layer program issues.

    ``kind``:  all_reduce | all_gather | reduce_scatter | all_to_all
    ``axis``:  logical axis group — "tp" (the MoE/attention TP group),
               "ep" (the expert-parallel group), "guard" (tp ∪ ep — the
               rank-uniform overflow predicate of the count-bounded
               exchange), "mesh" (all mesh axes — the aux-loss pmean).
    ``count``: issues per Decoder layer (already multiplied by the
               micro-chunk count C where the op runs once per chunk).
    ``phase``: program position — attn | resync | counts | moe_dispatch |
               moe_expert | moe_combine | moe_epilogue | shared | aux |
               overflow_guard.
    ``bytes``/``degree``/``link``: the alpha-beta pricing inputs
               (``bytes`` is the model-priced payload, NOT the padded
               buffer extent the implementation puts on the wire).
    ``priced``: whether ``comm_latency`` charges this entry.  Unpriced
               entries are metadata-sized (the int32 counts header, the
               scalar aux pmean, the overflow pmax) or deliberately
               outside the Eq. 5/12/13 scope (shared-expert epilogue,
               token-unslice AG) — the census still lists them so the
               trace contract can demand the lowered program contain
               EXACTLY these collectives and no others.
    ``traceable``: visible as a collective primitive in the shard_map
               jaxpr of ``models.moe.moe_block``.  Attention-block ARs
               and layout-resync AGs are inserted by GSPMD *after*
               lowering, so they only exist in compiled HLO — the jaxpr
               census must skip them.
    ``conditional``: issued only on the rare count-bound overflow path
               (inside the ``lax.cond`` worst-case-extent fallback).
    """

    kind: str
    axis: str
    count: int
    phase: str
    bytes: float = 0.0
    degree: int = 1
    link: str = "moe_intra"
    priced: bool = True
    traceable: bool = True
    conditional: bool = False
    note: str = ""


@dataclasses.dataclass(frozen=True)
class CommCensus:
    """The (kind, count, axis) collective list for one strategy.

    This is the single source of truth consumed by BOTH
    ``comm_latency`` (which prices the ``priced`` entries) and
    ``repro.analysis.trace_contract`` (which demands the jaxpr of the
    lowered MoE block contain exactly the ``traceable`` entries) — the
    MixServe claim that the analyzer prices the program XLA actually
    runs, made falsifiable.
    """

    strategy: Strategy
    layout: str          # pure_tp | dp_ep | mixserve | dense
    fused: bool
    token_sliced: bool
    chunks: int
    cap_bounded: bool
    entries: tuple[Collective, ...]

    def counts(self, *, traceable_only: bool = True,
               conditional: bool = False) -> dict[tuple[str, str], int]:
        """Aggregate (kind, axis) -> count map for the contract check."""
        out: dict[tuple[str, str], int] = {}
        for e in self.entries:
            if traceable_only and not e.traceable:
                continue
            if bool(e.conditional) != bool(conditional):
                continue
            key = (e.kind, e.axis)
            out[key] = out.get(key, 0) + e.count
        return out

    def select(self, **field_values) -> tuple[Collective, ...]:
        return tuple(e for e in self.entries
                     if all(getattr(e, f) == v for f, v in field_values.items()))


def _moe_census_entries(*, is_moe: bool, n_shared: int, moe_tp: int,
                        moe_ep: int, attn_tp: int, fused: bool,
                        token_sliced: bool, comm_algo: str, ep_degree: int,
                        size: float, size_ep: float, k: int, chunks: int,
                        cap_bounded: bool) -> list[Collective]:
    """MoE-block collectives exactly as ``models.moe`` emits them.

    Mirrors ``_moe_shard_dropless_fn`` (the default dropless dispatch)
    branch-for-branch; the trace contract asserts the mirror never drifts.
    """
    ent: list[Collective] = []
    if not is_moe:
        return ent
    C = max(1, chunks)

    if moe_ep <= 1:
        # ep==1 branch: local dropless pipeline + TP partial-sum reduction.
        if moe_tp > 1 and not token_sliced:
            ent.append(Collective("all_reduce", "tp", 1, "moe_expert",
                                  bytes=size, degree=moe_tp,
                                  note="psum of TP-partial expert outputs"))
        if token_sliced and moe_tp > 1:
            ent.append(Collective("all_gather", "tp", 1, "moe_epilogue",
                                  degree=moe_tp, priced=False,
                                  note="token-axis unslice"))
        if n_shared and moe_tp > 1:
            ent.append(Collective("all_reduce", "tp", 1, "shared",
                                  degree=moe_tp, priced=False,
                                  note="shared-expert TP partials"))
        return ent

    # ---- EP exchange (ep > 1): counts A2A + count-bounded dispatch ----
    ent.append(Collective("all_to_all", "ep", C, "counts",
                          degree=ep_degree, priced=False,
                          note="(ep, e_local) int32 slot counts"))
    if fused:
        # Alg. 1-2 fused RS-A2A-AG: the A2A rides 1/tp-sharded hidden
        # states (Eq. 13), an AG restores full width before the expert
        # GEMMs, the combine reduce-scatters back to 1/tp.
        ent += [
            Collective("all_to_all", "ep", C, "moe_dispatch",
                       bytes=size * k / moe_tp, degree=ep_degree, link="ep",
                       note="dispatch A2A on 1/tp-sharded tokens"),
            Collective("all_gather", "tp", C, "moe_dispatch",
                       bytes=size * k, degree=moe_tp,
                       note="restore full hidden width (Alg. 2)"),
            Collective("reduce_scatter", "tp", C, "moe_combine",
                       bytes=size * k, degree=moe_tp,
                       note="reduce TP-partial expert outputs (Alg. 1)"),
            Collective("all_to_all", "ep", C, "moe_combine",
                       bytes=size * k / moe_tp, degree=ep_degree, link="ep",
                       note="combine A2A on 1/tp-sharded outputs"),
            Collective("all_gather", "tp", 1, "moe_epilogue",
                       bytes=size, degree=moe_tp,
                       note="single epilogue AG of the weighted sum"),
        ]
        if n_shared:
            ent.append(Collective("reduce_scatter", "tp", 1, "shared",
                                  degree=moe_tp, priced=False,
                                  note="shared partials fold into the "
                                       "1/tp-sharded stream pre-epilogue-AG"))
    else:
        ent.append(Collective("all_to_all", "ep", C, "moe_dispatch",
                              bytes=size_ep * k, degree=ep_degree, link="ep",
                              note="full-width dispatch A2A (Eq. 12)"))
        if moe_tp > 1 and not token_sliced:
            ent.append(Collective("all_reduce", "tp", C, "moe_expert",
                                  bytes=size, degree=moe_tp,
                                  note="Tutel-style full-width TP AR"))
        ent.append(Collective("all_to_all", "ep", C, "moe_combine",
                              bytes=size_ep * k, degree=ep_degree, link="ep",
                              note="full-width combine A2A"))
        body_tp = moe_tp if moe_tp > 1 else attn_tp
        if token_sliced and body_tp > 1:
            ent.append(Collective("all_gather", "tp", 1, "moe_epilogue",
                                  degree=body_tp, priced=False,
                                  note="token-axis unslice (dp_ep layout)"))
        if n_shared and body_tp > 1:
            ent.append(Collective("all_reduce", "tp", 1, "shared",
                                  degree=body_tp, priced=False,
                                  note="shared-expert TP partials"))

    if cap_bounded:
        # Count-bounded exchange: rank-uniform overflow predicate, plus the
        # bit-exact worst-case-extent fallback inside lax.cond (the counts
        # A2A is NOT redone — counts are cap-independent).
        ent.append(Collective("all_reduce", "guard", C, "overflow_guard",
                              priced=False,
                              note="pmax of per-segment max over tp ∪ ep"))
        cond = [Collective("all_to_all", "ep", C, "moe_dispatch",
                           priced=False, conditional=True),
                Collective("all_to_all", "ep", C, "moe_combine",
                           priced=False, conditional=True)]
        if fused:
            cond += [Collective("all_gather", "tp", C, "moe_dispatch",
                                priced=False, conditional=True),
                     Collective("reduce_scatter", "tp", C, "moe_combine",
                                priced=False, conditional=True)]
        elif moe_tp > 1 and not token_sliced:
            cond.append(Collective("all_reduce", "tp", C, "moe_expert",
                                   priced=False, conditional=True))
        ent += cond
    return ent


def comm_census(model: ModelConfig, strat: Strategy, work: Workload, *,
                ep_overlap: EpOverlap | None = None,
                tokens_local: int | None = None) -> CommCensus:
    """The per-Decoder-layer collective census for ``strat``.

    Returns every collective the strategy's program issues per layer —
    kind, logical axis, count, phase — with the alpha-beta pricing inputs
    attached to the entries ``comm_latency`` charges.  The structure
    mirrors the dropless ``models.moe`` implementation (the default
    dispatch path) plus the GSPMD-side attention collectives.

    ``ep_overlap`` expands the counts to the micro-chunked count-bounded
    schedule (C issues of the per-chunk collectives, the overflow guard,
    and the conditional worst-case fallback).  ``tokens_local`` — the
    per-EP-rank token count the traced program will see — pins the chunk
    count C = gcd(chunks, tokens_local) and the cap decision exactly;
    without it the census estimates both from ``work``.
    """
    is_moe = model.is_moe
    n_shared = model.n_shared_experts if is_moe else 0
    # Layout resolution mirrors partitioner.make_plan's Strategy mapping:
    # moe_tp>1 & moe_ep>1 -> mixserve; moe_ep>1 -> dp_ep (token-sliced pure
    # EP, comm_algo forced "unfused"); else pure TP.
    if not is_moe:
        layout = "dense"
    elif strat.moe_ep <= 1:
        layout = "pure_tp"
    elif strat.moe_tp <= 1:
        layout = "dp_ep"
    else:
        layout = "mixserve"
    token_sliced = layout == "dp_ep" and strat.attn_tp > 1
    comm_algo = "unfused" if token_sliced else strat.comm_algo
    fused = (comm_algo in ("fused", "sync") and strat.moe_tp > 1
             and strat.moe_ep > 1 and not token_sliced)

    # Pricing sizes (Eq. 5 / 12 / 13 — see comm_latency for the link model).
    tokens = work.batch * work.seq_len / max(strat.attn_dp, strat.moe_ep)
    size = tokens * model.d_model * BYTES
    ep_degree = min(strat.moe_ep, strat.attn_dp) if strat.attn_dp > 1 \
        else strat.moe_ep
    if layout == "dp_ep":
        # pure EP (vLLM DP+EP): every device is its own token group — the
        # A2A runs at degree d_EP on bs/d_EP tokens (no Fig. 6c dropping).
        tok_ep = work.batch * work.seq_len / strat.moe_ep
        size_ep = tok_ep * model.d_model * BYTES
        ep_degree = strat.moe_ep
    else:
        size_ep = size

    # Micro-chunk schedule: C and the cap decision, pinned by tokens_local
    # when the caller knows the traced program's local token count.
    chunks, cap_bounded = 1, False
    if (ep_overlap is not None and strat.moe_ep > 1
            and not (ep_overlap.chunks <= 1 and ep_overlap.cap_rows == -1)):
        if tokens_local is None:
            est = work.batch * work.seq_len / max(strat.moe_ep, 1)
            if token_sliced and strat.attn_tp > 1:
                est /= strat.attn_tp
            tokens_local = max(1, int(est))
        chunks = math.gcd(ep_overlap.chunks, tokens_local) \
            if ep_overlap.chunks > 1 else 1
        n_c = (tokens_local // chunks) * max(1, model.top_k)
        cap_bounded = cap_rows_for(n_c, strat.moe_ep, ep_overlap) < n_c

    entries: list[Collective] = []
    tokens_attn = work.batch * work.seq_len / strat.attn_dp
    size_attn = tokens_attn * model.d_model * BYTES
    if strat.attn_tp > 1:
        # Attention block TP: 2 ARs per layer (attn out + [dense] ffn out
        # share the residual stream; Eq. 5 counts 2 x AR).  GSPMD inserts
        # these during SPMD partitioning -> not visible in the moe jaxpr.
        entries.append(Collective("all_reduce", "tp", 2, "attn",
                                  bytes=size_attn, degree=strat.attn_tp,
                                  link="attn", traceable=False))
    entries += _moe_census_entries(
        is_moe=is_moe, n_shared=n_shared, moe_tp=strat.moe_tp,
        moe_ep=strat.moe_ep, attn_tp=strat.attn_tp, fused=fused,
        token_sliced=token_sliced, comm_algo=comm_algo, ep_degree=ep_degree,
        size=size, size_ep=size_ep, k=max(1, model.top_k), chunks=chunks,
        cap_bounded=cap_bounded)
    if is_moe:
        # aux-loss pmean over every mesh axis (replicated out_spec).
        entries.append(Collective("all_reduce", "mesh", 1, "aux",
                                  priced=False, note="scalar aux-loss pmean"))
        if strat.attn_tp != strat.moe_tp and strat.moe_tp > 1:
            # layout resync between the attention TP group and the MoE TP
            # group (hidden states re-gathered on entry + exit) — GSPMD-side.
            entries.append(Collective(
                "all_gather", "tp", 2, "resync", bytes=size_attn,
                degree=max(strat.attn_tp, strat.moe_tp), link="resync",
                traceable=False))
    return CommCensus(strategy=strat, layout=layout, fused=fused,
                      token_sliced=token_sliced, chunks=chunks,
                      cap_bounded=cap_bounded, entries=tuple(entries))


def _routed_expert_seconds(model: ModelConfig, strat: Strategy,
                           work: Workload, cluster: ClusterSpec) -> float:
    """Per-rank routed-expert GEMM seconds (the Eq. 4 routed term alone).

    This is the compute the micro-chunked pipeline can overlap against the
    EP exchange: same sharding/efficiency model as ``compute_latency``'s
    MoE branch, restricted to the routed experts (shared experts and
    attention run outside the dispatch-FFN-combine window).
    """
    if not model.is_moe or model.top_k < 1:
        return 0.0
    global_tokens = work.batch * work.seq_len
    n_stage = strat.attn_tp * strat.attn_dp
    ffn_flops = 2 * model.expert_params() * model.top_k * global_tokens \
        / n_stage
    instances = max(1, n_stage // (strat.moe_ep * strat.moe_tp))
    tok_per_expert = global_tokens * model.top_k / (
        max(model.n_experts, 1) * instances)
    gemm_eff = tok_per_expert / (tok_per_expert + GEMM_RAMP_TOKENS)
    return ffn_flops / max(gemm_eff, 1e-2) / (cluster.peak_flops * MFU)


def moe_overlap_lambda(lam_moe: float, tau_expert: float, overlap: EpOverlap,
                       chunk_alpha: float = 0.0) -> float:
    """Visible MoE comm under the micro-chunked pipeline.

    With C micro-chunks, chunk i's dispatch A2A runs concurrently with
    chunk i-1's grouped GEMM and chunk i-2's combine A2A, so the pipelined
    makespan is max(lam, tau) + (lam + tau)/C for the fill/drain chunks
    instead of lam + tau.  Expressed as an *effective comm* term (so
    ``service_latency`` keeps adding tau separately):

        lam_eff = lam - (1 - 1/C) * min(lam, tau) + (C - 1) * chunk_alpha

    C=1 reproduces the serial sum; comm-bound (lam > tau) hides tau of
    wire time behind compute; compute-bound hides (almost) all of lam.
    ``chunk_alpha`` is the fixed launch latency each extra chunk's pair of
    A2As pays (the Eq. 3 alpha rounds do not shrink with payload) — it is
    what bounds the useful chunk count from above.
    """
    C = overlap.chunks
    if C <= 1:
        return lam_moe
    return (lam_moe - (1.0 - 1.0 / C) * min(lam_moe, tau_expert)
            + (C - 1) * chunk_alpha)


_COST_FN = {"all_reduce": ar_cost, "all_gather": ag_cost,
            "reduce_scatter": rs_cost, "all_to_all": a2a_cost}


def _census_links(strat: Strategy, cluster: ClusterSpec) -> dict:
    """(bw, alpha) per census link class (Fig. 3 / Fig. 6 link model).

    ``attn``: the attention TP fabric, with contention when several
    attention TP groups share one node.  ``moe_intra``: ditto for the MoE
    TP group.  ``ep``: the EP-exchange links, divided across the
    d_DP/d_EP parallel A2A groups that CONTEND for the same inter-node
    links (Fig. 6b).  ``resync``: the intra-node layout-resync AG.
    """
    bw_attn, a_attn = tp_link(cluster, strat.attn_tp)
    if 1 < strat.attn_tp < cluster.n_proc:
        bw_attn = bw_attn * strat.attn_tp / cluster.n_proc
    bw_moe, a_moe = tp_link(cluster, strat.moe_tp)
    if strat.moe_tp < cluster.n_proc:
        bw_moe = bw_moe * strat.moe_tp / cluster.n_proc
    inter = strat.ep_inter_node
    bw_ep, a_ep = cluster.bw(inter), cluster.latency(inter)
    if inter:
        n_groups = max(1, strat.attn_dp // max(strat.moe_ep, 1))
        bw_ep = bw_ep / n_groups
    return {"attn": (bw_attn, a_attn), "moe_intra": (bw_moe, a_moe),
            "ep": (bw_ep, a_ep), "resync": (cluster.intra_node_bw, a_attn)}


def _price(e: Collective, links: dict) -> float:
    bw, alpha = links["attn" if e.phase == "attn"
                      else "resync" if e.phase == "resync" else e.link]
    return e.count * _COST_FN[e.kind](e.bytes, e.degree, bw, alpha)


def comm_latency(model: ModelConfig, strat: Strategy, work: Workload,
                 cluster: ClusterSpec, *,
                 ep_overlap: EpOverlap | None = None) -> float:
    """lambda(d_TP, d_EP, d_DP): per-rank per-layer comm latency (Eq. 5).

    Prices the ``comm_census`` entry list — the same (kind, count, axis)
    collectives the trace contract checks against the lowered program —
    with the Eq. 1-3 alpha-beta costs and the Eq. 13 schedule:

    unfused:  AR(bsh, tp)  then  2 x A2A(bshk, ep)   at FULL hidden width
    fused:    RS-A2A-AG — the A2A operates on hidden states already sharded
              1/tp, so inter-node volume drops by 1/tp (Eq. 13); with
              ``comm_algo == 'fused'`` intra- and inter-node rounds overlap
              (Fig. 9) so each phase's wall time is max(inter, intra), plus
              the serial epilogue AG.

    ``ep_overlap``: price the micro-chunked dispatch/GEMM/combine pipeline
    (models.moe) instead of the serial sum-of-phases exchange.
    """
    census = comm_census(model, strat, work)   # monolithic (C=1) structure
    links = _census_links(strat, cluster)

    lam = sum(_price(e, links) for e in census.entries
              if e.priced and e.phase in ("attn", "resync"))

    moe = [e for e in census.entries
           if e.priced and e.phase.startswith("moe_")]
    if moe:
        if census.fused and strat.comm_algo == "fused":
            # Fig. 9: pairwise inter-node rounds overlap the intra-node
            # RS/AG rounds; wall time ~ max(inter, intra) per phase +
            # epilogue.
            phase_cost = {"moe_dispatch": 0.0, "moe_combine": 0.0,
                          "moe_epilogue": 0.0}
            for e in moe:
                p = _price(e, links)
                if e.phase == "moe_epilogue":
                    phase_cost[e.phase] += p
                else:
                    phase_cost[e.phase] = max(phase_cost[e.phase], p)
            lam_moe = sum(phase_cost.values())
        else:
            lam_moe = sum(_price(e, links) for e in moe)
        if (ep_overlap is not None and ep_overlap.chunks > 1
                and strat.moe_ep > 1):
            tau_e = _routed_expert_seconds(model, strat, work, cluster)
            # each extra chunk pays the payload-independent alpha rounds of
            # its own dispatch + combine A2A (pairwise: ep_degree - 1 rounds)
            a_ep = cluster.latency(strat.ep_inter_node)
            ep_degree = min(strat.moe_ep, strat.attn_dp) \
                if strat.attn_dp > 1 else strat.moe_ep
            chunk_alpha = 2 * a_ep * max(ep_degree - 1, 1)
            lam_moe = moe_overlap_lambda(lam_moe, tau_e, ep_overlap,
                                         chunk_alpha)
        lam += lam_moe
    return lam


def lambda_pure_ep(model: ModelConfig, strat: Strategy, work: Workload,
                   cluster: ClusterSpec) -> float:
    """Eq. 12: DeepSeek-V3-style deployment, MoE block fully EP."""
    tokens = work.batch * work.seq_len / strat.attn_dp
    size = tokens * model.d_model * BYTES
    k = max(1, model.top_k)
    return (ar_cost(size, strat.attn_tp, cluster.intra_node_bw,
                    cluster.intra_node_latency)
            + 2 * a2a_cost(size * k, strat.moe_ep,
                           cluster.bw(True), cluster.latency(True)))


# ---------------------------------------------------------------------------
# Eqs. 6-11: service latency, queuing, indicators
# ---------------------------------------------------------------------------

def service_latency(model: ModelConfig, strat: Strategy, work: Workload,
                    cluster: ClusterSpec, *,
                    ep_overlap: EpOverlap | None = None) -> float:
    """Delta t_svc (Eq. 6)."""
    tau = compute_latency(model, strat, work, cluster)
    lam = comm_latency(model, strat, work, cluster, ep_overlap=ep_overlap)
    t = model.n_layers * (tau + lam)
    if strat.d_pp > 1:
        tokens = work.batch * work.seq_len / strat.attn_dp
        size = tokens * model.d_model * BYTES
        t += (strat.d_pp - 1) * p2p_cost(size, cluster.bw(True),
                                         cluster.latency(True))
    return t


def queuing_delay(service_time: float, arrival_rate: float) -> float:
    """M/M/1 W_q (Eq. 7).  Returns inf when unstable (rho >= 1)."""
    if arrival_rate <= 0:
        return 0.0
    mu = 1.0 / service_time
    if arrival_rate >= mu:
        return math.inf
    return arrival_rate / (mu * (mu - arrival_rate))


@dataclasses.dataclass(frozen=True)
class Indicators:
    ttft: float
    itl: float
    throughput: float  # tokens/s (Eq. 11)
    w_q: float
    stable: bool


def indicators(model: ModelConfig, strat: Strategy, cluster: ClusterSpec, *,
               batch: int, l_in: int, l_out: int,
               arrival_rate: float = 0.0,
               ep_overlap: EpOverlap | None = None) -> Indicators:
    """TTFT (Eq. 9), ITL (Eq. 10), throughput Theta (Eq. 11).

    The M/M/1 service rate is batch-level: one continuous-batching "wave"
    serves ``batch`` requests in (prefill + l_out decode steps).  When the
    arrival rate exceeds that (rho >= 1) the system runs SATURATED — we then
    report W_q = 0 and the saturation throughput (what a loadgen measures on
    an overdriven server, which is how Fig. 10's throughput is collected),
    flagging ``stable=False``.
    """
    prf = service_latency(model, strat,
                          Workload(batch=batch, seq_len=l_in), cluster,
                          ep_overlap=ep_overlap)
    dec = service_latency(model, strat,
                          Workload(batch=batch, seq_len=1, kv_len=l_in + l_out),
                          cluster, ep_overlap=ep_overlap)
    t_request = (prf + l_out * dec) / max(batch, 1)
    w_q = queuing_delay(t_request, arrival_rate)
    stable = math.isfinite(w_q)
    if not stable:
        w_q = 0.0
    ttft = w_q + prf
    denom = w_q + prf + l_out * dec
    thr = batch * (l_in + l_out) / denom
    return Indicators(ttft=ttft, itl=dec, throughput=thr, w_q=w_q,
                      stable=stable)


# ---------------------------------------------------------------------------
# Speculative decoding: expected committed tokens per verify step
# ---------------------------------------------------------------------------

def spec_tokens_per_step(k: int, accept: float) -> float:
    """E[committed tokens] of one k-draft greedy-verify step.

    Acceptance is modeled i.i.d. per draft position with rate ``accept``:
    the step commits 1 + X tokens where X ~ min(Geometric misses, k), so
    E = sum_{j=0..k} accept^j = (1 - accept^(k+1)) / (1 - accept).  k=0 is
    plain decode (exactly 1 token); accept=1 commits all k+1 rows."""
    if k <= 0:
        return 1.0
    a = min(max(accept, 0.0), 1.0)
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


def speculation_itl(t_verify: float, t_draft: float, k: int,
                    accept: float) -> float:
    """Effective inter-token latency of speculative decode: one verify step
    (Eq. 4-6 at seq_len = 1+k) plus k draft proposals, amortized over the
    expected committed tokens.  With k=0 this is just ``t_verify``."""
    return (t_verify + max(k, 0) * t_draft) / spec_tokens_per_step(k, accept)


# ---------------------------------------------------------------------------
# Eq. 8: memory constraint
# ---------------------------------------------------------------------------

def memory_per_device(model: ModelConfig, strat: Strategy, *,
                      batch: int, seq_len: int,
                      bytes_per_el: int = BYTES) -> float:
    """LHS of Eq. 8: weights + KV cache bytes on one chip."""
    attn_total = model.n_layers * model.attn_params_per_layer()
    n_moe_layers = (model.n_layers - model.first_dense_layers) if model.is_moe else 0
    n_dense = model.n_layers - n_moe_layers
    dense_total = n_dense * model.dense_ffn_params_per_layer()
    moe_routed = n_moe_layers * model.n_experts * model.expert_params()
    moe_shared = n_moe_layers * (model.n_shared_experts * model.expert_params()
                                 + model.d_model * model.n_experts)
    embed = model.d_model * model.vocab_size * (1 if model.tie_embeddings else 2)

    w = (attn_total + dense_total + moe_shared) / strat.attn_tp
    w += moe_routed / (strat.moe_ep * strat.moe_tp)
    w += embed / strat.attn_tp
    w /= strat.d_pp

    kv = (batch / strat.attn_dp) * seq_len * model.n_layers \
        * model.kv_bytes_per_token_per_layer(bytes_per_el) / strat.d_pp
    # MQA/GQA KV is replicated when n_kv_heads < attn_tp; approximate by not
    # dividing KV by TP (conservative — vLLM does the same for MQA).
    return (w * bytes_per_el) + kv


def fits_memory(model: ModelConfig, strat: Strategy, cluster: ClusterSpec, *,
                batch: int, seq_len: int) -> bool:
    return memory_per_device(model, strat, batch=batch, seq_len=seq_len) \
        < cluster.hbm_bytes


__all__ = [
    "BYTES", "MFU", "Strategy", "Workload", "Indicators",
    "EpOverlap", "EP_OVERLAP_OFF", "cap_rows_for", "moe_overlap_lambda",
    "rs_cost", "ag_cost", "ar_cost", "a2a_cost", "p2p_cost",
    "Collective", "CommCensus", "comm_census",
    "compute_latency", "comm_latency", "lambda_pure_ep",
    "service_latency", "queuing_delay", "indicators",
    "memory_per_device", "fits_memory",
    "spec_tokens_per_step", "speculation_itl",
]
