"""Hybrid TP-EP partitioner (paper §III-C).

Maps *logical* tensor axes (declared by the models) onto *mesh* axes according
to the selected parallel strategy.  This is the online-stage "weight loader &
partitioner": given the analyzer's strategy it emits ``PartitionSpec``s for
every parameter and activation, which the launcher feeds to ``jax.jit`` as
``in_shardings`` / sharding constraints.

Mesh conventions (launch/mesh.py):
    single-pod: (data=16, model=16)            axes ("data", "model")
    multi-pod : (pod=2, data=16, model=16)     axes ("pod", "data", "model")

Strategy mapping (MixServe hybrid):
    "model" axis  = intra-node TP   (attention heads, FFN cols, vocab)
    "data"  axis  = inter-node EP for MoE block / DP for attention block
    "pod"   axis  = DCN-level DP (attention) and EP-group replication
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.cost_model import EpOverlap, Strategy
from repro.kernels.policy import NULL_POLICY, KernelPolicy

# Logical axis vocabulary used by the models.
#   vocab, embed, heads, kv_heads, head_dim, qk (mla latents), ffn, expert,
#   batch, seq, layers
MeshAxes = Optional[tuple]


@dataclasses.dataclass(frozen=True)
class ShardingPlan:
    """Logical-axis -> mesh-axis rules + the mesh itself.

    ``mesh=None`` disables all constraints (single-device smoke tests).
    """

    mesh: Optional[Mesh] = None
    rules: dict = dataclasses.field(default_factory=dict)
    # mesh axis-name groups used by the fused comm algorithms:
    tp_axes: tuple = ()        # "intra-node" TP group
    ep_axes: tuple = ()        # "inter-node" EP group (MoE)
    dp_axes: tuple = ()        # attention DP group (includes pod)
    comm_algo: str = "fused"   # fused | sync | unfused
    # which Pallas kernels the model layers use (works with mesh=None too —
    # the local/oracle paths honor it the same way the shard_map bodies do)
    kernels: KernelPolicy = NULL_POLICY
    # MoE dispatch buffers: "auto" (-> dropless for inference) | "capacity"
    # (fixed (E, C, h), training's load-balancing contract) | "dropless"
    # (ragged sorted-by-expert buffers, count-independent numerics).
    # Like ``kernels``, honored with mesh=None too (models.moe.moe_block).
    dispatch_mode: str = "auto"
    # Micro-chunked EP-exchange schedule for the dropless path (models.moe):
    # None = monolithic worst-case exchange (the pre-overlap graph, bit-exact
    # default); an EpOverlap prices the chunk count + per-rank row cap.
    ep_overlap: Optional[EpOverlap] = None

    @property
    def enabled(self) -> bool:
        return self.mesh is not None

    def axis_size(self, axes: tuple) -> int:
        if not self.enabled or not axes:
            return 1
        s = 1
        for a in axes:
            s *= self.mesh.shape[a]
        return s

    @property
    def tp(self) -> int:
        return self.axis_size(self.tp_axes)

    @property
    def ep(self) -> int:
        return self.axis_size(self.ep_axes)

    @property
    def dp(self) -> int:
        return self.axis_size(self.dp_axes)

    # ------------------------------------------------------------------
    def spec(self, logical_axes: tuple) -> PartitionSpec:
        """PartitionSpec for a tensor with the given logical axes."""
        if not self.enabled:
            return PartitionSpec()
        out, used = [], set()
        for ax in logical_axes:
            m = self.rules.get(ax)
            if m is None:
                out.append(None)
                continue
            m = tuple(x for x in (m if isinstance(m, tuple) else (m,))
                      if x not in used)
            used.update(m)
            out.append(m if len(m) > 1 else (m[0] if m else None))
        while out and out[-1] is None:
            out.pop()
        return PartitionSpec(*out)

    def spec_for_shape(self, shape: tuple, logical_axes: tuple) -> PartitionSpec:
        """Divisibility-aware ``spec``: assigns mesh axes dim-by-dim, skipping
        any that do not divide the dim (instead of uneven padding), which lets
        a LATER logical axis claim the freed mesh axis.

        This is the fallback chain that keeps small-head models sharded:
        smollm's 15 query heads cannot take the 16-wide "model" axis, so the
        ("heads", "head_dim") declaration shards head_dim instead; phi's 8 KV
        heads likewise fall through to ("kv_heads", "kv_head_dim").
        """
        if not self.enabled:
            return PartitionSpec()
        axes_seq = tuple(logical_axes) + (None,) * (len(shape)
                                                    - len(logical_axes))
        out: list = [None] * len(shape)
        used: set = set()

        def assign(i, ax, dim):
            rule = self.rules.get(ax) if ax is not None else None
            if rule is None:
                return
            cand = tuple(a for a in (rule if isinstance(rule, tuple)
                                     else (rule,)) if a not in used)
            size = 1
            for a in cand:
                size *= self.mesh.shape[a]
            if cand and dim % size == 0:
                used.update(cand)
                out[i] = cand if len(cand) > 1 else cand[0]

        # two passes: the FSDP axes ("layers", "embed") have LOWEST priority
        # so an expert/batch/vocab axis on the same tensor keeps its mesh
        # axis; the FSDP axis only claims what is left.
        low = ("layers", "embed")
        for i, (dim, ax) in enumerate(zip(shape, axes_seq)):
            if ax not in low:
                assign(i, ax, dim)
        for i, (dim, ax) in enumerate(zip(shape, axes_seq)):
            if ax in low:
                assign(i, ax, dim)
        while out and out[-1] is None:
            out.pop()
        return PartitionSpec(*out)

    def sharding(self, logical_axes: tuple) -> Optional[NamedSharding]:
        if not self.enabled:
            return None
        return NamedSharding(self.mesh, self.spec(logical_axes))

    def sharding_for(self, shape: tuple, logical_axes: tuple):
        if not self.enabled:
            return None
        return NamedSharding(self.mesh,
                             self.spec_for_shape(shape, logical_axes))

    def constrain(self, x, *logical_axes):
        """with_sharding_constraint when a mesh is active, else identity."""
        if not self.enabled:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh,
                             self.spec_for_shape(x.shape, logical_axes)))

    def tree_shardings(self, axes_pytree):
        """Map an axes_tree (from models.param) to NamedShardings."""
        is_axes = lambda t: isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t)
        return jax.tree.map(self.sharding, axes_pytree, is_leaf=is_axes)

    def tree_specs(self, axes_pytree):
        is_axes = lambda t: isinstance(t, tuple) and all(
            a is None or isinstance(a, str) for a in t)
        return jax.tree.map(self.spec, axes_pytree, is_leaf=is_axes)


NULL_PLAN = ShardingPlan()


def make_plan(strategy: str | Strategy, mesh: Optional[Mesh],
              comm_algo: str = "fused", *, fsdp: bool = False,
              sp: bool = True,
              kernels: Optional[KernelPolicy] = None,
              dispatch: str = "auto",
              ep_overlap: Optional[EpOverlap] = None) -> ShardingPlan:
    """Build the ShardingPlan for a named strategy on a given mesh.

    ``strategy`` ∈ {"mixserve", "pure_tp", "pure_ep", "dp_ep"} or a
    ``Strategy`` from the analyzer (mapped onto the closest mesh layout).

    ``kernels`` selects the Pallas kernels the model layers run
    (KernelPolicy); None = ``KernelPolicy.auto()`` — everything on a TPU
    backend, nothing elsewhere (the interpret-mode kernels are a
    correctness tool on CPU, not a fast path).

    ``dispatch`` selects the MoE dispatch buffers: "auto" (the default;
    resolves to dropless — count-independent ragged inference dispatch),
    "dropless", or "capacity" (training keeps this: train_step.loss_fn pins
    it regardless of the plan).

    ``ep_overlap`` selects the micro-chunked, count-bounded EP-exchange
    schedule for the dropless path (models.moe); None keeps the monolithic
    worst-case exchange.

    ``fsdp=True`` (training only): parameter/optimizer tensors shard their
    embed axis over the data axis (ZeRO-3 style), gathered on use.  Lowest
    priority — an expert/batch axis on the same tensor keeps the data axis.
    Never used for serving (decode would re-gather weights every token).

    ``sp=False`` disables the Megatron-SP residual-stream sharding
    ("seq_resid"): small-dense models fit without it and save the per-layer
    AG/RS transitions it costs (§Perf pair-3 iteration).
    """
    if kernels is None:
        kernels = KernelPolicy.auto()
    if mesh is None:
        plan = NULL_PLAN
        if kernels.any_enabled:
            plan = dataclasses.replace(plan, kernels=kernels)
        if dispatch != NULL_PLAN.dispatch_mode:
            plan = dataclasses.replace(plan, dispatch_mode=dispatch)
        if ep_overlap is not None:
            plan = dataclasses.replace(plan, ep_overlap=ep_overlap)
        return plan
    names = mesh.axis_names
    pod = ("pod",) if "pod" in names else ()
    data = ("data",)
    model = ("model",)

    if isinstance(strategy, Strategy):
        # Analyzer output: map degrees onto the canonical axes.  moe_tp>1
        # selects the hybrid layout; moe_tp==1 the pure-EP layout.
        strategy = "mixserve" if strategy.moe_tp > 1 else "dp_ep"

    if strategy == "mixserve":
        # Attention: TP over "model", DP over pod+data.
        # MoE: TP over "model", EP over "data", replicated over "pod"
        # (pod rides DCN — the analyzer never puts A2A there).
        return ShardingPlan(
            mesh=mesh,
            rules={
                "vocab": model, "heads": model, "ffn": model,
                "expert": data, "batch": pod + data,
                "kv_heads": model, "qk": model,
                "embed": data if fsdp else None,
                "head_dim": model, "kv_head_dim": model,
                "seq": None, "layers": None, "expert_ffn": model,
                "kv_seq": model, "seq_resid": model if sp else None,
            },
            tp_axes=model, ep_axes=data, dp_axes=pod + data,
            comm_algo=comm_algo, kernels=kernels,
            dispatch_mode=dispatch, ep_overlap=ep_overlap,
        )
    if strategy == "pure_tp":
        # vLLM TP[+PP]-style: everything TP over model axis; data/pod = DP.
        return ShardingPlan(
            mesh=mesh,
            rules={
                "vocab": model, "heads": model, "ffn": model,
                "expert": None, "expert_ffn": model,
                "batch": pod + data, "kv_heads": model,
                "head_dim": model, "kv_head_dim": model,
                "qk": model, "embed": data if fsdp else None, "seq": None,
                "layers": None,
                "kv_seq": model, "seq_resid": model if sp else None,
            },
            tp_axes=model, ep_axes=(), dp_axes=pod + data,
            comm_algo="unfused", kernels=kernels,
            dispatch_mode=dispatch, ep_overlap=ep_overlap,
        )
    if strategy in ("pure_ep", "dp_ep"):
        # vLLM DP+EP-style: attention TP over model, experts sharded over
        # data+model jointly (EP = n_devices per pod), full-width A2A.
        return ShardingPlan(
            mesh=mesh,
            rules={
                "vocab": model, "heads": model, "ffn": model,
                "expert": data + model, "expert_ffn": None,
                "batch": pod + data, "kv_heads": model,
                "head_dim": model, "kv_head_dim": model,
                "qk": model, "embed": data if fsdp else None, "seq": None,
                "layers": None,
                "kv_seq": model, "seq_resid": model if sp else None,
            },
            tp_axes=model, ep_axes=data + model, dp_axes=pod + data,
            comm_algo="unfused", kernels=kernels,
            dispatch_mode=dispatch, ep_overlap=ep_overlap,
        )
    raise KeyError(f"unknown strategy {strategy!r}")


__all__ = ["ShardingPlan", "NULL_PLAN", "make_plan"]
