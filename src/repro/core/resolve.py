"""Knob resolution for the declarative serving API (ServeSpec "auto" fields).

The paper's headline is *automatic*: model + cluster in, serving system
configured out (§III-A/B).  ``repro.serving.api.ServeSpec`` is the
declarative surface; THIS module is where each ``"auto"`` field becomes a
concrete value, derived from the offline analyzer / theoretical cost model
instead of user homework:

  cluster       explicit ClusterSpec / name (validated against the mesh) or
                the v5e heuristic fallback
  strategy      ``analyzer.select`` on the §III-B1 grammar, mapped to a
                ShardingPlan layout name (hybrid "mixserve" vs pure-EP
                "dp_ep", with the expert-divisibility guard)
  max_batch     largest power-of-two batch the Eq. 8 memory constraint
                admits on the target cluster (capped — engine slots, not
                cluster-wide batch)
  chunk         largest prefill chunk whose co-scheduled cost keeps the
                mixed step's ITL inflation under ``ITL_SLACK`` — Sarathi's
                chunk rule instantiated with the Eq. 4-6 token-time
                estimates (replaces the hardcoded 16)
  token_budget  max_batch decode tokens + one prefill chunk per unified
                iteration (replaces the B*chunk default, which let every
                slot prefill at once and spike ITL)
  max_len       the workload envelope l_in + l_out (+ frontend tokens),
                rounded up to the cache-row granule
  overload      bounded admission queue: cap = a wait-time bound divided by
                the Eq. 4-6 predicted per-request service time, plus the
                shed policy (deadline-infeasible-first) — priced
                *degradation*, not just priced performance
  kv            paged-KV layout; the page size consults the shape-keyed
                autotune cache ("kv_page" op) before the divisibility-
                degraded constant, so a measured sweep on this machine
                overrides the 16-token default
  ep_overlap    micro-chunked dispatch/GEMM/combine pipeline for the MoE
                EP exchange (models.moe): chunk count picked by pricing
                the overlapped schedule (moe_overlap_lambda) per candidate
                C, count-bounded buffers on by default
  speculation   draft length k for speculative decoding on the unified
                step: candidates priced by the Eq. 4-6 verify-step time
                against the expected committed tokens/step at the
                acceptance prior (cost_model.spec_tokens_per_step); the
                engine's measured acceptance EMA gates it at runtime

The ``AUTO_BATCH_CAP`` / ``ITL_SLACK`` constants are only analytic
defaults: ``resolved_batch_cap`` / ``resolved_itl_slack`` consult the
per-host "resolver" autotune entry first (kernels.autotune — populated by
the benchmarks/kernel_bench.py calibration pass), so a measured run on
this machine overrides them with ``autotune:measured`` provenance.

Everything here is deterministic: same (spec, model, cluster) in, same
resolved knobs out.  No serving imports — ``serving.api`` composes these
helpers, ``launch.auto`` reuses the strategy mapping.
"""

from __future__ import annotations

import dataclasses
from typing import Union

from repro.configs.base import ModelConfig
from repro.core import analyzer
from repro.core import cost_model as cm
from repro.core.topology import (CLUSTERS, TPU_V5E_MULTIPOD, TPU_V5E_POD,
                                 ClusterSpec)
from repro.kernels import autotune

AUTO = "auto"

# auto-chunk: prefill tokens riding a decode step may inflate it by at most
# this fraction (Sarathi's ITL-bounded chunking, priced by the cost model)
ITL_SLACK = 0.5
CHUNK_CANDIDATES = (4, 8, 16, 32, 64)
# auto max_batch: engine slots on ONE host; Eq. 8 bounds it from above,
# this caps it from sanity (a CPU dev host is not a pod)
AUTO_BATCH_CAP = 8
# max_len is allocated in cache-row granules
LEN_GRANULE = 64
# auto overload: the bounded admission queue holds at most this many
# seconds of predicted work (queue cap = bound / est. per-request service
# time) — generous so light traffic never sheds, finite so overload
# degrades to bounded latency instead of unbounded queueing
OVERLOAD_WAIT_BOUND_S = 30.0
_SHED_POLICIES = ("reject-newest", "deadline-first")


# ---------------------------------------------------------------------------
# Measured resolver constants (the "resolver" autotune entry)
# ---------------------------------------------------------------------------

def resolver_key() -> tuple:
    """Autotune cache-key shape for the per-host "resolver" entry.  The
    calibration (benchmarks/kernel_bench.py) and these lookups MUST build
    the same key or the measured constants never reach the resolver; the
    autotune cache file is already per-host, so the key is empty."""
    return ()


def resolved_batch_cap() -> tuple[int, str]:
    """(engine-slot sanity cap, provenance): the measured "resolver"
    autotune entry if a calibration ran on this host, else the
    ``AUTO_BATCH_CAP`` analytic default."""
    tuned = autotune.lookup("resolver", resolver_key(), "host")
    if tuned and int(tuned.get("batch_cap", 0)) > 0:
        return int(tuned["batch_cap"]), "autotune:measured"
    return AUTO_BATCH_CAP, f"autotune:default({AUTO_BATCH_CAP})"


def resolved_itl_slack() -> tuple[float, str]:
    """(auto-chunk ITL-inflation bound, provenance): measured calibration
    first (persisted as an integer percentage), ``ITL_SLACK`` default."""
    tuned = autotune.lookup("resolver", resolver_key(), "host")
    if tuned and int(tuned.get("itl_slack_pct", 0)) > 0:
        return int(tuned["itl_slack_pct"]) / 100.0, "autotune:measured"
    return ITL_SLACK, f"autotune:default({ITL_SLACK:.0%})"


def resolve_cluster(cluster: Union[str, ClusterSpec, None] = None, *,
                    mesh=None) -> tuple[ClusterSpec, str]:
    """(ClusterSpec, provenance).  Explicit name/spec wins — validated
    against ``mesh.devices.size`` when a mesh is given; ``auto``/None falls
    back to the v5e heuristic (multi-pod iff the mesh exceeds one pod)."""
    if cluster is None or cluster == AUTO:
        if mesh is not None:
            spec = TPU_V5E_MULTIPOD if mesh.devices.size > 256 else TPU_V5E_POD
            return spec, f"auto:mesh-heuristic({mesh.devices.size} devices)"
        return TPU_V5E_POD, "auto:default(v5e-pod-256)"
    if isinstance(cluster, str):
        if cluster not in CLUSTERS:
            raise KeyError(f"unknown cluster {cluster!r} "
                           f"(have {sorted(CLUSTERS)})")
        spec = CLUSTERS[cluster]
    else:
        spec = cluster
    if mesh is not None and spec.n_devices != mesh.devices.size:
        raise ValueError(
            f"cluster {spec.name!r} has {spec.n_devices} devices but the "
            f"mesh has {mesh.devices.size} — pass a matching ClusterSpec "
            "or let the heuristic pick one")
    return spec, "explicit"


def plan_name_for(cfg: ModelConfig, strat: cm.Strategy,
                  n_devices: int) -> str:
    """Map an analyzer Strategy onto a ShardingPlan layout name.

    The winning strategy maps to the hybrid ("mixserve") layout when its
    MoE block uses TP > 1, else to pure-EP — with a divisibility guard:
    pure-EP needs n_experts % n_devices == 0, otherwise the hybrid layout
    is the only implementable choice on this mesh (the deepseek-v2 case:
    160 experts on 256 chips).
    """
    name = "mixserve" if strat.moe_tp > 1 or not cfg.is_moe else "dp_ep"
    if name == "dp_ep" and cfg.n_experts % max(n_devices, 1) != 0:
        name = "mixserve"
    return name


def auto_max_batch(cfg: ModelConfig, strat: cm.Strategy,
                   cluster: ClusterSpec, *, l_in: int, l_out: int,
                   cap: Union[int, None] = None) -> tuple[int, str]:
    """Largest power-of-two batch under the Eq. 8 memory constraint.

    The sanity cap defaults to the measured per-host calibration
    (``resolved_batch_cap``), falling back to ``AUTO_BATCH_CAP``."""
    if cap is None:
        cap, cap_src = resolved_batch_cap()
    else:
        cap_src = "explicit"
    b = 1
    while b * 2 <= cap and cm.memory_per_device(
            cfg, strat, batch=b * 2, seq_len=l_in + l_out) < cluster.hbm_bytes:
        b *= 2
    return b, (f"auto:cost-model(Eq. 8 memory on {cluster.name}, "
               f"cap {cap} [{cap_src}])")


def token_times(cfg: ModelConfig, strat: cm.Strategy, cluster: ClusterSpec,
                *, batch: int, l_in: int, l_out: int) -> tuple[float, float]:
    """(per-prefill-token latency, decode-step latency) — Eq. 4-6."""
    prf = cm.service_latency(
        cfg, strat, cm.Workload(batch=batch, seq_len=l_in), cluster)
    dec = cm.service_latency(
        cfg, strat, cm.Workload(batch=batch, seq_len=1, kv_len=l_in + l_out),
        cluster)
    return prf / max(batch * l_in, 1), dec


def auto_chunk(cfg: ModelConfig, strat: cm.Strategy, cluster: ClusterSpec, *,
               batch: int, l_in: int, l_out: int,
               slack: Union[float, None] = None) -> tuple[int, str]:
    """Largest chunk whose prefill tokens inflate a decode step <= slack.

    A prefill chunk of c tokens co-scheduled with the decode batch adds
    ~``c * t_prefill_token`` to the unified step; Sarathi's rule bounds the
    resulting ITL inflation.  Candidates above the workload's prompt length
    are pointless (the (B, chunk) buffer is static) and skipped.  The slack
    bound defaults to the measured per-host calibration
    (``resolved_itl_slack``), falling back to ``ITL_SLACK``.
    """
    if slack is None:
        slack, slack_src = resolved_itl_slack()
    else:
        slack_src = "explicit"
    t_tok, t_dec = token_times(cfg, strat, cluster, batch=batch,
                               l_in=l_in, l_out=l_out)
    chunk = CHUNK_CANDIDATES[0]
    for c in CHUNK_CANDIDATES:
        if c > max(l_in, CHUNK_CANDIDATES[0]):
            break
        if c * t_tok <= slack * t_dec:
            chunk = c
    return chunk, (f"auto:cost-model({chunk} prefill tok <= "
                   f"{slack:.0%} [{slack_src}] of a "
                   f"{t_dec*1e3:.2f}ms decode step)")


def auto_token_budget(max_batch: int, chunk: int,
                      spec_k: int = 0) -> tuple[int, str]:
    """Decode-first budget: every slot's decode token + ONE prefill chunk
    per unified iteration (the cost-model-bounded prefill rate), replacing
    the B*chunk default that let every slot prefill at once.  With
    speculative decoding each decode slot may ride k extra draft rows, so
    the budget grows to ``max_batch * (1 + k)`` decode-side rows — the
    prefill chunk stays funded even when every slot speculates."""
    per_slot = 1 + max(int(spec_k), 0)
    budget = max_batch * per_slot + chunk
    if spec_k <= 0:
        return budget, (f"auto:cost-model({max_batch} decode tokens "
                        f"+ one {chunk}-token prefill chunk)")
    return budget, (f"auto:cost-model({max_batch} slots x (1+k={spec_k}) "
                    f"verify rows + one {chunk}-token prefill chunk)")


@dataclasses.dataclass(frozen=True)
class OverloadPolicy:
    """Bounded-admission policy: how the scheduler degrades under pressure.

    ``queue_cap`` bounds the admission queue; on overflow ``shed`` picks
    the victim — "reject-newest" drops the incoming request,
    "deadline-first" prefers a queued request whose deadline is already
    infeasible against ``est_request_s`` (the cost model's Eq. 4-6
    prediction of one request's service time), falling back to
    reject-newest when every queued deadline is still feasible.
    """

    queue_cap: int
    shed: str = "reject-newest"
    est_request_s: float = 0.0        # predicted per-request service time

    def __post_init__(self):
        if self.queue_cap < 1:
            raise ValueError(f"queue_cap must be >= 1, got {self.queue_cap}")
        if self.shed not in _SHED_POLICIES:
            raise ValueError(f"shed must be one of {_SHED_POLICIES}, "
                             f"got {self.shed!r}")

    def describe(self) -> str:
        est = f", est {self.est_request_s*1e3:.1f}ms/req" \
            if self.est_request_s else ""
        return f"queue_cap={self.queue_cap} shed={self.shed}{est}"


def auto_overload(cfg: ModelConfig, strat: cm.Strategy, cluster: ClusterSpec,
                  *, batch: int, l_in: int, l_out: int,
                  wait_bound_s: float = OVERLOAD_WAIT_BOUND_S
                  ) -> tuple[OverloadPolicy, str]:
    """Queue cap from the Eq. 4-6 token-time estimates: admit at most
    ``wait_bound_s`` seconds of predicted work, so worst-case queue wait is
    bounded by construction.  Shed policy is deadline-first — requests that
    cannot meet their SLO anyway are the cheapest work to drop."""
    t_tok, t_dec = token_times(cfg, strat, cluster, batch=batch,
                               l_in=l_in, l_out=l_out)
    # one request ~ its prefill + its decode steps, amortized over the batch
    est = (l_in * t_tok * batch + l_out * t_dec) / max(batch, 1)
    cap = max(2 * batch, int(wait_bound_s / max(est, 1e-6)))
    cap = min(cap, 100_000)           # finite even for microscopic models
    policy = OverloadPolicy(queue_cap=cap, shed="deadline-first",
                            est_request_s=est)
    return policy, (f"auto:cost-model({wait_bound_s:.0f}s wait bound / "
                    f"{est*1e3:.2f}ms predicted per request)")


def auto_max_len(l_in: int, l_out: int, front: int = 0,
                 granule: int = LEN_GRANULE) -> tuple[int, str]:
    """Cache rows for the workload envelope, rounded to the granule."""
    need = max(front + l_in + l_out, 1)
    n = -(-need // granule) * granule
    return n, (f"auto:workload({front} frontend + {l_in} prompt + "
               f"{l_out} new tokens, {granule}-row granule)")


# paged KV: tokens per fixed-size cache page (the block-table granule).
# Must divide max_len; auto_kv degrades to the largest power-of-two divisor.
# The constant is only the analytic floor — auto_kv first consults the
# shape-keyed "kv_page" autotune entry (kernels.autotune), so a measured
# sweep (benchmarks/kernel_bench.py --tune) overrides it per envelope.
KV_PAGE_SIZE = 16
_KV_BACKENDS = ("dense", "paged")


def kv_page_key(cfg: ModelConfig, max_len: int) -> tuple:
    """Autotune cache-key shape for the "kv_page" op: (length envelope,
    per-layer KV row elements).  ``auto_kv`` and the kernel_bench sweep MUST
    build the same key or the tuned page never reaches the resolver."""
    per_tok = kv_bytes_per_token(cfg, 1) // max(cfg.n_layers, 1)
    return (int(max_len), int(per_tok))


@dataclasses.dataclass(frozen=True)
class KVConfig:
    """KV-cache layout policy: how the engine stores per-slot KV state.

    ``dense`` is the per-slot ``(B, max_len, ...)`` buffer; ``paged`` is
    block/page indirection — fixed ``page_size``-token pages in a global
    ``pool_pages``-page pool, a per-slot block table, and (when
    ``prefix_cache``) a radix index that lets requests sharing a prompt
    prefix reference the same pages (docs/kv_cache.md).  ``pool_pages == 0``
    means "auto": the resolver sizes the pool from the Eq. 8 workload
    envelope instead of the dense worst case.
    """

    backend: str = "paged"
    page_size: int = KV_PAGE_SIZE
    pool_pages: int = 0               # 0 = auto (resolver fills from Eq. 8)
    prefix_cache: bool = True

    def __post_init__(self):
        if self.backend not in _KV_BACKENDS:
            raise ValueError(f"kv backend must be one of {_KV_BACKENDS}, "
                             f"got {self.backend!r}")
        if self.page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {self.page_size}")
        if self.pool_pages < 0:
            raise ValueError(f"pool_pages must be >= 0, got {self.pool_pages}")

    def describe(self) -> str:
        if self.backend == "dense":
            return "dense"
        pc = "on" if self.prefix_cache else "off"
        return (f"paged(page={self.page_size}, pool={self.pool_pages}p, "
                f"prefix_cache={pc})")


def kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int = 2) -> int:
    """KV bytes one cached token costs across all layers (the Eq. 8
    per-token memory term): MLA caches the compressed latent + rope key,
    GQA caches k/v heads."""
    if getattr(cfg, "attention", "gqa") == "mla":
        per = cfg.kv_lora_rank + cfg.rope_head_dim
    else:
        per = 2 * cfg.n_kv_heads * cfg.head_dim
    return cfg.n_layers * per * dtype_bytes


def auto_kv(cfg: ModelConfig, *, max_batch: int, max_len: int, l_in: int,
            l_out: int, front: int = 0, paged_ok: bool = True,
            backend: Union[str, None] = None, page_size: Union[int, None] = None,
            pool_pages: int = 0, prefix_cache: Union[bool, None] = None
            ) -> tuple[KVConfig, str]:
    """Resolve the ``kv`` knob: backend, page size, pool size, prefix cache.

    Explicit values (``backend``/``page_size``/``pool_pages``/
    ``prefix_cache``) pass through; auto fills the rest.  The pool is sized
    from the Eq. 8 memory bound instantiated on the workload envelope —
    ``max_batch`` concurrent requests each holding ``front + l_in + l_out``
    tokens of KV — instead of the dense worst case ``max_batch * max_len``,
    which is what makes paging a capacity lever (pages the envelope does
    not need stay free for more slots or longer prompts; exhaustion
    degrades to cache-preserving preemption, not OOM).
    """
    if backend is None and not paged_ok:
        return (KVConfig(backend="dense"),
                "auto:family(legacy blocking path keeps the dense cache)")
    if backend == "dense":
        return KVConfig(backend="dense"), "explicit"
    # page size: explicit > measured autotune entry > analytic constant —
    # then degraded to divide max_len (block tables address max_len//page
    # rows, so a non-dividing page would orphan the tail)
    if page_size:
        ps, ps_src = page_size, "explicit"
    else:
        tuned = autotune.lookup("kv_page", kv_page_key(cfg, max_len),
                                "bfloat16")
        if tuned and int(tuned.get("page", 0)) > 0:
            ps, ps_src = int(tuned["page"]), "autotune:measured"
        else:
            ps, ps_src = KV_PAGE_SIZE, f"autotune:default({KV_PAGE_SIZE})"
    while ps > 1 and max_len % ps:
        ps //= 2
    dense_pages = max_batch * (max_len // ps)
    if pool_pages:
        pool = pool_pages
    else:
        envelope = max(front + l_in + l_out, 1)
        per_slot = -(-envelope // ps)
        # floor: one slot must always be able to reach max_len
        pool = max(max_batch * per_slot, max_len // ps)
        pool = min(pool, dense_pages)
    pc = True if prefix_cache is None else prefix_cache
    kv = KVConfig(backend="paged", page_size=ps, pool_pages=pool,
                  prefix_cache=pc)
    src = "explicit" if backend == "paged" else "auto"
    bpt = kv_bytes_per_token(cfg)
    return kv, (f"{src}:cost-model(Eq. 8 envelope {front}+{l_in}+{l_out} tok "
                f"x {max_batch} slots @ {bpt}B/tok -> {pool} pages vs "
                f"{dense_pages} dense; page {ps} from {ps_src})")


# auto speculation: candidate draft lengths priced by the Eq. 4-6 verify
# step (seq_len = 1+k) against the expected committed tokens/step at the
# acceptance prior; the draft's own cost is modeled as a fraction of a
# decode step per proposed token (an n-gram draft is ~free, a reduced-model
# draft is not — the fraction is deliberately conservative).
SPEC_K_CANDIDATES = (0, 1, 2, 4)
SPEC_ACCEPT_PRIOR = 0.7
SPEC_DRAFT_COST_FRAC = 0.05
_DRAFT_SOURCES = ("ngram", "self", "mtp")


@dataclasses.dataclass(frozen=True)
class SpeculationConfig:
    """Speculative-decoding policy: how many tokens to draft and from what.

    ``k`` draft tokens ride each speculating slot's verify row block
    (q_len = k+1 on the unified step).  ``draft`` names the source:
    "ngram" (prompt-suffix matching, free), "self" (the serving model
    drafts for itself — an acceptance-1.0 oracle for tests), "mtp" (the
    DeepSeek-style multi-token-prediction head stub), or any config name
    from ``repro.configs`` run reduced as a draft model.  The engine
    pauses speculation when its measured acceptance EMA (smoothing
    ``ema_alpha``) falls below ``min_accept``, re-probing every
    ``probe_every`` steps so a workload shift can re-enable it.
    """

    k: int
    draft: str = "ngram"
    ngram: int = 3                    # n-gram draft: match length + 1
    min_accept: float = 0.25          # EMA gate: below this, stop drafting
    ema_alpha: float = 0.1            # acceptance EMA smoothing
    probe_every: int = 16             # re-probe cadence while gated off

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"speculation k must be >= 1, got {self.k}")
        if self.ngram < 2:
            raise ValueError(f"ngram must be >= 2, got {self.ngram}")
        if not 0.0 <= self.min_accept <= 1.0:
            raise ValueError(f"min_accept must be in [0, 1], "
                             f"got {self.min_accept}")
        if not 0.0 < self.ema_alpha <= 1.0:
            raise ValueError(f"ema_alpha must be in (0, 1], "
                             f"got {self.ema_alpha}")
        if self.probe_every < 1:
            raise ValueError(f"probe_every must be >= 1, "
                             f"got {self.probe_every}")

    def describe(self) -> str:
        return (f"k={self.k} draft={self.draft} "
                f"gate>={self.min_accept:g}(ema a={self.ema_alpha:g})")


def auto_speculation(cfg: ModelConfig, strat: cm.Strategy,
                     cluster: ClusterSpec, *, batch: int, l_in: int,
                     l_out: int, chunk: int, temperature: float = 0.0,
                     unified_ok: bool = True,
                     value: Union[str, int, SpeculationConfig, None] = AUTO,
                     accept_ema: Union[float, None] = None,
                     ) -> tuple[Union[SpeculationConfig, None], str]:
    """Resolve the ``speculation`` knob: (SpeculationConfig or None, why).

    ``"off"``/None disables drafting.  An int pins k (clamped to chunk-1 —
    the verify rows must fit one slot's chunk).  A SpeculationConfig passes
    through (k clamped the same way).  ``"auto"`` prices each candidate k:
    the verify step costs the Eq. 4-6 latency of a seq_len = 1+k step plus
    k draft proposals at ``SPEC_DRAFT_COST_FRAC`` of a decode step, and
    commits ``cm.spec_tokens_per_step(k, a)`` tokens in expectation at
    acceptance ``a`` (the engine's measured EMA when given, else the
    ``SPEC_ACCEPT_PRIOR``).  Greedy-only: sampling (temperature > 0) and
    the legacy blocking path resolve to off — or raise when speculation
    was explicitly requested.
    """
    if value is None or value == "off":
        return None, "explicit:off(non-speculative decode)"
    explicit = value != AUTO
    if temperature > 0.0:
        if explicit:
            raise ValueError("speculation requires greedy decoding "
                             f"(temperature=0), got {temperature}")
        return None, "auto:off(sampling — greedy verify only)"
    if not unified_ok:
        if explicit:
            raise ValueError("speculation requires the unified ragged step "
                             "(model family unsupported)")
        return None, "auto:off(legacy blocking path has no ragged verify)"
    k_cap = max(int(chunk) - 1, 0)
    if k_cap < 1:
        if explicit:
            raise ValueError(f"speculation needs chunk >= 2 for k+1 verify "
                             f"rows, got chunk={chunk}")
        return None, f"auto:off(chunk={chunk} leaves no draft rows)"
    if isinstance(value, SpeculationConfig):
        sc = value if value.k <= k_cap else dataclasses.replace(
            value, k=k_cap)
        note = "" if value.k <= k_cap else f", k clamped to chunk-1={k_cap}"
        return sc, f"explicit({sc.describe()}{note})"
    if explicit:
        k = int(value)
        if k < 1:
            raise ValueError(f"speculation k must be >= 1, got {k}")
        sc = SpeculationConfig(k=min(k, k_cap))
        note = "" if k <= k_cap else f", clamped to chunk-1={k_cap}"
        return sc, f"explicit(k={sc.k}{note})"

    a = accept_ema if accept_ema is not None else SPEC_ACCEPT_PRIOR
    kv = l_in + l_out

    def itl_eff(k: int) -> float:
        t_ver = cm.service_latency(
            cfg, strat, cm.Workload(batch=batch, seq_len=1 + k, kv_len=kv),
            cluster)
        t_dec = cm.service_latency(
            cfg, strat, cm.Workload(batch=batch, seq_len=1, kv_len=kv),
            cluster)
        return cm.speculation_itl(t_ver, SPEC_DRAFT_COST_FRAC * t_dec, k, a)

    best_k, best_t = 0, None
    for k in SPEC_K_CANDIDATES:
        if k > k_cap:
            continue
        t = itl_eff(k)
        if best_t is None or t < best_t:
            best_k, best_t = k, t
    base_t = itl_eff(0)
    if best_k < 1:
        return None, (f"auto:cost-model(k=0 wins at accept={a:.2f} — the "
                      f"1+k verify step outprices the amortized exchange "
                      f"on {cluster.name})")
    sc = SpeculationConfig(k=best_k)
    exp_tok = cm.spec_tokens_per_step(best_k, a)
    return sc, (f"auto:cost-model(k={best_k}: E[{exp_tok:.2f} tok/step] at "
                f"accept={a:.2f} -> {base_t / best_t:.2f}x ITL vs k=0 on "
                f"{cluster.name}; draft={sc.draft})")


# auto ep_overlap: candidate micro-chunk counts priced by the overlapped
# schedule estimate (per-chunk alpha overhead bounds the useful C)
EP_OVERLAP_CANDIDATES = (1, 2, 4, 8)


def auto_ep_overlap(cfg: ModelConfig, strat: cm.Strategy,
                    cluster: ClusterSpec, *, batch: int, l_in: int,
                    l_out: int,
                    value: Union[str, int, cm.EpOverlap, None] = AUTO
                    ) -> tuple[Union[cm.EpOverlap, None], str]:
    """Resolve the ``ep_overlap`` knob: (EpOverlap or None, provenance).

    ``"off"``/None keeps the monolithic worst-case-extent exchange (plan
    field stays None).  An int pins the chunk count (auto cap).  An
    EpOverlap passes through.  ``"auto"`` prices each candidate chunk count
    with the overlapped-schedule comm estimate (``cm.moe_overlap_lambda``
    via ``cm.service_latency``) on the workload envelope and keeps the
    cheapest — including C=1, where count-bounding alone still shrinks the
    exchange extent.  Dense models and EP-free strategies resolve to off:
    there is no EP exchange to hide.
    """
    if value is None or value == "off":
        return None, "explicit:off(monolithic worst-case exchange)"
    if isinstance(value, cm.EpOverlap):
        return value, f"explicit({value.describe()})"
    if not cfg.is_moe or strat.moe_ep <= 1:
        return None, ("auto:strategy(ep=1 — no EP exchange to hide)"
                      if cfg.is_moe else
                      "auto:model(dense — no EP exchange)")
    if value != AUTO:
        c = int(value)
        if c < 1:
            raise ValueError(f"ep_overlap chunks must be >= 1, got {c}")
        return cm.EpOverlap(chunks=c), f"explicit(C={c}, auto cap)"

    def step_s(ovl: cm.EpOverlap) -> float:
        prf = cm.service_latency(
            cfg, strat, cm.Workload(batch=batch, seq_len=l_in), cluster,
            ep_overlap=ovl)
        dec = cm.service_latency(
            cfg, strat,
            cm.Workload(batch=batch, seq_len=1, kv_len=l_in + l_out),
            cluster, ep_overlap=ovl)
        return prf + l_out * dec

    best_c, best_t = 1, None
    for c in EP_OVERLAP_CANDIDATES:
        t = step_s(cm.EpOverlap(chunks=c))
        if best_t is None or t < best_t:
            best_c, best_t = c, t
    base_t = step_s(cm.EpOverlap(chunks=1))
    hidden_ms = max(base_t - best_t, 0.0) * 1e3
    ovl = cm.EpOverlap(chunks=best_c)
    return ovl, (f"auto:cost-model(C={best_c} hides {hidden_ms:.2f}ms of EP "
                 f"A2A per request on {cluster.name}; cap="
                 f"mean+{ovl.cap_sigma:g}sigma rows/rank)")


__all__ = ["AUTO", "ITL_SLACK", "CHUNK_CANDIDATES", "AUTO_BATCH_CAP",
           "LEN_GRANULE", "OVERLOAD_WAIT_BOUND_S", "KV_PAGE_SIZE",
           "EP_OVERLAP_CANDIDATES", "SPEC_K_CANDIDATES", "SPEC_ACCEPT_PRIOR",
           "SPEC_DRAFT_COST_FRAC", "OverloadPolicy", "KVConfig",
           "SpeculationConfig", "kv_bytes_per_token", "kv_page_key",
           "auto_kv", "auto_ep_overlap", "auto_speculation",
           "resolve_cluster", "plan_name_for", "auto_max_batch",
           "token_times", "auto_chunk", "auto_token_budget", "auto_overload",
           "auto_max_len", "resolver_key", "resolved_batch_cap",
           "resolved_itl_slack"]
