"""Parallel-strategy grammar and enumeration (paper §III-B1).

The paper defines a context-free grammar over per-Decoder-layer strategies:

    strategy  -> Decoder | Decoder [PP = degree]
    Decoder   -> Attention, MoE
    block     -> intra-node + inter-node | parallel
    intra     -> parallel
    inter     -> parallel
    parallel  -> TP | EP (DP) = degree
    degree    -> 2^k

``enumerate_strategies`` produces every sentence of that grammar that covers
the given cluster: attention = TP x DP, MoE = TP x EP, degrees powers of two,
with (attn_tp * attn_dp) == (moe_tp * moe_ep) == devices_per_stage and
stage_count = d_pp.  Named presets reproduce the paper's baselines (Table II).
"""

from __future__ import annotations

from typing import Iterator

from repro.core.cost_model import CommAlgo, Strategy
from repro.core.topology import ClusterSpec, pow2_divisors


def enumerate_strategies(cluster: ClusterSpec, *, model_is_moe: bool,
                         max_pp: int = 8,
                         comm_algos: tuple[CommAlgo, ...] = ("fused",),
                         ) -> Iterator[Strategy]:
    """Yield every grammar sentence covering ``cluster.n_devices`` chips."""
    n = cluster.n_devices
    for d_pp in pow2_divisors(min(n, max_pp)):
        per_stage = n // d_pp
        if per_stage < 1 or n % d_pp:
            continue
        for attn_tp in pow2_divisors(per_stage):
            attn_dp = per_stage // attn_tp
            moe_opts = pow2_divisors(per_stage) if model_is_moe else [attn_tp]
            for moe_tp in moe_opts:
                moe_ep = per_stage // moe_tp
                if model_is_moe and moe_ep < 1:
                    continue
                # EP group spans nodes iff its size exceeds what fits in the
                # TP-complement of one node.
                ep_inter = moe_ep > max(1, cluster.n_proc // moe_tp)
                algos = comm_algos if (model_is_moe and moe_tp > 1 and moe_ep > 1) \
                    else ("fused",)
                for algo in algos:
                    s = Strategy(attn_tp=attn_tp, attn_dp=attn_dp,
                                 moe_tp=moe_tp, moe_ep=moe_ep, d_pp=d_pp,
                                 comm_algo=algo, ep_inter_node=ep_inter)
                    try:
                        s.validate()
                    except ValueError:
                        continue
                    yield s


# ---------------------------------------------------------------------------
# Named presets — the paper's baselines (Table II) and MixServe's choice.
# ---------------------------------------------------------------------------

def preset(name: str, cluster: ClusterSpec) -> Strategy:
    """Baseline presets; names mirror Table II rows."""
    n_proc, n_node = cluster.n_proc, cluster.n_node
    n = cluster.n_devices
    if name == "vllm_tp_pp":          # vLLM TP=n_proc [PP=n_node]
        return Strategy(attn_tp=n_proc, attn_dp=1, moe_tp=n_proc, moe_ep=1,
                        d_pp=n_node, comm_algo="unfused", ep_inter_node=False)
    if name == "vllm_dp_ep":          # vLLM TP=n_proc + DP=n_node, EP=n
        return Strategy(attn_tp=n_proc, attn_dp=n_node, moe_tp=1, moe_ep=n,
                        d_pp=1, comm_algo="unfused", ep_inter_node=True)
    if name == "vllm_dp_ep_tp4":      # vLLM TP=4 + DP=n/4, EP=n
        return Strategy(attn_tp=4, attn_dp=n // 4, moe_tp=1, moe_ep=n,
                        d_pp=1, comm_algo="unfused", ep_inter_node=True)
    if name == "tutel_tp_ep":         # Tutel TP=n_proc + EP=n_node, unfused
        return Strategy(attn_tp=n_proc, attn_dp=n_node,
                        moe_tp=n_proc, moe_ep=n_node,
                        d_pp=1, comm_algo="unfused", ep_inter_node=True)
    if name == "mixserve":            # hybrid TP-EP + fused AR-A2A
        return Strategy(attn_tp=n_proc, attn_dp=n_node,
                        moe_tp=n_proc, moe_ep=n_node,
                        d_pp=1, comm_algo="fused", ep_inter_node=True)
    if name == "mixserve_sync":       # ablation: same layout, no overlap
        return Strategy(attn_tp=n_proc, attn_dp=n_node,
                        moe_tp=n_proc, moe_ep=n_node,
                        d_pp=1, comm_algo="sync", ep_inter_node=True)
    raise KeyError(f"unknown preset {name!r}")


PRESETS = ("vllm_tp_pp", "vllm_dp_ep", "vllm_dp_ep_tp4", "tutel_tp_ep",
           "mixserve", "mixserve_sync")

__all__ = ["enumerate_strategies", "preset", "PRESETS"]
