"""Cluster / interconnect topology description.

The paper's analyzer consumes "the configuration of network and hardware
resources ... computational power, as well as intra-node and inter-node
network bandwidth and topology" (§III-A).  ``ClusterSpec`` is that input.

Bandwidths are *per-link, per-direction* bytes/s.  ``intra_node_bw`` models
NVLink / HCCS / TPU ICI; ``inter_node_bw`` models IB / RoCE / TPU DCN.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """A cluster of ``n_node`` nodes with ``n_proc`` accelerators each."""

    name: str
    n_node: int
    n_proc: int
    # per-chip peak compute (FLOP/s) at serving dtype
    peak_flops: float
    # per-chip HBM bandwidth (bytes/s)
    hbm_bw: float
    # per-chip HBM capacity (bytes)
    hbm_bytes: float
    # intra-node (NVLink / HCCS / ICI) bandwidth per chip, bytes/s
    intra_node_bw: float
    # inter-node (IB / RoCE / DCN) bandwidth per chip, bytes/s
    inter_node_bw: float
    # fixed per-collective latency (s) intra / inter node (alpha term)
    intra_node_latency: float = 5e-6
    inter_node_latency: float = 20e-6

    @property
    def n_devices(self) -> int:
        return self.n_node * self.n_proc

    def bw(self, inter_node: bool) -> float:
        return self.inter_node_bw if inter_node else self.intra_node_bw

    def latency(self, inter_node: bool) -> float:
        return self.inter_node_latency if inter_node else self.intra_node_latency


def _gbps(x: float) -> float:
    """Gigabits/s -> bytes/s."""
    return x * 1e9 / 8


def _gBps(x: float) -> float:
    """Gigabytes/s -> bytes/s."""
    return x * 1e9


# ---------------------------------------------------------------------------
# The paper's two evaluation clusters (§IV-A).
# ---------------------------------------------------------------------------

# 2 nodes x 8 Nvidia H20 (96 GB).  NVLink 4.0 "up to 900 GB/s" aggregate;
# InfiniBand 400 Gbps per node.
H20_CLUSTER = ClusterSpec(
    name="h20x16",
    n_node=2,
    n_proc=8,
    peak_flops=148e12,          # H20 bf16 dense
    hbm_bw=4.0e12,              # 4.0 TB/s
    hbm_bytes=96e9,
    intra_node_bw=_gBps(450.0),  # 900 GB/s bidirectional -> 450 per direction
    inter_node_bw=_gbps(400.0) / 8,  # 400 Gb/s NIC shared by 8 GPUs
)

# 4 nodes x 8 Ascend 910B (64 GB).  HCCS "up to 480 Gbps" per link; RoCE
# "up to 200 Gbps" per node.
ASCEND_910B_CLUSTER = ClusterSpec(
    name="910bx32",
    n_node=4,
    n_proc=8,
    peak_flops=376e12 / 2,      # 910B fp16 ~ 376 TFLOPS dense /2 derate
    hbm_bw=1.6e12,
    hbm_bytes=64e9,
    intra_node_bw=_gbps(480.0),
    inter_node_bw=_gbps(200.0) / 8,
)

# ---------------------------------------------------------------------------
# The TPU target for this reproduction: v5e pods.
# "model" axis rides ICI inside a pod slice; "pod" axis rides DCN.
# Hardware constants fixed by the task: 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s per ICI link.
# ---------------------------------------------------------------------------

TPU_V5E_POD = ClusterSpec(
    name="v5e-pod-256",
    n_node=16,                  # "data" axis (EP/DP groups)
    n_proc=16,                  # "model" axis (TP groups)
    peak_flops=197e12,
    hbm_bw=819e9,
    hbm_bytes=16e9,
    intra_node_bw=_gBps(50.0),  # ICI per link
    # within a single pod both axes are ICI; the 2x asymmetry below reflects
    # fewer hops / contention on the contiguous "model" axis vs the "data"
    # axis of a 16x16 torus.
    inter_node_bw=_gBps(25.0),
)

TPU_V5E_MULTIPOD = dataclasses.replace(
    TPU_V5E_POD,
    name="v5e-2pods-512",
    n_node=32,                  # 2 pods x 16 "data" rows
    inter_node_bw=_gBps(6.25),  # DCN between pods, far below ICI
)

CLUSTERS = {
    c.name: c for c in (H20_CLUSTER, ASCEND_910B_CLUSTER, TPU_V5E_POD, TPU_V5E_MULTIPOD)
}


def is_pow2(x: int) -> bool:
    return x >= 1 and (x & (x - 1)) == 0


def pow2_divisors(n: int) -> list[int]:
    """All powers of two d with d | n (the grammar's ``degree -> 2^k``)."""
    out = []
    d = 1
    while d <= n:
        if n % d == 0:
            out.append(d)
        d *= 2
    return out


def flops_per_s_total(spec: ClusterSpec) -> float:
    return spec.peak_flops * spec.n_devices


def bisection_inter_node_bw(spec: ClusterSpec) -> float:
    return spec.inter_node_bw * spec.n_devices


__all__ = [
    "ClusterSpec",
    "H20_CLUSTER",
    "ASCEND_910B_CLUSTER",
    "TPU_V5E_POD",
    "TPU_V5E_MULTIPOD",
    "CLUSTERS",
    "is_pow2",
    "pow2_divisors",
]
