"""Synthetic token pipeline — deterministic, seeded, learnable structure.

The offline container has no datasets; we generate a Zipf-distributed token
stream with a Markov bigram backbone so that a ~100M model trained for a few
hundred steps shows a *decreasing* loss (pure-uniform tokens would pin the
loss at log V).  For VLM/audio archs the pipeline also emits the stub
frontend embeddings (patch/frame) the model expects.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int = 8
    seq_len: int = 256
    seed: int = 0
    zipf_a: float = 1.2          # token unigram skew
    markov_states: int = 64      # bigram backbone states


class SyntheticLM:
    """Deterministic stream of {tokens, labels[, embeds, frames]} batches."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg, self.data = cfg, data
        rng = np.random.default_rng(data.seed)
        v = cfg.vocab_size
        k = min(data.markov_states, v)
        # static bigram transition table over k "hub" tokens scattered in V
        self.hubs = rng.choice(v, size=k, replace=False)
        self.trans = rng.dirichlet(np.ones(k) * 0.1, size=k)
        self.zipf_p = 1.0 / np.arange(1, v + 1) ** data.zipf_a
        self.zipf_p /= self.zipf_p.sum()
        self.perm = rng.permutation(v)

    def _sample_seq(self, rng, n: int) -> np.ndarray:
        k = len(self.hubs)
        out = np.empty(n, np.int64)
        state = rng.integers(k)
        for i in range(n):
            if rng.random() < 0.75:          # follow the bigram backbone
                state = rng.choice(k, p=self.trans[state])
                out[i] = self.hubs[state]
            else:                             # zipf noise (head of the dist)
                head = min(1000, len(self.zipf_p))
                out[i] = self.perm[rng.choice(
                    head, p=self.zipf_p[:head] / self.zipf_p[:head].sum())]
        return out

    def batches(self, n_steps: Optional[int] = None) -> Iterator[dict]:
        d, cfg = self.data, self.cfg
        rng = np.random.default_rng(d.seed + 1)
        step = 0
        s_text = d.seq_len
        n_front = 0
        if cfg.frontend == "vision_stub":
            n_front = cfg.n_frontend_tokens
            s_text = d.seq_len - n_front
        while n_steps is None or step < n_steps:
            toks = np.stack([self._sample_seq(rng, s_text + 1)
                             for _ in range(d.batch)])
            batch = {
                "tokens": toks[:, :-1].astype(np.int32),
                "labels": np.pad(toks[:, 1:], ((0, 0), (n_front, 0))
                                 ).astype(np.int32),
            }
            if n_front:
                batch["embeds"] = rng.standard_normal(
                    (d.batch, n_front, cfg.d_model)).astype(np.float32) * 0.02
                mask = np.ones((d.batch, d.seq_len), np.float32)
                mask[:, :n_front] = 0.0
                batch["mask"] = mask
            if cfg.frontend == "audio_stub":
                e = cfg.encoder
                batch["frames"] = rng.standard_normal(
                    (d.batch, e.n_frames, e.d_model)).astype(np.float32) * 0.02
            yield batch
            step += 1


__all__ = ["DataConfig", "SyntheticLM"]
