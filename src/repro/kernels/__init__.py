"""Pallas TPU kernel suite for the serving hot path.

  moe_gemm      grouped expert GEMM over (E, C, h) capacity buffers
  topk_gate     fused softmax + top-k router gate
  flash_chunk   ragged mixed-chunk flash attention (the unified-step kernel)
  flash_decode  single-token decode attention (= flash_chunk at sq == 1)
  permute       fused token permute / unpermute+weighted-combine (dispatch)
  autotune      shape-keyed block-size selection shared by the kernels
  policy        KernelPolicy switches (rides on core.partitioner.ShardingPlan)
  ops           jit'd public wrappers (interpret on CPU, native on TPU)
  ref           pure-jnp oracles (the allclose targets)

Only ``policy`` is imported eagerly — it is pulled in by the partitioner on
every launch path and must not drag the Pallas machinery along.
"""

from repro.kernels.policy import NULL_POLICY, KernelPolicy

__all__ = ["KernelPolicy", "NULL_POLICY"]
