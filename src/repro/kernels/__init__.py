"""Pallas TPU kernel suite for the serving hot path.

  moe_gemm      grouped expert GEMM over (E, C, h) capacity buffers
  topk_gate     fused softmax + top-k router gate
  flash_decode  single-token decode attention (online softmax over KV tiles)
  permute       fused token permute / unpermute+weighted-combine (dispatch)
  autotune      shape-keyed block-size selection shared by the kernels
  policy        KernelPolicy switches (rides on core.partitioner.ShardingPlan)
  ops           jit'd public wrappers (interpret on CPU, native on TPU)
  ref           pure-jnp oracles (the allclose targets)

Only ``policy`` is imported eagerly — it is pulled in by the partitioner on
every launch path and must not drag the Pallas machinery along.
"""

from repro.kernels.policy import NULL_POLICY, KernelPolicy

__all__ = ["KernelPolicy", "NULL_POLICY"]
