"""Block-size selection for the Pallas kernels: shape-keyed cache over
cost-model-informed defaults.

Every kernel wrapper asks ``select_blocks(op, shape, dtype)`` for its block
sizes when the caller does not pin them.  Resolution order:

  1. the process-local cache (previous ``select_blocks`` result for the same
     (op, shape, dtype) key, or an explicit ``register`` from a measured
     ``tune`` sweep), then
  2. an analytic default that maximizes MXU-aligned tiles under a VMEM
     working-set budget (the dominant constraint on real TPUs: x-tile +
     w-tile + accumulator must co-reside in ~16 MB/core VMEM).

``tune(op, fn, candidates, *args)`` optionally times candidate block dicts
(interpret mode on CPU, native on TPU) and registers the winner — used by
``benchmarks/kernel_bench.py``; the serving path only ever pays the cheap
analytic default plus one dict lookup per (shape, dtype).

Persistence: measured registrations (``register`` / ``tune``) are written
through to a versioned JSON under ``~/.cache/repro/autotune.json``
(override with ``REPRO_AUTOTUNE_CACHE``; set it to an empty string to
disable).  ``select_blocks`` loads the file lazily on the first in-memory
miss, so a ``tune`` sweep in one process benefits every later process.  A
version mismatch (the block-dict schema changed) silently invalidates the
whole file — stale overrides are worse than the analytic default.
Analytic defaults are never persisted (they are free to recompute).
"""

from __future__ import annotations

import dataclasses
import json
import os
import tempfile
from typing import Callable, Optional

import numpy as _np

# f32 working-set budget per grid step; conservative half of the ~16 MB/core
# VMEM so double-buffered pipelining of the next tiles fits alongside.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024
_MXU = 128


@dataclasses.dataclass(frozen=True)
class KernelKey:
    op: str           # "moe_gemm" | "permute" | "unpermute" | "topk_gate" | ...
    shape: tuple      # the op-defining dims (see each kernel's wrapper)
    dtype: str


_CACHE: dict[KernelKey, dict] = {}

# ---------------------------------------------------------------------------
# Cross-process persistence (measured registrations only)
# ---------------------------------------------------------------------------

# v2: added the "flash_chunk" op key ({bq, bs} block dicts) — v1 files
# predate the ragged mixed-chunk kernel and are invalidated wholesale.
# v3: added "flash_chunk_paged" (bs must divide the KV page size, so its
# defaults differ from flash_chunk's) — v2 files invalidated wholesale.
# v4: added "kv_page" ({page} dicts — the paged-KV page size feeds the
# flash_chunk_paged tile constraint) — v3 files invalidated wholesale.
# v5: added "resolver" ({batch_cap, itl_slack_pct} — the per-host serving
# resolver constants measured by the kernel_bench calibration) — v4 files
# invalidated wholesale.
CACHE_VERSION = 5
_persist_loaded = False


def cache_path() -> Optional[str]:
    """Resolved persistent-cache path, or None when disabled."""
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env is not None:
        return env or None            # "" disables persistence
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def _key_str(key: KernelKey) -> str:
    return f"{key.op}|{','.join(map(str, key.shape))}|{key.dtype}"


def _key_from_str(s: str) -> Optional[KernelKey]:
    try:
        op, shape, dtype = s.split("|")
        return KernelKey(op=op,
                         shape=tuple(int(x) for x in shape.split(",") if x),
                         dtype=dtype)
    except ValueError:
        return None


def _read_persistent() -> dict:
    """{key_str: blocks} from disk; {} on any problem or version skew."""
    path = cache_path()
    if not path or not os.path.exists(path):
        return {}
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}
    if payload.get("version") != CACHE_VERSION:
        return {}                     # stale schema: ignore wholesale
    entries = payload.get("entries")
    return entries if isinstance(entries, dict) else {}


def load_persistent() -> int:
    """Merge the on-disk registrations into the in-memory cache (in-memory
    entries win).  Idempotent per process; returns entries adopted."""
    global _persist_loaded
    _persist_loaded = True
    adopted = 0
    for ks, blocks in _read_persistent().items():
        key = _key_from_str(ks)
        if key is None or key in _CACHE or not isinstance(blocks, dict):
            continue
        _CACHE[key] = {k: int(v) for k, v in blocks.items()}
        adopted += 1
    return adopted


def _write_persistent(key: KernelKey, blocks: dict) -> None:
    path = cache_path()
    if not path:
        return
    try:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        entries = _read_persistent()
        entries[_key_str(key)] = {k: int(v) for k, v in blocks.items()}
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump({"version": CACHE_VERSION, "entries": entries}, f,
                      indent=1)
        os.replace(tmp, path)         # atomic vs concurrent writers
    except OSError:
        pass                          # persistence is best-effort


def cache_key(op: str, shape: tuple, dtype) -> KernelKey:
    # normalize through np.dtype so jnp.float32 (a type), an np.dtype and
    # the string "float32" all land on the same key — a register() with
    # the type object must be found by the serving path's x.dtype lookup
    try:
        dtype = _np.dtype(dtype)
    except TypeError:
        pass
    return KernelKey(op=op, shape=tuple(int(s) for s in shape),
                     dtype=str(dtype))


def register(op: str, shape: tuple, dtype, blocks: dict, *,
             persist: bool = True) -> None:
    key = cache_key(op, shape, dtype)
    _CACHE[key] = dict(blocks)
    if persist:
        _write_persistent(key, blocks)


def cache_info() -> dict:
    """Snapshot of the cache (tests / benchmarks introspection)."""
    return {k: dict(v) for k, v in _CACHE.items()}


def clear_cache(*, persistent: bool = False) -> None:
    global _persist_loaded
    _CACHE.clear()
    _persist_loaded = False           # allow a fresh lazy load
    if persistent:
        path = cache_path()
        if path and os.path.exists(path):
            os.remove(path)


def _bytes(dtype: str) -> int:
    return 2 if "bfloat16" in dtype or "float16" in dtype else 4


def _fit(dim: int, cap: int, align: int = _MXU) -> int:
    """Largest block <= cap covering dim, MXU-aligned once past ``align``."""
    if dim <= align:
        return max(1, dim)
    b = min(cap, dim)
    return max(align, (b // align) * align)


def _default_blocks(op: str, shape: tuple, dtype: str) -> dict:
    el = _bytes(dtype)
    if op == "moe_gemm":
        _, c, h, d = shape
        bc, bh, bd = _fit(c, 512), _fit(h, 512), _fit(d, 512)
        # shrink the largest tile until x(bc,bh) + w(bh,bd) + acc(bc,bd) fits
        while (bc * bh * el + bh * bd * el + bc * bd * 4) > VMEM_BUDGET_BYTES:
            m = max(bc, bh, bd)
            if m <= _MXU:
                break
            if bc == m:
                bc = max(_MXU, bc // 2)
            elif bh == m:
                bh = max(_MXU, bh // 2)
            else:
                bd = max(_MXU, bd // 2)
        return {"bc": bc, "bd": bd, "bh": bh}
    if op == "grouped_gemm":
        n, h, d, _e = shape
        bn, bh, bd = _fit(n, 512), _fit(h, 512), _fit(d, 512)
        # same working-set shrink as moe_gemm: x(bn,bh)+w(bh,bd)+acc(bn,bd);
        # smaller bn also means fewer masked rows per boundary-straddling
        # tile visit, so don't grow bn past the ragged row count.
        while (bn * bh * el + bh * bd * el + bn * bd * 4) > VMEM_BUDGET_BYTES:
            m = max(bn, bh, bd)
            if m <= _MXU:
                break
            if bn == m:
                bn = max(_MXU, bn // 2)
            elif bh == m:
                bh = max(_MXU, bh // 2)
            else:
                bd = max(_MXU, bd // 2)
        return {"bn": bn, "bd": bd, "bh": bh}
    if op in ("permute", "unpermute"):
        # n output rows per grid step; the gather source stays VMEM-resident,
        # so the block only covers the output tile + index/weight columns.
        n, h = shape[0], shape[1]
        bn = 8
        while bn * 2 <= n and bn * 2 * h * el <= VMEM_BUDGET_BYTES // 8:
            bn *= 2
        return {"bn": min(bn, 512)}
    if op == "topk_gate":
        t, e = shape
        bt = 8
        while bt * 2 <= t and bt * 2 * e * 4 <= VMEM_BUDGET_BYTES // 8:
            bt *= 2
        return {"bt": min(bt, 1024)}
    if op == "flash_decode":
        s = shape[1]          # key is k.shape = (B, S, nkv, hd)
        bs = 128
        while bs * 2 <= s and bs <= 1024:
            bs *= 2
        return {"bs": min(bs, 2048)}
    if op == "flash_chunk":
        # key is q.shape + (S,) = (B, sq, nq, hd, S): the q tile covers the
        # chunk (it is small — the token-budget chunk width), the S tile
        # grows with the cache like flash_decode's
        _b, sq, _nq, _hd, s = shape
        bq = 8
        while bq * 2 <= sq and bq <= 64:
            bq *= 2
        bs = 128
        while bs * 2 <= s and bs <= 1024:
            bs *= 2
        return {"bq": min(bq, 128), "bs": min(bs, 2048)}
    if op == "kv_page":
        # key is (max_len, kv_row_els): paged-KV page size.  Not a Pallas
        # tile — it sizes the cache pages the flash_chunk_paged block table
        # routes, so the analytic default is the serving-tier constant (16)
        # degraded to divide the length envelope; a measured ``tune`` sweep
        # (benchmarks/kernel_bench.py) overrides it per shape.
        max_len = shape[0]
        page = 16
        while page > 1 and max_len % page:
            page //= 2
        return {"page": max(page, 1)}
    if op == "flash_chunk_paged":
        # key is q.shape + (P, page) = (B, sq, nq, hd, P, page): q tile as
        # flash_chunk; the KV tile must DIVIDE the page size (the block
        # table routes whole tiles), so take the largest power-of-two
        # divisor of the page up to the flash_chunk cap
        _b, sq, _nq, _hd, _p, page = shape
        bq = 8
        while bq * 2 <= sq and bq <= 64:
            bq *= 2
        bs = 1
        while bs * 2 <= min(page, 2048) and page % (bs * 2) == 0:
            bs *= 2
        if page % bs:                 # odd page size: one tile per page
            bs = page
        return {"bq": min(bq, 128), "bs": bs}
    if op == "resolver":
        # key is (): per-host serving-resolver constants (the cache file is
        # already per-host).  These are the ANALYTIC defaults mirrored from
        # core.resolve — resolved_batch_cap/resolved_itl_slack use lookup()
        # (not select_blocks), so only a measured kernel_bench calibration
        # ever registers this entry; the dict here just keeps the op known.
        return {"batch_cap": 8, "itl_slack_pct": 50}
    raise KeyError(op)


def select_blocks(op: str, shape: tuple, dtype) -> dict:
    """Block sizes for ``op`` on ``shape``/``dtype`` (cached per key).

    Resolution: in-memory cache -> persisted registrations (lazily loaded
    once per process) -> analytic default."""
    key = cache_key(op, shape, dtype)
    hit = _CACHE.get(key)
    if hit is None and not _persist_loaded:
        load_persistent()
        hit = _CACHE.get(key)
    if hit is None:
        hit = _CACHE[key] = _default_blocks(op, key.shape, key.dtype)
    return dict(hit)


def lookup(op: str, shape: tuple, dtype) -> Optional[dict]:
    """A registration for the key, or None — WITHOUT falling back to (or
    caching) the analytic default.  Lets resolver-tier callers tell a
    measured ``tune``/``register`` entry apart from the formula default
    (``select_blocks`` deliberately blurs that line for the hot path)."""
    key = cache_key(op, shape, dtype)
    hit = _CACHE.get(key)
    if hit is None and not _persist_loaded:
        load_persistent()
        hit = _CACHE.get(key)
    return dict(hit) if hit is not None else None


def _key_shape(op: str, args: tuple) -> tuple:
    """The cache-key shape for ``op`` given the kernel's positional args —
    MUST mirror how the ops.py wrappers build their select_blocks keys."""
    if op == "moe_gemm":                  # (x, w) -> (E, C, H, D)
        return tuple(args[0].shape) + (args[1].shape[-1],)
    if op == "grouped_gemm":              # (x, w, offsets) -> (N, H, D, E)
        return tuple(args[0].shape) + (args[1].shape[-1], args[1].shape[0])
    if op in ("permute", "unpermute"):    # (x|buf, idx, ...) -> (N|T, h)
        return (args[1].shape[0], args[0].shape[-1])
    if op == "flash_decode":              # (q, k, v, lens) -> k.shape
        return tuple(args[1].shape)
    if op == "flash_chunk":               # (q, k, v, ...) -> q.shape + (S,)
        return tuple(args[0].shape) + (args[1].shape[1],)
    if op == "flash_chunk_paged":         # (q, kp, vp, bt, ...) -> q + (P, page)
        return tuple(args[0].shape) + (args[1].shape[0], args[1].shape[1])
    return tuple(args[0].shape)           # topk_gate: logits.shape


def tune(op: str, fn: Callable, candidates: list[dict], *args,
         shape: Optional[tuple] = None, dtype=None,
         warmup: int = 1, iters: int = 3) -> dict:
    """Time ``fn(*args, **blocks)`` per candidate, register + return the best.

    ``shape``/``dtype`` default to the key the ops.py wrapper for ``op``
    would build from the same arguments, so a tuned registration is
    guaranteed to be the one the serving path looks up.  Measured walltime
    only means something on the backend it ran on — and the winner IS
    persisted (via ``register``) for later processes on this machine, so
    don't ship a cache file tuned in interpret mode to a TPU host; set
    ``REPRO_AUTOTUNE_CACHE=""`` to keep a tuning run process-local.
    """
    import time as _time

    import jax

    if shape is None:
        shape = _key_shape(op, args)
    if dtype is None:
        dtype = args[0].dtype
    best, best_t = None, float("inf")
    for blocks in candidates:
        try:
            for _ in range(warmup):
                jax.block_until_ready(fn(*args, **blocks))
            ts = []
            for _ in range(iters):
                t0 = _time.perf_counter()
                jax.block_until_ready(fn(*args, **blocks))
                ts.append(_time.perf_counter() - t0)
            t = sorted(ts)[len(ts) // 2]
        except Exception:
            continue
        if t < best_t:
            best, best_t = blocks, t
    if best is None:
        best = _default_blocks(op, tuple(shape), str(dtype))
    register(op, shape, dtype, best)
    return dict(best)


__all__ = ["select_blocks", "lookup", "register", "tune", "cache_info",
           "clear_cache", "cache_key", "cache_path", "load_persistent",
           "CACHE_VERSION", "KernelKey", "VMEM_BUDGET_BYTES"]
