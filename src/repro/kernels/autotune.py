"""Block-size selection for the Pallas kernels: shape-keyed cache over
cost-model-informed defaults.

Every kernel wrapper asks ``select_blocks(op, shape, dtype)`` for its block
sizes when the caller does not pin them.  Resolution order:

  1. the process-local cache (previous ``select_blocks`` result for the same
     (op, shape, dtype) key, or an explicit ``register`` from a measured
     ``tune`` sweep), then
  2. an analytic default that maximizes MXU-aligned tiles under a VMEM
     working-set budget (the dominant constraint on real TPUs: x-tile +
     w-tile + accumulator must co-reside in ~16 MB/core VMEM).

``tune(op, fn, candidates, *args)`` optionally times candidate block dicts
(interpret mode on CPU, native on TPU) and registers the winner — used by
``benchmarks/kernel_bench.py``; the serving path only ever pays the cheap
analytic default plus one dict lookup per (shape, dtype).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

# f32 working-set budget per grid step; conservative half of the ~16 MB/core
# VMEM so double-buffered pipelining of the next tiles fits alongside.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024
_MXU = 128


@dataclasses.dataclass(frozen=True)
class KernelKey:
    op: str           # "moe_gemm" | "permute" | "unpermute" | "topk_gate" | ...
    shape: tuple      # the op-defining dims (see each kernel's wrapper)
    dtype: str


_CACHE: dict[KernelKey, dict] = {}


def cache_key(op: str, shape: tuple, dtype) -> KernelKey:
    return KernelKey(op=op, shape=tuple(int(s) for s in shape),
                     dtype=str(dtype))


def register(op: str, shape: tuple, dtype, blocks: dict) -> None:
    _CACHE[cache_key(op, shape, dtype)] = dict(blocks)


def cache_info() -> dict:
    """Snapshot of the cache (tests / benchmarks introspection)."""
    return {k: dict(v) for k, v in _CACHE.items()}


def clear_cache() -> None:
    _CACHE.clear()


def _bytes(dtype: str) -> int:
    return 2 if "bfloat16" in dtype or "float16" in dtype else 4


def _fit(dim: int, cap: int, align: int = _MXU) -> int:
    """Largest block <= cap covering dim, MXU-aligned once past ``align``."""
    if dim <= align:
        return max(1, dim)
    b = min(cap, dim)
    return max(align, (b // align) * align)


def _default_blocks(op: str, shape: tuple, dtype: str) -> dict:
    el = _bytes(dtype)
    if op == "moe_gemm":
        _, c, h, d = shape
        bc, bh, bd = _fit(c, 512), _fit(h, 512), _fit(d, 512)
        # shrink the largest tile until x(bc,bh) + w(bh,bd) + acc(bc,bd) fits
        while (bc * bh * el + bh * bd * el + bc * bd * 4) > VMEM_BUDGET_BYTES:
            m = max(bc, bh, bd)
            if m <= _MXU:
                break
            if bc == m:
                bc = max(_MXU, bc // 2)
            elif bh == m:
                bh = max(_MXU, bh // 2)
            else:
                bd = max(_MXU, bd // 2)
        return {"bc": bc, "bd": bd, "bh": bh}
    if op in ("permute", "unpermute"):
        # n output rows per grid step; the gather source stays VMEM-resident,
        # so the block only covers the output tile + index/weight columns.
        n, h = shape[0], shape[1]
        bn = 8
        while bn * 2 <= n and bn * 2 * h * el <= VMEM_BUDGET_BYTES // 8:
            bn *= 2
        return {"bn": min(bn, 512)}
    if op == "topk_gate":
        t, e = shape
        bt = 8
        while bt * 2 <= t and bt * 2 * e * 4 <= VMEM_BUDGET_BYTES // 8:
            bt *= 2
        return {"bt": min(bt, 1024)}
    if op == "flash_decode":
        s = shape[1]          # key is k.shape = (B, S, nkv, hd)
        bs = 128
        while bs * 2 <= s and bs <= 1024:
            bs *= 2
        return {"bs": min(bs, 2048)}
    raise KeyError(op)


def select_blocks(op: str, shape: tuple, dtype) -> dict:
    """Block sizes for ``op`` on ``shape``/``dtype`` (cached per key)."""
    key = cache_key(op, shape, dtype)
    hit = _CACHE.get(key)
    if hit is None:
        hit = _CACHE[key] = _default_blocks(op, key.shape, key.dtype)
    return dict(hit)


def _key_shape(op: str, args: tuple) -> tuple:
    """The cache-key shape for ``op`` given the kernel's positional args —
    MUST mirror how the ops.py wrappers build their select_blocks keys."""
    if op == "moe_gemm":                  # (x, w) -> (E, C, H, D)
        return tuple(args[0].shape) + (args[1].shape[-1],)
    if op in ("permute", "unpermute"):    # (x|buf, idx, ...) -> (N|T, h)
        return (args[1].shape[0], args[0].shape[-1])
    if op == "flash_decode":              # (q, k, v, lens) -> k.shape
        return tuple(args[1].shape)
    return tuple(args[0].shape)           # topk_gate: logits.shape


def tune(op: str, fn: Callable, candidates: list[dict], *args,
         shape: Optional[tuple] = None, dtype=None,
         warmup: int = 1, iters: int = 3) -> dict:
    """Time ``fn(*args, **blocks)`` per candidate, register + return the best.

    ``shape``/``dtype`` default to the key the ops.py wrapper for ``op``
    would build from the same arguments, so a tuned registration is
    guaranteed to be the one the serving path looks up.  Measured walltime
    only means something on the backend it ran on; the cache is
    process-local on purpose.
    """
    import time as _time

    import jax

    if shape is None:
        shape = _key_shape(op, args)
    if dtype is None:
        dtype = args[0].dtype
    best, best_t = None, float("inf")
    for blocks in candidates:
        try:
            for _ in range(warmup):
                jax.block_until_ready(fn(*args, **blocks))
            ts = []
            for _ in range(iters):
                t0 = _time.perf_counter()
                jax.block_until_ready(fn(*args, **blocks))
                ts.append(_time.perf_counter() - t0)
            t = sorted(ts)[len(ts) // 2]
        except Exception:
            continue
        if t < best_t:
            best, best_t = blocks, t
    if best is None:
        best = _default_blocks(op, tuple(shape), str(dtype))
    register(op, shape, dtype, best)
    return dict(best)


__all__ = ["select_blocks", "register", "tune", "cache_info", "clear_cache",
           "cache_key", "KernelKey", "VMEM_BUDGET_BYTES"]
