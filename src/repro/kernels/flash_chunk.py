"""Ragged mixed-chunk flash attention (Pallas TPU) — the unified-step kernel.

One kernel family serves every slot shape of the unified mixed
prefill/decode serving iteration: slot ``i`` contributes ``q_len[i]`` query
rows (a prefill chunk, one decode token, or 0 = idle) at absolute cache
offset ``q_offset[i]`` against a cache whose valid prefix is ``kv_len[i]``.

Grid ``(batch, kv_head, q-tile, S-tile)``; the S dimension is the innermost
sequential axis so the online-softmax state (m, l, acc) lives in VMEM
scratch across KV tiles (same schedule as the old ``flash_decode``, which
is now the ``sq == 1`` specialization of this kernel — see
``flash_decode.py``).  Per-slot ``q_offset``/``q_len``/``kv_len`` arrive
via scalar prefetch, so raggedness is handled at *tile* granularity:

  * KV tiles past a slot's causal frontier (``q_offset + q_tile_hi``) or
    past its ``kv_len`` are skipped entirely — a ``pl.when`` gates the MXU
    work and the k/v BlockSpec index maps clamp to the frontier tile, so
    the pipeline re-uses the resident block instead of streaming dead
    cache lines from HBM;
  * q tiles past ``q_len`` (the ragged tail) and idle slots
    (``q_len == 0``) do zero compute — their output rows are written as
    exact zeros (finite; callers never read them);
  * ``kv_len == 0`` is masked natively: no caller-side length floor needed.

Causal masking inside a live tile is per element: query row ``r`` of slot
``i`` sees keys ``pos <= q_offset[i] + r`` and ``pos < kv_len[i]``.  GQA
runs ``g = nq // nkv`` query groups per kv head; MLA-absorbed decode is the
``nkv == 1`` case with ``hdv != hd`` (latent keys carry the decoupled-rope
dims, values are the bare latent).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_chunk_kernel(meta_ref, q_ref, k_ref, v_ref, o_ref,
                        m_ref, l_ref, acc_ref, *,
                        bq: int, bs: int, g: int, scale: float):
    bi, qi, j = pl.program_id(0), pl.program_id(2), pl.program_id(3)
    q_off = meta_ref[0, bi]
    q_len = meta_ref[1, bi]
    kv_len = meta_ref[2, bi]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # rows of this q tile that are real queries, and the furthest key any of
    # them may see (the causal frontier, clipped to the cache's valid prefix)
    row_hi = jnp.minimum(q_len - qi * bq, bq)
    kv_limit = jnp.minimum(kv_len, q_off + qi * bq + row_hi)

    @pl.when((row_hi > 0) & (j * bs < kv_limit))
    def _tile():
        q = q_ref[0, :, 0].astype(jnp.float32)           # (bq, g, hd)
        q = q.reshape(bq * g, q.shape[-1]) * scale
        k = k_ref[0, :, 0].astype(jnp.float32)           # (bs, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)           # (bs, hdv)

        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq*g, bs)
        row = (qi * bq
               + jax.lax.broadcasted_iota(jnp.int32, (bq * g, bs), 0) // g)
        pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (bq * g, bs), 1)
        mask = (row < q_len) & (pos < kv_len) & (pos <= q_off + row)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))  # (bq*g, 1)
        # all-masked rows have m_new == NEG_INF and exp(s - m_new) == 1;
        # re-masking p keeps their l at 0 so the flush emits exact zeros
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(3) - 1)
    def _flush():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, :, 0] = out.reshape(bq, g, -1).astype(o_ref.dtype)


def _kv_tile_index(bi, hi, qi, j, m, *, bq: int, bs: int):
    """Clamp the KV tile index to the slot's frontier tile.

    Past the frontier the compute is pl.when-gated off anyway; clamping the
    index map means consecutive grid steps keep asking for the SAME block,
    which the Pallas pipeline recognizes and does not re-fetch — dead cache
    lines never leave HBM.
    """
    row_hi = jnp.minimum(m[1, bi] - qi * bq, bq)
    kv_limit = jnp.minimum(m[2, bi], m[0, bi] + qi * bq + row_hi)
    last = jnp.maximum((kv_limit - 1) // bs, 0)
    return bi, jnp.minimum(j, last), hi, 0


@functools.partial(jax.jit,
                   static_argnames=("bq", "bs", "scale", "interpret"))
def flash_chunk(q, k, v, q_offset, q_len, kv_len, *, bq: int = 128,
                bs: int = 512, scale: float = None,
                interpret: bool = False):
    """q (B, sq, nq, hd); k (B, S, nkv, hd); v (B, S, nkv, hdv);
    q_offset / q_len / kv_len (B,) int32 -> (B, sq, nq, hdv).

    Slot ``i``'s first ``q_len[i]`` rows are real queries at absolute
    positions ``q_offset[i] + r``; rows past ``q_len[i]`` come back as
    exact zeros.  ``scale`` defaults to ``hd ** -0.5``.
    """
    b, sq, nq, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    g = nq // nkv
    if scale is None:
        scale = hd ** -0.5
    bq = min(bq, sq)
    bs = min(bs, skv)
    pq, ps = (-sq) % bq, (-skv) % bs
    qg = q.reshape(b, sq, nkv, g, hd)
    if pq:
        qg = jnp.pad(qg, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    if ps:
        k = jnp.pad(k, ((0, 0), (0, ps), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, ps), (0, 0), (0, 0)))
    sqp, sp = sq + pq, skv + ps

    meta = jnp.stack([
        jnp.broadcast_to(jnp.atleast_1d(q_offset), (b,)),
        jnp.broadcast_to(jnp.atleast_1d(q_len), (b,)),
        jnp.broadcast_to(jnp.atleast_1d(kv_len), (b,)),
    ]).astype(jnp.int32)                                  # (3, B)

    kv_index = functools.partial(_kv_tile_index, bq=bq, bs=bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, nkv, sqp // bq, sp // bs),
        in_specs=[
            pl.BlockSpec((1, bq, 1, g, hd),
                         lambda bi, hi, qi, j, m: (bi, qi, hi, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), kv_index),
            pl.BlockSpec((1, bs, 1, hdv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, g, hdv),
                               lambda bi, hi, qi, j, m: (bi, qi, hi, 0, 0)),
        scratch_shapes=[pltpu.VMEM((bq * g, 1), jnp.float32),
                        pltpu.VMEM((bq * g, 1), jnp.float32),
                        pltpu.VMEM((bq * g, hdv), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_flash_chunk_kernel, bq=bq, bs=bs, g=g,
                          scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sqp, nkv, g, hdv), q.dtype),
        interpret=interpret,
    )(meta, qg, k, v)
    return out[:, :sq].reshape(b, sq, nq, hdv)


# ---------------------------------------------------------------------------
# Paged variant: KV tiles fetched through a per-slot block table
# ---------------------------------------------------------------------------

def _paged_kernel(meta_ref, bt_ref, *rest, **kw):
    # the block table is consumed by the index maps only; the kernel body is
    # the DENSE body verbatim (rows/positions are logical) — which is what
    # makes the paged output bit-identical to flash_chunk at equal (bq, bs)
    _flash_chunk_kernel(meta_ref, *rest, **kw)


def _paged_kv_tile_index(bi, hi, qi, j, m, bt, *, bq: int, bs: int, ps: int):
    """Frontier-clamped logical KV tile ``j`` -> (page, tile-in-page).

    Same causal-frontier clamp as ``_kv_tile_index`` (dead tiles re-request
    the resident block), then the logical tile routes through the slot's
    block table: page ``bt[bi, tile // (ps // bs)]``.  Unallocated entries
    (−1, beyond the slot's length by the host allocator's invariant) clamp
    to page 0 — their keys sit past ``kv_len`` and are masked in-kernel.
    """
    row_hi = jnp.minimum(m[1, bi] - qi * bq, bq)
    kv_limit = jnp.minimum(m[2, bi], m[0, bi] + qi * bq + row_hi)
    last = jnp.maximum((kv_limit - 1) // bs, 0)
    jj = jnp.minimum(j, last)
    tpp = ps // bs                    # KV tiles per page
    page = jnp.maximum(bt[bi, jj // tpp], 0)
    return page, jj % tpp, hi, 0


@functools.partial(jax.jit,
                   static_argnames=("bq", "bs", "scale", "interpret"))
def flash_chunk_paged(q, k_pages, v_pages, block_tables, q_offset, q_len,
                      kv_len, *, bq: int = 128, bs: int = None,
                      scale: float = None, interpret: bool = False):
    """``flash_chunk`` against a PAGED cache: q (B, sq, nq, hd);
    k_pages (P, page, nkv, hd); v_pages (P, page, nkv, hdv);
    block_tables (B, max_blocks) int32 -> (B, sq, nq, hdv).

    Slot ``i``'s logical KV row ``t`` lives at
    ``pages[block_tables[i, t // page], t % page]``; the indirection is
    resolved entirely in the BlockSpec index map (scalar-prefetched block
    tables), so the kernel body — and therefore the arithmetic, the online
    softmax order, and the result bits — is ``flash_chunk``'s verbatim.
    ``bs`` must divide the page size (default: one tile per page).
    """
    b, sq, nq, hd = q.shape
    n_pages, page = k_pages.shape[0], k_pages.shape[1]
    nkv = k_pages.shape[2]
    hdv = v_pages.shape[-1]
    nb = block_tables.shape[1]
    g = nq // nkv
    if scale is None:
        scale = hd ** -0.5
    bs = page if bs is None else min(bs, page)
    if page % bs:
        raise ValueError(f"bs {bs} must divide the page size {page}")
    bq = min(bq, sq)
    pq = (-sq) % bq
    qg = q.reshape(b, sq, nkv, g, hd)
    if pq:
        qg = jnp.pad(qg, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    sqp = sq + pq

    meta = jnp.stack([
        jnp.broadcast_to(jnp.atleast_1d(q_offset), (b,)),
        jnp.broadcast_to(jnp.atleast_1d(q_len), (b,)),
        jnp.broadcast_to(jnp.atleast_1d(kv_len), (b,)),
    ]).astype(jnp.int32)                                  # (3, B)

    kv_index = functools.partial(_paged_kv_tile_index, bq=bq, bs=bs, ps=page)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,        # meta (3, B) + block tables (B, nb)
        grid=(b, nkv, sqp // bq, nb * (page // bs)),
        in_specs=[
            pl.BlockSpec((1, bq, 1, g, hd),
                         lambda bi, hi, qi, j, m, bt: (bi, qi, hi, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), kv_index),
            pl.BlockSpec((1, bs, 1, hdv), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, g, hdv),
                               lambda bi, hi, qi, j, m, bt: (bi, qi, hi, 0, 0)),
        scratch_shapes=[pltpu.VMEM((bq * g, 1), jnp.float32),
                        pltpu.VMEM((bq * g, 1), jnp.float32),
                        pltpu.VMEM((bq * g, hdv), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, bq=bq, bs=bs, g=g, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sqp, nkv, g, hdv), q.dtype),
        interpret=interpret,
    )(meta, block_tables.astype(jnp.int32), qg, k_pages, v_pages)
    return out[:, :sq].reshape(b, sq, nq, hdv)


__all__ = ["flash_chunk", "flash_chunk_paged"]
