"""Flash-decode: single-token attention over a long KV cache (Pallas TPU).

Grid (batch, kv_head, S-tiles); the S dimension is the innermost sequential
axis so the online-softmax state (m, l, acc) lives in VMEM scratch across
tiles.  Per tile: one (g, bs) MXU dot for scores + one (bs, hd) dot for
values, masked by the per-request cache length.

This is the TPU-native version of the decode path that
``models.layers.decode_attention`` runs in pure JAX (and that the dry-run
shards kv_seq-over-model); the kernel is the per-shard compute body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, bs: int, scale: float):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (g, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)               # (bs, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)               # (bs, hdv)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (g, bs)
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))   # (g, 1)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "scale", "interpret"))
def flash_decode(q, k, v, lengths, *, bs: int = 512, scale: float = None,
                 interpret: bool = False):
    """q (B, nq, hd); k (B, S, nkv, hd); v (B, S, nkv, hdv); lengths (B,)
    -> (B, nq, hdv).

    ``hdv`` may differ from ``hd`` (MLA absorbed decode: latent keys carry
    the decoupled-rope dims, values are the bare latent); ``scale`` defaults
    to hd**-0.5.
    """
    b, nq, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    g = nq // nkv
    if scale is None:
        scale = hd ** -0.5
    bs = min(bs, skv)
    ps = (-skv) % bs
    if ps:
        k = jnp.pad(k, ((0, 0), (0, ps), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, ps), (0, 0), (0, 0)))
    sp = skv + ps

    qg = q.reshape(b, nkv, g, hd)
    # (B, S, nkv, hd) -> (B, nkv, S, hd) handled via BlockSpec index map on
    # the padded arrays directly (avoids a transpose copy in HBM).
    out = pl.pallas_call(
        functools.partial(_flash_decode_kernel, bs=bs, scale=scale),
        grid=(b, nkv, sp // bs),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, ji: (bi,)),
            pl.BlockSpec((1, 1, g, hd), lambda bi, hi, ji: (bi, hi, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda bi, hi, ji: (bi, ji, hi, 0)),
            pl.BlockSpec((1, bs, 1, hdv), lambda bi, hi, ji: (bi, ji, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hdv),
                               lambda bi, hi, ji: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nkv, g, hdv), q.dtype),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, hdv), jnp.float32)],
        interpret=interpret,
    )(lengths, qg, k, v)
    return out.reshape(b, nq, hdv)


__all__ = ["flash_decode"]
