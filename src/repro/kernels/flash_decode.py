"""Flash-decode: single-token attention over a long KV cache.

Since the ragged mixed-chunk kernel landed, flash-decode is the ``sq == 1``
specialization of ``kernels.flash_chunk``: the decode invariant
``q_position == kv_len - 1`` maps onto ``q_offset = lengths - 1``,
``q_len = min(lengths, 1)``, ``kv_len = lengths`` — with ``bq = 1`` the
grid degenerates to the classic ``(batch, kv_head, S-tile)`` flash-decode
schedule (one (g, bs) MXU dot for scores + one (bs, hdv) dot for values per
tile, online-softmax (m, l, acc) state in VMEM scratch).

``lengths == 0`` (an idle slot of the unified mixed step) is masked
natively by the chunk kernel and yields an exact-zero row — callers no
longer need the old ``max(lengths, 1)`` floor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_chunk import NEG_INF, flash_chunk

__all__ = ["flash_decode", "NEG_INF"]


@functools.partial(jax.jit, static_argnames=("bs", "scale", "interpret"))
def flash_decode(q, k, v, lengths, *, bs: int = 512, scale: float = None,
                 interpret: bool = False):
    """q (B, nq, hd); k (B, S, nkv, hd); v (B, S, nkv, hdv); lengths (B,)
    -> (B, nq, hdv).

    ``hdv`` may differ from ``hd`` (MLA absorbed decode: latent keys carry
    the decoupled-rope dims, values are the bare latent); ``scale`` defaults
    to hd**-0.5.  A slot with ``lengths == 0`` returns an exact-zero row.
    """
    lengths = jnp.asarray(lengths, jnp.int32)
    out = flash_chunk(q[:, None], k, v,
                      q_offset=jnp.maximum(lengths - 1, 0),
                      q_len=jnp.minimum(lengths, 1),
                      kv_len=lengths,
                      bq=1, bs=bs, scale=scale, interpret=interpret)
    return out[:, 0]
