"""Grouped expert GEMMs — the MoE hot-spot — as Pallas TPU kernels.

Two variants share the same MXU-tiled matmul-pipeline structure (innermost
grid dimension walks H so the f32 VMEM accumulator lives across sequential
grid steps; HBM->VMEM streaming of x/w tiles, MXU dot per step):

  moe_gemm      capacity-mode batched GEMM on dense (E, C, H) dispatch
                buffers — one (expert, C-tile, D-tile) output block per grid
                cell.  Compute volume is E*C rows regardless of load.

  grouped_gemm  dropless-mode segment GEMM on a ragged (N, H) buffer sorted
                by expert, with an ``group_offsets`` (E+1,) prefix-sum
                delimiting each expert's rows.  The grid walks *tile visits*:
                each row tile is visited once per expert segment overlapping
                it (megablocks/Megatron "grouped GEMM" under TPU
                constraints), so compute volume is sum(counts) = N = T*k rows
                — independent of E and of the capacity factor.  Segment
                boundaries are dynamic *values* (static shapes), delivered to
                the index maps through scalar prefetch.

TPU adaptation (DESIGN.md §2): the paper's NPU/GPU expert GEMMs become one
MXU-tiled grouped GEMM over the dispatch buffers that the fused AR-A2A
communication delivers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_gemm_kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(3) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bc", "bd", "bh", "interpret"))
def moe_gemm(x, w, *, bc: int = 128, bd: int = 128, bh: int = 128,
             interpret: bool = False):
    """x (E, C, H) @ w (E, H, D) -> (E, C, D), per-expert batched."""
    e, c, h = x.shape
    d = w.shape[-1]
    bc, bh, bd = min(bc, c), min(bh, h), min(bd, d)
    pc, ph, pd = (-c) % bc, (-h) % bh, (-d) % bd
    if pc or ph:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, ph)))
    if ph or pd:
        w = jnp.pad(w, ((0, 0), (0, ph), (0, pd)))
    cp, hp, dp = c + pc, h + ph, d + pd

    out = pl.pallas_call(
        _moe_gemm_kernel,
        grid=(e, cp // bc, dp // bd, hp // bh),
        in_specs=[
            pl.BlockSpec((1, bc, bh), lambda ei, ci, di, hi: (ei, ci, hi)),
            pl.BlockSpec((1, bh, bd), lambda ei, ci, di, hi: (ei, hi, di)),
        ],
        out_specs=pl.BlockSpec((1, bc, bd),
                               lambda ei, ci, di, hi: (ei, ci, di)),
        out_shape=jax.ShapeDtypeStruct((e, cp, dp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :c, :d]


# ---------------------------------------------------------------------------
# Dropless segment GEMM (group-offset grid)
# ---------------------------------------------------------------------------

def _group_metadata(group_offsets, n: int, bn: int, e: int,
                    num_tiles: int, n_visits: int):
    """Per-visit schedule for the segment GEMM — all static shapes.

    A *visit* is one (row-tile, expert) pair with a non-empty intersection;
    a row tile that straddles a segment boundary is visited once per
    overlapping expert, with out-of-segment rows masked in the kernel.
    Returns (5, n_visits) int32: [tile_id, group_id, row_start, row_end,
    first_visit_of_tile].

    Every tile gets exactly one first_visit=1 write: row tiles past the
    ragged extent (offsets[-1]) receive one all-masked visit each, so their
    output blocks are written with exact zeros — never left uninitialized
    (interpret mode would leave NaN; native TPU, undefined VMEM).  Visits
    beyond that land on the last tile with first_visit=0 and an empty row
    range, accumulating an exact zero into defined data.
    """
    counts = group_offsets[1:] - group_offsets[:-1]              # (E,)
    first_tile = group_offsets[:-1] // bn
    last_tile = jnp.maximum(group_offsets[1:] - 1, 0) // bn
    tiles_touched = jnp.where(counts > 0, last_tile - first_tile + 1, 0)
    cs = jnp.cumsum(tiles_touched)                               # (E,)
    total = cs[-1]
    v = jnp.arange(n_visits, dtype=jnp.int32)
    g = jnp.searchsorted(cs, v, side="right").astype(jnp.int32)
    g = jnp.minimum(g, e - 1)
    visit_start = cs[g] - tiles_touched[g]
    tile = first_tile[g] + (v - visit_start)
    valid = v < total
    # trailing tiles not covered by any segment: one zero-writing visit each
    lt = jnp.where(total > 0,
                   (jnp.maximum(group_offsets[-1], 1) - 1) // bn, -1)
    pad_tile = lt + 1 + (v - total)                   # for v in [total, ...)
    pad = (~valid) & (pad_tile <= num_tiles - 1)
    tile_ids = jnp.where(valid, tile,
                         jnp.where(pad, pad_tile,
                                   num_tiles - 1)).astype(jnp.int32)
    group_ids = jnp.where(valid, g, 0).astype(jnp.int32)
    row_start = jnp.where(valid, group_offsets[g], 0).astype(jnp.int32)
    row_end = jnp.where(valid, group_offsets[g + 1], 0).astype(jnp.int32)
    prev = jnp.concatenate([jnp.full((1,), -1, jnp.int32), tile_ids[:-1]])
    first = ((valid | pad) & (tile_ids != prev)).astype(jnp.int32)
    return jnp.stack([tile_ids, group_ids, row_start, row_end, first])


def _grouped_gemm_kernel(meta_ref, x_ref, w_ref, o_ref, acc_ref, *, bn: int):
    vi = pl.program_id(1)

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = (meta_ref[0, vi] * bn
            + jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0))
    mask = (rows >= meta_ref[2, vi]) & (rows < meta_ref[3, vi])
    xt = jnp.where(mask, x_ref[...], jnp.zeros_like(x_ref[...]))
    acc_ref[...] += jnp.dot(xt, w_ref[0], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        acc = acc_ref[...].astype(o_ref.dtype)
        # revisited tiles (segment boundary inside the tile) accumulate into
        # the still-VMEM-resident output block; the first visit overwrites it
        o_ref[...] = jnp.where(meta_ref[4, vi] == 1, acc, o_ref[...] + acc)


@functools.partial(jax.jit, static_argnames=("bn", "bd", "bh", "interpret"))
def grouped_gemm(x, w, group_offsets, *, bn: int = 128, bd: int = 128,
                 bh: int = 128, interpret: bool = False):
    """x (N, H) sorted by expert @ w (E, H, D) -> (N, D), per-segment.

    ``group_offsets`` (E+1,) int32 ascending prefix-sum: expert e owns rows
    [offsets[e], offsets[e+1]).  Rows at/after ``offsets[-1]`` belong to no
    segment; their output is exact zeros (the ragged EP exchange pads with
    zero rows that callers never read, but the kernel still writes every
    block — no uninitialized output memory).
    """
    n, h = x.shape
    e, _, d = w.shape
    bn, bh, bd = min(bn, n), min(bh, h), min(bd, d)
    pn, ph, pd = (-n) % bn, (-h) % bh, (-d) % bd
    if pn or ph:
        x = jnp.pad(x, ((0, pn), (0, ph)))
    if ph or pd:
        w = jnp.pad(w, ((0, 0), (0, ph), (0, pd)))
    np_, hp, dp = n + pn, h + ph, d + pd
    num_tiles = np_ // bn
    # static visit bound: every tile once + one extra visit per boundary
    n_visits = num_tiles + min(e, max(n, 1))
    meta = _group_metadata(group_offsets.astype(jnp.int32), n, bn, e,
                           num_tiles, n_visits)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(dp // bd, n_visits, hp // bh),
        in_specs=[
            pl.BlockSpec((bn, bh), lambda di, vi, hi, m: (m[0, vi], hi)),
            pl.BlockSpec((1, bh, bd),
                         lambda di, vi, hi, m: (m[1, vi], hi, di)),
        ],
        out_specs=pl.BlockSpec((bn, bd),
                               lambda di, vi, hi, m: (m[0, vi], di)),
        scratch_shapes=[pltpu.VMEM((bn, bd), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_grouped_gemm_kernel, bn=bn),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((np_, dp), x.dtype),
        interpret=interpret,
    )(meta, x, w)
    return out[:n, :d]


__all__ = ["moe_gemm", "grouped_gemm"]
