"""Grouped expert GEMM — the MoE hot-spot — as a Pallas TPU kernel.

One (expert, C-tile, D-tile) output block per grid cell, accumulating over
the contraction (H) dimension in a VMEM f32 scratch.  Block shapes default to
MXU-aligned 128x128 tiles; the innermost grid dimension walks H so the
accumulator lives across sequential grid steps (standard TPU matmul-pipeline
structure: HBM->VMEM streaming of x/w tiles, MXU dot per step).

TPU adaptation (DESIGN.md §2): the paper's NPU/GPU expert GEMMs become one
MXU-tiled grouped GEMM over the (E, capacity, H) dispatch buffers that the
fused AR-A2A communication delivers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_gemm_kernel(x_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(3) == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0], w_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bc", "bd", "bh", "interpret"))
def moe_gemm(x, w, *, bc: int = 128, bd: int = 128, bh: int = 128,
             interpret: bool = False):
    """x (E, C, H) @ w (E, H, D) -> (E, C, D), per-expert batched."""
    e, c, h = x.shape
    d = w.shape[-1]
    bc, bh, bd = min(bc, c), min(bh, h), min(bd, d)
    pc, ph, pd = (-c) % bc, (-h) % bh, (-d) % bd
    if pc or ph:
        x = jnp.pad(x, ((0, 0), (0, pc), (0, ph)))
    if ph or pd:
        w = jnp.pad(w, ((0, 0), (0, ph), (0, pd)))
    cp, hp, dp = c + pc, h + ph, d + pd

    out = pl.pallas_call(
        _moe_gemm_kernel,
        grid=(e, cp // bc, dp // bd, hp // bh),
        in_specs=[
            pl.BlockSpec((1, bc, bh), lambda ei, ci, di, hi: (ei, ci, hi)),
            pl.BlockSpec((1, bh, bd), lambda ei, ci, di, hi: (ei, hi, di)),
        ],
        out_specs=pl.BlockSpec((1, bc, bd),
                               lambda ei, ci, di, hi: (ei, ci, di)),
        out_shape=jax.ShapeDtypeStruct((e, cp, dp), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :c, :d]


__all__ = ["moe_gemm"]
