"""Jit'd public wrappers for the Pallas kernels.

On a TPU backend the kernels lower natively; elsewhere (this CPU container)
they execute in interpret mode, which runs the kernel body in Python and is
what the allclose sweep tests validate against ``ref.py``.

Block sizes default to the autotune layer's shape-keyed selection
(``kernels.autotune``); callers can still pin them explicitly.

``counters`` tallies wrapper invocations at trace time — inside ``jax.jit``
each entry counts traced call sites (once per compilation), which is exactly
what the integration tests assert: the jitted serving graph *contains* the
kernel, not merely could reach it.
"""

from __future__ import annotations

import collections

import jax

from repro.kernels import autotune, ref
from repro.kernels.flash_chunk import flash_chunk as _flash_chunk
from repro.kernels.flash_chunk import flash_chunk_paged as _flash_chunk_paged
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.moe_gemm import grouped_gemm as _grouped_gemm
from repro.kernels.moe_gemm import moe_gemm as _moe_gemm
from repro.kernels.permute import permute_tokens as _permute_tokens
from repro.kernels.permute import (permute_tokens_ragged
                                   as _permute_tokens_ragged)
from repro.kernels.permute import unpermute_tokens as _unpermute_tokens
from repro.kernels.topk_gate import topk_gate as _topk_gate

counters: collections.Counter = collections.Counter()


def reset_counters() -> None:
    counters.clear()


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def moe_gemm(x, w, **kw):
    counters["moe_gemm"] += 1
    kw.setdefault("interpret", _interpret())
    if not {"bc", "bd", "bh"} & kw.keys():
        e, c, h = x.shape
        blocks = autotune.select_blocks(
            "moe_gemm", (e, c, h, w.shape[-1]), x.dtype)
        kw.update(blocks)
    return _moe_gemm(x, w, **kw)


def grouped_gemm(x, w, group_offsets, **kw):
    counters["grouped_gemm"] += 1
    kw.setdefault("interpret", _interpret())
    if not {"bn", "bd", "bh"} & kw.keys():
        n, h = x.shape
        kw.update(autotune.select_blocks(
            "grouped_gemm", (n, h, w.shape[-1], w.shape[0]), x.dtype))
    return _grouped_gemm(x, w, group_offsets, **kw)


def topk_gate(logits, k: int, **kw):
    counters["topk_gate"] += 1
    kw.setdefault("interpret", _interpret())
    if "bt" not in kw:
        kw.update(autotune.select_blocks("topk_gate", logits.shape,
                                         logits.dtype))
    return _topk_gate(logits, k, **kw)


def flash_decode(q, k, v, lengths, **kw):
    counters["flash_decode"] += 1
    kw.setdefault("interpret", _interpret())
    if "bs" not in kw:
        kw.update(autotune.select_blocks("flash_decode", k.shape, k.dtype))
    return _flash_decode(q, k, v, lengths, **kw)


def flash_chunk(q, k, v, q_offset, q_len, kv_len, **kw):
    counters["flash_chunk"] += 1
    kw.setdefault("interpret", _interpret())
    if not {"bq", "bs"} & kw.keys():
        kw.update(autotune.select_blocks(
            "flash_chunk", tuple(q.shape) + (k.shape[1],), q.dtype))
    return _flash_chunk(q, k, v, q_offset, q_len, kv_len, **kw)


def flash_chunk_paged(q, k_pages, v_pages, block_tables, q_offset, q_len,
                      kv_len, **kw):
    counters["flash_chunk_paged"] += 1
    kw.setdefault("interpret", _interpret())
    if not {"bq", "bs"} & kw.keys():
        kw.update(autotune.select_blocks(
            "flash_chunk_paged",
            tuple(q.shape) + (k_pages.shape[0], k_pages.shape[1]), q.dtype))
    return _flash_chunk_paged(q, k_pages, v_pages, block_tables,
                              q_offset, q_len, kv_len, **kw)


def permute_tokens(x, src_tok, **kw):
    counters["permute_tokens"] += 1
    kw.setdefault("interpret", _interpret())
    if "bn" not in kw:
        kw.update(autotune.select_blocks(
            "permute", (src_tok.shape[0], x.shape[-1]), x.dtype))
    return _permute_tokens(x, src_tok, **kw)


def permute_tokens_ragged(x, src_tok, total, **kw):
    counters["permute_tokens_ragged"] += 1
    kw.setdefault("interpret", _interpret())
    if "bn" not in kw:
        kw.update(autotune.select_blocks(
            "permute", (src_tok.shape[0], x.shape[-1]), x.dtype))
    return _permute_tokens_ragged(x, src_tok, total, **kw)


def unpermute_tokens(buf, src_slot, weights, **kw):
    counters["unpermute_tokens"] += 1
    kw.setdefault("interpret", _interpret())
    if "bn" not in kw:
        kw.update(autotune.select_blocks(
            "unpermute", (src_slot.shape[0], buf.shape[-1]), buf.dtype))
    return _unpermute_tokens(buf, src_slot, weights, **kw)


# oracles re-exported for benches/tests
moe_gemm_ref = ref.moe_gemm_ref
grouped_gemm_ref = ref.grouped_gemm_ref
topk_gate_ref = ref.topk_gate_ref
flash_decode_ref = ref.flash_decode_ref
flash_chunk_ref = ref.flash_chunk_ref
flash_chunk_paged_ref = ref.flash_chunk_paged_ref
permute_tokens_ref = ref.permute_tokens_ref
permute_tokens_ragged_ref = ref.permute_tokens_ragged_ref
unpermute_tokens_ref = ref.unpermute_tokens_ref

__all__ = ["moe_gemm", "grouped_gemm", "topk_gate", "flash_decode",
           "flash_chunk", "flash_chunk_paged", "permute_tokens",
           "permute_tokens_ragged", "unpermute_tokens", "moe_gemm_ref",
           "grouped_gemm_ref", "topk_gate_ref", "flash_decode_ref",
           "flash_chunk_ref", "flash_chunk_paged_ref", "permute_tokens_ref",
           "permute_tokens_ragged_ref", "unpermute_tokens_ref", "counters",
           "reset_counters"]
