"""Jit'd public wrappers for the Pallas kernels.

On a TPU backend the kernels lower natively; elsewhere (this CPU container)
they execute in interpret mode, which runs the kernel body in Python and is
what the allclose sweep tests validate against ``ref.py``.
"""

from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode as _flash_decode
from repro.kernels.moe_gemm import moe_gemm as _moe_gemm
from repro.kernels.topk_gate import topk_gate as _topk_gate


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def moe_gemm(x, w, **kw):
    kw.setdefault("interpret", _interpret())
    return _moe_gemm(x, w, **kw)


def topk_gate(logits, k: int, **kw):
    kw.setdefault("interpret", _interpret())
    return _topk_gate(logits, k, **kw)


def flash_decode(q, k, v, lengths, **kw):
    kw.setdefault("interpret", _interpret())
    return _flash_decode(q, k, v, lengths, **kw)


# oracles re-exported for benches/tests
moe_gemm_ref = ref.moe_gemm_ref
topk_gate_ref = ref.topk_gate_ref
flash_decode_ref = ref.flash_decode_ref

__all__ = ["moe_gemm", "topk_gate", "flash_decode",
           "moe_gemm_ref", "topk_gate_ref", "flash_decode_ref"]
