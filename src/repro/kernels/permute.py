"""Fused token permute / unpermute+combine for MoE dispatch (Pallas TPU).

The jnp dispatch path materializes a zero (E, C, h) buffer, ``jnp.repeat``s
every token k times and scatter-adds the copies in HBM; the combine path
gathers (T*k, h) rows and reduces.  Both round-trip the full activation set
through HBM twice.  These kernels collapse each direction into a single
gather pass driven by precomputed int32 index vectors (Megatron-Core's
"fused token permutation/unpermutation" under TPU constraints):

  permute_tokens         out[i] = x[src_tok[i]]        (src_tok < 0 -> 0 row)
  permute_tokens_ragged  same, plus a dynamic row count so tiles past the
                         ragged extent skip the gather loop.  Monolithic
                         dropless EP exchange buffers are worst-case sized;
                         under the micro-chunked overlap schedule
                         (models.moe, plan.ep_overlap) each chunk's buffer
                         is COUNT-BOUNDED to ``ep * cap_rows_for(...)``
                         rows, so the static extent this kernel walks is
                         already near the ragged fill — the tile skipping
                         then only trims the cap's sigma headroom
  unpermute_tokens       out[t] = sum_j buf[src_slot[t,j]] * w[t,j]
                         (already segment-agnostic: it reads ragged buffers
                         through the same index vectors)

The index vectors are tiny (ints, not h-wide rows): the inverse map costs
one int32 scatter over E*C elements instead of a (T*k, h) float scatter-add.
The gather source (x / flattened buffers) stays VMEM-resident per grid step,
which bounds T*h (decode/prefill tiles) to the VMEM budget — the autotune
layer picks the output-rows block; source tiling is future work.

``models.moe.scatter_to_buffers`` / ``gather_from_buffers`` build the index
vectors from their DispatchInfo and call these via ``kernels.ops`` when the
KernelPolicy enables ``fused_permute``; ``kernels.ref`` holds the jnp
oracles the interpret-mode sweeps assert against.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import autotune


def _permute_kernel(idx_ref, x_ref, o_ref, *, bn: int):
    def body(i, _):
        tok = idx_ref[i]
        row = x_ref[pl.ds(jnp.maximum(tok, 0), 1), :]
        o_ref[pl.ds(i, 1), :] = jnp.where(tok >= 0, row,
                                          jnp.zeros_like(row))
        return 0

    jax.lax.fori_loop(0, bn, body, 0)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def permute_tokens(x, src_tok, *, bn: int = None, interpret: bool = False):
    """x (T, h), src_tok (N,) int32 -> (N, h); src_tok[i] < 0 yields a 0 row.

    One gather pass: row i of the output is token ``src_tok[i]``.  With
    ``src_tok`` the inverse of the (expert, position) assignment this IS the
    dispatch scatter, without the zeros+repeat+scatter-add HBM traffic.
    """
    t, h = x.shape
    n = src_tok.shape[0]
    if bn is None:
        bn = autotune.select_blocks("permute", (n, h), x.dtype)["bn"]
    bn = min(bn, n)
    pn = (-n) % bn
    if pn:
        src_tok = jnp.pad(src_tok, (0, pn), constant_values=-1)
    np_ = n + pn

    out = pl.pallas_call(
        functools.partial(_permute_kernel, bn=bn),
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((t, h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, h), x.dtype),
        interpret=interpret,
    )(src_tok.astype(jnp.int32), x)
    return out[:n]


def _permute_ragged_kernel(cnt_ref, idx_ref, x_ref, o_ref, *, bn: int,
                           seg_stride: int, n_seg: int):
    base = pl.program_id(0) * bn
    # a tile fully inside one segment whose local offset is past that
    # segment's populated prefix holds no data; straddling tiles always
    # gather (conservative — their -1 entries yield zero rows anyway)
    r0 = jnp.minimum(base // seg_stride, n_seg - 1)
    r1 = jnp.minimum((base + bn - 1) // seg_stride, n_seg - 1)
    empty = (r0 == r1) & ((base - r0 * seg_stride) >= cnt_ref[r0])

    @pl.when(empty)
    def _skip():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(~empty)
    def _gather():
        def body(i, _):
            tok = idx_ref[i]
            row = x_ref[pl.ds(jnp.maximum(tok, 0), 1), :]
            o_ref[pl.ds(i, 1), :] = jnp.where(tok >= 0, row,
                                              jnp.zeros_like(row))
            return 0

        jax.lax.fori_loop(0, bn, body, 0)


@functools.partial(jax.jit, static_argnames=("seg_stride", "bn", "interpret"))
def permute_tokens_ragged(x, src_tok, total, *, seg_stride: int = None,
                          bn: int = None, interpret: bool = False):
    """Segment-aware ``permute_tokens``: the output is a sequence of
    fixed-stride segments each populated only in a leading prefix, and
    tiles that lie entirely in a segment's empty tail skip the serial
    gather loop and just zero their block.

    ``total`` is either a () scalar — one segment spanning the whole
    buffer with ``total`` valid leading rows — or a (n_seg,) int32 vector
    of per-segment prefix counts with segments at ``seg_stride`` row
    intervals (the dropless EP send layout: destination rank r's rows
    live at [r*seg_stride, r*seg_stride + counts[r])).  Rows outside the
    prefixes must carry ``src_tok == -1`` (they come back as zero rows
    either way).  The buffers are sized for the worst-case skew, so at
    low load most tiles are empty."""
    t, h = x.shape
    n = src_tok.shape[0]
    totals = jnp.asarray(total, jnp.int32).reshape(-1)    # () -> (1,)
    n_seg = totals.shape[0]
    if seg_stride is None:
        seg_stride = n
    if n_seg * seg_stride < n:
        raise ValueError(f"segments ({n_seg} x {seg_stride}) do not cover "
                         f"the {n}-row buffer")
    if bn is None:
        bn = autotune.select_blocks("permute", (n, h), x.dtype)["bn"]
    bn = min(bn, n)
    pn = (-n) % bn
    if pn:
        src_tok = jnp.pad(src_tok, (0, pn), constant_values=-1)
    np_ = n + pn

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i, tot: (i,)),
            pl.BlockSpec((t, h), lambda i, tot: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, h), lambda i, tot: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_permute_ragged_kernel, bn=bn,
                          seg_stride=seg_stride, n_seg=n_seg),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((np_, h), x.dtype),
        interpret=interpret,
    )(totals, src_tok.astype(jnp.int32), x)
    return out[:n]


def _unpermute_kernel(slot_ref, w_ref, buf_ref, o_ref, *, bn: int, k: int):
    def body(i, _):
        acc = jnp.zeros((1, buf_ref.shape[-1]), jnp.float32)
        for j in range(k):             # k is 2..8 — unrolled slot walk
            s = slot_ref[i, j]
            row = buf_ref[pl.ds(jnp.maximum(s, 0), 1), :].astype(jnp.float32)
            acc = acc + row * jnp.where(s >= 0, w_ref[i, j], 0.0)
        o_ref[pl.ds(i, 1), :] = acc.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, bn, body, 0)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def unpermute_tokens(buf, src_slot, weights, *, bn: int = None,
                     interpret: bool = False):
    """buf (M, h), src_slot (T, k) int32, weights (T, k) -> (T, h).

    Weighted combine fused with the inverse permutation: token t's output is
    the f32-accumulated sum of its k expert rows, each scaled by its routing
    weight (dropped slots carry src_slot < 0 and contribute 0).
    """
    m, h = buf.shape
    t, k = src_slot.shape
    if bn is None:
        bn = autotune.select_blocks("unpermute", (t, h), buf.dtype)["bn"]
    bn = min(bn, t)
    pt = (-t) % bn
    if pt:
        src_slot = jnp.pad(src_slot, ((0, pt), (0, 0)), constant_values=-1)
        weights = jnp.pad(weights, ((0, pt), (0, 0)))
    tp = t + pt

    out = pl.pallas_call(
        functools.partial(_unpermute_kernel, bn=bn, k=k),
        grid=(tp // bn,),
        in_specs=[
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((bn, k), lambda i: (i, 0)),
            pl.BlockSpec((m, h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((tp, h), buf.dtype),
        interpret=interpret,
    )(src_slot.astype(jnp.int32), weights.astype(jnp.float32), buf)
    return out[:t]


__all__ = ["permute_tokens", "permute_tokens_ragged", "unpermute_tokens"]
