"""KernelPolicy — which Pallas kernels the serving hot path uses.

The policy rides on the ``ShardingPlan`` (core.partitioner) so it reaches
every layer the plan already reaches: ``model.forward`` ->
``layers.chunked_attention`` (flash_chunk, the ragged mixed-chunk kernel)
/ ``layers.decode_attention`` (flash_decode, its sq == 1 specialization)
and ``moe_block`` / ``_moe_shard_fn`` (topk_gate, moe_gemm, fused
permute/unpermute) — on both the local and the distributed (shard_map)
execution paths.  Routing table: docs/kernels.md.

``KernelPolicy.auto()`` enables everything on a TPU backend (kernels lower
natively) and disables everything elsewhere, where the interpret-mode
kernels are a correctness tool, not a fast path.  Tests and benchmarks force
``KernelPolicy.all_on()`` to exercise the kernelized graph on CPU.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Per-kernel opt-in switches for the serving hot path."""

    flash_decode: bool = False    # single-token decode attention
    flash_chunk: bool = False     # ragged mixed-chunk prefill attention
    topk_gate: bool = False       # fused softmax+top-k router gate
    moe_gemm: bool = False        # grouped expert GEMM on capacity buffers
    fused_permute: bool = False   # fused token permute / unpermute+combine

    @property
    def any_enabled(self) -> bool:
        return (self.flash_decode or self.flash_chunk or self.topk_gate
                or self.moe_gemm or self.fused_permute)

    @classmethod
    def all_on(cls) -> "KernelPolicy":
        return cls(flash_decode=True, flash_chunk=True, topk_gate=True,
                   moe_gemm=True, fused_permute=True)

    @classmethod
    def off(cls) -> "KernelPolicy":
        return cls()

    @classmethod
    def auto(cls) -> "KernelPolicy":
        import jax
        return cls.all_on() if jax.default_backend() == "tpu" else cls.off()

    @classmethod
    def parse(cls, value) -> "KernelPolicy":
        """The ServeSpec / CLI surface: "auto" | "on" | "off" (or an
        already-built policy, passed through) -> a concrete KernelPolicy."""
        if isinstance(value, cls):
            return value
        if value == "auto":
            return cls.auto()
        if value in ("on", "all_on"):
            return cls.all_on()
        if value in ("off", "none"):
            return cls.off()
        raise ValueError(
            f"kernel policy must be 'auto'/'on'/'off' or a KernelPolicy, "
            f"got {value!r}")

    def describe(self) -> str:
        """Compact provenance-report form: all-on / off / the enabled set."""
        on = [f.name for f in dataclasses.fields(self)
              if getattr(self, f.name)]
        if len(on) == len(dataclasses.fields(self)):
            return "all-on"
        return "+".join(on) if on else "off"


NULL_POLICY = KernelPolicy()

__all__ = ["KernelPolicy", "NULL_POLICY"]
