"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gemm_ref(x, w):
    """Grouped expert GEMM.  x (E, C, H) @ w (E, H, D) -> (E, C, D)."""
    return jnp.einsum("ech,ehd->ecd", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def grouped_gemm_ref(x, w, group_offsets):
    """Dropless segment GEMM.  x (N, H) sorted by expert, w (E, H, D),
    group_offsets (E+1,) prefix-sum -> (N, D): row i uses the weights of the
    expert whose [offsets[e], offsets[e+1]) segment contains i.

    Rows at/after offsets[-1] are unspecified (XLA's ragged_dot computes
    them with the trailing group) — callers never read them.
    """
    sizes = (group_offsets[1:] - group_offsets[:-1]).astype(jnp.int32)
    return jax.lax.ragged_dot(
        x, w, sizes, preferred_element_type=jnp.float32).astype(x.dtype)


def topk_gate_ref(logits, k: int, renorm: bool = True):
    """Fused softmax + top-k router gate.

    logits (T, E) -> (weights (T, k) f32, idx (T, k) i32).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    if renorm:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_i.astype(jnp.int32)


def flash_decode_ref(q, k, v, lengths, scale=None):
    """Decode attention.  q (B, nq, hd); k (B, S, nkv, hd); v (B, S, nkv, hdv);
    lengths (B,).

    Returns (B, nq, hdv).  Causal is implied by the length mask (the query is
    the token at position lengths-1, so exactly `lengths` slots are visible).
    """
    b, nq, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = hd ** -0.5 if scale is None else scale
    qg = q.reshape(b, nkv, g, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32))
    mask = jnp.arange(skv)[None] < lengths[:, None]          # (b, s)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, nq, v.shape[-1]).astype(q.dtype)


def flash_chunk_ref(q, k, v, q_offset, q_len, kv_len, scale=None):
    """Ragged mixed-chunk attention.  q (B, sq, nq, hd); k (B, S, nkv, hd);
    v (B, S, nkv, hdv); q_offset/q_len/kv_len (B,).

    Returns (B, sq, nq, hdv).  Row r of slot i is a real query iff
    ``r < q_len[i]``, sits at absolute position ``q_offset[i] + r`` and sees
    keys ``pos <= q_offset[i] + r`` with ``pos < kv_len[i]``.  Rows past
    ``q_len[i]`` (ragged tail / idle slots) and rows with no visible key
    come back as exact zeros — the kernel's garbage-but-finite contract.
    """
    b, sq, nq, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = hd ** -0.5 if scale is None else scale
    off = jnp.broadcast_to(jnp.atleast_1d(q_offset), (b,)).astype(jnp.int32)
    qlen = jnp.broadcast_to(jnp.atleast_1d(q_len), (b,)).astype(jnp.int32)
    kvlen = jnp.broadcast_to(jnp.atleast_1d(kv_len), (b,)).astype(jnp.int32)
    qg = q.reshape(b, sq, nkv, g, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bshd->bhgqs", qg, k.astype(jnp.float32))
    row = jnp.arange(sq)[:, None]                        # (sq, 1)
    pos = jnp.arange(skv)[None]                          # (1, skv)
    mask = ((row < qlen[:, None, None])
            & (pos < kvlen[:, None, None])
            & (pos <= off[:, None, None] + row))          # (b, sq, skv)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[:, None, None], p, 0.0)           # dead rows -> 0
    o = jnp.einsum("bhgqs,bshd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, nq, v.shape[-1]).astype(q.dtype)


def flash_chunk_paged_ref(q, k_pages, v_pages, block_tables, q_offset, q_len,
                          kv_len, scale=None):
    """Paged ragged mixed-chunk attention.  q (B, sq, nq, hd);
    k_pages (P, page, nkv, hd); v_pages (P, page, nkv, hdv);
    block_tables (B, max_blocks) int32 (−1 = unallocated).

    Gathers each slot's pages into a dense (B, max_blocks*page, ...) view —
    unallocated blocks read page 0, whose rows sit past ``kv_len`` and are
    masked — then defers to ``flash_chunk_ref``.
    """
    n_pages, page = k_pages.shape[0], k_pages.shape[1]
    b, nb = block_tables.shape
    bt = jnp.clip(block_tables, 0, n_pages - 1)
    k = jnp.take(k_pages, bt, axis=0).reshape(b, nb * page,
                                              *k_pages.shape[2:])
    v = jnp.take(v_pages, bt, axis=0).reshape(b, nb * page,
                                              *v_pages.shape[2:])
    return flash_chunk_ref(q, k, v, q_offset, q_len, kv_len, scale)


def permute_tokens_ref(x, src_tok):
    """x (T, h), src_tok (N,) int32 -> (N, h); src_tok[i] < 0 yields a 0 row."""
    rows = jnp.take(x, jnp.maximum(src_tok, 0), axis=0)
    return jnp.where(src_tok[:, None] >= 0, rows, jnp.zeros_like(rows))


def permute_tokens_ragged_ref(x, src_tok, total, *, seg_stride=None):
    """Oracle for the segment-aware ragged permute (kernels.permute).

    Validity rides entirely in ``src_tok`` — rows outside the per-segment
    prefixes carry ``-1`` and come back as zero rows — so the dense
    gather IS the answer; ``total``/``seg_stride`` only tell the kernel
    which tiles it may skip.
    """
    del total, seg_stride
    return permute_tokens_ref(x, src_tok)


def unpermute_tokens_ref(buf, src_slot, weights):
    """buf (M, h), src_slot (T, k) int32, weights (T, k) -> (T, h).

    f32-accumulated weighted combine; dropped slots (src_slot < 0) add 0.
    """
    rows = jnp.take(buf, jnp.maximum(src_slot, 0).reshape(-1),
                    axis=0).astype(jnp.float32)
    w = jnp.where(src_slot >= 0, weights.astype(jnp.float32), 0.0)
    t, k = src_slot.shape
    out = (rows.reshape(t, k, -1) * w[:, :, None]).sum(1)
    return out.astype(buf.dtype)


__all__ = ["moe_gemm_ref", "grouped_gemm_ref", "topk_gate_ref",
           "flash_decode_ref", "flash_chunk_ref", "flash_chunk_paged_ref",
           "permute_tokens_ref", "permute_tokens_ragged_ref",
           "unpermute_tokens_ref"]
