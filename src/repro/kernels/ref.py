"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_gemm_ref(x, w):
    """Grouped expert GEMM.  x (E, C, H) @ w (E, H, D) -> (E, C, D)."""
    return jnp.einsum("ech,ehd->ecd", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def topk_gate_ref(logits, k: int, renorm: bool = True):
    """Fused softmax + top-k router gate.

    logits (T, E) -> (weights (T, k) f32, idx (T, k) i32).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    if renorm:
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_i.astype(jnp.int32)


def flash_decode_ref(q, k, v, lengths):
    """Decode attention.  q (B, nq, hd); k/v (B, S, nkv, hd); lengths (B,).

    Returns (B, nq, hd).  Causal is implied by the length mask (the query is
    the token at position lengths-1, so exactly `lengths` slots are visible).
    """
    b, nq, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    qg = q.reshape(b, nkv, g, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k.astype(jnp.float32))
    mask = jnp.arange(skv)[None] < lengths[:, None]          # (b, s)
    s = jnp.where(mask[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, nq, hd).astype(q.dtype)


__all__ = ["moe_gemm_ref", "topk_gate_ref", "flash_decode_ref"]
