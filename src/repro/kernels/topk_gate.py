"""Fused softmax + top-k router gate as a Pallas TPU kernel.

The router is tiny FLOP-wise but sits on the critical path of every MoE
layer (its output gates the dispatch A2A).  Fusing softmax + iterative
argmax top-k into one VMEM-resident kernel avoids materializing the (T, E)
probability tensor in HBM between the two ops.

Top-k is unrolled argmax-and-mask (k is 2..8 for all assigned configs), each
iteration a VPU max-reduction over the expert axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_gate_kernel(logits_ref, w_ref, i_ref, *, k: int, renorm: bool):
    x = logits_ref[...].astype(jnp.float32)          # (bt, E)
    e = x.shape[-1]
    x = x - jax.lax.stop_gradient(x.max(-1, keepdims=True))
    ex = jnp.exp(x)
    probs = ex / ex.sum(-1, keepdims=True)

    p = probs
    ids = jnp.broadcast_to(jnp.arange(e, dtype=jnp.int32)[None], p.shape)
    ws, iis = [], []
    for _ in range(k):
        top = p.max(-1)
        idx = jnp.argmax(p, -1).astype(jnp.int32)
        ws.append(top)
        iis.append(idx)
        p = jnp.where(ids == idx[:, None], -1.0, p)
    w = jnp.stack(ws, -1)
    if renorm:
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    w_ref[...] = w
    i_ref[...] = jnp.stack(iis, -1)


@functools.partial(jax.jit, static_argnames=("k", "renorm", "bt", "interpret"))
def topk_gate(logits, k: int, *, renorm: bool = True, bt: int = 256,
              interpret: bool = False):
    """logits (T, E) -> (weights (T, k) f32, idx (T, k) i32)."""
    t, e = logits.shape
    bt = min(bt, t)
    pt = (-t) % bt
    if pt:
        logits = jnp.pad(logits, ((0, pt), (0, 0)))
    tp = t + pt

    w, i = pl.pallas_call(
        functools.partial(_topk_gate_kernel, k=k, renorm=renorm),
        grid=(tp // bt,),
        in_specs=[pl.BlockSpec((bt, e), lambda ti: (ti, 0))],
        out_specs=[pl.BlockSpec((bt, k), lambda ti: (ti, 0)),
                   pl.BlockSpec((bt, k), lambda ti: (ti, 0))],
        out_shape=[jax.ShapeDtypeStruct((tp, k), jnp.float32),
                   jax.ShapeDtypeStruct((tp, k), jnp.int32)],
        interpret=interpret,
    )(logits)
    return w[:t], i[:t]


__all__ = ["topk_gate"]
