"""Automatic strategy -> plan wiring (the full MixServe loop).

``auto_plan`` runs the offline analyzer for the target mesh's cluster spec,
takes the best feasible strategy, and maps it onto a ShardingPlan — the
"--strategy auto" path of the launcher/dry-run.  This is the piece that
makes the system *automatic* end to end: model config in, mesh in, sharded
program out.

The serving-side mirror of this loop is ``repro.serving.api.ServeSpec``
(docs/api.md), which resolves the SAME analyzer choice plus the online
knobs (chunk, token budget, kernels, dispatch) through ``core.resolve`` —
the strategy -> layout mapping is shared (``core.resolve.plan_name_for``).
"""

from __future__ import annotations

from typing import Union

from repro.configs.base import InputShape, ModelConfig
from repro.core import analyzer
from repro.core.partitioner import ShardingPlan, make_plan
from repro.core.resolve import plan_name_for, resolve_cluster
from repro.core.topology import ClusterSpec


def cluster_for_mesh(mesh, cluster: Union[str, ClusterSpec, None] = None
                     ) -> ClusterSpec:
    """ClusterSpec for a mesh: an explicit spec/name wins (validated
    against ``mesh.devices.size``); None falls back to the v5e heuristic
    (multi-pod iff the mesh exceeds one 256-chip pod)."""
    spec, _src = resolve_cluster(cluster, mesh=mesh)
    return spec


def auto_plan(cfg: ModelConfig, mesh, shape: InputShape, *,
              fsdp: bool = False, sp: bool = True,
              objective: str = "balanced",
              cluster: Union[str, ClusterSpec, None] = None) -> tuple:
    """(plan, report): analyzer-selected ShardingPlan for (model, mesh, shape).

    The analyzer enumerates the §III-B1 grammar on the mesh's cluster spec
    (explicit ``cluster`` or the heuristic fallback); the winning strategy
    maps to the hybrid ("mixserve") layout when its MoE block uses TP>1,
    else to pure-EP — with a divisibility guard: pure-EP needs
    n_experts % n_devices == 0, otherwise the hybrid layout is the only
    implementable choice on this mesh (the deepseek-v2 case: 160 experts
    on 256 chips).
    """
    cluster = cluster_for_mesh(mesh, cluster)
    if shape.kind == "train":
        batch, l_in, l_out = shape.global_batch, shape.seq_len, 1
    elif shape.kind == "prefill":
        batch, l_in, l_out = shape.global_batch, shape.seq_len, 1
    else:
        batch, l_in, l_out = shape.global_batch, shape.seq_len, 256
    rep = analyzer.select(cfg, cluster, batch=batch, l_in=min(l_in, 8192),
                          l_out=l_out, objective=objective)
    best = rep.best.strategy

    name = plan_name_for(cfg, best, mesh.devices.size)
    plan = make_plan(name, mesh, comm_algo=best.comm_algo, fsdp=fsdp, sp=sp)
    return plan, rep


__all__ = ["auto_plan", "cluster_for_mesh"]
