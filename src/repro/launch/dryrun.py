import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh).

The two lines above MUST precede any other import (jax locks the device count
on first init).  512 placeholder CPU devices let ``jax.make_mesh`` build the
production meshes:  (16,16) single pod and (2,16,16) two pods.

For every combination this prints/records:
  - compiled.memory_analysis()   (proves the sharded program fits)
  - compiled.cost_analysis()     (FLOPs / bytes for the roofline)
  - parsed collective bytes      (the roofline collective term)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-v2-236b \
      --shape decode_32k --mesh single --strategy mixserve
  (the --all driver spawns one subprocess per combo for memory isolation)
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


# per-arch grad-accum depth for train_4k (deepseek-v2's 236B needs the
# deepest split; see EXPERIMENTS.md §Perf iteration log)
TRAIN_MICROBATCHES = {"deepseek-v2-236b": 16}

# archs whose TRAIN runs skip the Megatron-SP residual sharding: with
# cleanly head-parallel attention (heads % 16 == 0) and small activations,
# the SP scatter/gather churn costs more collectives than it saves memory
# (minitron 3.9x with SP on; smollm is the opposite, 2.8x WORSE with SP
# off — its 15 heads aren't head-parallel.  EXPERIMENTS §Perf pair-3)
TRAIN_SP_OFF = {"minitron-8b"}


def run_one(arch: str, shape_name: str, mesh_kind: str, strategy: str,
            out_dir: str, save_hlo: bool = False,
            microbatches: int = 0, prefill_chunks: int = 8,
            sp: bool = True, fsdp: bool = True) -> dict:
    import jax
    import jax.numpy as jnp

    import repro.configs as C
    from repro.configs.base import INPUT_SHAPES
    from repro.core.partitioner import make_plan
    from repro.launch.hlo_analysis import summarize_costs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs
    from repro.models.model import forward
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_step import train_step

    cfg = C.get(arch)
    shape = INPUT_SHAPES[shape_name]
    if not microbatches:
        microbatches = TRAIN_MICROBATCHES.get(arch, 8)
    if not C.shape_supported(cfg, shape):
        return {"status": "skipped",
                "reason": "long_500k requires sub-quadratic attention "
                          "(DESIGN.md §4 shape-skip policy)"}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    if shape.kind == "train" and arch in TRAIN_SP_OFF:
        sp = False
    if strategy == "auto":
        # the full MixServe loop: offline analyzer -> plan
        from repro.launch.auto import auto_plan
        plan, _rep = auto_plan(cfg, mesh, shape,
                               fsdp=(fsdp and shape.kind == "train"), sp=sp)
    else:
        plan = make_plan(strategy, mesh,
                         fsdp=(fsdp and shape.kind == "train"), sp=sp)
    # grad-accum cannot split below one batch row per DP rank
    if shape.kind == "train":
        microbatches = min(microbatches, shape.global_batch // plan.dp)
    args, shardings = input_specs(cfg, shape, plan)

    if shape.kind == "train":
        import jax.numpy as _jnp
        def step(params, opt_state, batch):
            return train_step(params, opt_state, batch, cfg=cfg, plan=plan,
                              opt_cfg=AdamWConfig(), remat=True,
                              microbatches=microbatches,
                              accum_dtype=_jnp.bfloat16)
    elif shape.kind == "prefill":
        # Chunked prefill (Sarathi-style): the 32k prompt streams through in
        # seq chunks, bounding MoE capacity buffers / attention activations;
        # chunk i attends to the cache of chunks 0..i.
        n_pc = prefill_chunks
        def step(params, batch, cache):
            toks = batch["tokens"]
            s_total = toks.shape[1]
            while s_total % n_pc:
                raise ValueError(f"{s_total} tokens not divisible into "
                                 f"{n_pc} prefill chunks")
            chunk = s_total // n_pc
            out0 = forward(params, cfg, plan, tokens=toks[:, :chunk],
                           embeds=batch.get("embeds"),
                           frames=batch.get("frames"), cache=cache)

            def body(c, tchunk):
                o = forward(params, cfg, plan, tokens=tchunk, cache=c)
                return o.cache, o.logits[:, -1]

            if n_pc > 1:
                rest = toks[:, chunk:].reshape(
                    toks.shape[0], n_pc - 1, chunk).transpose(1, 0, 2)
                cache_f, logits = jax.lax.scan(body, out0.cache, rest)
                return logits[-1], cache_f
            return out0.logits[:, -1], out0.cache
    else:  # decode
        def step(params, tokens, cache):
            out = forward(params, cfg, plan, tokens=tokens, cache=cache)
            return out.logits[:, 0], out.cache

    # Donation: train aliases (params, opt_state) into their updates; the
    # serving steps alias the KV cache — without this every big buffer is
    # double-counted (input + output) and decode would never fit.
    donate = {"train": (0, 1), "prefill": (2,), "decode": (2,)}[
        shape.kind if shape.kind in ("train", "prefill") else "decode"]

    t0 = time.time()
    with mesh:
        lowered = jax.jit(step, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    hlo = compiled.as_text()
    costs = summarize_costs(compiled, hlo)
    n_dev = mesh.devices.size

    rec = {
        "status": "ok",
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "strategy": strategy, "n_devices": int(n_dev),
        "microbatches": microbatches if shape.kind == "train" else 1,
        "prefill_chunks": prefill_chunks if shape.kind == "prefill" else 1,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
        },
        "costs": costs,
    }
    if save_hlo:
        with open(os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_kind}_"
                               f"{strategy}.hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--strategy", default="mixserve",
                    choices=["mixserve", "dp_ep", "pure_tp", "auto"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape x both meshes) via "
                         "subprocesses")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    if not args.all:
        rec = run_one(args.arch, args.shape, args.mesh, args.strategy,
                      args.out, save_hlo=args.save_hlo)
        name = f"{args.arch}_{args.shape}_{args.mesh}_{args.strategy}"
        with open(os.path.join(args.out, name + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        print(json.dumps(rec, indent=1))
        return 0 if rec["status"] in ("ok", "skipped") else 1

    # --all: subprocess per combo (compile-memory isolation on the 1-core host)
    import repro.configs as C          # safe here: parent does no lowering
    from repro.configs.base import INPUT_SHAPES
    combos = [(a, s, m)
              for a in C.ARCH_IDS
              for s in INPUT_SHAPES
              for m in ("single", "multi")]
    failures = []
    for a, s, m in combos:
        name = f"{a}_{s}_{m}_{args.strategy}"
        path = os.path.join(args.out, name + ".json")
        if os.path.exists(path):
            with open(path) as f:
                if json.load(f).get("status") in ("ok", "skipped"):
                    print(f"[cached] {name}")
                    continue
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
               "--shape", s, "--mesh", m, "--strategy", args.strategy,
               "--out", args.out]
        if args.save_hlo:
            cmd.append("--save-hlo")
        t0 = time.time()
        try:
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=args.timeout)
            ok = r.returncode == 0
        except subprocess.TimeoutExpired:
            ok, r = False, None
        dt = time.time() - t0
        print(f"[{'ok' if ok else 'FAIL'}] {name}  ({dt:.0f}s)")
        if not ok:
            failures.append(name)
            if r is not None:
                tail = (r.stderr or "")[-2000:]
                with open(os.path.join(args.out, name + ".err"), "w") as f:
                    f.write(tail)
                print(tail[-800:])
    print(f"\n{len(combos) - len(failures)}/{len(combos)} combos passed")
    return 1 if failures else 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except Exception:
        traceback.print_exc()
        sys.exit(1)
