"""Collective-traffic extraction from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` reports FLOPs and HBM bytes but NOT collective
bytes — we parse the partitioned module text and sum the result-shape bytes of
every collective op, bucketed by kind.  Post-SPMD shapes are per-partition, so
the totals are per-device bytes on the wire (the §Roofline collective term's
numerator).

Scan-over-layers makes this subtle: a collective inside a ``lax.scan`` body
appears ONCE in the HLO while-loop body but executes trip-count times.  We
therefore account per-computation, detect ``while`` ops, recover their trip
counts from the loop-condition constant, and scale the body's traffic.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\(?[^)=]*?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(", re.M)
# computation header:  %name (args) -> shape {     (or "ENTRY %name ...")
# args may contain nested tuple parens, so match loosely to end-of-line "{".
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$", re.M)
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * nb
    return total


def _split_computations(text: str) -> dict:
    """computation name -> body text."""
    comps = {}
    matches = list(_COMP_RE.finditer(text))
    for i, m in enumerate(matches):
        end = matches[i + 1].start() if i + 1 < len(matches) else len(text)
        comps[m.group(1)] = text[m.start():end]
    # fallback: no headers matched -> whole module is one computation
    if not comps:
        comps["__module__"] = text
    return comps


def _local_traffic(body: str) -> tuple:
    """(bytes_by_kind, counts_by_kind) for collectives directly in a body."""
    out: dict = defaultdict(int)
    counts: dict = defaultdict(int)
    for m in _OP_RE.finditer(body):
        shape_str, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":           # count async pairs on the start op
            continue
        out[kind] += _shape_bytes(shape_str)
        counts[kind] += 1
    return out, counts


def _entry_name(text: str) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    return m.group(1) if m else next(iter(_split_computations(text)))


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind collective result bytes per device, while-loops scaled."""
    comps = _split_computations(hlo_text)
    local = {name: _local_traffic(body) for name, body in comps.items()}

    memo: dict = {}

    def resolve(name: str, depth: int = 0):
        if name in memo:
            return memo[name]
        if depth > 16 or name not in comps:
            return defaultdict(int), defaultdict(int)
        body = comps[name]
        bts = defaultdict(int, local[name][0])
        cts = defaultdict(int, local[name][1])
        for wm in _WHILE_RE.finditer(body):
            cond, wbody = wm.group(1), wm.group(2)
            trip = 1
            # prefer XLA's own annotation on the while line
            line_end = body.find("\n", wm.end())
            line = body[wm.end():line_end if line_end > 0 else len(body)]
            km = re.search(r'known_trip_count":\{"n":"(\d+)"', line)
            if km:
                trip = int(km.group(1))
            elif cond in comps:
                consts = [int(c) for c in _TRIP_RE.findall(comps[cond])]
                if consts:
                    trip = max(consts)
            sub_b, sub_c = resolve(wbody, depth + 1)
            for k, v in sub_b.items():
                bts[k] += v * trip
            for k, v in sub_c.items():
                cts[k] += v * trip
        for cm in _CALL_RE.finditer(body):
            sub_b, sub_c = resolve(cm.group(1), depth + 1)
            for k, v in sub_b.items():
                bts[k] += v
            for k, v in sub_c.items():
                cts[k] += v
        memo[name] = (bts, cts)
        return bts, cts

    bts, cts = resolve(_entry_name(hlo_text))
    total = sum(bts.values())
    return {"bytes": {**dict(bts), "total": total}, "counts": dict(cts)}


def summarize_costs(compiled, hlo_text: str | None = None) -> dict:
    """Merge cost_analysis() with parsed collective traffic."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            mem[f] = getattr(ma, f, None)
    except Exception:
        pass
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "collectives": coll,
        "memory": mem,
    }


__all__ = ["collective_bytes", "summarize_costs", "COLLECTIVES"]
