"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS *before* any jax init).

    single-pod: (data=16, model=16)         256 chips, both axes on ICI
    multi-pod : (pod=2, data=16, model=16)  512 chips, "pod" axis on DCN
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small CPU mesh for tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


__all__ = ["make_production_mesh", "make_host_mesh"]
