"""Serving driver: the MixServe online stage end-to-end.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3.5-moe-42b --reduced \
      --requests 16 --rate 4

Offline stage first (automatic analyzer on the target cluster), then the
engine + scheduler replay a Poisson workload and report measured TTFT / ITL /
throughput next to the analyzer's theoretical estimates (Eqs. 9-11).

The engine is the unified token-budget mixed prefill/decode step
(docs/serving.md): one jitted program, prefill chunks co-scheduled with
decode tokens under ``--chunk`` / ``--token-budget``.  Families the
unified step cannot serve (ssm/hybrid/frontend) fall back to the internal
blocking-prefill path automatically; the public ``--legacy-engine`` /
``REPRO_LEGACY_ENGINE`` escape hatch was retired after its one-release
window.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.core import analyzer
from repro.core.topology import CLUSTERS
from repro.kernels.policy import KernelPolicy
from repro.models.model import init_params
from repro.serving.engine import Engine
from repro.serving.scheduler import Scheduler, synthetic_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=C.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--cluster", default="v5e-pod-256",
                    choices=list(CLUSTERS))
    ap.add_argument("--kernels", default="auto", choices=("auto", "on", "off"),
                    help="Pallas kernel policy for the jitted serve graph: "
                         "auto = on for TPU backends, off elsewhere; on "
                         "forces the kernelized path (interpret mode on CPU)")
    ap.add_argument("--dispatch", default="auto",
                    choices=("auto", "dropless", "capacity"),
                    help="MoE dispatch buffers: auto (-> dropless, the "
                         "count-independent ragged inference dispatch) or "
                         "capacity (fixed (E, C, h) buffers; training's "
                         "scheme, kept for A/B comparison)")
    ap.add_argument("--chunk", type=int, default=16,
                    help="prefill chunk size of the unified mixed step: each "
                         "prefilling slot contributes at most this many "
                         "prompt tokens per iteration (decode slots always "
                         "contribute 1); also the static width of the "
                         "(B, chunk) token buffer")
    ap.add_argument("--token-budget", type=int, default=0,
                    help="total tokens per unified iteration across all "
                         "slots (0 -> max_batch * chunk); decode tokens are "
                         "scheduled first, prefill chunks fill the rest")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    policy = {"auto": KernelPolicy.auto(), "on": KernelPolicy.all_on(),
              "off": KernelPolicy.off()}[args.kernels]

    cfg_full = C.get(args.arch)
    cluster = CLUSTERS[args.cluster]

    # ---- offline stage: automatic analyzer on the FULL config ----
    rep = analyzer.select(cfg_full, cluster, batch=args.max_batch,
                          l_in=args.prompt_len, l_out=args.max_new,
                          arrival_rate=args.rate)
    print("== offline analyzer (theoretical, full config on "
          f"{cluster.name}) ==")
    print(rep.describe(top=3))

    # ---- online stage: run the reduced config on this host ----
    cfg = C.get_reduced(args.arch) if args.reduced else cfg_full
    params = init_params(jax.random.PRNGKey(args.seed), cfg, jnp.float32)
    embeds_fn = None
    if cfg.frontend == "audio_stub":
        e = cfg.encoder
        embeds_fn = lambda b: {"frames": jnp.full(
            (b, e.n_frames, e.d_model), 0.01, jnp.float32)}
    eng = Engine(cfg, params, max_batch=args.max_batch, max_len=args.max_len,
                 embeds_fn=embeds_fn, kernel_policy=policy,
                 dispatch_mode=args.dispatch, chunk=args.chunk)
    if eng.legacy:
        print(f"[engine] {cfg.name}: family {cfg.family!r} falls back to "
              "the internal blocking-prefill path")
    sched = Scheduler(eng, token_budget=args.token_budget or None)
    for r in synthetic_workload(args.requests, prompt_len=args.prompt_len,
                                max_new_tokens=args.max_new,
                                vocab=cfg.vocab_size,
                                arrival_rate=args.rate, seed=args.seed):
        sched.submit(r)
    sched.run()
    m = sched.metrics()
    print("== online measured (reduced config on this host) ==")
    print(m.row())


if __name__ == "__main__":
    main()
