"""Serving driver: the MixServe online stage end-to-end.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3.5-moe-42b \
      --requests 16 --rate 4

One door: the CLI builds a declarative ``ServeSpec`` (every knob defaults
to ``auto``), ``resolve`` runs the offline stage — the automatic analyzer
+ cost model pick the strategy, kernel policy, dispatch mode, prefill
chunk, token budget and slot envelope — and the ``LLM`` facade runs the
online engine with exactly that resolved configuration (the provenance
report printed below says which field came from where).  Explicit flags
(``--chunk 8``, ``--dispatch capacity``, ...) beat ``auto`` field by
field.  See docs/api.md.

The engine is the unified token-budget mixed prefill/decode step
(docs/serving.md); families the unified step cannot serve
(ssm/hybrid/frontend) fall back to the internal blocking-prefill path
automatically.
"""

from __future__ import annotations

import argparse

import repro.configs as C
from repro.core.topology import CLUSTERS
from repro.serving.api import AUTO, LLM, ServeSpec, SpeculationConfig
from repro.serving.scheduler import synthetic_workload


def _auto_int(v: str):
    return v if v == AUTO else int(v)


def _ep_overlap_arg(v: str):
    return v if v in (AUTO, "off") else int(v)


def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=C.ARCH_IDS)
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="serve the reduced config on this host (the "
                         "offline analyzer always prices the FULL config); "
                         "--no-reduced serves the full one")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=_auto_int, default=AUTO,
                    help="engine slots; auto = largest power-of-two batch "
                         "the Eq. 8 memory constraint admits")
    ap.add_argument("--max-len", type=_auto_int, default=AUTO,
                    help="cache rows per slot; auto = the workload "
                         "envelope (prompt + new tokens, rounded up)")
    ap.add_argument("--cluster", default=AUTO,
                    choices=[AUTO] + list(CLUSTERS),
                    help="offline-stage target cluster (auto -> v5e pod)")
    ap.add_argument("--objective", default="balanced",
                    choices=("ttft", "itl", "throughput", "balanced"))
    ap.add_argument("--strategy", default=AUTO,
                    choices=(AUTO, "mixserve", "dp_ep", "pure_ep",
                             "pure_tp"),
                    help="parallel layout; auto = the analyzer's pick")
    ap.add_argument("--kernels", default=AUTO, choices=(AUTO, "on", "off"),
                    help="Pallas kernel policy for the jitted serve graph: "
                         "auto = on for TPU backends, off elsewhere; on "
                         "forces the kernelized path (interpret mode on CPU)")
    ap.add_argument("--dispatch", default=AUTO,
                    choices=(AUTO, "dropless", "capacity"),
                    help="MoE dispatch buffers: auto (-> dropless, the "
                         "count-independent ragged inference dispatch) or "
                         "capacity (fixed (E, C, h) buffers; training's "
                         "scheme, kept for A/B comparison)")
    ap.add_argument("--chunk", type=_auto_int, default=AUTO,
                    help="prefill chunk size of the unified mixed step "
                         "(static width of the (B, chunk) token buffer); "
                         "auto = the largest chunk the cost model prices "
                         "under the ITL-inflation bound")
    ap.add_argument("--token-budget", type=_auto_int, default=AUTO,
                    help="total tokens per unified iteration across all "
                         "slots; auto = max_batch decode tokens + one "
                         "prefill chunk (decode is scheduled first)")
    ap.add_argument("--kv", default=AUTO, choices=(AUTO, "dense", "paged"),
                    help="KV cache backend: auto = paged with shared-"
                         "prefix reuse for unified-step families (pool "
                         "sized from the Eq. 8 envelope), dense for "
                         "legacy-path families (docs/kv_cache.md)")
    ap.add_argument("--ep-overlap", type=_ep_overlap_arg, default=AUTO,
                    metavar="auto|off|C",
                    help="micro-chunked EP-exchange overlap: auto = the "
                         "cost model picks the chunk count (count-bounded "
                         "A2A buffers on), off = monolithic worst-case "
                         "exchange, an int pins the chunk count")
    ap.add_argument("--speculation", type=_ep_overlap_arg, default="off",
                    metavar="auto|off|K",
                    help="speculative decoding on the unified step: off "
                         "(default), auto = the cost model prices draft "
                         "lengths against the verify step and picks k (or "
                         "off), an int pins k draft tokens per slot-step "
                         "(greedy sampling only; bit-exact either way)")
    ap.add_argument("--draft", default="ngram",
                    help="draft source for an explicit --speculation K: "
                         "ngram (zero-cost suffix matching), self (the "
                         "serving model drafting for itself), or a reduced "
                         "config name; auto always picks ngram")
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def build_spec(args: argparse.Namespace) -> ServeSpec:
    """CLI flags -> the declarative spec (auto flags stay auto)."""
    speculation = args.speculation
    if isinstance(speculation, int):   # explicit k honors --draft
        speculation = SpeculationConfig(k=speculation, draft=args.draft)
    return ServeSpec(
        arch=args.arch, reduced=args.reduced, cluster=args.cluster,
        strategy=args.strategy, kernels=args.kernels,
        dispatch=args.dispatch, chunk=args.chunk,
        token_budget=args.token_budget, kv=args.kv,
        max_batch=args.max_batch, max_len=args.max_len,
        prompt_len=args.prompt_len, max_new_tokens=args.max_new,
        arrival_rate=args.rate, objective=args.objective,
        ep_overlap=args.ep_overlap, speculation=speculation,
        seed=args.seed)


def main(argv=None):
    args = parse_args(argv)
    spec = build_spec(args)

    # ---- offline stage: automatic analyzer + knob resolution on the
    # FULL config (model + cluster in, serving configuration out) ----
    resolved = spec.resolve(C.get(args.arch))
    print("== offline analyzer (theoretical, full config on "
          f"{resolved.cluster}) ==")
    print(resolved.report.describe(top=3))
    print("\n== resolved serving spec (provenance) ==")
    print(resolved.describe())

    # ---- online stage: the LLM facade runs EXACTLY the resolved spec ----
    llm = LLM.from_spec(resolved)
    if llm.engine.legacy:
        print(f"[engine] {llm.cfg.name}: family {llm.cfg.family!r} falls "
              "back to the internal blocking-prefill path")
    sched = llm.serve(synthetic_workload(
        args.requests, prompt_len=args.prompt_len,
        max_new_tokens=args.max_new, vocab=llm.cfg.vocab_size,
        arrival_rate=args.rate, seed=args.seed))
    print("\n== online measured "
          f"({'reduced' if args.reduced else 'full'} config on this host) ==")
    print(sched.metrics().row())


if __name__ == "__main__":
    main()
