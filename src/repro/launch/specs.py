"""ShapeDtypeStruct input stand-ins + shardings for every (arch x shape).

``input_specs`` returns (abstract_inputs, in_shardings) for the step function
that the given workload shape lowers:

  train_4k                   -> train_step(params, opt_state, batch)
  prefill_32k                -> prefill_step(params, batch, cache)
  decode_32k / long_500k     -> serve_step(params, tokens, cache)

No device allocation happens here — everything is weak-type-correct
ShapeDtypeStructs, shardable via the plan's NamedShardings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.core.partitioner import ShardingPlan
from repro.models.model import abstract_params, cache_axes, init_cache, param_axes
from repro.training.optimizer import abstract_opt_state


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _tree_shardings_for(plan: ShardingPlan, abstract_tree_, axes_tree_):
    """NamedShardings for a tree of ShapeDtypeStructs + logical axes."""
    is_axes = lambda t: isinstance(t, tuple) and all(
        a is None or isinstance(a, str) for a in t)
    return jax.tree.map(
        lambda sds, ax: plan.sharding_for(sds.shape, ax),
        abstract_tree_, axes_tree_,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct) or is_axes(x))


def params_and_shardings(cfg: ModelConfig, plan: ShardingPlan,
                         dtype=jnp.bfloat16):
    ap = abstract_params(cfg, dtype)
    sh = _tree_shardings_for(plan, ap, param_axes(cfg))
    return ap, sh


def batch_specs(cfg: ModelConfig, shape: InputShape, plan: ShardingPlan, *,
                with_labels: bool):
    """Abstract {tokens, labels, embeds, frames, mask} + shardings."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        s = 1
    n_front = cfg.n_frontend_tokens if (cfg.frontend == "vision_stub"
                                        and shape.kind != "decode") else 0
    s_text = s - n_front
    batch = {"tokens": _sds((b, s_text), jnp.int32)}
    shard = {"tokens": plan.sharding_for((b, s_text), ("batch", "seq"))}
    if n_front:
        batch["embeds"] = _sds((b, n_front, cfg.d_model), jnp.bfloat16)
        shard["embeds"] = plan.sharding_for(batch["embeds"].shape,
                                            ("batch", "seq", "embed"))
    if cfg.frontend == "audio_stub" and shape.kind != "decode":
        e = cfg.encoder
        batch["frames"] = _sds((b, e.n_frames, e.d_model), jnp.bfloat16)
        shard["frames"] = plan.sharding_for(batch["frames"].shape,
                                            ("batch", "seq", "embed"))
    if with_labels:
        batch["labels"] = _sds((b, s), jnp.int32)
        shard["labels"] = plan.sharding_for((b, s), ("batch", "seq"))
        if n_front:
            batch["mask"] = _sds((b, s), jnp.float32)
            shard["mask"] = plan.sharding_for((b, s), ("batch", "seq"))
    return batch, shard


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                plan: ShardingPlan, dtype=jnp.bfloat16, *,
                vector_lengths: bool = True):
    """vector_lengths: per-slot (batch,) lengths (continuous-batching decode);
    prefill uses a scalar length (all requests start at offset 0)."""
    ac = init_cache(cfg, batch, max_len, dtype, abstract=True)
    ax = cache_axes(cfg, batch, max_len)
    if vector_lengths:
        ac = {**ac, "length": _sds((batch,), jnp.int32)}
        ax = {**ax, "length": ("batch",)}
    sh = _tree_shardings_for(plan, ac, ax)
    return ac, sh


def input_specs(cfg: ModelConfig, shape: InputShape, plan: ShardingPlan,
                dtype=jnp.bfloat16):
    """(abstract_args, in_shardings) matching the lowered step's signature."""
    ap, ap_sh = params_and_shardings(cfg, plan, dtype)
    if shape.kind == "train":
        batch, b_sh = batch_specs(cfg, shape, plan, with_labels=True)
        # bf16 moments: f32 would exceed HBM on the 236B config (see
        # training/optimizer.py note)
        opt = abstract_opt_state(ap, jnp.bfloat16)
        opt_sh = type(opt)(step=plan.sharding_for((), ()),
                           m=_tree_shardings_for(plan, opt.m, param_axes(cfg)),
                           v=_tree_shardings_for(plan, opt.v, param_axes(cfg)))
        return (ap, opt, batch), (ap_sh, opt_sh, b_sh)
    if shape.kind == "prefill":
        batch, b_sh = batch_specs(cfg, shape, plan, with_labels=False)
        cache, c_sh = cache_specs(cfg, shape.global_batch, shape.seq_len,
                                  plan, dtype, vector_lengths=False)
        return (ap, batch, cache), (ap_sh, b_sh, c_sh)
    # decode
    batch, b_sh = batch_specs(cfg, shape, plan, with_labels=False)
    cache, c_sh = cache_specs(cfg, shape.global_batch, shape.seq_len,
                              plan, dtype)
    return (ap, batch["tokens"], cache), (ap_sh, b_sh["tokens"], c_sh)


__all__ = ["input_specs", "params_and_shardings", "batch_specs",
           "cache_specs"]
