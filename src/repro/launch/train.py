"""Training driver.

CPU-runnable example:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --steps 50 --batch 8 --seq 128

On a real TPU slice, drop --reduced and pass --mesh single|multi to train the
full config under the MixServe sharding plan.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.core.partitioner import NULL_PLAN, make_plan
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models.model import count_params, init_params
from repro.training import checkpoint
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=C.ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = C.get_reduced(args.arch) if args.reduced else C.get(args.arch)
    print(f"arch={cfg.name} params={count_params(cfg):,}")

    plan = NULL_PLAN
    if args.mesh:
        from repro.launch.mesh import make_production_mesh
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        plan = make_plan("mixserve", mesh)

    dtype = jnp.float32 if args.reduced else jnp.bfloat16
    params = init_params(jax.random.PRNGKey(args.seed), cfg, dtype)
    opt_state = init_opt_state(params)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, plan, opt_cfg,
                                      remat=not args.reduced))

    data = SyntheticLM(cfg, DataConfig(batch=args.batch, seq_len=args.seq,
                                       seed=args.seed))
    t0 = time.time()
    for i, batch in enumerate(data.batches(args.steps)):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss={float(m['loss']):.4f} "
                  f"ce={float(m['ce']):.4f} aux={float(m['aux']):.3f} "
                  f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f} "
                  f"({time.time() - t0:.1f}s)")
    if args.save:
        checkpoint.save(args.save, {"params": params})
        print(f"saved -> {args.save}")


if __name__ == "__main__":
    main()
