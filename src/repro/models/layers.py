"""Transformer layer substrate: norms, RoPE/M-RoPE, attention variants, MLPs.

Attention is implemented with a chunked online-softmax scan over KV blocks
(flash-attention structure in pure JAX) so that prefill at 32k lowers with
bounded live memory; the Pallas ``flash_chunk`` kernel in ``repro.kernels``
is the TPU-optimized version of the cache-backed path (ragged mixed
chunks, uniform chunked prefill, and — as its sq == 1 specialization
``flash_decode`` — single-token decode); see docs/kernels.md.

All parameter declarations carry logical axes consumed by the partitioner:
  "heads"/"kv_heads"/"ffn"/"vocab" shard over the TP ("model") mesh axis,
  "batch" over the DP axes, "expert" over the EP axis (see moe.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.partitioner import NULL_PLAN, ShardingPlan
from repro.kernels.policy import KernelPolicy
from repro.models.param import P

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms & activations
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + weight.astype(jnp.float32))
            ).astype(dtype)


def activate(x_gate, x_up, kind: str):
    if kind == "swiglu":
        return jax.nn.silu(x_gate) * x_up
    if kind == "geglu":
        return jax.nn.gelu(x_gate, approximate=True) * x_up
    return jax.nn.gelu(x_gate, approximate=True)  # plain gelu: x_up unused


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., s, n, hd); positions: broadcastable to (..., s)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., s, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta: float = 10000.0):
    """Qwen2-VL multimodal RoPE: three position streams (t, h, w) drive
    disjoint frequency sections.  x: (b, s, n, hd); positions3: (b, 3, s);
    sections: per-stream pair counts summing to hd//2."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    # section id of every frequency pair -> which position stream drives it
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=hd // 2)
    pos = jnp.take_along_axis(
        positions3.astype(jnp.float32),
        jnp.broadcast_to(sec_id[None, :, None],
                         (x.shape[0], hd // 2, positions3.shape[-1])),
        axis=1)                          # (b, hd/2, s)
    ang = jnp.einsum("bfs,f->bsf", pos, inv)      # (b, s, hd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (flash structure, pure JAX)
# ---------------------------------------------------------------------------

_SCORE_BLOCK_BUDGET = 256 * 1024 * 1024   # f32 score-block bytes per q-step


def _pick_q_block(b: int, nq: int, sq: int, skv: int) -> int:
    """Largest power-of-2 q-block whose (b, nq, qb, skv) f32 score tensor
    stays under the budget (>= 8)."""
    qb = 8
    while qb * 2 <= sq and b * nq * (qb * 2) * skv * 4 <= _SCORE_BLOCK_BUDGET:
        qb *= 2
    return qb


def chunked_attention(q, k, v, *, q_offset=0, kv_len: Optional[jax.Array] = None,
                      causal: bool = True, window: int = 0,
                      chunk_size: Optional[int] = None,
                      scale: Optional[float] = None,
                      k_positions: Optional[jax.Array] = None,
                      policy: Optional[KernelPolicy] = None):
    """q: (b, sq, nq, hd); k, v: (b, skv, nkv, hd[v]).  GQA via head groups.

    Blocked over the QUERY axis: an outer ``lax.scan`` walks q blocks with no
    carry (ys only), so the backward pass recomputes per-block rather than
    saving running-softmax carries — this is what lets train_4k/prefill_32k
    fit.  Each step materializes one (b, nkv, g, qb, skv) f32 score block,
    with qb auto-sized to a fixed VMEM/HBM budget (or forced via chunk_size).

    ``q_offset`` and ``kv_len`` may be scalars (uniform batch — train /
    single-request prefill) or (b,) vectors (the unified mixed
    prefill/decode serving step, where every slot sits at its own cache
    offset).  A slot whose queries are all masked (a ragged tail / idle
    slot) yields finite garbage rows — callers discard them.  ``kv_len``
    masks the cache tail, ``window`` applies a sliding-window mask,
    ``k_positions`` ((skv,) or (b, skv)) gives explicit absolute KV
    positions for ring-buffer caches (negative = invalid).

    ``policy.flash_chunk`` routes the cache-backed causal case through the
    Pallas ragged mixed-chunk kernel (``repro.kernels.flash_chunk``): the
    per-slot query count is ``kv_len - q_offset`` (how the unified step and
    the uniform chunked prefill both encode it), KV tiles beyond a slot's
    causal frontier are skipped, and ragged-tail rows come back as exact
    zeros instead of garbage.  Sliding windows and explicit ``k_positions``
    (ring buffers) and the stateless train path (``kv_len is None``, which
    needs autodiff through the scan) keep this jnp body.
    """
    b, sq, nq, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    groups = nq // nkv
    scale = scale if scale is not None else hd ** -0.5

    if (policy is not None and policy.flash_chunk and causal
            and window == 0 and k_positions is None and kv_len is not None):
        from repro.kernels import ops as _kops
        off = jnp.broadcast_to(
            jnp.atleast_1d(jnp.asarray(q_offset, jnp.int32)), (b,))
        lens = jnp.broadcast_to(
            jnp.atleast_1d(jnp.asarray(kv_len, jnp.int32)), (b,))
        return _kops.flash_chunk(q, k, v, off, lens - off, lens,
                                 scale=float(scale))
    qb = chunk_size or _pick_q_block(b, nq, sq, skv)
    qb = min(qb, sq)
    n_blocks = -(-sq // qb)
    pad = n_blocks * qb - sq
    qg = (q * scale).reshape(b, sq, nkv, groups, hd)
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qc = qg.reshape(b, n_blocks, qb, nkv, groups, hd).transpose(1, 0, 2, 3, 4, 5)

    # (b_, 1) per-slot query offsets; b_ is 1 (scalar, broadcasts) or b
    q_off = jnp.atleast_1d(jnp.asarray(q_offset, jnp.int32))[:, None]
    if k_positions is None:
        k_pos = jnp.arange(skv)[None]                      # (1, skv)
        base_mask = (jnp.ones((1, skv), bool) if kv_len is None
                     else k_pos < jnp.atleast_1d(jnp.asarray(kv_len))[:, None])
    else:
        k_pos = jnp.atleast_2d(k_positions)                # (1|b, skv)
        base_mask = k_pos >= 0

    def step(_, inp):
        idx, q_blk = inp                       # q_blk: (b, qb, nkv, g, hd)
        q_pos = q_off + idx * qb + jnp.arange(qb)          # (b_, qb)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k,
                       preferred_element_type=jnp.float32)
        mask = jnp.broadcast_to(base_mask[:, None, :],
                                (base_mask.shape[0], qb, skv))
        if causal:
            mask = mask & (k_pos[:, None, :] <= q_pos[..., None])
        if window > 0:
            mask = mask & (k_pos[:, None, :] > q_pos[..., None] - window)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        l = p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
                        preferred_element_type=jnp.float32)
        out_blk = pv / jnp.maximum(l, 1e-20)[..., None]
        return (), out_blk.astype(q.dtype)     # (b, nkv, g, qb, hdv)

    step = jax.checkpoint(step, prevent_cse=False)
    _, outs = jax.lax.scan(step, (), (jnp.arange(n_blocks), qc))
    # (nb, b, nkv, g, qb, hdv) -> (b, nb*qb, nq, hdv)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, n_blocks * qb, nq, hdv)
    return out[:, :sq]


# ---------------------------------------------------------------------------
# Decode attention (single new token against a long cache)
# ---------------------------------------------------------------------------

def positions_from(idx, s: int):
    """(s,) or (b, s) absolute positions from a scalar or (b,) offset."""
    idx = jnp.asarray(idx)
    if idx.ndim:
        return idx[:, None] + jnp.arange(s)
    return jnp.arange(s) + idx


def write_cache(buf, new, idx, valid_len=None):
    """Write ``new`` (b, s, ...) into ``buf`` (b, S, ...) at offset ``idx``.

    scalar idx  -> dynamic_update_slice (uniform batch — train/prefill)
    (b,) idx    -> per-slot write at per-slot offsets (continuous batching);
                   ``valid_len`` (b,) keeps only the first valid_len[i] rows
                   of slot i — the ragged-tail mask of the unified mixed
                   prefill/decode step (rows past it are dropped, so an idle
                   or short-chunk slot never touches its cache)
    """
    if jnp.ndim(idx) == 0:
        return jax.lax.dynamic_update_slice_in_dim(buf, new, idx, axis=1)
    b, skv = buf.shape[:2]
    s = new.shape[1]
    if s == 1 and valid_len is None:   # classic decode: one masked write
        m = jnp.arange(skv)[None] == idx[:, None]          # (b, S)
        m = m.reshape(b, skv, *([1] * (buf.ndim - 2)))
        return jnp.where(m, new.astype(buf.dtype), buf)
    rows = idx[:, None] + jnp.arange(s, dtype=jnp.int32)[None]   # (b, s)
    if valid_len is not None:
        rows = jnp.where(jnp.arange(s)[None] < valid_len[:, None], rows, skv)
    bi = jnp.arange(b)[:, None]
    # invalid rows were parked at skv (out of bounds) -> dropped by scatter
    return buf.at[bi, rows].set(new.astype(buf.dtype), mode="drop")


def write_cache_paged(pool, new, idx, block_table, valid_len=None):
    """Write ``new`` (b, s, ...) into the paged pool (P, page, ...) at the
    slots' logical offsets ``idx`` (b,), routed through ``block_table``
    (b, max_blocks) — logical block ``pos // page`` maps to a physical page.

    Same ragged-tail contract as ``write_cache``: rows past ``valid_len[i]``
    (and rows whose logical block is unallocated, table entry < 0) are
    parked out of bounds and dropped by the scatter, so an idle slot — or a
    slot whose pages the host allocator withheld — never touches the pool.
    Shared prefix pages are never written either: the host hands a slot a
    cache offset past its shared full blocks, so ``pos`` starts beyond them.
    """
    P, ps = pool.shape[0], pool.shape[1]
    b, s = new.shape[:2]
    nb = block_table.shape[1]
    pos = idx[:, None] + jnp.arange(s, dtype=jnp.int32)[None]    # (b, s)
    blk = jnp.clip(pos // ps, 0, nb - 1)
    page = jnp.take_along_axis(block_table, blk, axis=1)         # (b, s)
    ok = page >= 0
    if valid_len is not None:
        ok = ok & (jnp.arange(s)[None] < valid_len[:, None])
    page = jnp.where(ok, page, P)      # park invalid rows out of bounds
    return pool.at[page, pos % ps].set(new.astype(pool.dtype), mode="drop")


def gather_pages(pool, block_table):
    """Materialize a slot-major dense view (b, max_blocks*page, ...) of the
    paged pool through the block tables.  ``max_blocks * page == max_len``,
    so the view is shape-identical to the dense cache buffer — unallocated
    blocks (table entry < 0) read page 0's garbage, which sits at logical
    positions >= the slot's length and is masked by ``kv_len`` exactly like
    a dense buffer's stale tail.  The jnp fallback of the paged attention
    path is therefore *the same computation* as the dense path."""
    P, ps = pool.shape[0], pool.shape[1]
    b, nb = block_table.shape
    pages = jnp.take(pool, jnp.clip(block_table, 0, P - 1), axis=0)
    return pages.reshape(b, nb * ps, *pool.shape[2:])


def decode_attention(q, k, v, *, kv_len=None, q_positions=None, window: int = 0,
                     k_positions: Optional[jax.Array] = None,
                     scale: Optional[float] = None,
                     policy: Optional[KernelPolicy] = None):
    """One-step attention: q (b, sq<=2, nq, hd) vs cache k/v (b, S, nkv, hd[v]).

    No chunk scan — the score tensor (b, nkv, g, sq, S) is materialized so
    that a *seq-sharded* cache (kv_seq over the TP mesh axis) keeps the whole
    computation local-per-shard with XLA inserting only the softmax-reduction
    collectives (flash-decode structure under GSPMD).

    ``kv_len`` (scalar or (b,)) masks slots >= length; ``k_positions`` ((S,) or
    (b, S)) gives explicit absolute positions for ring-buffer caches
    (negative = invalid) and replaces the slot index in causal/window tests.
    ``q_positions``: (sq,) or (b, sq) absolute positions of the queries.

    ``policy.flash_decode`` routes the standard decode case (sq == 1, no
    window, slot-indexed cache) through the Pallas online-softmax kernel
    (repro.kernels.flash_decode), whose length mask assumes the decode
    invariant q_position == kv_len - 1 — exactly what the model/engine pass
    here.  Other cases (ring buffers, windows, sq == 2) keep the jnp body.
    """
    b, sq, nq, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = scale if scale is not None else hd ** -0.5

    if (policy is not None and policy.flash_decode and sq == 1
            and window == 0 and k_positions is None and kv_len is not None):
        from repro.kernels import ops as _kops
        lens = jnp.broadcast_to(jnp.atleast_1d(kv_len), (b,)).astype(jnp.int32)
        # kv_len == 0 (idle slots of a unified mixed step) is masked
        # natively by the kernel — those rows come back as exact zeros and
        # callers discard them.
        return _kops.flash_decode(q[:, 0], k, v, lens,
                                  scale=float(scale))[:, None]
    if q_positions is None:
        q_positions = jnp.zeros((sq,), jnp.int32)
    q_pos = jnp.broadcast_to(jnp.atleast_2d(q_positions), (b, sq))
    qg = (q * scale).reshape(b, sq, nkv, g, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                   preferred_element_type=jnp.float32)
    if k_positions is None:
        k_pos = jnp.broadcast_to(jnp.arange(skv)[None], (b, skv))
        if kv_len is None:
            valid = jnp.ones((b, skv), bool)
        else:
            valid = k_pos < jnp.broadcast_to(jnp.atleast_1d(kv_len),
                                             (b,))[:, None]
    else:
        k_pos = jnp.broadcast_to(jnp.atleast_2d(k_positions), (b, skv))
        valid = k_pos >= 0
    mask = valid[:, None, :] & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window > 0:
        mask = mask & (k_pos[:, None, :] > q_pos[:, :, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)      # (b,nkv,g,sq,skv)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, nq, v.shape[-1]) \
        .astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA / MQA attention block
# ---------------------------------------------------------------------------

def gqa_spec(cfg: ModelConfig) -> dict:
    h, nq, nkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        # (heads, head_dim) fallback chain: when the head count does not
        # divide the TP axis (smollm 15q, phi 8kv, gemma 1kv) the partitioner
        # shards head_dim instead — see ShardingPlan.spec_for_shape.
        "wq": P((h, nq, hd), ("embed", "heads", "head_dim")),
        "wk": P((h, nkv, hd), ("embed", "kv_heads", "kv_head_dim")),
        "wv": P((h, nkv, hd), ("embed", "kv_heads", "kv_head_dim")),
        "wo": P((nq, hd, h), ("heads", "head_dim", "embed")),
        "norm": P((h,), ("embed",), init="zeros"),
    }


@dataclasses.dataclass
class KVView:
    """Either fresh K/V (prefill/train) or a cache to read+update (decode).

    With ``block_table`` set, k/v are paged POOLS (P, page, nkv, hd) and the
    table (b, max_blocks) maps each slot's logical blocks to physical pages
    (docs/kv_cache.md); without it they are dense (b, max_len, nkv, hd)
    per-slot buffers."""
    k: jax.Array
    v: jax.Array
    length: Optional[jax.Array] = None  # valid prefix length of the cache
    block_table: Optional[jax.Array] = None


def gqa_attention(p, x, cfg: ModelConfig, plan: ShardingPlan = NULL_PLAN, *,
                  positions=None, cache: Optional[KVView] = None,
                  window: int = 0, chunk_size: int = 1024, q_lens=None):
    """Returns (out, new_cache_kv).  x: (b, s, h).

    Four modes:
      cache is None                 train / stateless prefill (fresh K/V)
      cache given, s > 1            prefill INTO a preallocated cache buffer
      cache given, s == 1           decode — single token vs the cache, via
                                    ``decode_attention`` (seq-sharded friendly)
      cache given, q_lens (b,)      unified mixed step — slot i contributes
                                    the first q_lens[i] of its s rows
                                    (prefill chunk, single decode token, or
                                    0 = idle); cache.length is the per-slot
                                    offset vector and ragged tails are
                                    masked out of both writes and scores
    """
    b, s, h = x.shape
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsh,hnd->bsnd", xn, p["wq"])
    k = jnp.einsum("bsh,hnd->bsnd", xn, p["wk"])
    v = jnp.einsum("bsh,hnd->bsnd", xn, p["wv"])
    if cache is None or s > 1:
        q = plan.constrain(q, "batch", "seq", "heads", None)
        k = plan.constrain(k, "batch", "seq", "kv_heads", None)

    idx = 0 if cache is None else cache.length
    if positions is None:
        positions = jnp.atleast_2d(positions_from(idx, s))
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is None:
        out = chunked_attention(q, k, v, causal=True, window=window,
                                chunk_size=chunk_size)
        new_kv = (k, v)
    elif cache.block_table is not None:   # paged cache (pool + block table)
        if q_lens is None:
            raise NotImplementedError(
                "paged KV cache requires the unified mixed step (q_lens)")
        if window:
            raise NotImplementedError("paged KV cache with sliding window")
        bt = cache.block_table
        kc = write_cache_paged(cache.k, k, idx, bt, valid_len=q_lens)
        vc = write_cache_paged(cache.v, v, idx, bt, valid_len=q_lens)
        # no plan.constrain on the pools: they carry no batch axis and stay
        # replicated for now (seq-sharding a page pool is a follow-up)
        kv_len = idx + q_lens
        pol = plan.kernels
        if pol is not None and pol.flash_chunk:
            from repro.kernels import ops as _kops
            out = _kops.flash_chunk_paged(q, kc, vc, bt, idx, q_lens, kv_len,
                                          scale=float(q.shape[-1] ** -0.5))
        else:
            # gathered view is shape-identical to the dense buffer, so the
            # jnp fallback routes EXACTLY like the dense path (bit-identical
            # streams — the engine-level paged-vs-dense oracle relies on it)
            kd, vd = gather_pages(kc, bt), gather_pages(vc, bt)
            if s == 1:
                out = decode_attention(q, kd, vd, kv_len=kv_len,
                                       q_positions=positions_from(idx, s),
                                       policy=pol)
            else:
                out = chunked_attention(q, kd, vd, q_offset=idx,
                                        kv_len=kv_len, causal=True,
                                        chunk_size=chunk_size, policy=pol)
        new_kv = (kc, vc)
    else:
        kc = write_cache(cache.k, k, idx, valid_len=q_lens)
        vc = write_cache(cache.v, v, idx, valid_len=q_lens)
        kc = plan.constrain(kc, "batch", "kv_seq", None, None)
        vc = plan.constrain(vc, "batch", "kv_seq", None, None)
        if q_lens is not None:   # unified mixed step (per-slot ragged batch)
            if s == 1:           # decode-shaped budget: flash_decode eligible
                out = decode_attention(q, kc, vc, kv_len=idx + q_lens,
                                       q_positions=positions_from(idx, s),
                                       window=window, policy=plan.kernels)
            else:
                out = chunked_attention(q, kc, vc, q_offset=idx,
                                        kv_len=idx + q_lens, causal=True,
                                        window=window, chunk_size=chunk_size,
                                        policy=plan.kernels)
        elif s == 1:
            out = decode_attention(q, kc, vc, kv_len=idx + s,
                                   q_positions=positions_from(idx, s),
                                   window=window, policy=plan.kernels)
        else:  # prefill into the buffer (uniform batch, scalar idx)
            out = chunked_attention(q, kc, vc, q_offset=idx, kv_len=idx + s,
                                    causal=True, window=window,
                                    chunk_size=chunk_size,
                                    policy=plan.kernels)
        new_kv = (kc, vc)
    out = jnp.einsum("bsnd,ndh->bsh", out, p["wo"])
    return plan.constrain(out, "batch", "seq_resid", "embed"), new_kv


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention) — DeepSeek-V2 / MiniCPM3
# ---------------------------------------------------------------------------

def mla_spec(cfg: ModelConfig) -> dict:
    h, nh, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    r, qr, rd, vd = (cfg.kv_lora_rank, cfg.q_lora_rank or cfg.d_model,
                     cfg.rope_head_dim, cfg.v_head_dim)
    return {
        "w_dq": P((h, qr), ("embed", "qk")),
        "q_norm": P((qr,), ("qk",), init="zeros"),
        "w_uq": P((qr, nh, hd + rd), ("qk", "heads", "head_dim")),
        "w_dkv": P((h, r), ("embed", "qk")),
        "kv_norm": P((r,), ("qk",), init="zeros"),
        "w_kr": P((h, rd), ("embed", None)),
        "w_uk": P((r, nh, hd), ("qk", "heads", "head_dim")),
        "w_uv": P((r, nh, vd), ("qk", "heads", "head_dim")),
        "wo": P((nh, vd, h), ("heads", "head_dim", "embed")),
        "norm": P((h,), ("embed",), init="zeros"),
    }


def _mla_qkr(p, x, cfg, positions):
    """Shared query path + rope'd latent key."""
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    ql = rms_norm(xn @ p["w_dq"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rnd->bsnd", ql, p["w_uq"])
    q_nope, q_rope = jnp.split(q, [cfg.head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c = rms_norm(xn @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)   # (b, s, r)
    k_rope = apply_rope((xn @ p["w_kr"])[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]                 # (b, s, rd)
    return q_nope, q_rope, c, k_rope


def mla_attention(p, x, cfg: ModelConfig, plan: ShardingPlan = NULL_PLAN, *,
                  positions=None, cache=None, chunk_size: Optional[int] = None,
                  absorb: Optional[bool] = None, q_lens=None,
                  block_table=None):
    """MLA attention.  cache = (c_cache, kr_cache, length) for decode.

    ``block_table`` (b, max_blocks) marks a paged latent cache: c/kr are
    pools (P, page, r|rd) and attention reads them through a gathered
    slot-major view (the latent is rank-r compressed, so the gather is
    cheap — both MLA regimes materialize latent-sized tensors anyway; the
    dedicated paged kernel is reserved for the GQA path).

    ``absorb=None`` auto-selects the regime (the DeepSeek serving recipe):
      s > 1 (train / prefill)  expanded — K/V up-projected per head, standard
                               attention; scores cost nh*(hd+rd) per pair.
      s == 1 (decode)          absorbed — scores/values live in the rank-r
                               latent space, so the per-step cache read is
                               (r + rd) per token instead of 2*nh*hd.
    Using absorbed at s >> 1 would multiply score FLOPs/bytes by ~r/hd (4x
    for deepseek-v2) — that blowup is exactly what the auto rule avoids.

    ``q_lens`` (b,) marks the unified mixed serving step: slot i contributes
    its first q_lens[i] rows (cache length is the per-slot offset vector);
    ragged tails are masked from cache writes and the kv mask.
    """
    b, s, h = x.shape
    nh, hd, vd, r = cfg.n_heads, cfg.head_dim, cfg.v_head_dim, cfg.kv_lora_rank
    if absorb is None:
        absorb = (cache is not None and s == 1)
    if positions is None:
        off = 0 if cache is None else cache[2]
        positions = jnp.atleast_2d(positions_from(off, s))
    q_nope, q_rope, c, k_rope = _mla_qkr(p, x, cfg, positions)
    q_nope = plan.constrain(q_nope, "batch", "seq", "heads", None)

    if not absorb:
        # training/prefill: expand K,V per head and run standard attention.
        # With a cache (chunked prefill) the expansion reads the FULL latent
        # buffer so chunk i attends to chunks 0..i (DeepSeek's recipe:
        # recompute per-head K/V from the latent cache).
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        if cache is not None and block_table is not None:   # paged latent
            if q_lens is None:
                raise NotImplementedError(
                    "paged KV cache requires the unified mixed step (q_lens)")
            c_cache, kr_cache, idx = cache
            cc = write_cache_paged(c_cache, c, idx, block_table,
                                   valid_len=q_lens)
            krc = write_cache_paged(kr_cache, k_rope, idx, block_table,
                                    valid_len=q_lens)
            src_c = gather_pages(cc, block_table)
            src_kr = gather_pages(krc, block_table)
            skv = src_c.shape[1]
            off = idx
            kv_len = idx + q_lens
            new_cache = (cc, krc)
        elif cache is not None:
            c_cache, kr_cache, idx = cache
            cc = write_cache(c_cache, c, idx, valid_len=q_lens)
            krc = write_cache(kr_cache, k_rope, idx, valid_len=q_lens)
            cc = plan.constrain(cc, "batch", "kv_seq", None)
            krc = plan.constrain(krc, "batch", "kv_seq", None)
            src_c, src_kr, skv = cc, krc, cc.shape[1]
            off = idx
            kv_len = idx + (s if q_lens is None else q_lens)
            new_cache = (cc, krc)
        else:
            src_c, src_kr, skv = c, k_rope, s
            off, kv_len = 0, None
            new_cache = (c, k_rope)
        k_nope = jnp.einsum("bsr,rnd->bsnd", src_c, p["w_uk"])
        v = jnp.einsum("bsr,rnd->bsnd", src_c, p["w_uv"])
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(src_kr[:, :, None],
                                      (b, skv, nh, cfg.rope_head_dim))],
            axis=-1)
        # explicit head sharding: without these GSPMD's backward guesses
        # reshard q/k/v to full-head replicas ("involuntary full
        # rematerialization" warnings, f32 full copies in the HLO)
        q = plan.constrain(q, "batch", "seq", "heads", None)
        k = plan.constrain(k, "batch", "seq", "heads", None)
        v = plan.constrain(v, "batch", "seq", "heads", None)
        # kv_len is None on the stateless train path, so the policy routing
        # only fires for cache-backed (chunked / mixed) prefill
        out = chunked_attention(q, k, v, q_offset=off, kv_len=kv_len,
                                causal=True, chunk_size=chunk_size,
                                scale=(hd + cfg.rope_head_dim) ** -0.5,
                                policy=plan.kernels)
    else:
        # absorbed attention: fold w_uk into q, w_uv into the output
        q_lat = jnp.einsum("bsnd,rnd->bsnr", q_nope, p["w_uk"])  # (b,s,nh,r)
        if cache is None:
            cc, krc, off, kv_len = c, k_rope, 0, None
            new_cache = (c, k_rope)
        elif block_table is not None:    # paged latent cache
            if q_lens is None:
                raise NotImplementedError(
                    "paged KV cache requires the unified mixed step (q_lens)")
            c_cache, kr_cache, idx = cache
            cc_pool = write_cache_paged(c_cache, c, idx, block_table,
                                        valid_len=q_lens)
            krc_pool = write_cache_paged(kr_cache, k_rope, idx, block_table,
                                         valid_len=q_lens)
            cc = gather_pages(cc_pool, block_table)
            krc = gather_pages(krc_pool, block_table)
            off = idx
            kv_len = idx + q_lens
            new_cache = (cc_pool, krc_pool)
        else:
            c_cache, kr_cache, idx = cache
            cc = write_cache(c_cache, c, idx, valid_len=q_lens)
            krc = write_cache(kr_cache, k_rope, idx, valid_len=q_lens)
            cc = plan.constrain(cc, "batch", "kv_seq", None)
            krc = plan.constrain(krc, "batch", "kv_seq", None)
            off = idx
            kv_len = idx + (s if q_lens is None else q_lens)
            new_cache = (cc, krc)
        # latent "keys" = [c ; k_rope], latent "values" = c (single kv head)
        k_lat = jnp.concatenate([cc, krc], axis=-1)[:, :, None, :]
        q_full = jnp.concatenate([q_lat, q_rope], axis=-1)       # (b,s,nh,r+rd)
        if cache is not None and s == 1:                         # decode
            o_lat = decode_attention(
                q_full, k_lat, cc[:, :, None, :], kv_len=kv_len,
                q_positions=positions_from(off, s),
                scale=(hd + cfg.rope_head_dim) ** -0.5,
                policy=plan.kernels)                             # (b,s,nh,r)
        else:
            o_lat = chunked_attention(
                q_full, k_lat, cc[:, :, None, :], q_offset=off, kv_len=kv_len,
                causal=True, chunk_size=chunk_size,
                scale=(hd + cfg.rope_head_dim) ** -0.5,
                policy=plan.kernels)                             # (b,s,nh,r)
        out = jnp.einsum("bsnr,rnd->bsnd", o_lat, p["w_uv"])
    out = jnp.einsum("bsnd,ndh->bsh", out, p["wo"])
    return plan.constrain(out, "batch", "seq_resid", "embed"), new_cache


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / GeGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_spec(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    h, f = cfg.d_model, d_ff or cfg.d_ff
    gated = cfg.activation in ("swiglu", "geglu")
    spec = {
        "w_in": P((h, f), ("embed", "ffn")),
        "w_out": P((f, h), ("ffn", "embed")),
        "norm": P((h,), ("embed",), init="zeros"),
    }
    if gated:
        spec["w_gate"] = P((h, f), ("embed", "ffn"))
    return spec


def mlp(p, x, cfg: ModelConfig, plan: ShardingPlan = NULL_PLAN):
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    up = xn @ p["w_in"]
    gate = xn @ p["w_gate"] if "w_gate" in p else up
    hmid = activate(gate, up, cfg.activation)
    hmid = plan.constrain(hmid, "batch", "seq", "ffn")
    out = hmid @ p["w_out"]
    return plan.constrain(out, "batch", "seq_resid", "embed")


__all__ = [
    "rms_norm", "activate", "apply_rope", "apply_mrope", "chunked_attention",
    "gqa_spec", "gqa_attention", "mla_spec", "mla_attention",
    "mlp_spec", "mlp", "KVView", "NEG_INF",
    "write_cache", "write_cache_paged", "gather_pages",
]
