"""Generic decoder covering all six assigned architecture families.

One ``ModelConfig`` + this module = a runnable model for any of:
  dense / vlm   GQA|MQA|MLA attention + dense MLP        (qwen2-vl, gemma,
                                                           smollm, minicpm3,
                                                           minitron)
  moe           attention + MixServe hybrid TP-EP MoE     (phi3.5, deepseek-v2)
  ssm           RWKV6 time-mix + channel-mix              (rwkv6)
  hybrid        (rec, rec, attn) Griffin pattern          (recurrentgemma)
  audio         whisper enc-dec (conv/mel frontend stub)  (whisper-tiny)

Layers of the same kind are *stacked* on a leading axis and driven by
``lax.scan`` so the HLO stays O(1) in depth (62-layer minicpm3 at 512 devices
must compile on a CPU host).  Heterogeneous stacks (hybrid pattern,
first-dense-layer MoE) are split into homogeneous groups scanned separately.

Three execution modes share one ``forward``:
  cache=None                    train / stateless forward
  cache given, seq > 1          prefill INTO preallocated cache buffers
  cache given, seq == 1         decode (single token, seq-sharded KV)

Decode KV caches are sharded along the *sequence* axis over the TP ("model")
mesh axis — the universal scheme that works for MQA (kv=1), GQA (any head
count) and MLA (headless latent), keeping per-chip cache bytes ~1/d_TP.

The plan's ``KernelPolicy`` (``plan.kernels``) rides through every layer
call here: cache-backed prefill and the unified mixed step run the ragged
``flash_chunk`` Pallas kernel, single-token decode its ``flash_decode``
specialization, and the MoE block the ``topk_gate``/fused-permute/
``moe_gemm`` pipeline when enabled (routing table: docs/kernels.md).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.partitioner import NULL_PLAN, ShardingPlan
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as S
from repro.models.param import P, abstract_tree, init_tree, stack


# ---------------------------------------------------------------------------
# Layer grouping (homogeneous scan groups)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Group:
    kind: str            # dense | moe | rwkv | rec | attn | xdec | pattern
    count: int           # scan length
    sub: tuple = ()      # pattern: per-repeat sub-layer kinds


def layer_plan(cfg: ModelConfig) -> tuple[Group, ...]:
    if cfg.family == "moe":
        g = []
        if cfg.first_dense_layers:
            g.append(Group("dense", cfg.first_dense_layers))
        g.append(Group("moe", cfg.n_layers - cfg.first_dense_layers))
        return tuple(g)
    if cfg.family == "ssm":
        return (Group("rwkv", cfg.n_layers),)
    if cfg.family == "hybrid":
        pat = cfg.block_pattern
        reps, rem = divmod(cfg.n_layers, len(pat))
        g = []
        if reps:
            g.append(Group("pattern", reps, pat))
        # remainder layers continue the pattern prefix, grouped by equal kind
        i = 0
        while i < rem:
            j = i
            while j < rem and pat[j] == pat[i]:
                j += 1
            g.append(Group(pat[i], j - i))
            i = j
        return tuple(g)
    if cfg.family == "audio":
        return (Group("xdec", cfg.n_layers),)
    return (Group("dense", cfg.n_layers),)   # dense / vlm


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _attn_spec(cfg: ModelConfig) -> dict:
    return L.mla_spec(cfg) if cfg.attention == "mla" else L.gqa_spec(cfg)


def xattn_spec(cfg: ModelConfig) -> dict:
    """Cross-attention (whisper decoder -> encoder output)."""
    h, nq, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        "wq": P((h, nq, hd), ("embed", "heads", "head_dim")),
        "wk": P((h, nq, hd), ("embed", "heads", "head_dim")),
        "wv": P((h, nq, hd), ("embed", "heads", "head_dim")),
        "wo": P((nq, hd, h), ("heads", "head_dim", "embed")),
        "norm": P((h,), ("embed",), init="zeros"),
    }


def sublayer_spec(cfg: ModelConfig, kind: str) -> dict:
    if kind == "dense":
        return {"attn": _attn_spec(cfg), "mlp": L.mlp_spec(cfg)}
    if kind == "moe":
        return {"attn": _attn_spec(cfg), "moe": MOE.moe_spec(cfg)}
    if kind == "rwkv":
        return {"tm": S.rwkv6_spec(cfg), "cm": S.rwkv6_channel_mix_spec(cfg)}
    if kind == "rec":
        return {"rglru": S.rglru_spec(cfg), "mlp": L.mlp_spec(cfg)}
    if kind == "attn":
        return {"attn": L.gqa_spec(cfg), "mlp": L.mlp_spec(cfg)}
    if kind == "xdec":
        return {"attn": L.gqa_spec(cfg), "xattn": xattn_spec(cfg),
                "mlp": L.mlp_spec(cfg)}
    raise KeyError(kind)


def group_spec(cfg: ModelConfig, g: Group) -> dict:
    if g.kind == "pattern":
        per = {f"l{i}": sublayer_spec(cfg, k) for i, k in enumerate(g.sub)}
        return stack(per, g.count)
    return stack(sublayer_spec(cfg, g.kind), g.count)


def enc_layer_spec(cfg: ModelConfig) -> dict:
    e = cfg.encoder
    d, nh, f = e.d_model, e.n_heads, e.d_ff
    hd = d // nh
    return {
        "wq": P((d, nh, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, nh, hd), ("embed", "heads", "head_dim")),
        "wv": P((d, nh, hd), ("embed", "heads", "head_dim")),
        "wo": P((nh, hd, d), ("heads", "head_dim", "embed")),
        "norm": P((d,), ("embed",), init="zeros"),
        "mlp_in": P((d, f), ("embed", "ffn")),
        "mlp_out": P((f, d), ("ffn", "embed")),
        "mlp_norm": P((d,), ("embed",), init="zeros"),
    }


def model_spec(cfg: ModelConfig) -> dict:
    h, v = cfg.d_model, cfg.padded_vocab
    spec: dict[str, Any] = {
        "embed": P((v, h), ("vocab", "embed"), scale=0.02),
        "final_norm": P((h,), ("embed",), init="zeros"),
        "groups": [group_spec(cfg, g) for g in layer_plan(cfg)],
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = P((h, v), ("embed", "vocab"))
    if cfg.family == "audio":
        e = cfg.encoder
        spec["enc"] = {
            "pos": P((e.n_frames, e.d_model), (None, "embed"), scale=0.02),
            "layers": stack(enc_layer_spec(cfg), e.n_layers),
            "final_norm": P((e.d_model,), ("embed",), init="zeros"),
        }
    return spec


def init_params(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    return init_tree(key, model_spec(cfg), dtype)


def abstract_params(cfg: ModelConfig, dtype=jnp.bfloat16):
    return abstract_tree(model_spec(cfg), dtype)


def param_axes(cfg: ModelConfig):
    from repro.models.param import axes_tree
    return axes_tree(model_spec(cfg))


def count_params(cfg: ModelConfig) -> int:
    from repro.models.param import param_count
    return param_count(model_spec(cfg))


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def _cache_for(cfg: ModelConfig, kind: str, count: int, batch: int,
               max_len: int, mk, dtype) -> dict:
    h = cfg.d_model
    if kind in ("dense", "moe", "xdec"):
        if cfg.attention == "mla":
            c = {"c": mk((count, batch, max_len, cfg.kv_lora_rank), dtype),
                 "kr": mk((count, batch, max_len, cfg.rope_head_dim), dtype)}
        else:
            nkv, hd = cfg.n_kv_heads, cfg.head_dim
            c = {"k": mk((count, batch, max_len, nkv, hd), dtype),
                 "v": mk((count, batch, max_len, nkv, hd), dtype)}
        if kind == "xdec":
            e = cfg.encoder
            nq, hd = cfg.n_heads, cfg.head_dim
            c["xk"] = mk((count, batch, e.n_frames, nq, hd), dtype)
            c["xv"] = mk((count, batch, e.n_frames, nq, hd), dtype)
        return c
    if kind == "rwkv":
        nh = max(1, h // 64)
        hd = h // nh
        return {"state": mk((count, batch, nh, hd, hd), jnp.float32),
                "x_tm": mk((count, batch, 1, h), dtype),
                "x_cm": mk((count, batch, 1, h), dtype)}
    if kind == "rec":
        w, cw = cfg.lru_width, cfg.conv1d_width
        return {"lru": mk((count, batch, w), jnp.float32),
                "conv": mk((count, batch, cw - 1, w), dtype)}
    if kind == "attn":
        nkv, hd, W = cfg.n_kv_heads, cfg.head_dim, cfg.window_size
        W = min(W, max_len) if W else max_len
        return {"k": mk((count, batch, W, nkv, hd), dtype),
                "v": mk((count, batch, W, nkv, hd), dtype),
                "kpos": mk((count, batch, W), jnp.int32)}
    raise KeyError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, abstract: bool = False):
    if abstract:
        mk = lambda s, dt: jax.ShapeDtypeStruct(s, dt)
    else:
        def mk(s, dt):
            if dt == jnp.int32:           # ring positions start invalid
                return jnp.full(s, -1, dt)
            return jnp.zeros(s, dt)
    groups = []
    for g in layer_plan(cfg):
        if g.kind == "pattern":
            groups.append({f"l{i}": _cache_for(cfg, k, g.count, batch,
                                               max_len, mk, dtype)
                           for i, k in enumerate(g.sub)})
        else:
            groups.append(_cache_for(cfg, g.kind, g.count, batch, max_len,
                                     mk, dtype))
    length = (jax.ShapeDtypeStruct((), jnp.int32) if abstract
              else jnp.zeros((), jnp.int32))
    return {"groups": groups, "length": length}


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int, *,
                     page_size: int, pool_pages: int, dtype=jnp.bfloat16):
    """Paged KV cache: per-group KV *pools* + per-slot block tables.

    Pools are ``(count, pool_pages, page_size, ...)`` — page 0..P-1 of a
    global free pool instead of ``(count, batch, max_len, ...)`` per-slot
    rows.  ``block_tables`` (batch, max_len // page_size) int32 maps a
    slot's logical block b to its physical page (−1 = unallocated); the
    host-side allocator (``serving.kv_cache.PagedKVCache``) owns the
    tables, refcounts and the prefix index.  Attention-cached kinds only —
    recurrent/ring state has no page structure to share.
    """
    if max_len % page_size:
        raise ValueError(f"page_size {page_size} must divide "
                         f"max_len {max_len}")
    max_blocks = max_len // page_size
    groups = []
    for g in layer_plan(cfg):
        if g.kind not in ("dense", "moe"):
            raise NotImplementedError(
                f"paged KV cache unsupported for layer kind {g.kind!r} — "
                "this family serves through the dense legacy path")
        if cfg.attention == "mla":
            c = {"c": jnp.zeros((g.count, pool_pages, page_size,
                                 cfg.kv_lora_rank), dtype),
                 "kr": jnp.zeros((g.count, pool_pages, page_size,
                                  cfg.rope_head_dim), dtype)}
        else:
            nkv, hd = cfg.n_kv_heads, cfg.head_dim
            c = {"k": jnp.zeros((g.count, pool_pages, page_size, nkv, hd),
                                dtype),
                 "v": jnp.zeros((g.count, pool_pages, page_size, nkv, hd),
                                dtype)}
        groups.append(c)
    return {"groups": groups,
            "length": jnp.zeros((batch,), jnp.int32),
            "block_tables": jnp.full((batch, max_blocks), -1, jnp.int32)}


def cache_axes(cfg: ModelConfig, batch: int, max_len: int):
    """Logical axes matching init_cache's pytree (for shardings)."""
    def mk(shape, dt):
        if len(shape) >= 3 and shape[2] in (max_len,
                                            min(cfg.window_size or max_len,
                                                max_len)):
            # (layers, batch, kv_seq, ...)
            return ("layers", "batch", "kv_seq") + (None,) * (len(shape) - 3)
        if len(shape) >= 2 and shape[1] == batch:
            return ("layers", "batch") + (None,) * (len(shape) - 2)
        return ("layers",) + (None,) * (len(shape) - 1)
    groups = []
    for g in layer_plan(cfg):
        if g.kind == "pattern":
            groups.append({f"l{i}": jax.tree.map(
                lambda a: mk(a.shape, None),
                _cache_for(cfg, k, g.count, batch, max_len,
                           lambda s, dt: jax.ShapeDtypeStruct(s, jnp.float32),
                           jnp.float32))
                for i, k in enumerate(g.sub)})
        else:
            groups.append(jax.tree.map(
                lambda a: mk(a.shape, None),
                _cache_for(cfg, g.kind, g.count, batch, max_len,
                           lambda s, dt: jax.ShapeDtypeStruct(s, jnp.float32),
                           jnp.float32)))
    return {"groups": groups, "length": ()}


# ---------------------------------------------------------------------------
# Sub-layer application
# ---------------------------------------------------------------------------

def _ring_from_prefill(k, v, W: int, s: int):
    """Build a ring-buffer cache holding the last W tokens (slot = pos % W)."""
    b = k.shape[0]
    if s >= W:
        kc = jnp.roll(k[:, s - W:], shift=s % W, axis=1)
        vc = jnp.roll(v[:, s - W:], shift=s % W, axis=1)
        kpos = jnp.roll(jnp.arange(s - W, s, dtype=jnp.int32), shift=s % W)
    else:
        pad = ((0, 0), (0, W - s), (0, 0), (0, 0))
        kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
        kpos = jnp.concatenate([jnp.arange(s, dtype=jnp.int32),
                                jnp.full((W - s,), -1, jnp.int32)])
    return kc, vc, jnp.broadcast_to(kpos, (b, W))


def _local_attn(p, x, cfg: ModelConfig, plan: ShardingPlan, c, length):
    """Sliding-window GQA layer (recurrentgemma).  Ring-buffer cache."""
    b, s, h = x.shape
    W = c["k"].shape[1] if c is not None else cfg.window_size
    xn = L.rms_norm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsh,hnd->bsnd", xn, p["wq"])
    k = jnp.einsum("bsh,hnd->bsnd", xn, p["wk"])
    v = jnp.einsum("bsh,hnd->bsnd", xn, p["wv"])
    idx = 0 if c is None else length
    positions = jnp.atleast_2d(L.positions_from(idx, s))
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)

    if c is None:
        out = L.chunked_attention(q, k, v, causal=True,
                                  window=cfg.window_size)
        new_c = None
    elif s > 1:  # (chunked) prefill into ring (uniform batch, scalar idx)
        assert s >= W or cfg.window_size >= W, \
            "chunked prefill requires chunk >= window (ring rebuild)"
        # chunk i > 0 must see the previous chunk's ring tail: attend over
        # [ring slots ; fresh chunk] with explicit positions.
        kpos_old = c["kpos"][0] if c["kpos"].ndim == 2 else c["kpos"]
        k_cat = jnp.concatenate([c["k"], k], axis=1)
        v_cat = jnp.concatenate([c["v"], v], axis=1)
        pos_cat = jnp.concatenate(
            [kpos_old, jnp.arange(s, dtype=jnp.int32) + idx])
        out = L.chunked_attention(q, k_cat, v_cat, q_offset=idx,
                                  causal=True, window=cfg.window_size,
                                  k_positions=pos_cat)
        kc, vc, kpos = _ring_from_prefill(k, v, W, s)
        # positions are chunk-local in _ring_from_prefill; shift by idx
        kpos = jnp.where(kpos >= 0, kpos + idx, kpos)
        new_c = {"k": kc, "v": vc, "kpos": kpos}
    else:        # decode against ring (slot = position % W)
        idx_vec = jnp.broadcast_to(jnp.asarray(idx, jnp.int32), (b,))
        slot = idx % W
        kc = L.write_cache(c["k"], k, slot)
        vc = L.write_cache(c["v"], v, slot)
        kpos = L.write_cache(c["kpos"][..., None],
                             idx_vec[:, None, None], slot)[..., 0]
        kc = plan.constrain(kc, "batch", "kv_seq", None, None)
        vc = plan.constrain(vc, "batch", "kv_seq", None, None)
        out = L.decode_attention(q, kc, vc,
                                 q_positions=L.positions_from(idx, s),
                                 window=cfg.window_size, k_positions=kpos)
        new_c = {"k": kc, "v": vc, "kpos": kpos}
    out = jnp.einsum("bsnd,ndh->bsh", out, p["wo"])
    return plan.constrain(out, "batch", "seq_resid", "embed"), new_c


def _cross_attn(p, x, cfg: ModelConfig, plan: ShardingPlan, xk, xv):
    """Whisper decoder cross-attention over (cached) encoder K/V."""
    xn = L.rms_norm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsh,hnd->bsnd", xn, p["wq"])
    out = L.chunked_attention(q, xk, xv, causal=False)
    out = jnp.einsum("bsnd,ndh->bsh", out, p["wo"])
    return plan.constrain(out, "batch", "seq_resid", "embed")


def apply_sublayer(kind: str, p, x, c, *, cfg: ModelConfig,
                   plan: ShardingPlan, positions, length, enc_out=None,
                   q_lens=None, block_tables=None, with_stats: bool = False):
    """One residual layer.  Returns (x, new_cache_or_None, aux) — plus a
    per-expert routed-count vector when ``with_stats`` (zeros for non-MoE
    kinds, so the scan carry stays homogeneous).

    ``q_lens`` (b,) marks the unified mixed prefill/decode serving step:
    per-slot ragged query counts against per-slot cache offsets.  Only
    attention-cached kinds support it — the recurrent/ring kinds advance
    their state by every row and cannot mask a ragged tail.
    ``block_tables`` (b, max_blocks) marks a paged cache: ``c`` holds KV
    *pools* and reads/writes go through the per-slot page indirection."""
    aux = jnp.zeros((), jnp.float32)
    counts = jnp.zeros((max(cfg.n_experts, 1),), jnp.int32)

    def _ret(x, new_c, aux, counts=counts):
        return (x, new_c, aux, counts) if with_stats else (x, new_c, aux)
    if q_lens is not None and kind not in ("dense", "moe"):
        raise NotImplementedError(
            f"unified mixed step (q_lens) unsupported for layer kind "
            f"{kind!r} — serve this family through the legacy engine path")

    if kind in ("dense", "moe", "xdec"):
        if cfg.attention == "mla":
            mla_cache = None if c is None else (c["c"], c["kr"], length)
            a_out, new_kv = L.mla_attention(p["attn"], x, cfg, plan,
                                            positions=positions,
                                            cache=mla_cache, q_lens=q_lens,
                                            block_table=block_tables)
            new_c = None if c is None else {"c": new_kv[0], "kr": new_kv[1]}
        else:
            kv_view = None if c is None else L.KVView(c["k"], c["v"], length,
                                                      block_tables)
            a_out, new_kv = L.gqa_attention(p["attn"], x, cfg, plan,
                                            positions=positions,
                                            cache=kv_view, q_lens=q_lens)
            new_c = None if c is None else {"k": new_kv[0], "v": new_kv[1]}
        x = x + a_out

        if kind == "xdec":
            if c is not None and enc_out is None:      # decode: cached enc KV
                xk, xv = c["xk"], c["xv"]
            else:                                       # prefill: fresh enc KV
                xk = jnp.einsum("bsh,hnd->bsnd", enc_out, p["xattn"]["wk"])
                xv = jnp.einsum("bsh,hnd->bsnd", enc_out, p["xattn"]["wv"])
            x = x + _cross_attn(p["xattn"], x, cfg, plan, xk, xv)
            if new_c is not None:
                new_c["xk"], new_c["xv"] = xk, xv

        if kind == "moe":
            if with_stats:
                m_out, aux, counts = MOE.moe_block(p["moe"], x, cfg, plan,
                                                   with_stats=True)
            else:
                m_out, aux = MOE.moe_block(p["moe"], x, cfg, plan)
            x = x + m_out
        else:
            x = x + L.mlp(p["mlp"], x, cfg, plan)
        return _ret(x, new_c, aux, counts)

    if kind == "rwkv":
        st = (None, None) if c is None else (c["state"], c["x_tm"])
        t_out, (state, x_tm) = S.rwkv6_time_mix(p["tm"], x, cfg, plan,
                                                state=st[0], x_prev=st[1])
        x = x + t_out
        cm_prev = None if c is None else c["x_cm"]
        c_out, x_cm = S.rwkv6_channel_mix(p["cm"], x, cfg, plan,
                                          x_prev=cm_prev)
        x = x + c_out
        new_c = None if c is None else {"state": state, "x_tm": x_tm,
                                        "x_cm": x_cm}
        return _ret(x, new_c, aux)

    if kind == "rec":
        st = (None, None) if c is None else (c["lru"], c["conv"])
        r_out, (lru, conv) = S.rglru_block(p["rglru"], x, cfg, plan,
                                           state=st[0], conv_state=st[1])
        x = x + r_out
        x = x + L.mlp(p["mlp"], x, cfg, plan)
        new_c = None if c is None else {"lru": lru, "conv": conv}
        return _ret(x, new_c, aux)

    if kind == "attn":
        a_out, new_c = _local_attn(p["attn"], x, cfg, plan, c, length)
        x = x + a_out
        x = x + L.mlp(p["mlp"], x, cfg, plan)
        return _ret(x, new_c, aux)

    raise KeyError(kind)


# ---------------------------------------------------------------------------
# Encoder (whisper)
# ---------------------------------------------------------------------------

def encode_audio(params, frames, cfg: ModelConfig, plan: ShardingPlan):
    """frames: (b, n_frames, d_enc) — precomputed conv/mel stub embeddings."""
    e = cfg.encoder
    p_enc = params["enc"]
    x = frames + p_enc["pos"][None]
    x = plan.constrain(x, "batch", "seq", "embed")

    def body(x, p):
        xn = L.rms_norm(x, p["norm"], cfg.norm_eps)
        q = jnp.einsum("bsh,hnd->bsnd", xn, p["wq"])
        k = jnp.einsum("bsh,hnd->bsnd", xn, p["wk"])
        v = jnp.einsum("bsh,hnd->bsnd", xn, p["wv"])
        a = L.chunked_attention(q, k, v, causal=False)     # bidirectional
        x = x + jnp.einsum("bsnd,ndh->bsh", a, p["wo"])
        xn = L.rms_norm(x, p["mlp_norm"], cfg.norm_eps)
        x = x + jax.nn.gelu(xn @ p["mlp_in"], approximate=True) @ p["mlp_out"]
        return x, None

    x, _ = jax.lax.scan(body, x, p_enc["layers"])
    return L.rms_norm(x, p_enc["final_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=("logits", "cache", "aux", "expert_counts"),
                   meta_fields=())
@dataclasses.dataclass
class Output:
    logits: jax.Array
    cache: Optional[dict]
    aux: jax.Array          # router load-balance loss (0 for non-MoE)
    # per-expert routed-slot counts summed over MoE layers, (n_experts,)
    # int32 — only populated by forward(expert_stats=True), else None
    expert_counts: Optional[jax.Array] = None


def forward(params, cfg: ModelConfig, plan: ShardingPlan = NULL_PLAN, *,
            tokens=None, embeds=None, frames=None, positions=None,
            cache=None, remat: bool = False, q_lens=None,
            last_only: bool = False, logit_rows=None,
            expert_stats: bool = False) -> Output:
    """Unified forward.

    tokens  (b, s_text) int32 — text token ids (None for pure-embed input)
    embeds  (b, s_front, h)   — vlm patch-embedding stub, prepended to tokens
    frames  (b, n_frames, d)  — audio frame-embedding stub (whisper encoder)
    cache   from ``init_cache`` (prefill fills it, decode reads+updates)
    q_lens  (b,) int32        — unified mixed prefill/decode step: slot i
                                contributes the first q_lens[i] of its s
                                token rows (a prefill chunk, one decode
                                token, or 0 = idle) at its own cache offset
                                (``cache["length"]`` must be the per-slot
                                vector); the cache advances by q_lens per
                                slot and rows past q_lens[i] are inert
                                padding whose logits/cache writes are
                                masked.  Requires a cache; attention-cached
                                families only (dense/vlm-text, moe, mla).
    expert_stats                accumulate per-expert routed-slot counts
                                across MoE layers into
                                ``Output.expert_counts`` (the serving
                                tier's expert-load-skew observability);
                                None on the output otherwise.
    last_only                   with q_lens: apply the LM head only to each
                                slot's last valid row (position
                                q_lens[i] - 1), returning (b, 1, v) logits —
                                the serving hot path, which would otherwise
                                pay the vocab matmul on every pad row of the
                                (b, chunk) buffer.
    logit_rows  (b, n) int32    with last_only: override the per-slot row
                                selection — the LM head is applied to these
                                row indices instead of just q_lens[i] - 1,
                                returning (b, n, v) logits.  Speculative
                                verify extracts one logit row per draft
                                position this way without paying the vocab
                                matmul on the padding rows.
    """
    if q_lens is not None and cache is None:
        raise ValueError("q_lens (unified mixed step) requires a cache")
    if last_only and q_lens is None:
        raise ValueError("last_only requires q_lens (the unified mixed step)")
    if logit_rows is not None and not last_only:
        raise ValueError("logit_rows requires last_only (it overrides the "
                         "per-slot head-row selection)")
    length = None if cache is None else cache["length"]
    block_tables = None if cache is None else cache.get("block_tables")
    if block_tables is not None and q_lens is None:
        raise ValueError("a paged cache (block_tables) requires the unified "
                         "mixed step (q_lens)")
    idx = 0 if cache is None else length

    parts = []
    if embeds is not None:
        parts.append(embeds.astype(jnp.bfloat16)
                     if cfg.dtype == "bfloat16" else embeds)
    if tokens is not None:
        t_emb = jnp.take(params["embed"], tokens, axis=0)
        if cfg.tie_embeddings:
            t_emb = t_emb * math.sqrt(cfg.d_model)
        parts.append(t_emb)
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    b, s, h = x.shape
    x = plan.constrain(x, "batch", "seq", "embed")

    if positions is None:
        base = jnp.atleast_2d(L.positions_from(idx, s))
        positions = (jnp.broadcast_to(base[:, None], (b, 3, s))
                     if cfg.mrope else base)

    enc_out = None
    if cfg.family == "audio" and frames is not None:
        enc_out = encode_audio(params, frames, cfg, plan)

    aux_total = jnp.zeros((), jnp.float32)
    counts_total = jnp.zeros((max(cfg.n_experts, 1),), jnp.int32) \
        if expert_stats else None
    new_groups = []
    for gi, g in enumerate(layer_plan(cfg)):
        p_g = params["groups"][gi]
        c_g = None if cache is None else cache["groups"][gi]

        def body(carry, xs, _g=g):
            if expert_stats:
                x, aux, cnt = carry
            else:
                x, aux = carry
                cnt = None
            p_l, c_l = xs

            def apply(kind, pp, xx, cc):
                r = apply_sublayer(kind, pp, xx, cc, cfg=cfg, plan=plan,
                                   positions=positions, length=length,
                                   enc_out=enc_out, q_lens=q_lens,
                                   block_tables=block_tables,
                                   with_stats=expert_stats)
                return r if expert_stats else r + (None,)

            if _g.kind == "pattern":
                new_c_l = {}
                for i, k in enumerate(_g.sub):
                    ci = None if c_l is None else c_l[f"l{i}"]
                    x, nc, a, c_ = apply(k, p_l[f"l{i}"], x, ci)
                    aux = aux + a
                    if cnt is not None:
                        cnt = cnt + c_
                    if nc is not None:
                        new_c_l[f"l{i}"] = nc
                new_c_l = new_c_l or None
            else:
                x, new_c_l, a, c_ = apply(_g.kind, p_l, x, c_l)
                aux = aux + a
                if cnt is not None:
                    cnt = cnt + c_
            # Megatron-style sequence parallelism on the residual stream:
            # the scan carry (saved for backward, x n_layers) lives
            # seq-sharded over the TP axis instead of replicated.
            x = plan.constrain(x, "batch", "seq_resid", "embed")
            carry = (x, aux, cnt) if expert_stats else (x, aux)
            return carry, new_c_l

        if remat:
            body = jax.checkpoint(body)
        if expert_stats:
            (x, aux_total, counts_total), new_c_g = jax.lax.scan(
                body, (x, aux_total, counts_total), (p_g, c_g))
        else:
            (x, aux_total), new_c_g = jax.lax.scan(
                body, (x, aux_total), (p_g, c_g))
        new_groups.append(new_c_g)

    if last_only:   # per-slot last valid row(s); norm/head are per-token ops
        rows = (jnp.maximum(q_lens - 1, 0)[:, None] if logit_rows is None
                else logit_rows)
        x = jnp.take_along_axis(x, rows[:, :, None], axis=1)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = jnp.einsum("bsh,hv->bsv", x, head)
    if cfg.padded_vocab != cfg.vocab_size:   # mask padding columns
        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, cfg.padded_vocab), 2)
        logits = jnp.where(col < cfg.vocab_size, logits, L.NEG_INF)
    logits = plan.constrain(logits, "batch", "seq", "vocab")

    new_cache = None
    if cache is not None:
        adv = s if q_lens is None else q_lens     # per-slot ragged advance
        new_cache = {"groups": new_groups, "length": length + adv}
        if block_tables is not None:    # host-owned mapping rides through
            new_cache["block_tables"] = block_tables
    return Output(logits=logits, cache=new_cache, aux=aux_total,
                  expert_counts=counts_total)


__all__ = ["Group", "layer_plan", "model_spec", "init_params",
           "abstract_params", "param_axes", "count_params", "init_cache",
           "init_paged_cache", "cache_axes", "forward", "Output",
           "encode_audio", "apply_sublayer", "sublayer_spec"]
