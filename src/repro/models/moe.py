"""MoE block with MixServe's hybrid TP-EP layout and fused AR-A2A comm.

Four execution paths, selected by the ShardingPlan (see partitioner.make_plan):

  local      no mesh — single-device oracle (dispatch/compute/combine in-core)
  mixserve   hybrid TP-EP: experts sharded over the EP ("data") axis, expert
             FFN sharded over the TP ("model") axis.
             comm_algo == "fused"/"sync": the paper's RS-A2A-AG — the A2A rides
             the inter-node wire on 1/d_TP-sharded hidden states (Alg. 1-2).
             comm_algo == "unfused": Tutel-style — full-width A2A + AR.
  dp_ep      vLLM-style pure EP: experts sharded over (data x model); tokens
             sliced over the model axis (EP≡DP among experts), full-width A2A.
  pure_tp    no EP: every device holds all experts TP-sharded; AR only.

All paths share routing/dispatch/combine numerics, so with ample capacity they
are numerically equivalent — tests/test_moe.py asserts this on a CPU mesh.

Dispatch modes (``ShardingPlan.dispatch_mode`` / the ``dispatch`` argument):

  capacity   classic fixed (E, C, h) buffers, C = capacity_for(T, ...).
             C depends on the TOTAL token count, so a token's MoE output can
             change with batch composition (different slots get dropped) —
             the bucketed-prefill-vs-full-forward divergence.  Kept for
             training, where the capacity bound is the load-balancing
             contract.
  dropless   sort the (token, k) slots by expert into a ragged (T*k, h)
             buffer with an ``expert_offsets`` (E+1,) prefix-sum and run the
             expert FFN as a grouped GEMM over segments (kernels.moe_gemm.
             grouped_gemm).  No capacity, no drops, no zero padding: every
             slot's result depends only on (token row, expert), so MoE
             outputs are count-independent — prefill buckets, decode steps
             and the full forward agree exactly.  Expert compute volume is
             T*k rows instead of E*C.  Under EP the ranks first exchange
             per-rank counts (a small int32 A2A), then the ragged token A2A
             runs on worst-case-sized but mostly-empty buffers; the fused
             RS-A2A-AG collective order is preserved verbatim.
  auto       the default everywhere: resolves to dropless for inference
             plans; training (train_step.loss_fn) pins capacity.

Kernelization: the ShardingPlan carries a ``KernelPolicy``
(repro.kernels.policy) selecting which Pallas kernels replace the jnp
bodies on BOTH the local and the distributed (shard_map) paths:
  topk_gate      fused softmax+top-k router gate   (route_topk)
  fused_permute  single-gather token permute / unpermute+weighted-combine
                 (scatter_to_buffers / gather_from_buffers) instead of the
                 repeat + zeros + scatter-add / gather + reduce HBM traffic
  moe_gemm       MXU-tiled grouped expert GEMM     (expert_ffn)
The jnp bodies remain the oracles; tests/test_kernel_integration.py asserts
policy-on == policy-off to allclose, locally and on a CPU mesh.

TPU adaptation note (DESIGN.md §2): the paper's async isend/irecv rounds
become XLA async collectives; what we encode is the *communication structure*
(volume and axis placement), which is the dominant term of Eq. 13.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec

from repro.configs.base import ModelConfig
from repro.core.cost_model import EP_OVERLAP_OFF, EpOverlap, cap_rows_for
from repro.core.partitioner import NULL_PLAN, ShardingPlan
from repro.kernels.policy import NULL_POLICY, KernelPolicy
from repro.models.layers import activate, rms_norm
from repro.models.param import P

try:                                   # jax >= 0.6: public API
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
except AttributeError:                 # jax 0.4.x: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_KW = {"check_rep": False}


# ---------------------------------------------------------------------------
# Spec
# ---------------------------------------------------------------------------

def moe_spec(cfg: ModelConfig) -> dict:
    h, e, de = cfg.d_model, cfg.n_experts, cfg.d_expert
    gated = cfg.activation in ("swiglu", "geglu")
    spec = {
        "norm": P((h,), ("embed",), init="zeros"),
        "router": P((h, e), ("embed", None), scale=0.02),
        "w_in": P((e, h, de), ("expert", "embed", "expert_ffn")),
        "w_out": P((e, de, h), ("expert", "expert_ffn", "embed")),
    }
    if gated:
        spec["w_gate"] = P((e, h, de), ("expert", "embed", "expert_ffn"))
    if cfg.n_shared_experts:
        ds = cfg.d_expert * cfg.n_shared_experts
        spec["shared_in"] = P((h, ds), ("embed", "ffn"))
        spec["shared_out"] = P((ds, h), ("ffn", "embed"))
        if gated:
            spec["shared_gate"] = P((h, ds), ("embed", "ffn"))
    return spec


# ---------------------------------------------------------------------------
# Routing (top-k, capacity, sort-based position assignment)
# ---------------------------------------------------------------------------

def route_topk(logits, k: int, renorm: bool = True,
               use_kernel: bool = False):
    """logits: (T, E) -> (idx (T,k), weights (T,k), aux_loss scalar).

    ``use_kernel=True`` routes through the fused Pallas softmax+top-k gate
    (repro.kernels.topk_gate) — the TPU hot path; the jnp path is the
    oracle.  Both return identical (idx, weights) (tests/test_kernels.py).
    """
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if use_kernel:
        from repro.kernels import ops as _kops
        top_p, top_i = _kops.topk_gate(logits, k, renorm=renorm)
        top_p = top_p.astype(logits.dtype)
    else:
        top_p, top_i = jax.lax.top_k(probs, k)
        if renorm:
            top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        top_p = top_p.astype(logits.dtype)
    # Switch-Transformer load-balance loss: E * sum_e f_e * P_e
    e = logits.shape[-1]
    f = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f = f / jnp.maximum(top_i.size, 1)
    p_mean = probs.mean(0)
    aux = e * jnp.sum(f * p_mean)
    return top_i, top_p, aux


def positions_in_expert(flat_e, n_experts: int):
    """Rank of each (token, k) slot within its expert, via stable sort —
    O(N log N), no (N, E) one-hot materialization."""
    n = flat_e.shape[0]
    # Known-risk sort, deliberately kept: single consumer chain (the
    # gather below), no interpret-mode pallas_call sibling to trigger the
    # R1 fusion-duplication miscompile, and the result is pinned bitwise
    # against the one-hot oracle in the sharded subprocess lanes.
    # Revisit if the capacity path ever feeds a Pallas kernel directly.
    order = jnp.argsort(flat_e, stable=True)  # repro-lint: disable=R1
    sorted_e = flat_e[order]
    ar = jnp.arange(n, dtype=jnp.int32)
    new_seg = jnp.concatenate([jnp.ones((1,), bool),
                               sorted_e[1:] != sorted_e[:-1]])
    seg_start = jax.lax.associative_scan(jnp.maximum,
                                         jnp.where(new_seg, ar, 0))
    pos_sorted = ar - seg_start
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)


def capacity_for(tokens: int, k: int, n_experts: int, cf: float) -> int:
    """Per-expert buffer capacity.

    Aligned up to a multiple of 8 for TPU layouts ONLY once past 8 — a hard
    floor of 8 inflated decode-time buffers up to 8x (8 tokens x top-6 over
    160 experts needs capacity 1, not 8), and every dispatch/combine
    collective scales with E x capacity (§Perf pair-2 iteration)."""
    c = int(math.ceil(tokens * k * cf / max(n_experts, 1)))
    if c <= 8:
        return max(1, c)
    return -(-c // 8) * 8


@dataclasses.dataclass
class DispatchInfo:
    flat_e: jax.Array     # (T*k,) expert id per slot
    pos: jax.Array        # (T*k,) clipped position within expert buffer
    keep: jax.Array       # (T*k,) bool, False => dropped (over capacity)
    weights: jax.Array    # (T*k,)
    capacity: int


def make_dispatch(idx, weights, n_experts: int, capacity: int) -> DispatchInfo:
    flat_e = idx.reshape(-1)
    pos = positions_in_expert(flat_e, n_experts)
    keep = pos < capacity
    return DispatchInfo(flat_e=flat_e, pos=jnp.minimum(pos, capacity - 1),
                        keep=keep, weights=weights.reshape(-1),
                        capacity=capacity)


def dispatch_src_tok(d: DispatchInfo, n_experts: int, t: int):
    """Inverse slot map for the fused permute kernel: (E*C,) int32 whose
    entry for flat buffer slot (e, c) is the source TOKEN id, or -1.

    Kept (token, k) slots occupy distinct (expert, position) cells, so a
    plain int32 scatter builds the inverse exactly — E*C ints instead of the
    (T*k, h) float repeat + scatter-add the jnp dispatch pays."""
    k = d.flat_e.shape[0] // t
    cells = d.flat_e * d.capacity + d.pos
    cells = jnp.where(d.keep, cells, n_experts * d.capacity)   # park drops
    inv = jnp.full((n_experts * d.capacity + 1,), -1, jnp.int32)
    tok_of_slot = (jnp.arange(d.flat_e.shape[0], dtype=jnp.int32) // k)
    return inv.at[cells].set(tok_of_slot)[:-1]


def dispatch_src_slot(d: DispatchInfo, t: int):
    """(T, k) int32 flat buffer cells per token for the fused combine
    (negative = dropped), plus the matching (T, k) weights."""
    k = d.flat_e.shape[0] // t
    cell = d.flat_e * d.capacity + d.pos
    cell = jnp.where(d.keep, cell, -1).reshape(t, k).astype(jnp.int32)
    return cell, d.weights.reshape(t, k)


def scatter_to_buffers(x, d: DispatchInfo, n_experts: int,
                       use_kernel: bool = False):
    """x: (T, h) -> (E, C, h) capacity buffers (dropped slots contribute 0).

    ``use_kernel=True`` routes through the fused Pallas permute kernel: one
    gather pass over an int32 inverse map, no (T*k, h) repeat + zero-buffer
    scatter-add HBM round trip."""
    t, h = x.shape
    if use_kernel:
        from repro.kernels import ops as _kops
        src = dispatch_src_tok(d, n_experts, t)
        return _kops.permute_tokens(x, src).reshape(n_experts, d.capacity, h)
    k = d.flat_e.shape[0] // t
    vals = jnp.repeat(x, k, axis=0) * d.keep[:, None].astype(x.dtype)
    buf = jnp.zeros((n_experts, d.capacity, h), x.dtype)
    return buf.at[d.flat_e, d.pos].add(vals)


def gather_from_buffers(buf, d: DispatchInfo, t: int,
                        use_kernel: bool = False):
    """buf: (E, C, h) -> (T, h) weighted combine.

    ``use_kernel=True`` fuses the gather and the weighted k-way reduce into
    the Pallas unpermute kernel (single pass, f32 accumulation)."""
    if use_kernel:
        from repro.kernels import ops as _kops
        slot, w = dispatch_src_slot(d, t)
        return _kops.unpermute_tokens(
            buf.reshape(-1, buf.shape[-1]), slot, w).astype(buf.dtype)
    vals = buf[d.flat_e, d.pos]
    vals = vals * (d.weights * d.keep.astype(d.weights.dtype))[:, None]
    k = d.flat_e.shape[0] // t
    return vals.reshape(t, k, -1).sum(1)


# ---------------------------------------------------------------------------
# Dropless (ragged, count-independent) dispatch
# ---------------------------------------------------------------------------

def resolve_dispatch(mode: Optional[str]) -> str:
    """"auto"/None -> "dropless" (the inference default); validates others."""
    if mode in (None, "auto"):
        return "dropless"
    if mode not in ("capacity", "dropless"):
        raise ValueError(f"unknown dispatch mode {mode!r} "
                         "(want 'auto' | 'capacity' | 'dropless')")
    return mode


@dataclasses.dataclass
class DroplessInfo:
    """Sorted-slot dispatch: all shapes static, values dynamic."""
    order: jax.Array      # (T*k,) slot id of sorted row i (by expert, stable)
    inv: jax.Array        # (T*k,) sorted row of slot f (inverse of order)
    counts: jax.Array     # (E,) tokens routed to each expert
    offsets: jax.Array    # (E+1,) prefix-sum: expert e owns rows [e, e+1)
    weights: jax.Array    # (T*k,) routing weights per slot


def make_dropless(idx, weights, n_experts: int) -> DroplessInfo:
    flat_e = idx.reshape(-1)
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True).astype(jnp.int32)
    counts = jnp.zeros((n_experts,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts).astype(jnp.int32)])
    inv = jnp.zeros((n,), jnp.int32).at[order].set(
        jnp.arange(n, dtype=jnp.int32))
    return DroplessInfo(order=order, inv=inv, counts=counts,
                        offsets=offsets, weights=weights.reshape(-1))


def gather_rows(x, src, use_kernel: bool = False, total=None,
                seg_stride: Optional[int] = None):
    """x (M, h), src (N,) int32 -> (N, h); src < 0 yields a 0 row.

    ``total`` (dynamic): valid-prefix row count(s) — a scalar for one
    prefix over the whole buffer, or a per-segment vector with segments at
    ``seg_stride`` intervals (the EP send layout).  Routes to the
    segment-aware ragged permute kernel that skips empty tiles; validity
    layout is metadata only — ``src`` must already be -1 outside it."""
    if use_kernel:
        from repro.kernels import ops as _kops
        if total is not None:
            return _kops.permute_tokens_ragged(x, src, total,
                                               seg_stride=seg_stride)
        return _kops.permute_tokens(x, src)
    from repro.kernels import ref as _kref
    return _kref.permute_tokens_ref(x, src)


def combine_rows(buf, src_slot, weights, use_kernel: bool = False):
    """buf (M, h), src_slot (T, k), weights (T, k) -> (T, h) weighted sum."""
    if use_kernel:
        from repro.kernels import ops as _kops
        return _kops.unpermute_tokens(buf, src_slot, weights)
    from repro.kernels import ref as _kref
    return _kref.unpermute_tokens_ref(buf, src_slot, weights)


def grouped_ffn(p, xs, offsets, cfg: ModelConfig, use_kernel: bool = False):
    """Expert FFN over a ragged buffer.  xs (N, h) sorted by expert,
    offsets (E_local+1,) -> (N, h).  Rows at/after offsets[-1] are
    unspecified (never read).  Partial sum over TP shards when de' < de,
    exactly like ``expert_ffn``."""
    if use_kernel:
        from repro.kernels import ops as _kops
        gg = _kops.grouped_gemm
    else:
        from repro.kernels import ref as _kref
        gg = _kref.grouped_gemm_ref
    up = gg(xs, p["w_in"], offsets)
    if "w_gate" in p:
        mid = activate(gg(xs, p["w_gate"], offsets), up, cfg.activation)
    else:
        mid = activate(up, up, cfg.activation)
    return gg(mid, p["w_out"], offsets)


# ---------------------------------------------------------------------------
# Expert FFN on capacity buffers
# ---------------------------------------------------------------------------

def expert_ffn(p, buf, cfg: ModelConfig, use_kernel: bool = False):
    """buf: (E_local, C', h) with w_in (E_local, h, de') -> (E_local, C', h).

    Output is a *partial sum* over the expert_ffn (TP) shards when de' < de.
    ``use_kernel=True`` runs the grouped GEMMs through the Pallas
    ``moe_gemm`` kernel (MXU-tiled) instead of jnp.einsum.
    """
    if use_kernel:
        from repro.kernels import ops as _kops
        up = _kops.moe_gemm(buf, p["w_in"])
        if "w_gate" in p:
            mid = activate(_kops.moe_gemm(buf, p["w_gate"]), up,
                           cfg.activation)
        else:
            mid = activate(up, up, cfg.activation)
        return _kops.moe_gemm(mid, p["w_out"])
    up = jnp.einsum("ech,ehd->ecd", buf, p["w_in"])
    if "w_gate" in p:
        gate = jnp.einsum("ech,ehd->ecd", buf, p["w_gate"])
        mid = activate(gate, up, cfg.activation)
    else:
        mid = activate(up, up, cfg.activation)
    return jnp.einsum("ecd,edh->ech", mid, p["w_out"])


def shared_expert_ffn(p, x, cfg: ModelConfig):
    """Shared experts on local tokens; returns partial sums under TP."""
    up = x @ p["shared_in"]
    if "shared_gate" in p:
        mid = activate(x @ p["shared_gate"], up, cfg.activation)
    else:
        mid = activate(up, up, cfg.activation)
    return mid @ p["shared_out"]


# ---------------------------------------------------------------------------
# Local (single-device) oracle
# ---------------------------------------------------------------------------

def moe_local(p, x, cfg: ModelConfig, cf: Optional[float] = None,
              use_kernels: bool = False,
              policy: Optional[KernelPolicy] = None,
              dispatch: Optional[str] = None,
              with_stats: bool = False):
    """x: (b, s, h).  Returns (out, aux_loss)
    [+ per-expert routed counts (E,) int32 when ``with_stats``].

    ``policy`` selects the Pallas kernels per stage (interpret mode on CPU;
    native on TPU); ``use_kernels=True`` is the legacy shorthand for
    ``KernelPolicy.all_on()``.  ``dispatch`` ("auto" -> dropless) picks the
    buffer scheme; ``cf`` only applies to capacity mode."""
    if policy is None:
        policy = KernelPolicy.all_on() if use_kernels else NULL_POLICY
    dispatch = resolve_dispatch(dispatch)
    b, s, h = x.shape
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    tok = xn.reshape(-1, h)
    t = tok.shape[0]
    k = cfg.top_k
    idx, w, aux = route_topk(tok @ p["router"], k,
                             use_kernel=policy.topk_gate)
    if dispatch == "dropless":
        dl = make_dropless(idx, w, cfg.n_experts)
        xs = gather_rows(tok, dl.order // k,               # (T*k, h) sorted
                         use_kernel=policy.fused_permute)
        ys = grouped_ffn(p, xs, dl.offsets, cfg, use_kernel=policy.moe_gemm)
        out = combine_rows(ys, dl.inv.reshape(t, k), w.reshape(t, k),
                           use_kernel=policy.fused_permute)
    else:
        if cf is None:
            cf = cfg.capacity_factor
        cap = capacity_for(t, k, cfg.n_experts, cf)
        d = make_dispatch(idx, w, cfg.n_experts, cap)
        buf = scatter_to_buffers(tok, d, cfg.n_experts,
                                 use_kernel=policy.fused_permute)
        out_buf = expert_ffn(p, buf, cfg, use_kernel=policy.moe_gemm)
        out = gather_from_buffers(out_buf, d, t,
                                  use_kernel=policy.fused_permute)
    if cfg.n_shared_experts:
        out = out + shared_expert_ffn(p, tok, cfg)
    out = out.reshape(b, s, h).astype(x.dtype)
    if with_stats:
        counts = jnp.zeros((cfg.n_experts,), jnp.int32).at[
            idx.reshape(-1)].add(1)
        return out, aux, counts
    return out, aux


# ---------------------------------------------------------------------------
# Distributed paths (shard_map)
# ---------------------------------------------------------------------------

def _axis_sz(a):
    try:
        return jax.lax.axis_size(a)
    except AttributeError:       # jax 0.4.x: psum of a literal folds to size
        return jax.lax.psum(1, a)


def _axis_index(axes: tuple):
    if not axes:
        return 0
    idx = jax.lax.axis_index(axes[0])
    for a in axes[1:]:
        idx = idx * _axis_sz(a) + jax.lax.axis_index(a)
    return idx


def _axis_size(axes: tuple):
    s = 1
    for a in axes:
        s *= _axis_sz(a)
    return s


def _routed_counts_stat(idx, e_global: int, mesh_axes: tuple, tp_axes: tuple,
                        token_sliced: bool):
    """(E,) int32 global routed-slot counts, replicated (observability).

    Tokens are sharded over the non-TP mesh axes (and over tp too when
    token-sliced); sum those partials, keep the TP replication."""
    c = jnp.zeros((e_global,), jnp.int32).at[idx.reshape(-1)].add(1)
    cnt_axes = tuple(a for a in mesh_axes if a not in tuple(tp_axes))
    if token_sliced:
        cnt_axes = cnt_axes + tuple(a for a in tp_axes if a not in cnt_axes)
    if cnt_axes:
        c = jax.lax.psum(c, cnt_axes)
    return c


def _moe_shard_fn(p, x, *, cfg: ModelConfig, tp_axes, ep_axes, comm_algo,
                  token_sliced: bool, cf: float, mesh_axes: tuple = (),
                  policy: KernelPolicy = NULL_POLICY,
                  with_stats: bool = False):
    """Per-device body.  x: (b_loc, s, h) — replicated across tp_axes.

    Returns (out (b_loc, s, h), aux scalar) — out replicated across tp_axes
    — plus (E,) int32 routed counts when ``with_stats``.
    ``policy`` kernelizes the per-device compute (gate, permute, expert
    GEMMs); the collectives between them are untouched.
    """
    b, s, h = x.shape
    tp = _axis_size(tp_axes) if tp_axes else 1
    ep = _axis_size(ep_axes) if ep_axes else 1
    e_global = cfg.n_experts
    e_local = e_global // max(ep, 1)

    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    tok_full = xn.reshape(-1, h)

    t_real = tok_full.shape[0]
    if token_sliced and tp > 1:
        # pure-EP (vLLM DP+EP): the TP group's replicated tokens are sliced
        # along the *token* axis so each (data x model) device is its own
        # EP/DP rank — "EP is essentially equivalent to DP among the experts".
        # Pad to a multiple of tp (odd counts occur in tests/serving).
        pad = (-t_real) % tp
        if pad:
            tok_full = jnp.pad(tok_full, ((0, pad), (0, 0)))
        t_loc = tok_full.shape[0] // tp
        tok = jax.lax.dynamic_slice_in_dim(
            tok_full, _axis_index(tp_axes) * t_loc, t_loc, axis=0)
    else:
        tok = tok_full
    t = tok.shape[0]

    idx, w, aux = route_topk(tok @ p["router"], cfg.top_k,
                             use_kernel=policy.topk_gate)
    cap = capacity_for(t, cfg.top_k, e_global, cf)
    d = make_dispatch(idx, w, e_global, cap)

    fused = (comm_algo in ("fused", "sync")) and tp > 1 and ep > 1 \
        and not token_sliced

    # ---------------- dispatch ----------------
    if fused:
        # Fused AG-Dispatch (Alg. 2): scatter only the LOCAL 1/tp hidden
        # shard; the inter-node A2A then moves 1/tp of the volume, and the
        # intra-node AG reconstructs full width (overlapped by XLA).
        hs = h // tp
        tok_shard = jax.lax.dynamic_slice_in_dim(
            tok, _axis_index(tp_axes) * hs, hs, axis=1)
        buf = scatter_to_buffers(tok_shard, d, e_global,
                                 use_kernel=policy.fused_permute)  # (E,C,h/tp)
    else:
        buf = scatter_to_buffers(tok, d, e_global,
                                 use_kernel=policy.fused_permute)  # (E,C,h)

    if ep > 1:
        buf = buf.reshape(ep, e_local, cap, buf.shape[-1])
        ax = ep_axes if len(ep_axes) > 1 else ep_axes[0]
        buf = jax.lax.all_to_all(buf, ax, split_axis=0, concat_axis=0,
                                 tiled=False)
        # (ep, e_local, C, h') — dim 0 now indexes the source EP rank
        buf = buf.reshape(ep, e_local, cap, buf.shape[-1])
    else:
        buf = buf.reshape(1, e_global, cap, buf.shape[-1])

    if fused:
        buf = jax.lax.all_gather(buf, tp_axes, axis=-1, tiled=True)  # full h

    # ---------------- expert compute ----------------
    if ep > 1:
        # (ep, e_local, C, h) -> (e_local, ep*C, h): one GEMM batch per
        # local expert over the buffers received from every source rank.
        comp = buf.transpose(1, 0, 2, 3).reshape(e_local, ep * cap, h)
    else:
        comp = buf.reshape(e_global, cap, h)
    out_buf = expert_ffn(p, comp, cfg,     # partial over tp when sharded
                         use_kernel=policy.moe_gemm)

    # ---------------- combine ----------------
    if ep > 1:
        out_buf = out_buf.reshape(e_local, ep, cap, h).transpose(1, 0, 2, 3)

    # Shared experts: under token slicing the TP ranks hold *different*
    # tokens, so partial-width products cannot be psummed per slice — compute
    # them on the full replicated token set instead (vLLM DP+EP does the
    # same: shared experts stay TP with a standard AR).
    shared_partial = None
    if cfg.n_shared_experts:
        shared_partial = shared_expert_ffn(
            p, tok_full if token_sliced else tok, cfg)

    if fused:
        # Fused RS-Combine (Alg. 1): reduce-scatter the TP-partial expert
        # outputs down to 1/tp width, A2A back at 1/tp volume, weighted
        # combine, and a single epilogue AG restores full width.
        out_buf = jax.lax.psum_scatter(out_buf, tp_axes, scatter_dimension=3,
                                       tiled=True)            # (ep,eL,C,h/tp)
        ax = ep_axes if len(ep_axes) > 1 else ep_axes[0]
        out_buf = jax.lax.all_to_all(out_buf, ax, split_axis=0, concat_axis=0)
        out_buf = out_buf.reshape(e_global, cap, h // tp)
        out_tok = gather_from_buffers(out_buf, d, t,          # (T, h/tp)
                                      use_kernel=policy.fused_permute)
        if shared_partial is not None:
            # fold the shared-expert partial into the same epilogue: RS it to
            # 1/tp width and add before the single AG (beyond-paper fusion).
            out_tok = out_tok + jax.lax.psum_scatter(
                shared_partial, tp_axes, scatter_dimension=1, tiled=True)
        out_tok = jax.lax.all_gather(out_tok, tp_axes, axis=-1, tiled=True)
    else:
        if tp > 1 and not token_sliced:
            out_buf = jax.lax.psum(out_buf, tp_axes)          # AR (unfused)
        if ep > 1:
            ax = ep_axes if len(ep_axes) > 1 else ep_axes[0]
            out_buf = jax.lax.all_to_all(out_buf, ax, split_axis=0,
                                         concat_axis=0)
            out_buf = out_buf.reshape(e_global, cap, h)
        else:
            out_buf = out_buf.reshape(e_global, cap, h)
        out_tok = gather_from_buffers(out_buf, d, t,
                                      use_kernel=policy.fused_permute)
        if token_sliced and tp > 1:
            # undo the token slice: gather the TP group's token shards back
            out_tok = jax.lax.all_gather(out_tok, tp_axes, axis=0, tiled=True)
            out_tok = out_tok[:t_real]          # drop slicing pad
        if shared_partial is not None:
            if tp > 1:
                shared_partial = jax.lax.psum(shared_partial, tp_axes)
            out_tok = out_tok + shared_partial

    out = out_tok.reshape(b, s, h).astype(x.dtype)
    if mesh_axes:
        aux = jax.lax.pmean(aux, mesh_axes)  # replicate for the P() out_spec
    if with_stats:
        return out, aux, _routed_counts_stat(idx, e_global, mesh_axes,
                                             tp_axes, token_sliced)
    return out, aux


def _moe_shard_dropless_fn(p, x, *, cfg: ModelConfig, tp_axes, ep_axes,
                           comm_algo, token_sliced: bool,
                           mesh_axes: tuple = (),
                           policy: KernelPolicy = NULL_POLICY,
                           ep_overlap: Optional[EpOverlap] = None,
                           with_stats: bool = False):
    """Per-device dropless body.  x: (b_loc, s, h) — replicated across
    tp_axes.  Returns (out (b_loc, s, h), aux scalar)
    [+ counts (E,) int32 when ``with_stats``].

    EP exchange without capacity padding: ranks first A2A their per-expert
    slot counts (an (ep, e_local) int32 — bytes, not activations), then A2A
    ragged token buffers.  The fused RS-A2A-AG path keeps the paper's
    collective order: the dispatch A2A rides on 1/tp-sharded hidden states,
    an AG restores full width before the expert GEMMs, and the combine
    reduces-scatters back to 1/tp before the return A2A and a single
    epilogue AG (Alg. 1-2).

    ``ep_overlap`` selects the exchange *schedule* (values never change):

    * ``None`` / ``EP_OVERLAP_OFF`` — monolithic: one dispatch A2A at the
      worst-case per-destination extent ``S = T_local*k`` (every rank could
      receive everything), one grouped GEMM, one combine A2A, strictly
      serial.
    * otherwise — micro-chunked pipeline: the token batch splits into C
      equal chunks; ALL chunks' counts + dispatch A2As are issued before
      any chunk's grouped GEMM, and each chunk's combine A2A is issued as
      soon as its GEMM finishes, so chunk i's dispatch rides the wire under
      chunk i-1's GEMM and chunk i-2's combine (XLA's async scheduler
      overlaps the independent ops).  Each chunk's exchange is
      **count-bounded**: a static per-rank row cap ``S = cap_rows_for(...)``
      priced from the routing distribution replaces the worst-case extent,
      shrinking A2A bytes by ~S*ep/n.  If any (source, dest) segment in the
      collective group overflows the cap, THAT chunk recomputes at the
      worst-case extent (a rank-uniform ``lax.cond`` — every peer takes the
      same branch), so the result is bit-identical to the monolithic
      schedule in all cases."""
    b, s, h = x.shape
    tp = _axis_size(tp_axes) if tp_axes else 1
    ep = _axis_size(ep_axes) if ep_axes else 1
    e_global = cfg.n_experts
    e_local = e_global // max(ep, 1)
    k = cfg.top_k

    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    tok_full = xn.reshape(-1, h)

    t_real = tok_full.shape[0]
    if token_sliced and tp > 1:
        pad = (-t_real) % tp
        if pad:
            tok_full = jnp.pad(tok_full, ((0, pad), (0, 0)))
        t_loc = tok_full.shape[0] // tp
        tok = jax.lax.dynamic_slice_in_dim(
            tok_full, _axis_index(tp_axes) * t_loc, t_loc, axis=0)
    else:
        tok = tok_full
    t = tok.shape[0]
    n = t * k

    idx, w, aux = route_topk(tok @ p["router"], k,
                             use_kernel=policy.topk_gate)

    fused = (comm_algo in ("fused", "sync")) and tp > 1 and ep > 1 \
        and not token_sliced

    def _ret(out_tok):
        out = out_tok.reshape(b, s, h).astype(x.dtype)
        nonlocal aux
        if mesh_axes:
            aux = jax.lax.pmean(aux, mesh_axes)
        if with_stats:
            return out, aux, _routed_counts_stat(idx, e_global, mesh_axes,
                                                 tp_axes, token_sliced)
        return out, aux

    if ep == 1:
        # no EP exchange: the local dropless pipeline, with the usual TP
        # partial-sum reduction over the expert_ffn shards.
        dl = make_dropless(idx, w, e_global)
        xs = gather_rows(tok, dl.order // k,
                         use_kernel=policy.fused_permute)
        ys = grouped_ffn(p, xs, dl.offsets, cfg, use_kernel=policy.moe_gemm)
        if tp > 1 and not token_sliced:
            ys = jax.lax.psum(ys, tp_axes)
        out_tok = combine_rows(ys, dl.inv.reshape(t, k), w.reshape(t, k),
                               use_kernel=policy.fused_permute)
        if token_sliced and tp > 1:
            out_tok = jax.lax.all_gather(out_tok, tp_axes, axis=0,
                                         tiled=True)[:t_real]
        if cfg.n_shared_experts:
            sp = shared_expert_ffn(p, tok_full if token_sliced else tok, cfg)
            if tp > 1:          # shared FFN weights are TP-sharded partials
                sp = jax.lax.psum(sp, tp_axes)
            out_tok = out_tok + sp[:out_tok.shape[0]]
        return _ret(out_tok)

    ax = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    ovl = ep_overlap if ep_overlap is not None else EP_OVERLAP_OFF
    # Micro-chunking: C equal token slices (C=1 = the monolithic schedule).
    # gcd keeps chunk shapes static and equal for any local token count.
    C = math.gcd(ovl.chunks, t) if ovl.chunks > 1 else 1
    t_c = t // C
    n_c = t_c * k                  # worst-case per-rank rows for one chunk
    cap = cap_rows_for(n_c, ep, ovl)   # count-bounded per-rank extent

    if fused:
        hs = h // tp
        tok_payload = jax.lax.dynamic_slice_in_dim(
            tok, _axis_index(tp_axes) * hs, hs, axis=1)   # (t, h/tp)
    else:
        tok_payload = tok

    # every axis that participates in this block's collectives must take the
    # same cond branch; counts are TP-replicated so the pmax is uniform.
    ovf_axes = tuple(dict.fromkeys(tuple(ep_axes) + tuple(tp_axes)))

    def send_recv(tok_c, dl, S):
        """Count-bounded dispatch A2A for one chunk at static extent S:
        rank r's rows are the sorted segment [offsets[r*e_local],
        offsets[(r+1)*e_local]) truncated to its first S rows."""
        rank_off = dl.offsets[::e_local]                  # (ep+1,)
        rank_cnt = rank_off[1:] - rank_off[:-1]           # (ep,)
        rank_eff = jnp.minimum(rank_cnt, S)
        i_in = jnp.arange(S, dtype=jnp.int32)[None, :]    # (1, S)
        p_sorted = rank_off[:-1, None] + i_in             # (ep, S)
        valid_send = i_in < rank_eff[:, None]
        src_tok_send = jnp.where(
            valid_send, dl.order[jnp.minimum(p_sorted, n_c - 1)] // k, -1)
        # per-destination-rank prefixes, NOT one contiguous prefix: rank r's
        # rows live at [r*S, r*S + rank_eff[r]), so the elision metadata is
        # the (ep,) count vector with stride S
        send = gather_rows(tok_c, src_tok_send.reshape(-1),
                           use_kernel=policy.fused_permute,
                           total=rank_eff, seg_stride=S)  # (ep*S, h')
        send = send.reshape(ep, S, -1)
        recv = jax.lax.all_to_all(send, ax, split_axis=0, concat_axis=0,
                                  tiled=False)            # (ep, S, h')
        recv = recv.reshape(ep, S, send.shape[-1])
        if fused:
            recv = jax.lax.all_gather(recv, tp_axes, axis=-1, tiled=True)
        return recv

    def expert_phase(recv, recv_counts, S):
        """Regroup received rows by local expert (closed form from the count
        prefix-sums — no sort), grouped FFN, TP reduction, and the combine
        A2A.  comb row of recv row (s, i) = expert base (off_local[le]) +
        rows for le from earlier sources + rank of i within source s's le
        segment."""
        i_in = jnp.arange(S, dtype=jnp.int32)[None, :]    # (1, S)
        csum = jnp.cumsum(recv_counts, axis=1)            # (ep, e_local)
        # Under the row cap each sender truncated its expert-sorted segment
        # at S rows, so the *effective* per-(source, expert) counts are the
        # prefix sums clipped at S, re-diffed (matches the sender exactly).
        csum_eff = jnp.minimum(csum, S)
        cnt_eff = csum_eff - jnp.concatenate(
            [jnp.zeros((ep, 1), jnp.int32), csum_eff[:, :-1]], axis=1)
        tot_src = csum_eff[:, -1]                         # (ep,)
        le = (csum_eff[:, :, None] <= i_in[None, :, :]).sum(1)  # (ep, S)
        le = le.astype(jnp.int32)
        valid_2d = i_in < tot_src[:, None]                # (ep, S)
        counts_le = cnt_eff.sum(0)                        # (e_local,)
        off_local = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                     jnp.cumsum(counts_le).astype(jnp.int32)])
        le_c = jnp.minimum(le, e_local - 1)
        start_in_src = jnp.take_along_axis(csum_eff - cnt_eff, le_c, axis=1)
        from_earlier = jnp.take_along_axis(
            jnp.cumsum(cnt_eff, axis=0) - cnt_eff, le_c, axis=1)
        pos = off_local[le_c] + from_earlier + (i_in - start_in_src)
        valid_recv = valid_2d.reshape(-1)                 # (ep*S,)
        comb_inv = jnp.where(valid_recv, pos.reshape(-1), 0).astype(jnp.int32)
        park = jnp.where(valid_recv, comb_inv, ep * S)
        comb_src = jnp.full((ep * S + 1,), -1, jnp.int32).at[park].set(
            jnp.arange(ep * S, dtype=jnp.int32))[:-1]
        m_real = tot_src.sum()
        comb = gather_rows(recv.reshape(ep * S, -1), comb_src,
                           use_kernel=policy.fused_permute, total=m_real)
        ys = grouped_ffn(p, comb, off_local, cfg,         # partial over tp
                         use_kernel=policy.moe_gemm)
        if fused:
            ys = jax.lax.psum_scatter(ys, tp_axes, scatter_dimension=1,
                                      tiled=True)         # (M, h/tp)
        elif tp > 1 and not token_sliced:
            ys = jax.lax.psum(ys, tp_axes)
        out_recv = gather_rows(ys, jnp.where(valid_recv, comb_inv, -1),
                               use_kernel=policy.fused_permute)
        out_send = jax.lax.all_to_all(
            out_recv.reshape(ep, S, -1), ax, split_axis=0, concat_axis=0)
        return out_send.reshape(ep * S, -1)

    def combine_phase(out_send, dl, w_c, S):
        """Weighted k-way combine.  Slot f sits at sorted position
        p = inv[f]; its rank r is the segment containing p (closed-form
        prefix-sum arithmetic == searchsorted(rank_off, p, 'right') - 1),
        its exchange row r*S + (p - rank_off[r])."""
        rank_off = dl.offsets[::e_local]
        p_pos = dl.inv
        r_of = (rank_off[None, 1:] <= p_pos[:, None]).sum(1).astype(jnp.int32)
        off_in_rank = p_pos - rank_off[r_of]
        row = r_of * S + jnp.minimum(off_in_rank, S - 1)
        return combine_rows(out_send, row.reshape(t_c, k),
                            w_c.reshape(t_c, k),
                            use_kernel=policy.fused_permute)

    # ---- stage 1: per-chunk routing metadata; issue ALL counts A2As and
    # count-bounded dispatch A2As up front (nothing downstream depends on
    # them yet, so they ride the wire under later chunks' compute) ----
    stage = []
    for i in range(C):
        sl = slice(i * t_c, (i + 1) * t_c)
        dl = make_dropless(idx[sl], w[sl], e_global)
        recv_counts = jax.lax.all_to_all(
            dl.counts.reshape(ep, e_local), ax, split_axis=0, concat_axis=0,
            tiled=False)                                  # (ep, e_local)
        recv = send_recv(tok_payload[sl], dl, cap)
        if cap < n_c:
            # overflow: any (source, dest) segment beyond the cap anywhere
            # in the collective group -> this chunk recomputes at the
            # worst-case extent (rank-uniform predicate).
            rank_off = dl.offsets[::e_local]
            seg_max = jnp.max(rank_off[1:] - rank_off[:-1])
            ovf = jax.lax.pmax(seg_max, ovf_axes) > cap
        else:
            ovf = None
        stage.append((dl, recv_counts, recv, ovf, w[sl], tok_payload[sl]))

    # ---- stage 2: per-chunk grouped FFN + combine A2A (chunk i's GEMM has
    # no dependency on chunk i+1's dispatch A2A — they overlap) ----
    outs = []
    for dl, recv_counts, recv, ovf, w_c, tok_c in stage:
        out_c = combine_phase(expert_phase(recv, recv_counts, cap),
                              dl, w_c, cap)
        if ovf is not None:
            # rare path: redo dispatch->FFN->combine at worst-case extent.
            # The counts A2A is NOT redone (counts are cap-independent).
            full = jax.lax.cond(
                ovf,
                lambda tc=tok_c, d=dl, rc=recv_counts, wc=w_c: combine_phase(
                    expert_phase(send_recv(tc, d, n_c), rc, n_c), d, wc, n_c),
                lambda: jnp.zeros((t_c, out_c.shape[-1]), out_c.dtype))
            out_c = jnp.where(ovf, full, out_c)
        outs.append(out_c)
    out_tok = outs[0] if C == 1 else jnp.concatenate(outs, axis=0)

    # ---------------- epilogue (full token batch) ----------------
    shared_partial = None
    if cfg.n_shared_experts:
        shared_partial = shared_expert_ffn(
            p, tok_full if token_sliced else tok, cfg)

    if fused:
        if shared_partial is not None:
            out_tok = out_tok + jax.lax.psum_scatter(
                shared_partial, tp_axes, scatter_dimension=1, tiled=True)
        out_tok = jax.lax.all_gather(out_tok, tp_axes, axis=-1, tiled=True)
    else:
        if token_sliced and tp > 1:
            out_tok = jax.lax.all_gather(out_tok, tp_axes, axis=0,
                                         tiled=True)[:t_real]
        if shared_partial is not None:
            if tp > 1:          # shared FFN weights are TP-sharded partials
                shared_partial = jax.lax.psum(shared_partial, tp_axes)
            out_tok = out_tok + shared_partial[:out_tok.shape[0]]

    return _ret(out_tok)


def moe_block(p, x, cfg: ModelConfig, plan: ShardingPlan = NULL_PLAN, *,
              cf: Optional[float] = None, dispatch: Optional[str] = None,
              with_stats: bool = False):
    """The MoE block.  x: (b, s, h) -> (out, aux_loss)
    [+ per-expert routed counts (E,) int32, replicated, when ``with_stats``
    — the expert-load observability feed, on every path].

    ``plan.kernels`` (a KernelPolicy) decides which stages run as Pallas
    kernels.  ``plan.ep_overlap`` (an EpOverlap) selects the micro-chunked
    count-bounded EP-exchange schedule on the dropless path; None keeps the
    monolithic worst-case exchange.  ``dispatch`` overrides
    ``plan.dispatch_mode`` ("auto" resolves to dropless — see the module
    docstring); ``cf`` only applies to capacity mode, where cf=0.0 is a
    legal (degenerate) capacity factor, so only None falls back to the
    config default."""
    mode = resolve_dispatch(dispatch if dispatch is not None
                            else getattr(plan, "dispatch_mode", None))
    if cf is None:
        cf = cfg.capacity_factor
    if not plan.enabled:
        return moe_local(p, x, cfg, cf, policy=plan.kernels, dispatch=mode,
                         with_stats=with_stats)

    mesh = plan.mesh
    # dp_ep plan: ep_axes overlaps tp_axes (experts span data x model) ->
    # token-sliced pure EP; tokens replicated within the TP group are sliced
    # along the token axis instead of the hidden axis.
    token_sliced = bool(set(plan.tp_axes) & set(plan.ep_axes))
    comm_algo = "unfused" if token_sliced else plan.comm_algo

    # The shard_map body expects the canonical EP/TP layout; any outer FSDP
    # storage sharding (embed->data) is converted at the shard_map boundary
    # (= the FSDP gather-on-use).  Pin the layout by dropping the fsdp rule.
    import dataclasses as _dc
    moe_plan = _dc.replace(plan, rules={**plan.rules, "embed": None})
    x_spec = moe_plan.spec(("batch", "seq", "embed"))
    p_axes = {k: v.axes for k, v in moe_spec(cfg).items() if k in p}
    p_specs = {k: moe_plan.spec(ax) for k, ax in p_axes.items()}

    ep = plan.axis_size(plan.ep_axes)
    if ep > 1 and cfg.n_experts % ep:
        raise ValueError(
            f"{cfg.name}: n_experts={cfg.n_experts} not divisible by "
            f"EP degree {ep} — pick a different plan (analyzer enforces this)")

    if mode == "dropless":
        fn = functools.partial(
            _moe_shard_dropless_fn, cfg=cfg, tp_axes=plan.tp_axes,
            ep_axes=plan.ep_axes, comm_algo=comm_algo,
            token_sliced=token_sliced, mesh_axes=tuple(mesh.axis_names),
            policy=plan.kernels, ep_overlap=plan.ep_overlap,
            with_stats=with_stats)
    else:
        fn = functools.partial(
            _moe_shard_fn, cfg=cfg, tp_axes=plan.tp_axes,
            ep_axes=plan.ep_axes, comm_algo=comm_algo,
            token_sliced=token_sliced, cf=cf,
            mesh_axes=tuple(mesh.axis_names), policy=plan.kernels,
            with_stats=with_stats)

    out_specs = (x_spec, PartitionSpec())
    if with_stats:
        out_specs = out_specs + (PartitionSpec(),)
    return _shard_map(
        fn, mesh=mesh,
        in_specs=(p_specs, x_spec),
        out_specs=out_specs,
        **_SHARD_MAP_KW,
    )(p, x)


__all__ = [
    "moe_spec", "moe_block", "moe_local", "route_topk", "make_dispatch",
    "scatter_to_buffers", "gather_from_buffers", "expert_ffn",
    "capacity_for", "positions_in_expert", "DispatchInfo",
    "dispatch_src_tok", "dispatch_src_slot",
    "resolve_dispatch", "DroplessInfo", "make_dropless", "gather_rows",
    "combine_rows", "grouped_ffn",
]
