"""Minimal parameter-declaration system (no flax dependency).

A model declares its parameters as a nested dict of ``P`` objects; ``init_tree``
materializes arrays and ``axes_tree`` yields the matching pytree of *logical
axis names* that the partitioner maps to mesh axes.  Keeping shapes, init and
logical axes in one declaration is what lets the weight loader/partitioner
(paper §III-A online stage) emit sharding specs without touching model code.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class P:
    """Declaration of one parameter tensor."""

    shape: tuple
    axes: tuple  # logical axis names (or None), len == len(shape)
    init: str = "normal"     # normal | zeros | ones
    scale: Optional[float] = None  # stddev; default 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_one(key, p: P, dtype):
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
    scale = p.scale if p.scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, p.shape, jnp.float32) * scale).astype(dtype)


def is_p(x) -> bool:
    return isinstance(x, P)


def init_tree(key, spec, dtype=jnp.bfloat16):
    """Materialize a spec pytree into parameter arrays."""
    leaves, treedef = jax.tree.flatten(spec, is_leaf=is_p)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_one(k, p, dtype) for k, p in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrs)


def axes_tree(spec):
    """The pytree of logical-axis tuples matching ``init_tree``'s output."""
    return jax.tree.map(lambda p: p.axes, spec, is_leaf=is_p)


def abstract_tree(spec, dtype=jnp.bfloat16):
    """ShapeDtypeStruct stand-ins (no allocation) — used by the dry-run."""
    return jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, dtype),
                        spec, is_leaf=is_p)


def param_count(spec) -> int:
    leaves = jax.tree.leaves(spec, is_leaf=is_p)
    return sum(math.prod(p.shape) for p in leaves)


def stack(spec, n: int, axis_name: str = "layers"):
    """Stack a per-layer spec ``n`` times along a new leading axis (for scan)."""
    def one(p: P) -> P:
        return P((n,) + p.shape, (axis_name,) + p.axes, p.init, p.scale)
    return jax.tree.map(one, spec, is_leaf=is_p)


__all__ = ["P", "init_tree", "axes_tree", "abstract_tree", "param_count",
           "stack", "is_p"]
