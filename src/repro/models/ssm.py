"""Recurrent / state-space layers: RWKV6 (Finch) and RG-LRU (RecurrentGemma).

Both are attention-free, O(seq) layers, so the ``long_500k`` decode shape is
supported for these families (see DESIGN.md §4 shape skips).

Sequence processing uses a chunked ``lax.scan`` over time (state carried
across chunks); decode processes one token against a carried state — the
recurrent analogue of the KV cache.

Note on the paper's technique (DESIGN.md §Arch-applicability): these layers
have no attention heads and no experts, so MixServe's fused AR-A2A does not
apply; TP shards the channel dimension and DP shards batch, which is what the
partitioner's rules do for ``"heads"``-free specs.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.partitioner import NULL_PLAN, ShardingPlan
from repro.models.layers import rms_norm
from repro.models.param import P


# ---------------------------------------------------------------------------
# RWKV6 (Finch) time-mix: data-dependent decay  [arXiv:2404.05892]
# ---------------------------------------------------------------------------

def rwkv6_spec(cfg: ModelConfig) -> dict:
    h = cfg.d_model
    nh = max(1, h // 64)           # rwkv6 uses head_size 64
    hd = h // nh
    lora = 64                       # decay LoRA rank (w_lora in Finch)
    return {
        "norm": P((h,), ("embed",), init="zeros"),
        "mu": P((5, h), (None, "embed"), init="zeros"),     # token-shift mixes r,k,v,w,g
        "wr": P((h, nh, hd), ("embed", "heads", None)),
        "wk": P((h, nh, hd), ("embed", "heads", None)),
        "wv": P((h, nh, hd), ("embed", "heads", None)),
        "wg": P((h, nh, hd), ("embed", "heads", None)),
        "w0": P((nh, hd), ("heads", None), init="zeros"),   # decay bias
        "w_lora_a": P((h, lora), ("embed", None)),
        "w_lora_b": P((lora, nh, hd), (None, "heads", None)),
        "bonus": P((nh, hd), ("heads", None), init="zeros"),  # "u" first-token bonus
        "ln_x": P((h,), ("embed",), init="zeros"),          # group norm on output
        "wo": P((nh, hd, h), ("heads", None, "embed")),
    }


def _rwkv6_step(state, rkvwu):
    """One recurrence step.  state: (b, nh, hd, hd) outer-product memory."""
    r, k, v, w, u = rkvwu                     # each (b, nh, hd)
    kv = k[..., :, None] * v[..., None, :]    # (b, nh, hd, hd)
    out = jnp.einsum("bnij,bni->bnj", state + u[..., :, None] * kv, r)
    state = state * w[..., :, None] + kv
    return state, out


def rwkv6_time_mix(p, x, cfg: ModelConfig, plan: ShardingPlan = NULL_PLAN, *,
                   state: Optional[jax.Array] = None,
                   x_prev: Optional[jax.Array] = None):
    """RWKV6 time-mix.  x: (b, s, h).  Returns (out, (state, x_last)).

    ``state`` is the (b, nh, hd, hd) wkv memory, ``x_prev`` the last input
    token (for token-shift across decode steps).
    """
    b, s, h = x.shape
    nh = p["w0"].shape[0]
    hd = h // nh
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    if x_prev is None:
        x_prev = jnp.zeros((b, 1, h), xn.dtype)
    shifted = jnp.concatenate([x_prev, xn[:, :-1]], axis=1)

    mix = xn[None] + p["mu"][:, None, None, :] * (shifted - xn)[None]  # (5,b,s,h)
    xr, xk, xv, xw, xg = mix

    r = jnp.einsum("bsh,hnd->bsnd", xr, p["wr"])
    k = jnp.einsum("bsh,hnd->bsnd", xk, p["wk"])
    v = jnp.einsum("bsh,hnd->bsnd", xv, p["wv"])
    g = jax.nn.silu(jnp.einsum("bsh,hnd->bsnd", xg, p["wg"]))
    # data-dependent decay (the Finch contribution): w_t = exp(-exp(dd_t))
    dd = p["w0"] + jnp.einsum("bsh,hl,lnd->bsnd", xw, p["w_lora_a"],
                              p["w_lora_b"])
    w = jnp.exp(-jnp.exp(dd.astype(jnp.float32)))
    u = jnp.broadcast_to(p["bonus"], (b, s, nh, hd))

    if state is None:
        state = jnp.zeros((b, nh, hd, hd), jnp.float32)

    seq = (r.astype(jnp.float32), k.astype(jnp.float32),
           v.astype(jnp.float32), w, u.astype(jnp.float32))
    seq = jax.tree.map(lambda a: a.transpose(1, 0, 2, 3), seq)  # (s, b, nh, hd)
    state, outs = jax.lax.scan(_rwkv6_step, state, seq)
    out = outs.transpose(1, 0, 2, 3)                            # (b, s, nh, hd)
    out = out.reshape(b, s, h).astype(x.dtype)
    out = rms_norm(out, p["ln_x"], cfg.norm_eps) * g.reshape(b, s, h)
    out = jnp.einsum("bsnd,ndh->bsh", out.reshape(b, s, nh, hd), p["wo"])
    return plan.constrain(out, "batch", "seq_resid", "embed"), (state, xn[:, -1:])


def rwkv6_channel_mix_spec(cfg: ModelConfig) -> dict:
    h, f = cfg.d_model, cfg.d_ff
    return {
        "norm": P((h,), ("embed",), init="zeros"),
        "mu": P((2, h), (None, "embed"), init="zeros"),
        "wk": P((h, f), ("embed", "ffn")),
        "wv": P((f, h), ("ffn", "embed")),
        "wr": P((h, h), ("embed", None)),
    }


def rwkv6_channel_mix(p, x, cfg: ModelConfig, plan: ShardingPlan = NULL_PLAN, *,
                      x_prev: Optional[jax.Array] = None):
    b, s, h = x.shape
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    if x_prev is None:
        x_prev = jnp.zeros((b, 1, h), xn.dtype)
    shifted = jnp.concatenate([x_prev, xn[:, :-1]], axis=1)
    mix = xn[None] + p["mu"][:, None, None, :] * (shifted - xn)[None]
    xk, xr = mix
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    k = plan.constrain(k, "batch", "seq", "ffn")
    kv = k @ p["wv"]
    out = jax.nn.sigmoid(xr @ p["wr"]) * kv
    return plan.constrain(out, "batch", "seq_resid", "embed"), xn[:, -1:]


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin)  [arXiv:2402.19427]
# ---------------------------------------------------------------------------

def rglru_spec(cfg: ModelConfig) -> dict:
    h = cfg.d_model
    w = cfg.lru_width
    cw = cfg.conv1d_width
    return {
        "norm": P((h,), ("embed",), init="zeros"),
        "w_x": P((h, w), ("embed", "ffn")),       # input branch
        "w_gate_branch": P((h, w), ("embed", "ffn")),
        "conv_w": P((cw, w), (None, "ffn"), init="zeros"),
        "conv_b": P((w,), ("ffn",), init="zeros"),
        # RG-LRU gates
        "w_input_gate": P((w, w), ("ffn", None)),
        "b_input_gate": P((w,), ("ffn",), init="zeros"),
        "w_rec_gate": P((w, w), ("ffn", None)),
        "b_rec_gate": P((w,), ("ffn",), init="zeros"),
        "lambda_p": P((w,), ("ffn",), init="zeros"),  # recurrence magnitude param
        "w_out": P((w, h), ("ffn", "embed")),
    }


_C_LRU = 8.0  # Griffin's fixed scalar on the recurrence gate


def _rglru_step(h_prev, inp):
    a, gated_x = inp
    h_new = a * h_prev + jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 0.0)) * gated_x
    return h_new, h_new


def rglru_block(p, x, cfg: ModelConfig, plan: ShardingPlan = NULL_PLAN, *,
                state: Optional[jax.Array] = None,
                conv_state: Optional[jax.Array] = None):
    """Griffin recurrent block: conv1d + RG-LRU, gated.  x: (b, s, h).

    Returns (out, (lru_state, conv_state)).
    """
    b, s, h = x.shape
    w = cfg.lru_width
    cw = cfg.conv1d_width
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    gate = jax.nn.gelu(xn @ p["w_gate_branch"], approximate=True)
    u = xn @ p["w_x"]
    u = plan.constrain(u, "batch", "seq", "ffn")

    # temporal conv1d (causal, width cw)
    if conv_state is None:
        conv_state = jnp.zeros((b, cw - 1, w), u.dtype)
    u_pad = jnp.concatenate([conv_state, u], axis=1)
    new_conv_state = u_pad[:, -(cw - 1):] if cw > 1 else conv_state
    conv = sum(u_pad[:, i:i + s] * p["conv_w"][i] for i in range(cw))
    conv = conv + p["conv_b"]

    # RG-LRU
    i_gate = jax.nn.sigmoid(conv @ p["w_input_gate"] + p["b_input_gate"])
    r_gate = jax.nn.sigmoid(conv @ p["w_rec_gate"] + p["b_rec_gate"])
    log_a = -_C_LRU * r_gate * jax.nn.softplus(p["lambda_p"])
    a = jnp.exp(log_a.astype(jnp.float32))
    gated_x = (i_gate * conv).astype(jnp.float32)

    if state is None:
        state = jnp.zeros((b, w), jnp.float32)
    a_t = a.transpose(1, 0, 2)
    gx_t = gated_x.transpose(1, 0, 2)
    state, ys = jax.lax.scan(_rglru_step, state, (a_t, gx_t))
    y = ys.transpose(1, 0, 2).astype(x.dtype)

    out = (y * gate) @ p["w_out"]
    return plan.constrain(out, "batch", "seq_resid", "embed"), (state, new_conv_state)


__all__ = [
    "rwkv6_spec", "rwkv6_time_mix", "rwkv6_channel_mix_spec",
    "rwkv6_channel_mix", "rglru_spec", "rglru_block",
]
