"""The serving stack: one resolvable API (docs/api.md).

``ServeSpec`` is the declarative surface — every knob defaults to "auto"
and is resolved by the offline analyzer / cost model; ``LLM`` is the
facade that owns Engine + Scheduler construction.
"""

from repro.serving.api import (AUTO, LLM, ResolvedServeSpec, ServeSpec,
                               spec_from_engine_kwargs)
from repro.serving.engine import (Engine, PromptTooLongError, Request,
                                  unified_supported)
from repro.serving.scheduler import (Scheduler, ServeMetrics, mixed_workload,
                                     synthetic_workload)

__all__ = ["AUTO", "LLM", "ServeSpec", "ResolvedServeSpec",
           "spec_from_engine_kwargs", "Engine", "Request",
           "PromptTooLongError", "unified_supported", "Scheduler",
           "ServeMetrics", "synthetic_workload", "mixed_workload"]
