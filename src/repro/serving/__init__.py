"""The serving stack: one resolvable API (docs/api.md).

``ServeSpec`` is the declarative surface — every knob defaults to "auto"
and is resolved by the offline analyzer / cost model; ``LLM`` is the
facade that owns Engine + Scheduler construction.  The robustness layer
(request lifecycle, bounded admission, preemption, fault injection) rides
the same spec: docs/serving.md "Robustness & degradation".
"""

from repro.core.resolve import OverloadPolicy
from repro.serving.api import AUTO, LLM, ResolvedServeSpec, ServeSpec
from repro.serving.engine import (Engine, PromptTooLongError, Request,
                                  RequestState, unified_supported)
from repro.serving.faults import Fault, FaultInjector, InjectedFault
from repro.serving.scheduler import (Scheduler, ServeMetrics,
                                     StalledEngineError, mixed_workload,
                                     synthetic_workload, tiered_workload)

__all__ = ["AUTO", "LLM", "ServeSpec", "ResolvedServeSpec", "OverloadPolicy",
           "Engine", "Request", "RequestState", "PromptTooLongError",
           "unified_supported", "Fault", "FaultInjector", "InjectedFault",
           "Scheduler", "ServeMetrics", "StalledEngineError",
           "synthetic_workload", "mixed_workload", "tiered_workload"]
