"""ServeSpec — ONE resolvable serving API (the automatic loop, closed).

MixServe's claim is *automatic*: model + cluster in, best strategy out,
serving system configured end-to-end.  ``ServeSpec`` is the single public
surface for that: every knob that used to ride on ``Engine`` / ``Scheduler``
kwargs and ``launch/serve.py`` flags is a declarative field defaulting to
``"auto"``, and ``ServeSpec.resolve(cfg, cluster/mesh)`` fills every auto
field from the offline analyzer / cost model (``core.resolve``), returning
a fully-concrete frozen ``ResolvedServeSpec`` whose ``describe()`` prints a
provenance report — which value came from where.

On top sits the ``LLM`` facade, which owns Engine + Scheduler construction:

    spec = ServeSpec(arch="phi3.5-moe-42b")          # everything "auto"
    llm = LLM.from_spec(spec)                        # resolve + build
    print(llm.spec.describe())                       # provenance report
    outs = llm.generate(prompts, max_new_tokens=16)  # blocking
    rid = llm.submit(prompt)                         # or streaming:
    for rid, tok in llm.stream(): ...

``Engine(cfg, params, spec=resolved)`` consumes the resolved spec directly
(the PR 5 per-knob kwargs shim is gone after its one-release window).
Resolution always analyses the FULL config (the offline stage prices the
real model on the real cluster); ``reduced`` only selects which weights the
local engine loads.  Robustness knobs ride the same surface: ``overload``
("auto" -> a cost-model-priced bounded admission queue) and ``faults`` (a
tuple of deterministic fault injections for chaos testing — docs/serving.md
"Robustness & degradation").  Full field/resolution table: docs/api.md.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterator, Optional, Union

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import analyzer
from repro.core import cost_model as cm
from repro.core import resolve as R
from repro.core.partitioner import NULL_PLAN, ShardingPlan, make_plan
from repro.core.resolve import (AUTO, KVConfig, OverloadPolicy,
                                SpeculationConfig)
from repro.core.topology import ClusterSpec
from repro.kernels.policy import KernelPolicy
from repro.serving.engine import Engine, Request, RequestState, \
    unified_supported
from repro.serving.faults import Fault, InjectedFault
from repro.serving.scheduler import Scheduler

_DISPATCH_MODES = (AUTO, "dropless", "capacity")
_STRATEGY_NAMES = (AUTO, "mixserve", "dp_ep", "pure_ep", "pure_tp")


def _concrete(v) -> bool:
    return v != AUTO


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Declarative serving configuration; ``"auto"`` fields are filled by
    ``resolve`` from the analyzer / cost model."""

    # which model: a registry id (repro.configs.ARCH_IDS); ``reduced``
    # selects the reduced local variant for the online engine (resolution
    # always analyses the full config)
    arch: Optional[str] = None
    reduced: bool = True
    # offline stage: target cluster (name / ClusterSpec / "auto")
    cluster: Union[str, ClusterSpec] = AUTO
    # parallel strategy: "auto" (analyzer) | plan name | analyzer Strategy
    strategy: Union[str, cm.Strategy] = AUTO
    # Pallas kernels: "auto"|"on"|"off" | explicit KernelPolicy
    kernels: Union[str, KernelPolicy] = AUTO
    # MoE dispatch buffers: "auto" (-> dropless) | "dropless" | "capacity"
    dispatch: str = AUTO
    # unified-step knobs ("auto" -> cost model)
    chunk: Union[int, str] = AUTO
    token_budget: Union[int, str] = AUTO
    max_batch: Union[int, str] = AUTO
    max_len: Union[int, str] = AUTO
    # workload hints — the analyzer's offline-stage inputs (Eqs. 9-11)
    prompt_len: int = 128
    max_new_tokens: int = 32
    arrival_rate: float = 0.0
    objective: str = "balanced"
    # robustness: bounded admission ("auto" -> cost-model queue cap +
    # deadline-first shedding) and the deterministic chaos-fault plan
    overload: Union[str, OverloadPolicy] = AUTO
    faults: tuple = ()
    # KV cache backend: "auto" (-> paged from the Eq. 8 envelope for
    # unified families, dense for legacy) | "dense" | "paged" | a KVConfig
    kv: Union[str, KVConfig] = AUTO
    # EP-exchange overlap: "auto" (cost model picks the micro-chunk count;
    # count-bounded buffers on) | "off" (monolithic worst-case exchange) |
    # an int chunk count | an explicit cm.EpOverlap
    ep_overlap: Union[str, int, cm.EpOverlap] = AUTO
    # speculative decoding: "off" (default) | "auto" (cost model prices
    # draft length k against the verify step) | an int k | an explicit
    # R.SpeculationConfig.  Greedy-only (temperature must stay 0).
    speculation: Union[str, int, R.SpeculationConfig] = "off"
    # sampling / debug
    temperature: float = 0.0
    seed: int = 0
    debug_logits: bool = False

    def __post_init__(self):
        if self.dispatch not in _DISPATCH_MODES:
            raise ValueError(f"dispatch must be one of {_DISPATCH_MODES}, "
                             f"got {self.dispatch!r}")
        if isinstance(self.strategy, str) \
                and self.strategy not in _STRATEGY_NAMES:
            raise ValueError(f"strategy must be one of {_STRATEGY_NAMES} "
                             f"or a Strategy, got {self.strategy!r}")
        for f in ("chunk", "token_budget", "max_batch", "max_len"):
            v = getattr(self, f)
            if isinstance(v, str) and v != AUTO:
                raise ValueError(f"{f} must be an int or 'auto', got {v!r}")
        if not isinstance(self.overload, OverloadPolicy) \
                and self.overload != AUTO:
            raise ValueError("overload must be 'auto' or an OverloadPolicy, "
                             f"got {self.overload!r}")
        if not isinstance(self.kv, KVConfig) \
                and self.kv not in (AUTO, "dense", "paged"):
            raise ValueError("kv must be 'auto'|'dense'|'paged' or a "
                             f"KVConfig, got {self.kv!r}")
        eo = self.ep_overlap
        if not isinstance(eo, cm.EpOverlap):
            if isinstance(eo, bool) or not (
                    eo in (AUTO, "off")
                    or (isinstance(eo, int) and eo >= 1)):
                raise ValueError(
                    "ep_overlap must be 'auto'|'off', a chunk count >= 1 "
                    f"or an EpOverlap, got {eo!r}")
        sp = self.speculation
        if not isinstance(sp, R.SpeculationConfig):
            if isinstance(sp, bool) or not (
                    sp in (AUTO, "off")
                    or (isinstance(sp, int) and sp >= 1)):
                raise ValueError(
                    "speculation must be 'auto'|'off', a draft length >= 1 "
                    f"or a SpeculationConfig, got {sp!r}")
        object.__setattr__(self, "faults", tuple(self.faults))
        for f in self.faults:
            if not isinstance(f, Fault):
                raise ValueError(
                    f"faults must be serving.faults.Fault instances, "
                    f"got {f!r}")

    # ------------------------------------------------------------------
    def resolve(self, cfg: Optional[ModelConfig] = None,
                cluster: Union[str, ClusterSpec, None] = None, *,
                mesh=None, fsdp: bool = False,
                sp: bool = True) -> "ResolvedServeSpec":
        """Fill every ``"auto"`` field from the analyzer / cost model.

        ``cfg`` defaults to the FULL config of ``arch`` (the offline stage
        prices the real model); ``cluster`` overrides the spec's field;
        ``mesh`` (optional) makes the plan a real sharded layout and
        validates an explicit cluster against the device count.
        Deterministic: same inputs, same resolved spec.
        """
        import jax

        import repro.configs as C

        if cfg is None:
            if self.arch is None:
                raise ValueError(
                    "ServeSpec.resolve needs a ModelConfig or spec.arch")
            cfg = C.get(self.arch)
        arch = self.arch or cfg.name
        prov: dict[str, str] = {}

        cl = self.cluster if cluster is None else cluster
        cluster_spec, prov["cluster"] = R.resolve_cluster(cl, mesh=mesh)
        n_devices = mesh.devices.size if mesh is not None \
            else cluster_spec.n_devices

        # ---- offline stage: the analyzer runs regardless of strategy
        # being explicit — its cost estimates price chunk/budget/batch ----
        l_in, l_out = self.prompt_len, self.max_new_tokens
        analysis_batch = self.max_batch if _concrete(self.max_batch) \
            else R.resolved_batch_cap()[0]
        # pricing hint: when the overlapped exchange is not disabled, the
        # analyzer prices every candidate with the micro-chunked schedule —
        # strategies whose A2A hides behind expert compute stop losing
        if self.ep_overlap == "off":
            price_ovl = None
        elif isinstance(self.ep_overlap, cm.EpOverlap):
            price_ovl = self.ep_overlap
        elif isinstance(self.ep_overlap, int):
            price_ovl = cm.EpOverlap(chunks=self.ep_overlap)
        else:                         # "auto": a representative chunk count
            price_ovl = cm.EpOverlap(chunks=4)
        report = analyzer.select(
            cfg, cluster_spec, batch=int(analysis_batch),
            l_in=min(l_in, 8192), l_out=l_out,
            arrival_rate=self.arrival_rate, objective=self.objective,
            ep_overlap=price_ovl)
        best = report.best.strategy

        # ---- strategy -> plan layout name ----
        if isinstance(self.strategy, cm.Strategy):
            cost_strat = self.strategy
            name = R.plan_name_for(cfg, cost_strat, n_devices)
            comm_algo = cost_strat.comm_algo
            prov["strategy"] = "explicit:Strategy"
        elif _concrete(self.strategy):
            # a bare layout name has no degrees, so the analyzer's best
            # strategy still prices chunk/budget/batch; when the name is
            # the very layout the best maps to, keep its comm algorithm
            # too instead of pinning "fused"
            cost_strat, name = best, self.strategy
            same = R.plan_name_for(cfg, best, n_devices) == name
            comm_algo = best.comm_algo if same else "fused"
            prov["strategy"] = ("explicit (cost estimates from the "
                                f"analyzer best: {best.describe()})")
        else:
            cost_strat = best
            name = R.plan_name_for(cfg, best, n_devices)
            comm_algo = best.comm_algo
            prov["strategy"] = (f"auto:analyzer({self.objective} on "
                                f"{cluster_spec.name}: {best.describe()})")

        # ---- kernels (the policy participates in resolution) ----
        if isinstance(self.kernels, KernelPolicy):
            kernels, prov["kernels"] = self.kernels, "explicit"
        elif _concrete(self.kernels):
            kernels = KernelPolicy.parse(self.kernels)
            prov["kernels"] = "explicit"
        else:
            kernels = KernelPolicy.auto()
            prov["kernels"] = f"auto:backend({jax.default_backend()})"

        # ---- dispatch ----
        if _concrete(self.dispatch):
            dispatch, prov["dispatch"] = self.dispatch, "explicit"
        else:
            dispatch = "dropless"
            prov["dispatch"] = ("auto:inference(count-independent ragged "
                                "buffers; docs/dispatch.md)")

        # ---- engine envelope from the cost model ----
        if _concrete(self.max_batch):
            max_batch, prov["max_batch"] = int(self.max_batch), "explicit"
        else:
            max_batch, prov["max_batch"] = R.auto_max_batch(
                cfg, cost_strat, cluster_spec, l_in=l_in, l_out=l_out)

        front = cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0
        if _concrete(self.max_len):
            max_len, prov["max_len"] = int(self.max_len), "explicit"
        else:
            max_len, prov["max_len"] = R.auto_max_len(l_in, l_out, front)

        if _concrete(self.chunk):
            chunk, prov["chunk"] = int(self.chunk), "explicit"
        else:
            chunk, prov["chunk"] = R.auto_chunk(
                cfg, cost_strat, cluster_spec, batch=max_batch,
                l_in=l_in, l_out=l_out)
        chunk = max(1, min(chunk, max_len))

        # ---- speculation: draft length priced against the verify step ----
        speculation, prov["speculation"] = R.auto_speculation(
            cfg, cost_strat, cluster_spec, batch=max_batch, l_in=l_in,
            l_out=l_out, chunk=chunk, temperature=self.temperature,
            unified_ok=unified_supported(cfg), value=self.speculation)

        if _concrete(self.token_budget) and int(self.token_budget) > 0:
            token_budget = int(self.token_budget)
            prov["token_budget"] = "explicit"
        else:
            token_budget, prov["token_budget"] = R.auto_token_budget(
                max_batch, chunk,
                spec_k=speculation.k if speculation else 0)

        # ---- overload: priced degradation (bounded admission queue) ----
        if isinstance(self.overload, OverloadPolicy):
            overload, prov["overload"] = self.overload, "explicit"
        else:
            overload, prov["overload"] = R.auto_overload(
                cfg, cost_strat, cluster_spec, batch=max_batch,
                l_in=l_in, l_out=l_out)

        # ---- KV cache: Eq. 8 memory envelope -> paged pool sizing ----
        paged_ok = unified_supported(cfg)
        if isinstance(self.kv, KVConfig):
            if self.kv.backend == "paged" and not paged_ok:
                raise ValueError(
                    f"kv backend 'paged' needs the unified step, which "
                    f"cannot serve {cfg.name} (family {cfg.family}) — use "
                    "kv='dense' or kv='auto'")
            kv, prov["kv"] = self.kv, "explicit"
        else:
            backend = None if self.kv == AUTO else self.kv
            if backend == "paged" and not paged_ok:
                raise ValueError(
                    f"kv='paged' needs the unified step, which cannot "
                    f"serve {cfg.name} (family {cfg.family}) — use "
                    "kv='dense' or kv='auto'")
            kv, prov["kv"] = R.auto_kv(
                cfg, max_batch=max_batch, max_len=max_len, l_in=l_in,
                l_out=l_out, front=front, paged_ok=paged_ok,
                backend=backend)

        # ---- EP-exchange overlap: micro-chunk count + row cap ----
        ep_ovl, prov["ep_overlap"] = R.auto_ep_overlap(
            cfg, cost_strat, cluster_spec, batch=max_batch, l_in=l_in,
            l_out=l_out, value=self.ep_overlap)

        plan = make_plan(name, mesh, comm_algo=comm_algo, fsdp=fsdp, sp=sp,
                         kernels=kernels, dispatch=dispatch,
                         ep_overlap=ep_ovl)

        return ResolvedServeSpec(
            arch=arch, reduced=self.reduced, cluster=cluster_spec.name,
            strategy=name, strategy_detail=cost_strat.describe(),
            kernels=kernels, dispatch=dispatch, chunk=chunk,
            token_budget=token_budget, max_batch=max_batch, max_len=max_len,
            prompt_len=l_in, max_new_tokens=l_out,
            arrival_rate=self.arrival_rate, objective=self.objective,
            overload=overload, faults=self.faults, kv=kv,
            ep_overlap=ep_ovl, speculation=speculation,
            moe_ep=cost_strat.moe_ep if cfg.is_moe else 1,
            moe_tp=cost_strat.moe_tp if cfg.is_moe else 1,
            temperature=self.temperature, seed=self.seed,
            debug_logits=self.debug_logits, plan=plan, report=report,
            provenance=prov)


@dataclasses.dataclass(frozen=True)
class ResolvedServeSpec:
    """A ServeSpec with every field concrete, plus where each came from.

    Round-trips through ``dataclasses.replace`` (all fields are init
    fields); ``report`` is analysis payload, excluded from equality so
    resolution determinism is a value property.
    """

    arch: Optional[str]
    reduced: bool
    cluster: str                      # cluster NAME
    strategy: str                     # plan layout name
    strategy_detail: str              # analyzer Strategy.describe()
    kernels: KernelPolicy
    dispatch: str
    chunk: int
    token_budget: int
    max_batch: int
    max_len: int
    prompt_len: int
    max_new_tokens: int
    arrival_rate: float
    objective: str
    overload: OverloadPolicy
    temperature: float
    seed: int
    debug_logits: bool
    faults: tuple = ()
    kv: KVConfig = dataclasses.field(default_factory=KVConfig)
    ep_overlap: Optional[cm.EpOverlap] = None   # None = monolithic exchange
    speculation: Optional[R.SpeculationConfig] = None   # None = off
    # the priced strategy's MoE degrees (the engine's expert-load/A2A
    # observability buckets measured counts by them — the local engine
    # itself runs the NULL_PLAN single-device layout)
    moe_ep: int = 1
    moe_tp: int = 1
    plan: ShardingPlan = NULL_PLAN
    report: Optional[analyzer.AnalyzerReport] = dataclasses.field(
        default=None, compare=False, repr=False)
    provenance: dict = dataclasses.field(default_factory=dict)

    _KNOBS = ("strategy", "kernels", "dispatch", "chunk", "token_budget",
              "max_batch", "max_len", "cluster", "overload", "kv",
              "ep_overlap", "speculation")

    def describe(self) -> str:
        """The provenance report: every knob, its value, and its source."""
        head = (f"ResolvedServeSpec: {self.arch or '?'}"
                f"{' (reduced engine)' if self.reduced else ''} "
                f"on {self.cluster}  "
                f"[workload b<={self.max_batch} l_in={self.prompt_len} "
                f"l_out={self.max_new_tokens} "
                f"objective={self.objective}]")
        rows = []
        for f in self._KNOBS:
            v = getattr(self, f)
            if f == "strategy" and self.strategy_detail:
                v = f"{v} ({self.strategy_detail})"
            elif isinstance(v, (KernelPolicy, OverloadPolicy, KVConfig,
                                cm.EpOverlap, R.SpeculationConfig)):
                v = v.describe()
            elif v is None and f in ("ep_overlap", "speculation"):
                v = "off"
            rows.append((f, str(v), self.provenance.get(f, "?")))
        w0 = max(len(r[0]) for r in rows)
        w1 = max(len(r[1]) for r in rows)
        lines = [head] + [f"  {f:<{w0}}  {v:<{w1}}  <- {src}"
                          for f, v, src in rows]
        return "\n".join(lines)

    def as_meta(self) -> dict:
        """JSON-able provenance block (benchmark artifacts / logs)."""
        resolved = {}
        for f in self._KNOBS:
            v = getattr(self, f)
            if isinstance(v, (KernelPolicy, OverloadPolicy, KVConfig,
                              cm.EpOverlap, R.SpeculationConfig)):
                v = v.describe()
            elif v is None and f in ("ep_overlap", "speculation"):
                v = "off"
            resolved[f] = v
        return {
            "resolved": resolved,
            "provenance": dict(self.provenance),
            "faults": [f.describe() for f in self.faults],
        }


class LLM:
    """The facade over Engine + Scheduler: one resolvable spec in, a
    serving endpoint out.

    ``generate`` is the blocking API; ``submit``/``stream`` the streaming
    one (tokens yielded as the unified steps produce them); ``serve``
    replays a timed Request workload through the Scheduler and returns it
    (for metrics).
    """

    def __init__(self, cfg: ModelConfig, params, spec: ResolvedServeSpec, *,
                 embeds_fn=None, dtype=None):
        import jax.numpy as jnp
        dtype = jnp.float32 if dtype is None else dtype
        if embeds_fn is None and cfg.frontend == "audio_stub":
            e = cfg.encoder
            embeds_fn = lambda b: {"frames": jnp.full(
                (b, e.n_frames, e.d_model), 0.01, jnp.float32)}
        self.cfg, self.params, self.spec = cfg, params, spec
        self.engine = Engine(cfg, params, spec=spec, embeds_fn=embeds_fn,
                             dtype=dtype)
        self._queue: deque[Request] = deque()
        self._next_rid = 0

    @classmethod
    def from_spec(cls, spec: Union[ServeSpec, ResolvedServeSpec], *,
                  cfg: Optional[ModelConfig] = None, params=None,
                  cluster=None, mesh=None, embeds_fn=None,
                  dtype=None) -> "LLM":
        """Resolve (if needed), load/init weights, build Engine+Scheduler.

        ``cfg``/``params`` override the registry lookup (custom configs,
        pre-initialized weights); with a plain ``ServeSpec`` the FULL
        config is analysed and the reduced/full engine config follows
        ``spec.reduced``.
        """
        import jax
        import jax.numpy as jnp

        import repro.configs as C
        from repro.models.model import init_params

        if isinstance(spec, ServeSpec):
            full = cfg if (cfg is not None and spec.arch is None) else None
            resolved = spec.resolve(full, cluster, mesh=mesh)
        else:
            resolved = spec
        if cfg is None:
            if resolved.arch is None:
                raise ValueError("pass cfg= for a spec without .arch")
            cfg = (C.get_reduced(resolved.arch) if resolved.reduced
                   else C.get(resolved.arch))
        dtype = jnp.float32 if dtype is None else dtype
        if params is None:
            params = init_params(jax.random.PRNGKey(resolved.seed), cfg,
                                 dtype)
        return cls(cfg, params, resolved, embeds_fn=embeds_fn, dtype=dtype)

    # -- streaming -------------------------------------------------------
    def submit(self, prompt, max_new_tokens: Optional[int] = None, *,
               priority: int = 0,
               deadline_s: Optional[float] = None) -> int:
        """Queue a prompt; returns its request id.  Validates eagerly
        (``PromptTooLongError`` raised here never corrupts already-queued
        requests).  ``priority`` orders nothing in the plain stream() loop
        but rides into Scheduler-driven serving; ``deadline_s`` is seconds
        from NOW — an expired request is cancelled mid-flight."""
        if max_new_tokens is None:
            max_new_tokens = self.spec.max_new_tokens or 32
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens,
                      arrival=time.perf_counter(),
                      priority=priority, deadline_s=deadline_s)
        self.engine.validate(req)
        self._queue.append(req)
        return rid

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or running request.  Frees its engine slot
        immediately; a ``stream()`` in progress simply stops yielding its
        tokens.  Returns False for unknown/already-finished rids."""
        for req in self._queue:
            if req.rid == rid:
                self._queue.remove(req)
                req.state = RequestState.CANCELLED
                req.error = "cancelled"
                req.t_done = time.perf_counter()
                self.engine.events["cancel"] += 1
                return True
        return self.engine.cancel(rid) is not None

    def stream(self) -> Iterator[tuple[int, int]]:
        """Drive unified steps, yielding (rid, token) as tokens land.

        Lifecycle-aware: cancelled/failed/deadline-expired requests stop
        yielding and free their slots; injected admission faults shed
        exactly the targeted request; later rids keep completing.
        """
        emitted: dict[int, int] = {}
        live: dict[int, Request] = {}
        while self._queue or self.engine.n_active:
            while self._queue and self.engine.free_slots():
                req = self._queue[0]
                try:
                    if not self.engine.admit(req):
                        break
                except InjectedFault as e:
                    self._queue.popleft()
                    req.state = RequestState.SHED
                    req.error = str(e)
                    req.t_done = time.perf_counter()
                    continue
                self._queue.popleft()
                live[req.rid] = req
            now = time.perf_counter()
            for req in list(live.values()):
                if not req.terminal and not req.done and now > req.deadline:
                    slot = self.engine.slot_of(req.rid)
                    if slot is not None:
                        self.engine.release(
                            slot, RequestState.CANCELLED,
                            error="deadline expired mid-flight",
                            reason="deadline_miss")
            retired = self.engine.step(self.spec.token_budget)
            for req in retired:
                # pool-pressure preemption: back to the head of the queue
                # (recompute-on-resume; prefix index keeps its full pages)
                if req is not None and req.state == RequestState.PREEMPTED:
                    self._queue.appendleft(req)
            for req in list(live.values()):
                if req.terminal and req.state != RequestState.DONE:
                    del live[req.rid]       # cancelled / failed / shed
                    continue
                n0 = emitted.get(req.rid, 0)
                for tok in req.out_tokens[n0:]:
                    yield req.rid, int(tok)
                emitted[req.rid] = len(req.out_tokens)
                if req.done:
                    del live[req.rid]

    # -- blocking --------------------------------------------------------
    def generate(self, prompts,
                 max_new_tokens: Optional[int] = None) -> list[list[int]]:
        """Serve a batch of prompts to completion; outputs in input order.

        Drains the whole queue — requests from earlier ``submit()`` calls
        complete too (their tokens are not in this call's return value;
        interleave with ``stream()`` to observe them).
        """
        rids = [self.submit(p, max_new_tokens) for p in prompts]
        outs: dict[int, list[int]] = {r: [] for r in rids}
        for rid, tok in self.stream():
            outs.setdefault(rid, []).append(tok)
        return [outs[r] for r in rids]

    def serve(self, requests, *, max_steps: int = 100000) -> Scheduler:
        """Replay a timed Request workload (arrival offsets honored) and
        return the Scheduler for ``metrics()`` / ``finished``."""
        sched = Scheduler(self.engine)
        for r in requests:
            sched.submit(r)
        sched.run(max_steps=max_steps)
        return sched


__all__ = ["AUTO", "ServeSpec", "ResolvedServeSpec", "OverloadPolicy",
           "KVConfig", "SpeculationConfig", "Fault", "LLM"]
