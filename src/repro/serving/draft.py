"""Draft sources for speculative decoding on the unified ragged step.

The engine's verify side is the EXISTING ``(B, chunk)`` jitted step: a
speculating slot just contributes ``q_len = k+1`` rows (its last committed
token followed by k draft tokens) instead of 1, and the multi-row logit
extraction (``model.forward(logit_rows=...)``) scores every draft position
in one pass.  THIS module is the draft side: where the k proposed tokens
come from.

``NGramDraft``
    prompt-suffix matching (lookahead-style): the most recent (n-1)-gram
    of the slot's context is searched backwards through the context; on a
    match, the tokens that followed it are proposed.  Zero extra compute,
    zero state — acceptance is workload-dependent (great on repetitive /
    templated text, poor on fresh prose), but a rejected draft costs only
    its budget rows.

``ModelDraft``
    a real (small) model proposing greedily: its own dense KV cache, its
    own jitted single-row decode step, host-side context mirrors.  The
    draft model is any ``repro.configs`` config — typically a reduced one —
    with its own params; ``draft="self"`` reuses the serving model's own
    cfg/params as an acceptance-1.0 oracle (greedy self-drafts are always
    accepted — the bit-exactness test rides this).  A draft model with a
    different vocab is safe: proposed ids outside the verifier's vocab are
    clamped by ``jnp.take`` on the embedding and then simply rejected.

``MTPDraft``
    interface stub for a DeepSeek-V3-style multi-token-prediction head
    (Megatron-Core MTP shape): k extra transformer blocks predicting
    positions t+2..t+k+1 from the backbone's hidden state.  Wiring it
    needs trained MTP weights, which this repo does not ship — the stub
    documents the contract and raises.

All sources implement ``DraftSource``: ``propose`` maps slot -> proposed
token array (possibly shorter than asked, possibly empty), and the
lifecycle hooks (``begin``/``observe``/``release``) let stateful sources
mirror the engine's slot state.  Proposals are HINTS: the engine may trim
them against the token budget and KV capacity, and the verify step is
what commits tokens — a draft source can be arbitrarily wrong without
affecting output correctness (greedy verify is bit-exact by construction).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.configs.base import ModelConfig
from repro.core.resolve import SpeculationConfig
from repro.core.partitioner import NULL_PLAN
from repro.models.model import forward, init_params
from repro.serving.kv_cache import make_batched_cache

# model-draft catch-up feeds context in chunks of this many rows
DRAFT_CHUNK = 16


class DraftSource:
    """Per-engine draft proposer (one instance, all slots)."""

    name = "none"

    def begin(self, slot: int, tokens: np.ndarray) -> None:
        """A request was admitted to ``slot`` with ``tokens`` context."""

    def observe(self, slot: int, committed: np.ndarray) -> None:
        """The verify step committed ``committed`` tokens for ``slot``."""

    def release(self, slot: int) -> None:
        """The slot was freed (done / failed / preempted)."""

    def propose(self, ctx: dict[int, np.ndarray],
                want: dict[int, int]) -> dict[int, np.ndarray]:
        """slot -> up to ``want[slot]`` proposed next tokens, given each
        slot's full committed context (prompt + outputs).  May return
        fewer (or no) tokens per slot; must not return more."""
        raise NotImplementedError


class NGramDraft(DraftSource):
    """Suffix-match drafting: propose the continuation of the most recent
    occurrence of the context's trailing (n-1)-gram."""

    name = "ngram"

    def __init__(self, ngram: int = 3):
        self.g = max(int(ngram) - 1, 1)

    def propose(self, ctx, want):
        out = {}
        for slot, k in want.items():
            toks = ctx[slot]
            g = self.g
            if k < 1 or len(toks) < g + 1:
                continue
            pat = toks[-g:]
            # newest match first (recency beats frequency for decode text)
            for st in range(len(toks) - g - 1, -1, -1):
                if np.array_equal(toks[st:st + g], pat):
                    prop = toks[st + g:st + g + k]
                    if prop.size:
                        out[slot] = prop.astype(np.int64)
                    break
        return out


class ModelDraft(DraftSource):
    """A small model drafting greedily with its own dense KV cache.

    The draft cache length is host-authoritative: ``_len[slot]`` counts the
    tokens the draft model has actually consumed, and every jitted call
    rebuilds the device length vector from it — so speculative rows the
    draft itself wrote past a rejection become inert ragged-tail padding,
    exactly like the verifier's rejected rows.  ``observe`` reconciles:
    when the engine's committed stream diverges from what the draft
    consumed (rejected drafts), the slot replays from the fork point on
    the next propose (cheap: DRAFT_CHUNK-row catch-up steps).
    """

    name = "model"

    def __init__(self, cfg: ModelConfig, params, *, plan=NULL_PLAN,
                 max_batch: int = 8, max_len: int = 512,
                 dtype=jnp.bfloat16):
        self.cfg, self.params, self.plan = cfg, params, plan
        self.max_len = max_len
        self.cache = make_batched_cache(cfg, max_batch, max_len, dtype)
        self._len = np.zeros(max_batch, np.int64)   # tokens consumed
        self._fed: list[Optional[np.ndarray]] = [None] * max_batch
        self._step = jax.jit(self._impl)

    def _impl(self, params, tokens, q_lens, cache):
        out = forward(params, self.cfg, self.plan, tokens=tokens,
                      cache=cache, q_lens=q_lens, last_only=True)
        nxt = jnp.argmax(out.logits[:, 0], axis=-1).astype(jnp.int32)
        return nxt, out.cache

    def _run(self, toks: np.ndarray, q: np.ndarray) -> np.ndarray:
        """One draft step: feed ``toks`` rows per ``q`` lengths, return the
        per-slot greedy next token.  Length vector rebuilt from the host
        mirror so stale draft rows never leak across calls."""
        cache = {**self.cache,
                 "length": jnp.asarray(self._len, jnp.int32)}
        nxt, self.cache = self._step(self.params, jnp.asarray(toks),
                                     jnp.asarray(q, jnp.int32), cache)
        self._len += q.astype(np.int64)
        return np.asarray(nxt)

    def release(self, slot: int) -> None:
        self._len[slot] = 0
        self._fed[slot] = None

    def observe(self, slot: int, committed: np.ndarray) -> None:
        fed = self._fed[slot]
        if fed is None:
            return
        self._fed[slot] = np.concatenate(
            [fed, np.asarray(committed, fed.dtype)])
        # divergence (rejected drafts) is detected lazily in propose(): the
        # engine's ctx is the truth, _fed only records what the DRAFT ate.

    def _sync(self, slot: int, ctx: np.ndarray) -> bool:
        """Catch the slot's draft cache up to ``ctx[:-1]`` (the last token
        is fed by the propose loop itself).  Returns False when the slot
        cannot be synced (context longer than the draft cache)."""
        if len(ctx) > self.max_len - 1:
            return False
        fed = self._fed[slot]
        n_ok = int(self._len[slot])
        if fed is None or n_ok > len(ctx) \
                or not np.array_equal(fed[:n_ok], ctx[:n_ok]):
            n_ok = 0                   # fork/divergence: replay from scratch
            self._len[slot] = 0
        self._fed[slot] = ctx
        # feed ctx[n_ok:-1] in DRAFT_CHUNK-row ragged steps
        b = len(self._len)
        pos = n_ok
        while pos < len(ctx) - 1:
            n = min(DRAFT_CHUNK, len(ctx) - 1 - pos)
            toks = np.zeros((b, DRAFT_CHUNK), np.int32)
            q = np.zeros(b, np.int32)
            toks[slot, :n] = ctx[pos:pos + n]
            q[slot] = n
            self._run(toks, q)
            pos += n
        return True

    def propose(self, ctx, want):
        live = {}
        for slot, k in want.items():
            if k >= 1 and self._sync(slot, np.asarray(ctx[slot], np.int32)):
                live[slot] = k
        if not live:
            return {}
        b = len(self._len)
        props = {slot: [] for slot in live}
        # iteration 0 feeds each slot's last committed token -> d_1;
        # iteration j feeds d_j -> d_{j+1}
        cur = {slot: int(ctx[slot][-1]) for slot in live}
        for j in range(max(live.values())):
            toks = np.zeros((b, DRAFT_CHUNK), np.int32)
            q = np.zeros(b, np.int32)
            for slot, k in live.items():
                if j < k:
                    toks[slot, 0] = cur[slot]
                    q[slot] = 1
            nxt = self._run(toks, q)
            for slot, k in live.items():
                if j < k:
                    cur[slot] = int(nxt[slot])
                    props[slot].append(cur[slot])
        # the proposed tokens themselves were fed for all but the last
        # position; reconcile _fed/_len to the committed context only —
        # the next observe/propose treats the speculative rows as stale.
        for slot in live:
            self._len[slot] = len(ctx[slot])
            self._fed[slot] = np.asarray(ctx[slot], np.int32)
        return {slot: np.asarray(p, np.int64)
                for slot, p in props.items() if p}


class MTPDraft(DraftSource):
    """DeepSeek-V3-style multi-token-prediction head (interface stub).

    Contract (Megatron-Core MTP shape): ``k`` extra transformer blocks,
    block j consuming [backbone hidden state at t; embedding of token
    t+j] through a projection to predict position t+j+1 — so one backbone
    pass plus k tiny block passes yields k drafts that share the
    verifier's representations (acceptance far above an independent
    draft model).  Requires trained MTP weights alongside the serving
    checkpoint; this repo ships none, so construction raises.
    """

    name = "mtp"

    def __init__(self, *_, **__):
        raise NotImplementedError(
            "MTP drafting needs trained multi-token-prediction head "
            "weights (DeepSeek-V3 / Megatron-Core MTP shape); none ship "
            "with this repo — use draft='ngram', 'self', or a reduced "
            "config name")


def make_draft(sc: SpeculationConfig, cfg: ModelConfig, params, *,
               plan=NULL_PLAN, max_batch: int = 8, max_len: int = 512,
               dtype=jnp.bfloat16) -> DraftSource:
    """Build the draft source a resolved ``SpeculationConfig`` names.

    "ngram" -> NGramDraft; "self" -> ModelDraft on the serving model's own
    cfg/params (acceptance-1.0 greedy oracle); "mtp" -> the stub (raises);
    any other name -> a reduced config of that arch with freshly
    initialized params (structure-correct, low acceptance — exercises the
    foreign-draft path end to end).
    """
    if sc.draft == "ngram":
        return NGramDraft(ngram=sc.ngram)
    if sc.draft == "self":
        return ModelDraft(cfg, params, plan=plan, max_batch=max_batch,
                          max_len=max_len, dtype=dtype)
    if sc.draft == "mtp":
        return MTPDraft()
    dcfg = C.get_reduced(sc.draft)     # raises KeyError on unknown arch
    dparams = init_params(jax.random.PRNGKey(0), dcfg, dtype)
    return ModelDraft(dcfg, dparams, plan=NULL_PLAN, max_batch=max_batch,
                      max_len=max_len, dtype=dtype)


__all__ = ["DraftSource", "NGramDraft", "ModelDraft", "MTPDraft",
           "make_draft", "DRAFT_CHUNK"]
