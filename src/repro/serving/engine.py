"""vLLM-lite serving engine: token-budget continuous batching over a slotted
KV cache.

Default path — ONE jitted program (the unified mixed prefill/decode step):

  unified_fn(params, tokens(B, chunk), q_lens(B,), cache, key)
      -> (next_token(B,), last_logits(B, V), step_logits, cache)
  (step_logits = every row's (B, chunk, V) logits under ``debug_logits``,
   else None — the hot path runs the LM head only on last valid rows)

Every iteration each slot contributes ``q_lens[i] ∈ {0, 1, …, chunk}``
tokens against the fixed (B, chunk) buffer: a decoding slot contributes its
1 sampled token, a prefilling slot contributes the next chunk of its
prompt, an idle slot contributes 0.  Admission is just bookkeeping (the
prompt goes into the slot's pending queue and the slot's cache length is
zeroed) — no blocking prefill, so a long prompt never stalls the decode
slots (Sarathi-style chunked prefill, finally wired into the online
engine).  Ragged tails are masked at every level: per-slot cache writes
drop rows past q_lens[i], attention masks keys past
``length[i] + q_lens[i]``, and — because the default dropless MoE dispatch
is count-independent — pad rows cannot perturb any other slot's logits
(see docs/serving.md and docs/dispatch.md).

Legacy path — the pre-unified two-program engine (bucket-padded blocking
prefill in ``admit`` + a separate decode program).  The public escape
hatch (``legacy=True`` / ``--legacy-engine`` / env
``REPRO_LEGACY_ENGINE=1``) was retired after its one-release window (PR 3
-> PR 4); the path now exists ONLY for families the unified step cannot
serve — ``unified_supported`` returns False for recurrent state (ssm),
hybrid ring buffers, whisper enc-dec and stub-frontend models, whose
per-row state cannot mask a ragged tail — and the engine falls back to it
automatically for exactly those configs.

This is the "online stage" host of MixServe, configured by ONE object: a
``repro.serving.api.ResolvedServeSpec`` (``Engine(cfg, params, spec=...)``)
carrying the analyzer-selected ShardingPlan, the ``KernelPolicy`` (default
``auto()`` = Pallas kernels on TPU backends — for MoE archs the
``topk_gate`` / fused-permute / grouped-GEMM dropless pipeline; ``chunk ==
1`` runs the Pallas ``flash_decode`` attention, ``chunk > 1`` the ragged
``flash_chunk`` kernel, see docs/kernels.md), the MoE ``dispatch`` mode
(dropless is what makes the mixed batch safe), and the
chunk/token-budget/slot envelope the cost model resolved.  The old
per-knob kwargs (``max_batch=``, ``chunk=``, ``kernel_policy=``, ...)
survive one release as a deprecation shim that folds them into a spec
internally — see docs/api.md.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.partitioner import NULL_PLAN, ShardingPlan
from repro.kernels.policy import KernelPolicy
from repro.models.model import forward, init_cache
from repro.serving.kv_cache import insert_slot, with_lengths


class PromptTooLongError(ValueError):
    """Prompt (+ frontend tokens + generation budget) cannot fit the cache."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (s,) int32 token ids
    max_new_tokens: int = 32
    arrival: float = 0.0
    # filled by the engine:
    out_tokens: list = dataclasses.field(default_factory=list)
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.arrival

    @property
    def itl(self) -> float:
        n = len(self.out_tokens)
        if n <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / (n - 1)


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


MAX_BUCKET = 4096      # largest legacy prefill bucket — hard prompt cap


def unified_supported(cfg: ModelConfig) -> bool:
    """Whether the unified mixed step can serve this config.

    Attention-cached text families only: recurrent (rwkv) and ring-buffer
    (hybrid local-attn) state advances by every scanned row so a ragged
    tail cannot be masked, and stub-frontend models (vision/audio) need
    per-request embeds injected at prefill — both stay on the legacy path.
    """
    return cfg.family in ("dense", "moe", "vlm") and not cfg.frontend \
        and not cfg.mrope


class Engine:
    def __init__(self, cfg: ModelConfig, params, plan: ShardingPlan = NULL_PLAN,
                 *, spec=None,
                 max_batch: Optional[int] = None,
                 max_len: Optional[int] = None,
                 dtype=jnp.float32, temperature: Optional[float] = None,
                 seed: Optional[int] = None,
                 embeds_fn: Optional[Callable] = None,
                 kernel_policy: Optional[KernelPolicy] = None,
                 dispatch_mode: Optional[str] = None,
                 chunk: Optional[int] = None,
                 debug_logits: Optional[bool] = None):
        # ``spec`` (a serving.api.ResolvedServeSpec) is THE configuration
        # surface: strategy/plan, kernels, dispatch, chunk, token budget and
        # the slot envelope all ride on it, resolved by the analyzer / cost
        # model.  The per-knob kwargs below are a one-release deprecation
        # shim that folds them into a spec internally.
        legacy_kwargs = {k: v for k, v in dict(
            max_batch=max_batch, max_len=max_len, temperature=temperature,
            seed=seed, kernel_policy=kernel_policy,
            dispatch_mode=dispatch_mode, chunk=chunk,
            debug_logits=debug_logits).items() if v is not None}
        from repro.serving.api import spec_from_engine_kwargs
        if spec is None:
            if legacy_kwargs:
                warnings.warn(
                    "Engine(max_batch=..., max_len=..., chunk=, "
                    "kernel_policy=, dispatch_mode=, ...) kwargs are "
                    "deprecated: build a repro.serving.api.ServeSpec and "
                    "pass Engine(cfg, params, spec=spec.resolve(...)) — or "
                    "use the LLM facade (docs/api.md)",
                    DeprecationWarning, stacklevel=2)
            spec = spec_from_engine_kwargs(cfg, plan, **legacy_kwargs)
        else:
            if legacy_kwargs:
                raise ValueError(
                    "pass knobs on the ResolvedServeSpec, not alongside it "
                    f"(got both spec= and {sorted(legacy_kwargs)})")
            if plan is not NULL_PLAN and plan != spec.plan:
                raise ValueError(
                    "the ShardingPlan rides on the spec "
                    "(ResolvedServeSpec.plan) — don't pass both")
        self.spec = spec
        plan = spec.plan
        self.cfg, self.params, self.plan = cfg, params, plan
        self.max_batch, self.max_len = spec.max_batch, spec.max_len
        self.temperature = spec.temperature
        self.key = jax.random.PRNGKey(spec.seed)
        self.embeds_fn = embeds_fn    # vlm/audio stub-frontend provider
        self.chunk = max(1, min(int(spec.chunk), spec.max_len))
        # debug/oracle mode: keep every row's logits (B, chunk, V) per step
        # in ``step_logits``; the hot path applies the LM head only to each
        # slot's last valid row (forward last_only)
        self.debug_logits = bool(spec.debug_logits)

        # the blocking-prefill path survives ONLY as the automatic fallback
        # for families the unified step cannot serve (ssm/hybrid/frontend);
        # the public legacy escape hatch was retired after PR 3's window
        self.legacy = not unified_supported(cfg)

        self.cache = with_lengths(
            init_cache(cfg, self.max_batch, self.max_len, dtype),
            jnp.zeros((self.max_batch,), jnp.int32))
        self.slots: list[Optional[Request]] = [None] * self.max_batch
        self.cur_tokens = jnp.zeros((self.max_batch, 1), jnp.int32)
        # unified-step slot bookkeeping (host side, mirrors device lengths)
        self._prompt_pos = [0] * self.max_batch   # prompt tokens written
        self._last_tok = [0] * self.max_batch     # last sampled token
        self._admit_seq = [0] * self.max_batch    # admission (prefill FIFO)
        self._seq = 0
        self.last_logits = None                # (B, V) of the last step
        self.step_logits = None                # (B, chunk, V), debug_logits

        self._prefill_cache = {}
        self._decode = jax.jit(self._decode_impl)
        self._unified = jax.jit(self._unified_impl)
        self.dtype = dtype

    # -- validation ------------------------------------------------------
    def validate(self, req: Request) -> None:
        """Reject a request whose prompt + generation can never fit.

        The legacy path used to let an over-length prompt overflow the
        bucket buffer / cache writes silently (positions past max_len were
        clamp-scattered onto the last rows) — now both paths refuse it
        up front with a clear error.
        """
        s = len(req.prompt) + self._front_len()
        need = s + max(0, req.max_new_tokens - 1)
        if self.legacy:
            # the blocking prefill writes a whole bucket-padded buffer into
            # the cache, so the BUCKET must fit, not just the prompt
            need = max(need, _bucket(len(req.prompt)) + self._front_len())
        if len(req.prompt) > MAX_BUCKET or need > self.max_len:
            raise PromptTooLongError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"(+{self._front_len()} frontend, +{req.max_new_tokens} new) "
                f"needs {need} cache positions but max_len={self.max_len} "
                f"(prompt cap {MAX_BUCKET}) — raise max_len, shorten the "
                "prompt, or lower max_new_tokens")

    # -- jitted programs -------------------------------------------------
    def _unified_impl(self, params, tokens, q_lens, cache, key):
        """THE serving program: one mixed token-budget iteration.

        tokens (B, chunk) int32, q_lens (B,) int32.  Slot i's valid rows are
        tokens[i, :q_lens[i]] — a prefill chunk or a single decode token —
        at cache offset length[i]; rows past q_lens[i] are inert.  Samples
        each slot's next token from its last valid row's logits (only
        meaningful to the host when the slot just finished its prompt or is
        decoding; the host ignores the rest).  The LM head runs only on
        those last rows unless ``debug_logits`` asks for every row (the
        oracle tests).
        """
        out = forward(params, self.cfg, self.plan, tokens=tokens,
                      cache=cache, q_lens=q_lens,
                      last_only=not self.debug_logits)
        if self.debug_logits:
            last = jnp.take_along_axis(
                out.logits, jnp.maximum(q_lens - 1, 0)[:, None, None],
                axis=1)[:, 0]                               # (B, V)
            step_logits = out.logits
        else:
            last = out.logits[:, 0]
            step_logits = None
        if self.temperature > 0:
            nxt = jax.random.categorical(key, last / self.temperature, -1)
        else:
            nxt = jnp.argmax(last, -1)
        return nxt.astype(jnp.int32), last, step_logits, out.cache

    def _prefill_impl(self, params, tokens, real_len):
        cache = init_cache(self.cfg, 1, self.max_len, self.dtype)
        kw = {}
        if self.embeds_fn is not None:
            kw = self.embeds_fn(1)
        out = forward(params, self.cfg, self.plan, tokens=tokens,
                      cache=cache, **kw)
        # bucketed prompt: logits of the last REAL token
        last = out.logits[:, real_len - 1 + self._front_len() ]
        cache = with_lengths(out.cache,
                             jnp.full((1,), real_len + self._front_len(),
                                      jnp.int32))
        return last, cache

    def _front_len(self) -> int:
        if self.cfg.frontend == "vision_stub":
            return self.cfg.n_frontend_tokens
        return 0

    def _decode_impl(self, params, tokens, cache, active, key):
        out = forward(params, self.cfg, self.plan, tokens=tokens, cache=cache)
        logits = out.logits[:, 0]
        if self.temperature > 0:
            nxt = jax.random.categorical(key, logits / self.temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        # only advance lengths of active slots
        new_len = jnp.where(active, out.cache["length"], cache["length"])
        return nxt.astype(jnp.int32), with_lengths(out.cache, new_len)

    # -- slot management -------------------------------------------------
    def free_slots(self) -> list:
        return [i for i, r in enumerate(self.slots) if r is None]

    def admit(self, req: Request) -> bool:
        """Admit into a free slot.  Unified path: pure bookkeeping — the
        prompt becomes the slot's pending queue and the slot's cache length
        is zeroed; its tokens flow through subsequent unified steps.  Legacy
        path: the old blocking bucket-padded prefill."""
        self.validate(req)
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        if self.legacy:
            return self._admit_legacy(req, slot)
        self.slots[slot] = req
        self._prompt_pos[slot] = 0
        self._last_tok[slot] = 0
        self._admit_seq[slot] = self._seq
        self._seq += 1
        self.cache = with_lengths(
            self.cache, self.cache["length"].at[slot].set(0))
        req.t_admitted = time.perf_counter()
        return True

    def _admit_legacy(self, req: Request, slot: int) -> bool:
        s = len(req.prompt)
        bucket = _bucket(s)
        if bucket not in self._prefill_cache:
            self._prefill_cache[bucket] = jax.jit(
                self._prefill_impl, static_argnames=())
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :s] = req.prompt
        last_logits, cache1 = self._prefill_cache[bucket](
            self.params, jnp.asarray(toks), s)
        first = int(jnp.argmax(last_logits[0]))
        self.cache = insert_slot(self.cache, cache1, slot)
        self.cur_tokens = self.cur_tokens.at[slot, 0].set(first)
        req.out_tokens.append(first)
        req.t_admitted = req.t_first_token = time.perf_counter()
        self.slots[slot] = req
        return True

    # -- token-budget planning (Sarathi-style, decode-first) -------------
    def plan_q_lens(self, token_budget: Optional[int] = None) -> np.ndarray:
        """Per-slot token counts for the next unified step.

        Decode slots always get their 1 token (they are never starved by
        prefill work); the remaining budget — default ``max_batch * chunk``
        — is filled with prefill chunks in admission (FIFO) order, each
        capped at ``chunk``.
        """
        budget = int(token_budget) if token_budget else \
            self.max_batch * self.chunk
        q = np.zeros((self.max_batch,), np.int32)
        prefilling = []
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            if self._prompt_pos[i] < len(r.prompt):
                prefilling.append(i)
            elif not r.done:
                q[i] = 1
        budget -= int(q.sum())
        for i in sorted(prefilling, key=lambda j: self._admit_seq[j]):
            if budget <= 0:
                break
            n = min(self.chunk, len(self.slots[i].prompt)
                    - self._prompt_pos[i], budget)
            q[i] = n
            budget -= n
        return q

    # -- stepping --------------------------------------------------------
    def step(self, token_budget: Optional[int] = None) -> list:
        """One engine iteration.  Returns finished requests.

        Unified: one mixed token-budget step over all slots.  Legacy: one
        decode step for all active (fully prefilled) slots."""
        if self.legacy:
            return self._step_legacy()
        return self.unified_step(self.plan_q_lens(token_budget))

    def unified_step(self, q_lens) -> list:
        """Run the jitted unified step with an explicit per-slot plan."""
        q_lens = np.asarray(q_lens, np.int32)
        if not q_lens.any():
            return []
        toks = np.zeros((self.max_batch, self.chunk), np.int32)
        for i, r in enumerate(self.slots):
            n = int(q_lens[i])
            if r is None or n == 0:
                continue
            pos = self._prompt_pos[i]
            if pos < len(r.prompt):
                toks[i, :n] = r.prompt[pos:pos + n]
            else:
                toks[i, 0] = self._last_tok[i]
        self.key, sub = jax.random.split(self.key)
        nxt, self.last_logits, self.step_logits, self.cache = self._unified(
            self.params, jnp.asarray(toks), jnp.asarray(q_lens),
            self.cache, sub)
        # one (B,) host read per step, for request bookkeeping + the next
        # step's token buffer (which must merge host-side prompt chunks
        # anyway — the (B, chunk) int32 upload is noise next to the model)
        nxt_host = np.asarray(nxt)
        now = time.perf_counter()
        finished = []
        for i, r in enumerate(self.slots):
            n = int(q_lens[i])
            if r is None or n == 0:
                continue
            pos = self._prompt_pos[i]
            if pos < len(r.prompt):                    # prefill chunk
                self._prompt_pos[i] = pos + n
                if self._prompt_pos[i] < len(r.prompt):
                    continue                           # still prefilling
                r.t_first_token = now                  # prompt done: TTFT
            tok = int(nxt_host[i])
            r.out_tokens.append(tok)
            self._last_tok[i] = tok
            r.t_done = now
            if r.done:
                finished.append(r)
                self.slots[i] = None
        return finished

    def _step_legacy(self) -> list:
        finished = []
        # reap requests already complete: the blocking prefill emits the
        # first token inside admit, so a max_new_tokens==1 request is done
        # before its first decode step — without this sweep it would pin
        # its slot forever (and the append loop below would push a token
        # past its budget)
        for i, r in enumerate(self.slots):
            if r is not None and r.done:
                finished.append(r)
                self.slots[i] = None
        active = jnp.asarray([r is not None for r in self.slots])
        if not bool(active.any()):
            return finished
        self.key, sub = jax.random.split(self.key)
        nxt, self.cache = self._decode(self.params, self.cur_tokens,
                                       self.cache, active, sub)
        # next step's inputs stay on device; the host reads the tokens once,
        # purely for request bookkeeping (no device->host->device round trip)
        self.cur_tokens = nxt[:, None]
        now = time.perf_counter()
        nxt_host = np.asarray(nxt)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            r.out_tokens.append(int(nxt_host[i]))
            r.t_done = now
            if r.done:
                finished.append(r)
                self.slots[i] = None
        return finished

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slots)


__all__ = ["Engine", "Request", "PromptTooLongError", "unified_supported",
           "MAX_BUCKET"]
