"""vLLM-lite serving engine: token-budget continuous batching over a slotted
KV cache.

Default path — ONE jitted program (the unified mixed prefill/decode step):

  unified_fn(params, tokens(B, chunk), q_lens(B,), v_lens(B,), cache, key)
      -> (tokens(B, n), n_emit(B,), last_logits(B, V), step_logits, cache,
          bad(B,))
  (n = 1 + speculation k; step_logits = every row's (B, chunk, V) logits
   under ``debug_logits``, else None — the hot path runs the LM head only
   on the selected rows; bad[i] flags a non-finite sampled-logits row, the
   NaN/Inf quarantine signal)

Every iteration each slot contributes ``q_lens[i] ∈ {0, 1, …, chunk}``
tokens against the fixed (B, chunk) buffer: a decoding slot contributes its
1 sampled token — or, when speculating, 1 + k rows whose draft tail is
greedy-verified in the same pass (``v_lens`` marks the verify slots) — a
prefilling slot contributes the next chunk of its pending tokens, an idle
slot contributes 0.  Admission is just bookkeeping
(the slot's pending buffer is ``prompt + tokens generated so far`` — the
recompute-on-resume suffix is what makes preemption exact — and the slot's
cache length is zeroed); no blocking prefill, so a long prompt never stalls
the decode slots.  Ragged tails are masked at every level (docs/serving.md,
docs/dispatch.md).

Robustness (docs/serving.md "Robustness & degradation"):

- every ``Request`` carries a lifecycle ``state``
  (QUEUED/RUNNING/DONE/CANCELLED/SHED/FAILED/PREEMPTED), a ``priority``
  and an optional ``deadline_s``;
- ``release``/``cancel`` free a slot mid-decode (deadline kills, user
  cancellation), ``preempt`` evicts a slot for recompute-on-resume (the
  vLLM recompute strategy — works on the dense per-slot cache today and
  carries over verbatim to paged KV);
- a NaN/Inf guard on the sampled-logits rows quarantines exactly the
  offending slot (state FAILED) instead of propagating;
- a ``FaultInjector`` (``repro.serving.faults``, wired through
  ``ServeSpec.faults``) deterministically injects latency spikes, NaN
  rows, admit failures and clock skew for chaos testing.

Legacy path — the pre-unified two-program engine (bucket-padded blocking
prefill in ``admit`` + a separate decode program) survives ONLY for
families the unified step cannot serve (``unified_supported`` False:
recurrent state, hybrid ring buffers, enc-dec/stub frontends).

The engine is configured by ONE object: a
``repro.serving.api.ResolvedServeSpec`` (``Engine(cfg, params, spec)``)
carrying the analyzer-selected ShardingPlan, the ``KernelPolicy``, the MoE
``dispatch`` mode, the chunk/token-budget/slot envelope, the overload
policy and the fault plan.  The PR 5 per-knob kwargs shim
(``max_batch=``, ``chunk=``, ...) has been removed after its one-release
window — build a ``ServeSpec`` and resolve it (docs/api.md).
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import Counter
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import cap_rows_for
from repro.models.model import forward, init_cache
from repro.serving.draft import make_draft
from repro.serving.faults import FaultInjector, InjectedFault
from repro.serving.kv_cache import insert_slot, make_kv_cache, with_lengths


class PromptTooLongError(ValueError):
    """Prompt (+ frontend tokens + generation budget) cannot fit the cache."""


class RequestState:
    """Request lifecycle.  Terminal states never transition again;
    PREEMPTED requests re-enter RUNNING when re-admitted (recompute)."""

    QUEUED = "QUEUED"
    RUNNING = "RUNNING"
    DONE = "DONE"
    CANCELLED = "CANCELLED"
    SHED = "SHED"
    FAILED = "FAILED"
    PREEMPTED = "PREEMPTED"

    TERMINAL = frozenset({DONE, CANCELLED, SHED, FAILED})


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (s,) int32 token ids
    max_new_tokens: int = 32
    arrival: float = 0.0
    priority: int = 0                   # higher = more important
    deadline_s: Optional[float] = None  # seconds after arrival; None = none
    # filled by the engine / scheduler:
    state: str = RequestState.QUEUED
    error: str = ""
    n_preempted: int = 0
    out_tokens: list = dataclasses.field(default_factory=list)
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens

    @property
    def terminal(self) -> bool:
        return self.state in RequestState.TERMINAL

    @property
    def deadline(self) -> float:
        """Absolute deadline (same clock as ``arrival``); +inf if none."""
        if self.deadline_s is None:
            return float("inf")
        return self.arrival + self.deadline_s

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.arrival

    @property
    def itl(self) -> float:
        n = len(self.out_tokens)
        if n <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / (n - 1)


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


MAX_BUCKET = 4096      # largest legacy prefill bucket — hard prompt cap


def unified_supported(cfg: ModelConfig) -> bool:
    """Whether the unified mixed step can serve this config.

    Attention-cached text families only: recurrent (rwkv) and ring-buffer
    (hybrid local-attn) state advances by every scanned row so a ragged
    tail cannot be masked, and stub-frontend models (vision/audio) need
    per-request embeds injected at prefill — both stay on the legacy path.
    """
    return cfg.family in ("dense", "moe", "vlm") and not cfg.frontend \
        and not cfg.mrope


class Engine:
    def __init__(self, cfg: ModelConfig, params, spec=None, *,
                 embeds_fn: Optional[Callable] = None, dtype=jnp.float32):
        # ``spec`` (a serving.api.ResolvedServeSpec) is THE configuration
        # surface: strategy/plan, kernels, dispatch, chunk, token budget,
        # the slot envelope, overload policy and fault plan all ride on it.
        if spec is None:
            raise TypeError(
                "Engine needs a resolved spec: Engine(cfg, params, "
                "ServeSpec(...).resolve()) — the per-knob kwargs shim was "
                "removed after its one-release window (docs/api.md)")
        self.spec = spec
        self.cfg, self.params, self.plan = cfg, params, spec.plan
        self.max_batch, self.max_len = spec.max_batch, spec.max_len
        self.temperature = spec.temperature
        self.key = jax.random.PRNGKey(spec.seed)
        self.embeds_fn = embeds_fn    # vlm/audio stub-frontend provider
        self.chunk = max(1, min(int(spec.chunk), spec.max_len))
        # debug/oracle mode: keep every row's logits (B, chunk, V) per step
        # in ``step_logits``; the hot path applies the LM head only to each
        # slot's last valid row (forward last_only)
        self.debug_logits = bool(spec.debug_logits)

        # expert-load observability (MoE only, unified path): per-expert
        # routed-slot counts summed over layers/steps plus the EP-exchange
        # byte ledger — moved under the resolved count-bounded extent vs
        # the monolithic worst case (``ep_load_stats``)
        self.expert_counts = np.zeros((max(cfg.n_experts, 1),), np.int64)
        self.a2a_bytes_moved = 0
        self.a2a_bytes_worst = 0

        # deterministic chaos harness (ServeSpec.faults); empty = inert
        self.faults = FaultInjector(getattr(spec, "faults", ()),
                                    seed=spec.seed)
        # robustness event counters, merged into ServeMetrics by the
        # scheduler: fault / preempt / cancel / deadline_miss
        self.events: Counter = Counter()

        # the blocking-prefill path survives ONLY as the automatic fallback
        # for families the unified step cannot serve (ssm/hybrid/frontend)
        self.legacy = not unified_supported(cfg)

        # ONE KVCache owns the device KV state (docs/kv_cache.md): the
        # resolved ``spec.kv`` picks dense per-slot buffers or the paged
        # pool + block tables + prefix index.  The legacy path's insert_slot
        # prefill only understands dense buffers, so it pins that backend.
        kvcfg = getattr(spec, "kv", None)
        if self.legacy and kvcfg is not None and kvcfg.backend != "dense":
            kvcfg = None
        self.kv = make_kv_cache(cfg, kvcfg, self.max_batch, self.max_len,
                                dtype)

        # speculative decoding (resolved ``spec.speculation`` or None): a
        # speculating slot contributes 1 + k rows (last committed token +
        # k draft tokens) to the SAME unified step, greedy verify accepts
        # the longest matching draft prefix plus one bonus token, and the
        # rejected tail rolls back (device length in the jitted step,
        # paged pages via kv.rollback).  Greedy-only and unified-only —
        # the resolver enforces both, this re-checks defensively.
        sc = getattr(spec, "speculation", None)
        self.spec_cfg = self.draft = None
        self.spec_k = 0
        if sc is not None and not self.legacy and self.temperature == 0:
            k = min(int(sc.k), self.chunk - 1)
            if k >= 1:
                self.spec_cfg = sc if sc.k == k \
                    else dataclasses.replace(sc, k=k)
                self.spec_k = k
                self.draft = make_draft(self.spec_cfg, cfg, params,
                                        plan=self.plan,
                                        max_batch=self.max_batch,
                                        max_len=self.max_len, dtype=dtype)
        self.n_logits = self.spec_k + 1   # logit rows per slot per step
        # per-slot draft tokens planned for the NEXT unified step
        self._drafts: list[Optional[np.ndarray]] = [None] * self.max_batch
        # acceptance accounting: slot-steps that drafted, tokens proposed/
        # accepted, and the EMA that gates drafting (optimistic start so
        # the first steps always probe)
        self.spec_steps = self.spec_drafted = self.spec_accepted = 0
        self.accept_ema = 1.0

        self.slots: list[Optional[Request]] = [None] * self.max_batch
        self.cur_tokens = jnp.zeros((self.max_batch, 1), jnp.int32)
        # unified-step slot bookkeeping (host side, mirrors device lengths)
        self._pending = [None] * self.max_batch   # tokens to prefill
        self._prompt_pos = [0] * self.max_batch   # pending tokens written
        self._last_tok = [0] * self.max_batch     # last sampled token
        self._admit_seq = [0] * self.max_batch    # admission (prefill FIFO)
        self._seq = 0
        self._step_idx = 0                # engine step counter (fault keys)
        self.last_step_tokens = 0         # tokens processed by the last
        #                                   step — the watchdog's progress
        #                                   signal (0 = the step was a no-op)
        self.last_logits = None                # (B, V) of the last step
        self.step_logits = None                # (B, chunk, V), debug_logits

        self._prefill_cache = {}
        self._decode = jax.jit(self._decode_impl)
        self._unified = jax.jit(self._unified_impl)
        self.dtype = dtype

    # -- KV state --------------------------------------------------------
    @property
    def cache(self):
        """The live device KV pytree (owned by ``self.kv``)."""
        return self.kv.cache

    @cache.setter
    def cache(self, value):
        self.kv.cache = value

    # -- validation ------------------------------------------------------
    def validate(self, req: Request) -> None:
        """Reject a request whose prompt + generation can never fit.

        The legacy path used to let an over-length prompt overflow the
        bucket buffer / cache writes silently (positions past max_len were
        clamp-scattered onto the last rows) — now both paths refuse it
        up front with a clear error.
        """
        s = len(req.prompt) + self._front_len()
        need = s + max(0, req.max_new_tokens - 1)
        if self.legacy:
            # the blocking prefill writes a whole bucket-padded buffer into
            # the cache, so the BUCKET must fit, not just the prompt
            need = max(need, _bucket(len(req.prompt)) + self._front_len())
        if len(req.prompt) > MAX_BUCKET or need > self.max_len:
            raise PromptTooLongError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"(+{self._front_len()} frontend, +{req.max_new_tokens} new) "
                f"needs {need} cache positions but max_len={self.max_len} "
                f"(prompt cap {MAX_BUCKET}) — raise max_len, shorten the "
                "prompt, or lower max_new_tokens")
        if self.kv.pool_tokens is not None and need > self.kv.pool_tokens:
            raise PromptTooLongError(
                f"request {req.rid}: needs {need} cache positions but the "
                f"paged KV pool holds {self.kv.pool_tokens} tokens — raise "
                "ServeSpec.kv pool_pages (or leave kv='auto')")

    # -- jitted programs -------------------------------------------------
    def _unified_impl(self, params, tokens, q_lens, v_lens, cache, key):
        """THE serving program: one mixed token-budget iteration.

        tokens (B, chunk) int32, q_lens (B,) int32.  Slot i's valid rows are
        tokens[i, :q_lens[i]] — a prefill chunk, a single decode token, or
        a 1 + k speculative verify block — at cache offset length[i]; rows
        past q_lens[i] are inert.  ``v_lens[i]`` marks decode-phase slots
        (v = q: the rows are [last committed token, k drafts]); 0 means
        prefill/idle.  ``bad[i]`` flags a non-finite sampled-logits row on
        a scheduled slot — the NaN/Inf quarantine signal.

        Returns (toks (B, n), n_emit (B,), last (B, V), step_logits,
        cache, bad (B,), expert_counts) with n = 1 + spec_k.  Slot i's
        committed tokens are ``toks[i, :n_emit[i]]``: greedy verify accepts
        draft j+1 iff it equals the argmax of row j's logits (row j is the
        prediction after consuming rows 0..j), so the accepted drafts ARE
        the greedy rows and the first rejected position contributes its
        greedy token as the bonus — bit-exact vs non-speculative greedy by
        construction.  The rejected tail's cache rows are subtracted from
        the returned per-slot length (stale rows beyond it are masked like
        any ragged tail).  With spec_k == 0 this is exactly the
        non-speculative program (n_emit is 1 on every scheduled slot and
        temperature sampling applies).
        """
        n = self.n_logits
        if n == 1:                        # non-speculative: the PR 8 graph
            out = forward(params, self.cfg, self.plan, tokens=tokens,
                          cache=cache, q_lens=q_lens,
                          last_only=not self.debug_logits,
                          expert_stats=self.cfg.is_moe)
            if self.debug_logits:
                last = jnp.take_along_axis(
                    out.logits, jnp.maximum(q_lens - 1, 0)[:, None, None],
                    axis=1)[:, 0]                           # (B, V)
                step_logits = out.logits
            else:
                last = out.logits[:, 0]
                step_logits = None
            bad = (q_lens > 0) & ~jnp.isfinite(last).all(axis=-1)
            if self.temperature > 0:
                nxt = jax.random.categorical(key, last / self.temperature, -1)
            else:
                nxt = jnp.argmax(last, -1)
            emit = jnp.where(q_lens > 0, 1, 0).astype(jnp.int32)
            return (nxt.astype(jnp.int32)[:, None], emit, last, step_logits,
                    out.cache, bad, out.expert_counts)

        # speculative verify: score one logit row per draft position.  Row
        # j's logits are the model's prediction AFTER consuming rows 0..j
        # (causal flash_chunk masks per row, so the extra rows never touch
        # earlier rows' softmax).  Non-verify slots (prefill: v = 0) pin
        # every row to their last valid row, so toks[:, 0] is the plain
        # sampled token.
        last_row = jnp.maximum(q_lens - 1, 0)               # (B,)
        j = jnp.arange(n)
        rows = jnp.where((v_lens > 0)[:, None],
                         jnp.minimum(j[None, :], last_row[:, None]),
                         last_row[:, None])                 # (B, n)
        out = forward(params, self.cfg, self.plan, tokens=tokens,
                      cache=cache, q_lens=q_lens,
                      last_only=not self.debug_logits,
                      logit_rows=None if self.debug_logits else rows,
                      expert_stats=self.cfg.is_moe)
        if self.debug_logits:
            sel = jnp.take_along_axis(out.logits, rows[:, :, None], axis=1)
            step_logits = out.logits
        else:
            sel = out.logits                                # (B, n, V)
            step_logits = None
        bad = (q_lens > 0) & ~jnp.isfinite(sel).all(axis=(1, 2))
        greedy = jnp.argmax(sel, -1).astype(jnp.int32)      # (B, n)
        s = jnp.maximum(v_lens - 1, 0)                      # drafted count
        drafts = tokens[:, 1:n]                             # (B, n-1)
        ok = (drafts == greedy[:, :n - 1]) \
            & (jnp.arange(n - 1)[None, :] < s[:, None])
        # accepted = longest matching prefix; +1 bonus token from the
        # first non-accepted row's own greedy prediction
        acc = jnp.sum(jnp.cumprod(ok.astype(jnp.int32), axis=1), axis=1)
        emit = jnp.where(q_lens > 0,
                         jnp.where(v_lens > 0, acc + 1, 1),
                         0).astype(jnp.int32)
        rollback = jnp.where(v_lens > 0, s - acc, 0).astype(jnp.int32)
        cache2 = {**out.cache, "length": out.cache["length"] - rollback}
        return (greedy, emit, sel[:, 0], step_logits, cache2, bad,
                out.expert_counts)

    def _prefill_impl(self, params, tokens, real_len):
        cache = init_cache(self.cfg, 1, self.max_len, self.dtype)
        kw = {}
        if self.embeds_fn is not None:
            kw = self.embeds_fn(1)
        out = forward(params, self.cfg, self.plan, tokens=tokens,
                      cache=cache, **kw)
        # bucketed prompt: logits of the last REAL token
        last = out.logits[:, real_len - 1 + self._front_len() ]
        cache = with_lengths(out.cache,
                             jnp.full((1,), real_len + self._front_len(),
                                      jnp.int32))
        return last, cache

    def _front_len(self) -> int:
        if self.cfg.frontend == "vision_stub":
            return self.cfg.n_frontend_tokens
        return 0

    def _decode_impl(self, params, tokens, cache, active, key):
        out = forward(params, self.cfg, self.plan, tokens=tokens, cache=cache)
        logits = out.logits[:, 0]
        if self.temperature > 0:
            nxt = jax.random.categorical(key, logits / self.temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        # only advance lengths of active slots
        new_len = jnp.where(active, out.cache["length"], cache["length"])
        return nxt.astype(jnp.int32), with_lengths(out.cache, new_len)

    # -- slot management -------------------------------------------------
    def free_slots(self) -> list:
        return [i for i, r in enumerate(self.slots) if r is None]

    def slot_of(self, rid: int) -> Optional[int]:
        for i, r in enumerate(self.slots):
            if r is not None and r.rid == rid:
                return i
        return None

    def admit(self, req: Request) -> bool:
        """Admit into a free slot.  Unified path: pure bookkeeping — the
        slot's pending buffer becomes ``prompt + out_tokens`` (the
        recompute-on-resume stream; plain prompt for a fresh request) and
        the slot's cache length is zeroed.  Legacy path: the old blocking
        bucket-padded prefill.  Raises ``InjectedFault`` when an "admit"
        fault targets this request (the scheduler sheds it)."""
        self.validate(req)
        fault = self.faults.admit_blocked(self._step_idx, req.rid) \
            if self.faults else None
        if fault is not None:
            raise InjectedFault(
                f"request {req.rid}: injected admission failure "
                f"({fault.describe()})")
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        if self.legacy:
            return self._admit_legacy(req, slot)
        self.slots[slot] = req
        # resume replays generated-so-far tokens as a prompt suffix: the
        # prefill of prompt+out reproduces the evicted KV exactly, and the
        # next sampled token continues the sequence bit-for-bit (greedy)
        self._pending[slot] = np.concatenate(
            [np.asarray(req.prompt, np.int32),
             np.asarray(req.out_tokens, np.int32)]) \
            if req.out_tokens else np.asarray(req.prompt, np.int32)
        # paged KV: ``begin`` matches the pending stream against the prefix
        # index and returns how many leading tokens are already cached —
        # prefill starts past them (a warm restart / shared system prompt
        # skips straight to its unique tail).  Dense: always 0.
        self._prompt_pos[slot] = self.kv.begin(slot, self._pending[slot])
        self._last_tok[slot] = 0
        if self.draft is not None:
            self.draft.begin(slot, self._pending[slot])
        self._admit_seq[slot] = self._seq
        self._seq += 1
        if req.t_admitted == 0.0:
            req.t_admitted = time.perf_counter()
        req.state = RequestState.RUNNING
        return True

    def _admit_legacy(self, req: Request, slot: int) -> bool:
        s = len(req.prompt)
        bucket = _bucket(s)
        if bucket not in self._prefill_cache:
            self._prefill_cache[bucket] = jax.jit(
                self._prefill_impl, static_argnames=())
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :s] = req.prompt
        last_logits, cache1 = self._prefill_cache[bucket](
            self.params, jnp.asarray(toks), s)
        first = int(jnp.argmax(last_logits[0]))
        self.cache = insert_slot(self.cache, cache1, slot)
        self.cur_tokens = self.cur_tokens.at[slot, 0].set(first)
        req.out_tokens.append(first)
        req.t_admitted = req.t_first_token = time.perf_counter()
        req.state = RequestState.RUNNING
        self.slots[slot] = req
        return True

    def release(self, slot: int, state: str, error: str = "",
                reason: str = "") -> Optional[Request]:
        """Free a slot mid-flight: deadline kill, cancel, fault quarantine.

        Zeroes the slot's cache length (rows become masked stale data for
        the next occupant) and stamps the request's terminal/transition
        state.  ``reason`` increments the engine's event counter.
        """
        req = self.slots[slot]
        if req is None:
            return None
        self.slots[slot] = None
        self._pending[slot] = None
        self._drafts[slot] = None
        if self.draft is not None:
            self.draft.release(slot)
        # free the slot's KV; a quarantined slot's pages may hold the very
        # NaNs we are quarantining, so they never enter the prefix index
        self.kv.free(slot, keep_prefix=state != RequestState.FAILED)
        req.state = state
        if error:
            req.error = error
        if state in RequestState.TERMINAL:
            req.t_done = time.perf_counter()
        if reason:
            self.events[reason] += 1
        return req

    def cancel(self, rid: int) -> Optional[Request]:
        """Cancel a RUNNING request; frees its slot immediately."""
        slot = self.slot_of(rid)
        if slot is None:
            return None
        return self.release(slot, RequestState.CANCELLED, error="cancelled",
                            reason="cancel")

    def preempt(self, slot: int) -> Optional[Request]:
        """Evict a slot for a higher-priority request (recompute-on-resume).

        The dense per-slot cache is simply abandoned (length zeroed); on
        re-admission the pending buffer ``prompt + out_tokens`` recomputes
        it, so the resumed request's final output matches its
        uninterrupted run exactly.  Paged KV makes the eviction
        cache-preserving: ``kv.free`` parks the victim's computed full
        pages in the prefix index, so the resume's ``begin`` re-matches
        them and only the uncached tail is recomputed.
        """
        req = self.release(slot, RequestState.PREEMPTED, reason="preempt")
        if req is not None:
            req.n_preempted += 1
        return req

    def victim_slot(self, below_priority: int) -> Optional[int]:
        """Lowest-priority occupied slot strictly below ``below_priority``
        — the preemption victim (youngest admission breaks ties, so the
        request with the most sunk work keeps its slot)."""
        best = None
        for i, r in enumerate(self.slots):
            if r is None or r.priority >= below_priority:
                continue
            key = (r.priority, -self._admit_seq[i])
            if best is None or key < best[0]:
                best = (key, i)
        return None if best is None else best[1]

    # -- token-budget planning (Sarathi-style, decode-first) -------------
    def plan_q_lens(self, token_budget: Optional[int] = None) -> np.ndarray:
        """Per-slot token counts for the next unified step.

        Decode slots always get their 1 token (they are never starved by
        prefill work); the remaining budget — default ``max_batch * chunk``
        — is filled with prefill chunks in admission (FIFO) order, each
        capped at ``chunk``.

        Every grant passes through ``kv.reserve`` so the paged backend can
        shrink it to what the pool can hold (dense grants everything) — a
        slot the pool cannot extend simply sits out the step; ``step``
        breaks a full deadlock by preempting.
        """
        budget = int(token_budget) if token_budget else \
            self.max_batch * self.chunk
        q = np.zeros((self.max_batch,), np.int32)
        prefilling, decoding = [], []
        for i, r in enumerate(self.slots):
            if r is None or r.terminal:
                continue
            if self._prompt_pos[i] < len(self._pending[i]):
                prefilling.append(i)
            elif not r.done:
                q[i] = self.kv.reserve(i, 1)
                decoding.append(i)
        budget -= int(q.sum())
        if self.draft is not None:
            # draft rows are decode-side work: price them BEFORE prefill
            # (a speculating slot costs 1 + k budget rows) — the auto
            # budget max_batch*(1+k) + chunk keeps the prefill chunk
            # funded even when every slot speculates
            budget = self._plan_drafts(decoding, q, budget)
        for i in sorted(prefilling, key=lambda j: self._admit_seq[j]):
            if budget <= 0:
                break
            n = min(self.chunk, len(self._pending[i])
                    - self._prompt_pos[i], budget)
            n = self.kv.reserve(i, n)
            q[i] = n
            budget -= n
        return q

    def _plan_drafts(self, decoding: list, q: np.ndarray,
                     budget: int) -> int:
        """Extend decode slots with draft rows, priced against the budget.

        Each speculating slot's grant grows from 1 to 1 + k rows where
        k <= spec_k is trimmed by the request's remaining generation room,
        the budget, and what the KV pool can actually reserve.  Drafting
        pauses when the acceptance EMA drops under the configured gate,
        re-probing every ``probe_every`` steps.  Returns remaining budget.
        """
        self._drafts = [None] * self.max_batch
        sc = self.spec_cfg
        if not decoding or budget <= 0:
            return budget
        if self.accept_ema < sc.min_accept \
                and self._step_idx % sc.probe_every != 0:
            return budget                  # gated off; periodic re-probe
        want, ctx = {}, {}
        for i in decoding:
            if q[i] < 1:
                continue                   # pool could not even extend by 1
            r = self.slots[i]
            # full acceptance commits k + 1 tokens; keep <= max_new_tokens
            room = r.max_new_tokens - len(r.out_tokens) - 1
            k = min(self.spec_k, room, budget)
            if k < 1:
                continue
            ctx[i] = np.concatenate([np.asarray(r.prompt, np.int64),
                                     np.asarray(r.out_tokens, np.int64)])
            want[i] = k
        if not want:
            return budget
        props = self.draft.propose(ctx, want)
        for i in sorted(props, key=lambda jj: self._admit_seq[jj]):
            if budget <= 0:
                break
            d = np.asarray(props[i], np.int64)[:want[i]][:budget]
            if d.size == 0:
                continue
            # the decode row already reserved 1; extend to 1 + k rows
            # (paged exhaustion grants fewer — trim the draft to fit)
            grant = self.kv.reserve(i, 1 + int(d.size))
            d = d[:max(0, grant - 1)]
            if d.size == 0:
                continue
            self._drafts[i] = d
            q[i] += int(d.size)
            budget -= int(d.size)
        return budget

    # -- stepping --------------------------------------------------------
    def step(self, token_budget: Optional[int] = None) -> list:
        """One engine iteration.  Returns retired requests (state DONE, or
        FAILED for NaN-quarantined slots).

        Unified: one mixed token-budget step over all slots.  Legacy: one
        decode step for all active (fully prefilled) slots."""
        if self.legacy:
            return self._step_legacy()
        q = self.plan_q_lens(token_budget)
        preempted = []
        # paged-pool deadlock breaker: live slots exist but the pool could
        # not extend ANY of them — free a victim's pages (cache-preserving:
        # its prompt pages drop into the prefix index) and replan.  Never
        # fires on dense (reserve always grants) and never preempts the
        # last live slot (validate bounds a lone request by the pool).
        while (not q.any()
               and any(r is not None and not r.terminal and not r.done
                       for r in self.slots)
               and self.n_active > 1):
            victim = self.victim_slot(1 << 30)
            if victim is None:
                break
            preempted.append(self.preempt(victim))
            q = self.plan_q_lens(token_budget)
        return preempted + self.unified_step(q)

    def _reap(self) -> list:
        """Sweep slots already retired (terminal state set by cancel/
        release) or trivially complete (max_new_tokens satisfied — e.g. a
        zero-token request, which previously pinned its slot and busy-spun
        the scheduler forever)."""
        retired = []
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            if r.terminal:
                self.slots[i] = None
                self._pending[i] = None
                self.kv.free(i)
                retired.append(r)
            elif r.done:
                r.state = RequestState.DONE
                r.t_done = r.t_done or time.perf_counter()
                self.slots[i] = None
                self._pending[i] = None
                self.kv.free(i)
                retired.append(r)
            else:
                continue
            self._drafts[i] = None
            if self.draft is not None:
                self.draft.release(i)
        return retired

    def unified_step(self, q_lens) -> list:
        """Run the jitted unified step with an explicit per-slot plan."""
        step_idx = self._step_idx
        self._step_idx += 1
        retired = self._reap()
        q_lens = np.asarray(q_lens, np.int32)
        self.last_step_tokens = int(q_lens.sum())
        if self.faults:
            spike = self.faults.step_latency_s(step_idx)
            if spike > 0:
                time.sleep(spike)            # injected straggler
        if not q_lens.any():
            return retired
        toks = np.zeros((self.max_batch, self.chunk), np.int32)
        v_lens = np.zeros((self.max_batch,), np.int32)
        for i, r in enumerate(self.slots):
            n = int(q_lens[i])
            if r is None or n == 0:
                continue
            pos = self._prompt_pos[i]
            if pos < len(self._pending[i]):
                toks[i, :n] = self._pending[i][pos:pos + n]
            else:
                toks[i, 0] = self._last_tok[i]
                d = self._drafts[i]
                if d is not None and n == 1 + len(d):
                    toks[i, 1:n] = d          # verify rows: [t0, d1..dk]
                    v_lens[i] = n
                else:
                    v_lens[i] = min(n, 1)     # plain decode (stale/absent
                    #                           drafts never reach the step)
        self.key, sub = jax.random.split(self.key)
        self.kv.flush()          # push dirty block tables to device
        (nxt, emit, self.last_logits, self.step_logits, self.cache, bad,
         ecnt) = self._unified(self.params, jnp.asarray(toks),
                               jnp.asarray(q_lens), jnp.asarray(v_lens),
                               self.cache, sub)
        if ecnt is not None:
            self.expert_counts += np.asarray(ecnt, np.int64)
            self._account_a2a(int(q_lens.sum()))
        self.kv.advance(q_lens)  # host length mirror follows the device
        # one (B, n) host read per step, for request bookkeeping + the next
        # step's token buffer (which must merge host-side prompt chunks
        # anyway — the (B, chunk) int32 upload is noise next to the model)
        nxt_host = np.asarray(nxt)
        emit_host = np.asarray(emit)
        bad_host = np.array(bad)       # copy: fault injection writes into it
        if self.faults:
            live = {i: r.rid for i, r in enumerate(self.slots)
                    if r is not None and q_lens[i] > 0}
            for i in self.faults.nan_slots(step_idx, live):
                bad_host[i] = True           # injected NaN-logits row
                self.last_logits = self.last_logits.at[i].set(jnp.nan)
        now = time.perf_counter()
        for i, r in enumerate(self.slots):
            n = int(q_lens[i])
            if r is None or n == 0:
                continue
            s_i = max(int(v_lens[i]) - 1, 0)     # draft rows this slot fed
            if bad_host[i]:
                # quarantine exactly this slot: non-finite logits never
                # produce a token, never touch a neighbour
                retired.append(self.release(
                    i, RequestState.FAILED, error="non-finite logits",
                    reason="fault"))
                continue
            pos = self._prompt_pos[i]
            if pos < len(self._pending[i]):            # prefill chunk
                self._prompt_pos[i] = pos + n
                if self._prompt_pos[i] < len(self._pending[i]):
                    continue                           # still prefilling
                if r.t_first_token == 0.0:
                    r.t_first_token = now              # prompt done: TTFT
            if r.done:                                 # zero-token budget:
                continue                               # reaped next sweep
            m = int(emit_host[i])                      # accepted + bonus
            if s_i:
                # release the rejected tail's pages (the device length is
                # already rolled back inside the jitted step); counters +
                # the acceptance EMA that gates the next plan's drafting
                a_i = m - 1
                self.kv.rollback(i, s_i - a_i)
                self.spec_steps += 1
                self.spec_drafted += s_i
                self.spec_accepted += a_i
                al = self.spec_cfg.ema_alpha
                self.accept_ema = ((1 - al) * self.accept_ema
                                   + al * (a_i / s_i))
            m = min(m, r.max_new_tokens - len(r.out_tokens))   # defensive
            if m <= 0:
                continue
            committed = [int(t) for t in nxt_host[i, :m]]
            r.out_tokens.extend(committed)
            self._last_tok[i] = committed[-1]
            if self.draft is not None:
                self.draft.observe(i, np.asarray(committed, np.int64))
            r.t_done = now
            if r.done:
                r.state = RequestState.DONE
                retired.append(r)
                self.slots[i] = None
                self._pending[i] = None
                self.kv.free(i)
                if self.draft is not None:
                    self.draft.release(i)
        self._drafts = [None] * self.max_batch    # drafts are one-shot
        return retired

    def spec_stats(self) -> dict:
        """Speculation counters for ServeMetrics / bench meta."""
        steps, drafted = self.spec_steps, self.spec_drafted
        return {
            "n_spec_steps": int(steps),
            "n_spec_drafted": int(drafted),
            "n_spec_accepted": int(self.spec_accepted),
            "spec_accept_rate":
                float(self.spec_accepted / drafted) if drafted else 0.0,
            "spec_tokens_per_step":
                float((self.spec_accepted + steps) / steps) if steps else 0.0,
        }

    # -- expert-load / EP-exchange observability -------------------------
    def _account_a2a(self, step_tokens: int) -> None:
        """Price the step's EP exchange into the byte ledger.

        The engine itself runs the model on this host (NULL_PLAN when
        single-device), so the ledger prices what the RESOLVED deployment
        would move: ``moved`` under the count-bounded micro-chunked extent
        the spec resolved to, ``worst`` under the monolithic worst-case
        buffers (every routed row replicated to every EP rank).  Both are
        dispatch + combine over every MoE layer.
        """
        cfg, spec = self.cfg, self.spec
        ep = int(getattr(spec, "moe_ep", 1) or 1)
        if not cfg.is_moe or step_tokens <= 0 or ep <= 1:
            return
        n_moe_layers = cfg.n_layers - cfg.first_dense_layers
        n_rows = step_tokens * cfg.top_k     # routed slots per MoE layer
        ovl = getattr(spec, "ep_overlap", None)
        if ovl is None or getattr(ovl, "chunks", 1) <= 1:
            rows_moved = ep * n_rows         # monolithic worst-case extent
        else:
            chunks = max(1, math.gcd(int(ovl.chunks), step_tokens))
            n_chunk_rows = (step_tokens // chunks) * cfg.top_k
            cap = cap_rows_for(n_chunk_rows, ep, ovl)
            rows_moved = ep * chunks * cap   # count-bounded extent
        # fused RS-A2A-AG ships the TP shard (h / moe_tp); the unfused
        # ladder moves full-width rows
        moe_tp = int(getattr(spec, "moe_tp", 1) or 1)
        width = cfg.d_model
        if getattr(spec.plan, "comm_algo", "") == "fused":
            width = cfg.d_model // max(moe_tp, 1)
        elem = jnp.dtype(self.dtype).itemsize
        per_row = width * elem * 2 * n_moe_layers      # dispatch + combine
        self.a2a_bytes_moved += rows_moved * per_row
        self.a2a_bytes_worst += ep * n_rows * per_row

    def ep_load_stats(self) -> dict:
        """Expert-load skew bucketed by resolved EP rank + the A2A ledger.

        Returns ``ep_rank_max_tokens`` / ``ep_rank_mean_tokens`` (routed
        slots landing on the hottest / average EP rank, summed over layers
        and steps — their ratio is the skew the count-bounded buffers must
        absorb) and the ``a2a_bytes_moved`` / ``a2a_bytes_worst`` ledger.
        """
        counts = self.expert_counts
        ep = int(getattr(self.spec, "moe_ep", 1) or 1)
        if not self.cfg.is_moe or counts.sum() == 0:
            return {"ep_rank_max_tokens": 0, "ep_rank_mean_tokens": 0.0,
                    "a2a_bytes_moved": int(self.a2a_bytes_moved),
                    "a2a_bytes_worst": int(self.a2a_bytes_worst)}
        if ep <= 1 or counts.shape[0] % ep:
            ep = 1                      # degenerate bucketing: one rank
        per_rank = counts.reshape(ep, counts.shape[0] // ep).sum(axis=1)
        return {"ep_rank_max_tokens": int(per_rank.max()),
                "ep_rank_mean_tokens": float(per_rank.mean()),
                "a2a_bytes_moved": int(self.a2a_bytes_moved),
                "a2a_bytes_worst": int(self.a2a_bytes_worst)}

    def _step_legacy(self) -> list:
        step_idx = self._step_idx
        self._step_idx += 1
        if self.faults:
            spike = self.faults.step_latency_s(step_idx)
            if spike > 0:
                time.sleep(spike)
        # reap requests already complete or externally released: the
        # blocking prefill emits the first token inside admit, so a
        # max_new_tokens==1 request is done before its first decode step —
        # without this sweep it would pin its slot forever (and the append
        # loop below would push a token past its budget)
        finished = []
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            if r.terminal:
                self.slots[i] = None
                finished.append(r)
            elif r.done:
                r.state = RequestState.DONE
                r.t_done = r.t_done or time.perf_counter()
                self.slots[i] = None
                finished.append(r)
        active = jnp.asarray([r is not None for r in self.slots])
        self.last_step_tokens = int(sum(r is not None for r in self.slots))
        if not bool(active.any()):
            return finished
        self.key, sub = jax.random.split(self.key)
        nxt, self.cache = self._decode(self.params, self.cur_tokens,
                                       self.cache, active, sub)
        # next step's inputs stay on device; the host reads the tokens once,
        # purely for request bookkeeping (no device->host->device round trip)
        self.cur_tokens = nxt[:, None]
        now = time.perf_counter()
        nxt_host = np.asarray(nxt)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            r.out_tokens.append(int(nxt_host[i]))
            r.t_done = now
            if r.done:
                r.state = RequestState.DONE
                finished.append(r)
                self.slots[i] = None
        return finished

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slots)


__all__ = ["Engine", "Request", "RequestState", "PromptTooLongError",
           "unified_supported", "MAX_BUCKET"]
