"""vLLM-lite serving engine: continuous batching over a slotted KV cache.

The engine owns two jitted programs:
  prefill_fn(params, tokens(1, s_bucket))           -> (last_logits, cache_1)
  decode_fn(params, tokens(B, 1), cache, active(B)) -> (logits, cache)

Requests are admitted into free slots at iteration granularity (Orca-style
iteration-level scheduling); one decode step advances every active slot.
Inactive slots decode a pad token whose cache writes land at their frozen
``length`` — invisible (masked by kv_len) and overwritten before that
position ever becomes visible to a future occupant.

This is the "online stage" host of MixServe: the ShardingPlan injected here
is the one the automatic analyzer selected offline.

Kernelization: ``kernel_policy`` (repro.kernels.KernelPolicy; default
``auto()`` = Pallas kernels on TPU backends, jnp elsewhere) is attached to
the plan, so the jitted decode step runs ``flash_decode`` attention and —
for MoE archs — the ``topk_gate`` / fused-permute / grouped-GEMM dispatch
pipeline.  The decode loop keeps ``cur_tokens`` on device (the host copy of
each step's tokens is read once, for request bookkeeping only), so steps
chain device-to-device.

MoE dispatch: ``dispatch_mode`` (default: the plan's, which defaults to
"auto" -> dropless) selects capacity vs dropless buffers.  Serving wants
dropless — bucketed prefill and single-token decode then produce logits
that are count-independent, and decode-sized batches pay T*k rows of
expert compute instead of E*C (see docs/dispatch.md).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.partitioner import NULL_PLAN, ShardingPlan
from repro.kernels.policy import KernelPolicy
from repro.models.model import forward, init_cache
from repro.serving.kv_cache import insert_slot, with_lengths


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (s,) int32 token ids
    max_new_tokens: int = 32
    arrival: float = 0.0
    # filled by the engine:
    out_tokens: list = dataclasses.field(default_factory=list)
    t_admitted: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    @property
    def done(self) -> bool:
        return len(self.out_tokens) >= self.max_new_tokens

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.arrival

    @property
    def itl(self) -> float:
        n = len(self.out_tokens)
        if n <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / (n - 1)


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class Engine:
    def __init__(self, cfg: ModelConfig, params, plan: ShardingPlan = NULL_PLAN,
                 *, max_batch: int = 8, max_len: int = 512,
                 dtype=jnp.float32, temperature: float = 0.0, seed: int = 0,
                 embeds_fn: Optional[Callable] = None,
                 kernel_policy: Optional[KernelPolicy] = None,
                 dispatch_mode: Optional[str] = None):
        if kernel_policy is None:
            # respect a policy the caller already put on the plan (make_plan
            # kernels=...); only a plan with everything off falls to auto()
            kernel_policy = (plan.kernels if plan.kernels.any_enabled
                             else KernelPolicy.auto())
        if kernel_policy != plan.kernels:
            plan = dataclasses.replace(plan, kernels=kernel_policy)
        if dispatch_mode is not None and dispatch_mode != plan.dispatch_mode:
            # explicit argument wins over the plan; the plan default ("auto")
            # already resolves to the dropless inference dispatch
            plan = dataclasses.replace(plan, dispatch_mode=dispatch_mode)
        self.cfg, self.params, self.plan = cfg, params, plan
        self.max_batch, self.max_len = max_batch, max_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.embeds_fn = embeds_fn    # vlm/audio stub-frontend provider

        self.cache = with_lengths(
            init_cache(cfg, max_batch, max_len, dtype),
            jnp.zeros((max_batch,), jnp.int32))
        self.slots: list[Optional[Request]] = [None] * max_batch
        self.cur_tokens = jnp.zeros((max_batch, 1), jnp.int32)

        self._prefill_cache = {}
        self._decode = jax.jit(self._decode_impl)
        self.dtype = dtype

    # -- jitted programs -------------------------------------------------
    def _prefill_impl(self, params, tokens, real_len):
        cache = init_cache(self.cfg, 1, self.max_len, self.dtype)
        kw = {}
        if self.embeds_fn is not None:
            kw = self.embeds_fn(1)
        out = forward(params, self.cfg, self.plan, tokens=tokens,
                      cache=cache, **kw)
        # bucketed prompt: logits of the last REAL token
        last = out.logits[:, real_len - 1 + self._front_len() ]
        cache = with_lengths(out.cache,
                             jnp.full((1,), real_len + self._front_len(),
                                      jnp.int32))
        return last, cache

    def _front_len(self) -> int:
        if self.cfg.frontend == "vision_stub":
            return self.cfg.n_frontend_tokens
        return 0

    def _decode_impl(self, params, tokens, cache, active, key):
        out = forward(params, self.cfg, self.plan, tokens=tokens, cache=cache)
        logits = out.logits[:, 0]
        if self.temperature > 0:
            nxt = jax.random.categorical(key, logits / self.temperature, -1)
        else:
            nxt = jnp.argmax(logits, -1)
        # only advance lengths of active slots
        new_len = jnp.where(active, out.cache["length"], cache["length"])
        return nxt.astype(jnp.int32), with_lengths(out.cache, new_len)

    # -- slot management -------------------------------------------------
    def free_slots(self) -> list:
        return [i for i, r in enumerate(self.slots) if r is None]

    def admit(self, req: Request) -> bool:
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        s = len(req.prompt)
        bucket = _bucket(s)
        if bucket not in self._prefill_cache:
            self._prefill_cache[bucket] = jax.jit(
                self._prefill_impl, static_argnames=())
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :s] = req.prompt
        last_logits, cache1 = self._prefill_cache[bucket](
            self.params, jnp.asarray(toks), s)
        first = int(jnp.argmax(last_logits[0]))
        self.cache = insert_slot(self.cache, cache1, slot)
        self.cur_tokens = self.cur_tokens.at[slot, 0].set(first)
        req.out_tokens.append(first)
        req.t_admitted = req.t_first_token = time.perf_counter()
        self.slots[slot] = req
        return True

    def step(self) -> list:
        """One decode iteration for all active slots.  Returns finished."""
        active = jnp.asarray([r is not None and not r.done
                              for r in self.slots])
        if not bool(active.any()):
            return []
        self.key, sub = jax.random.split(self.key)
        nxt, self.cache = self._decode(self.params, self.cur_tokens,
                                       self.cache, active, sub)
        # next step's inputs stay on device; the host reads the tokens once,
        # purely for request bookkeeping (no device->host->device round trip)
        self.cur_tokens = nxt[:, None]
        now = time.perf_counter()
        finished = []
        nxt_host = np.asarray(nxt)
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            r.out_tokens.append(int(nxt_host[i]))
            r.t_done = now
            if r.done:
                finished.append(r)
                self.slots[i] = None
        return finished

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slots)


__all__ = ["Engine", "Request"]
