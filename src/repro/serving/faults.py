"""Deterministic fault injection for the serving tier (chaos harness).

Production serving survives stragglers, bad numerics and bad requests by
*isolating* them; this module makes those failures reproducible so the
isolation machinery (engine NaN quarantine, scheduler shedding, the
no-progress watchdog) can be tested bit-for-bit.  A ``Fault`` is a frozen,
declarative description of WHEN (explicit step indices, a period, or a
seeded per-step probability) and WHAT fires:

  kind="latency"     sleep ``ms`` milliseconds inside the engine step —
                     a straggler / GC-pause / preemption spike
  kind="nan"         corrupt the sampled-logits row of one slot (by request
                     id, slot index, or any live slot) — the bad-numerics
                     case the engine's NaN/Inf guard must quarantine to
                     exactly that request
  kind="admit"       admission of the targeted request raises
                     ``InjectedFault`` — an un-admittable request the
                     scheduler must shed instead of spinning on
  kind="clock_skew"  shift the scheduler's wall clock by ``ms`` (cumulative)
                     — arrival/deadline bookkeeping under a jumping clock

Probabilistic faults are keyed by ``(seed, fault index, step)`` through a
counter-based RNG, so a replay with the same ``ServeSpec.faults`` and seed
fires on exactly the same steps — no hidden global RNG state.  Every
firing is appended to ``FaultInjector.log`` for assertions and postmortems.

Wiring: ``ServeSpec(faults=(...), seed=...)`` -> the engine builds one
``FaultInjector`` and consults it per unified step; the scheduler reads the
same injector for clock skew.  See docs/serving.md "Robustness &
degradation".
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

FAULT_KINDS = ("latency", "nan", "admit", "clock_skew")


class InjectedFault(RuntimeError):
    """Raised by an injected admission failure (kind="admit")."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One declarative failure source.

    Firing predicate (first match wins): explicit ``at`` engine-step
    indices; else ``every`` N steps; else per-step probability ``p`` (seeded
    — deterministic).  ``n_max`` caps total firings (0 = unlimited).
    ``rid``/``slot`` target a specific request / engine slot for "nan" and
    "admit" faults (-1 = any live slot / every request).
    """

    kind: str
    at: tuple = ()
    every: int = 0
    p: float = 0.0
    n_max: int = 0
    rid: int = -1
    slot: int = -1
    ms: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind must be one of {FAULT_KINDS}, got {self.kind!r}")
        if not (self.at or self.every or self.p):
            raise ValueError(
                f"fault {self.kind!r} never fires: set at=, every= or p=")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"fault p must be in [0, 1], got {self.p}")

    def describe(self) -> str:
        when = (f"at={list(self.at)}" if self.at
                else f"every={self.every}" if self.every else f"p={self.p}")
        tgt = (f" rid={self.rid}" if self.rid >= 0
               else f" slot={self.slot}" if self.slot >= 0 else "")
        mag = f" ms={self.ms:g}" if self.ms else ""
        return f"{self.kind}({when}{tgt}{mag})"


class FaultInjector:
    """Evaluates a tuple of ``Fault``s against the engine step counter.

    Deterministic: probabilistic faults draw from an RNG keyed by
    ``(seed, fault index, step)``, so the same (faults, seed) replays the
    same firing sequence regardless of wall time or call interleaving.
    """

    def __init__(self, faults: tuple = (), seed: int = 0):
        self.faults = tuple(faults)
        self.seed = int(seed)
        self._fired = [0] * len(self.faults)
        self.skew_s = 0.0                  # cumulative clock skew (seconds)
        self._skewed_steps: set[int] = set()
        self.log: list[tuple[int, str, str]] = []   # (step, kind, detail)

    def __bool__(self) -> bool:
        return bool(self.faults)

    def _fires(self, idx: int, f: Fault, step: int) -> bool:
        if f.n_max and self._fired[idx] >= f.n_max:
            return False
        if f.at:
            hit = step in f.at
        elif f.every:
            hit = step % f.every == 0
        else:
            hit = bool(np.random.default_rng(
                [self.seed, idx, step]).random() < f.p)
        if hit:
            self._fired[idx] += 1
        return hit

    def _record(self, step: int, f: Fault, detail: str = "") -> None:
        self.log.append((step, f.kind, detail or f.describe()))

    # -- per-kind queries (each evaluated once per engine step) ----------
    def step_latency_s(self, step: int) -> float:
        """Seconds of injected straggler latency for this engine step."""
        total = 0.0
        for i, f in enumerate(self.faults):
            if f.kind == "latency" and self._fires(i, f, step):
                total += f.ms / 1e3
                self._record(step, f)
        return total

    def admit_blocked(self, step: int, rid: int) -> Optional[Fault]:
        """The admit fault hitting request ``rid`` at this step, if any."""
        for i, f in enumerate(self.faults):
            if f.kind == "admit" and (f.rid < 0 or f.rid == rid) \
                    and self._fires(i, f, step):
                self._record(step, f, f"rid={rid}")
                return f
        return None

    def nan_slots(self, step: int, slot_rids: dict) -> set:
        """Slots whose sampled-logits row is corrupted this step.

        ``slot_rids`` maps live slot index -> request id (only slots with
        scheduled tokens this step).
        """
        bad = set()
        for i, f in enumerate(self.faults):
            if f.kind != "nan":
                continue
            if f.rid >= 0:
                hits = [s for s, r in slot_rids.items() if r == f.rid]
            elif f.slot >= 0:
                hits = [f.slot] if f.slot in slot_rids else []
            else:
                hits = sorted(slot_rids)
            if hits and self._fires(i, f, step):
                bad.update(hits)
                self._record(step, f, f"slots={hits}")
        return bad

    def advance_clock(self, step: int) -> float:
        """Apply clock-skew faults once per step; returns cumulative skew
        in seconds (added to the scheduler's wall-clock reads)."""
        if step not in self._skewed_steps:
            self._skewed_steps.add(step)
            for i, f in enumerate(self.faults):
                if f.kind == "clock_skew" and self._fires(i, f, step):
                    self.skew_s += f.ms / 1e3
                    self._record(step, f, f"skew={self.skew_s:g}s")
        return self.skew_s


__all__ = ["FAULT_KINDS", "Fault", "FaultInjector", "InjectedFault"]
