"""KVCache — ONE allocation surface over the engine's device KV state.

The device pytrees are defined by ``repro.models.model`` (``init_cache``
for the dense per-slot layout, ``init_paged_cache`` for the paged pools +
block tables).  This module is the *host-side owner* of that state: the
``KVCache`` interface (begin / reserve / advance / free / evict / flush +
occupancy stats) with two backends behind one surface —

``DenseKVCache``
    the classic per-slot ``(B, max_len, ...)`` buffers.  Allocation is
    trivial (a slot always owns its max_len rows); begin/free just zero the
    slot's length.

``PagedKVCache``
    fixed ``page_size``-token KV pages in a global ``pool_pages`` pool,
    a per-slot block table mapping logical block -> physical page, page
    refcounts, and a radix-trie *prefix index*: when a request is freed its
    prompt's full pages are inserted into the trie keyed by their token
    content, and a later ``begin`` whose prompt shares that prefix
    *references the same pages* instead of recomputing them (the fork
    point is page-granular: only FULL pages are shared, so a running slot
    never writes a shared page and no copy-on-write epilogue is needed —
    the first partial block is simply recomputed).  Pages are reclaimed
    refcount-0-first from the free list, then by LRU eviction of
    unreferenced index leaves; when even eviction cannot produce a page the
    engine preempts a slot (cache-preserving: its prompt pages stay in the
    index, so the resume re-matches them).  See docs/kv_cache.md.

Which backend a spec gets is the ``ServeSpec.kv`` knob, resolved by
``core.resolve.auto_kv`` from the Eq. 8 memory envelope (docs/api.md).

The legacy helpers (``insert_slot`` / ``with_lengths`` / ...) that the
blocking-prefill fallback path uses are kept at the bottom unchanged.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.resolve import KVConfig
from repro.models.model import (cache_axes, init_cache,  # re-export
                                init_paged_cache)


@dataclasses.dataclass
class KVStats:
    """Cache-efficiency counters (surfaced in ``ServeMetrics``)."""
    n_prefix_hits: int = 0        # begins that reused >= 1 shared page
    prefix_hit_tokens: int = 0    # prompt tokens served from shared pages
    n_evictions: int = 0          # index pages evicted to satisfy a reserve


class KVCache:
    """The allocation interface the engine drives (one per Engine).

    ``cache`` is the live device pytree the jitted step consumes/returns —
    the engine assigns the step's updated pytree back after every
    iteration.  All other methods are host-side bookkeeping:

      begin(slot, tokens) -> int   claim the slot for a request; returns
                                   how many leading prompt tokens are
                                   already cached (prefix reuse — the
                                   engine starts prefill past them)
      reserve(slot, n) -> int      guarantee capacity for the slot's next
                                   n tokens; returns how many fit (paged
                                   exhaustion grants < n, possibly 0)
      advance(q_lens)              the step landed: lengths += q_lens
      rollback(slot, n) -> int     the speculative tail was rejected:
                                   lengths[slot] -= n, pages the shorter
                                   length no longer touches return to the
                                   pool (tail-only — shared prefix pages
                                   sit at the FRONT of the block table and
                                   are never released); returns pages freed
      free(slot, keep_prefix=...)  release the slot's pages; with
                                   keep_prefix the prompt's full pages
                                   enter the prefix index (cache-preserving
                                   preemption = free + later re-begin)
      flush()                      push dirty host block tables to device
      occupancy() -> float         fraction of KV capacity in use
      kv_bytes() -> int            device bytes held by the KV buffers
    """

    backend = "none"
    cache: dict
    stats: KVStats
    pool_tokens: Optional[int] = None     # paged: hard pool capacity

    def begin(self, slot: int, tokens) -> int:
        raise NotImplementedError

    def reserve(self, slot: int, n: int) -> int:
        raise NotImplementedError

    def advance(self, q_lens) -> None:
        raise NotImplementedError

    def rollback(self, slot: int, n: int) -> int:
        raise NotImplementedError

    def free(self, slot: int, keep_prefix: bool = True) -> None:
        raise NotImplementedError

    def flush(self) -> None:
        raise NotImplementedError

    def occupancy(self) -> float:
        raise NotImplementedError

    def kv_bytes(self) -> int:
        """Concrete device bytes of the KV buffers (pools or dense)."""
        leaves = jax.tree.leaves(self.cache["groups"])
        return int(sum(x.size * x.dtype.itemsize for x in leaves))


class DenseKVCache(KVCache):
    """Per-slot dense buffers — allocation is the slot itself."""

    backend = "dense"

    def __init__(self, cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
        self.batch, self.max_len = batch, max_len
        self.cache = make_batched_cache(cfg, batch, max_len, dtype)
        self.stats = KVStats()

    def begin(self, slot: int, tokens) -> int:
        self.cache = {**self.cache,
                      "length": self.cache["length"].at[slot].set(0)}
        return 0

    def reserve(self, slot: int, n: int) -> int:
        return n                      # a slot always owns its max_len rows

    def advance(self, q_lens) -> None:
        pass                          # device length is authoritative

    def rollback(self, slot: int, n: int) -> int:
        # device length is authoritative (the jitted verify step already
        # returned the cache with the rejected tail subtracted); the stale
        # rows beyond it are inert ragged-tail padding.
        return 0

    def free(self, slot: int, keep_prefix: bool = True) -> None:
        self.cache = {**self.cache,
                      "length": self.cache["length"].at[slot].set(0)}

    def flush(self) -> None:
        pass

    def occupancy(self) -> float:
        lens = np.asarray(self.cache["length"])
        return float(lens.sum()) / float(self.batch * self.max_len)


class _Node:
    """Radix-trie node: one full KV page keyed by its page of token ids."""
    __slots__ = ("key", "page", "parent", "children", "tick")

    def __init__(self, key: bytes, page: int, parent: "_Node", tick: int):
        self.key, self.page, self.parent = key, page, parent
        self.children: dict[bytes, _Node] = {}
        self.tick = tick


class PagedKVCache(KVCache):
    """Global page pool + per-slot block tables + radix prefix index."""

    backend = "paged"

    def __init__(self, cfg: ModelConfig, batch: int, max_len: int, *,
                 page_size: int, pool_pages: int, prefix_cache: bool = True,
                 dtype=jnp.bfloat16):
        if max_len % page_size:
            raise ValueError(f"page_size {page_size} must divide "
                             f"max_len {max_len}")
        self.batch, self.max_len = batch, max_len
        self.ps, self.n_pages = page_size, pool_pages
        self.nb = max_len // page_size
        self.prefix_cache = prefix_cache
        self.pool_tokens = pool_pages * page_size
        self.cache = init_paged_cache(cfg, batch, max_len,
                                      page_size=page_size,
                                      pool_pages=pool_pages, dtype=dtype)
        self.stats = KVStats()
        # allocator state (host): pop() hands out pages 0, 1, 2, ...
        self._free: list[int] = list(range(pool_pages - 1, -1, -1))
        self.ref = np.zeros(pool_pages, np.int32)
        self.bt = np.full((batch, self.nb), -1, np.int32)
        self.n_blocks = np.zeros(batch, np.int32)
        self.lengths = np.zeros(batch, np.int64)
        self._tokens: list[Optional[np.ndarray]] = [None] * batch
        self._root = _Node(b"", -1, None, 0)   # type: ignore[arg-type]
        self._node_of_page: dict[int, _Node] = {}
        self._tick = 0
        self._dirty = True

    # -- prefix index ------------------------------------------------------

    def _match(self, tokens: np.ndarray) -> list[int]:
        """Pages of the longest indexed FULL-page prefix of ``tokens[:-1]``
        (the last prompt token is never served from cache — its logits
        must be computed to sample the first output token)."""
        pages, node = [], self._root
        for i in range((len(tokens) - 1) // self.ps):
            child = node.children.get(tokens[i * self.ps:
                                             (i + 1) * self.ps].tobytes())
            if child is None:
                break
            self._tick += 1
            child.tick = self._tick
            pages.append(child.page)
            node = child
        return pages

    def _insert(self, tokens: np.ndarray, pages: list[int]) -> None:
        """Index ``pages`` (full pages of a freed request's prompt) under
        their token content.  A chain already indexed dedupes: the freed
        duplicate page simply drops to refcount 0 and returns to the pool."""
        node = self._root
        for i, page in enumerate(pages):
            key = tokens[i * self.ps:(i + 1) * self.ps].tobytes()
            child = node.children.get(key)
            if child is None:
                if page in self._node_of_page:    # defensive: never re-home
                    break
                self._tick += 1
                child = _Node(key, page, node, self._tick)
                node.children[key] = child
                self._node_of_page[page] = child
            node = child

    def evict(self) -> Optional[int]:
        """Drop the least-recently-used unreferenced index LEAF and return
        its page (None if every index page is referenced or interior).
        Leaf-only keeps every surviving chain matchable from the root."""
        best = None
        for page, node in self._node_of_page.items():
            if self.ref[page] != 0 or node.children:
                continue
            k = (node.tick, page)
            if best is None or k < best[0]:
                best = (k, page, node)
        if best is None:
            return None
        _, page, node = best
        node.parent.children.pop(node.key, None)
        del self._node_of_page[page]
        self.stats.n_evictions += 1
        return page

    def _alloc_page(self) -> Optional[int]:
        if self._free:
            return self._free.pop()
        return self.evict()

    # -- the KVCache interface --------------------------------------------

    def begin(self, slot: int, tokens) -> int:
        if self.n_blocks[slot] or self._tokens[slot] is not None:
            self.free(slot)           # defensive: a begin implies a free
        toks = np.asarray(tokens, np.int32)
        pages = self._match(toks) if self.prefix_cache else []
        k = len(pages)
        if k:
            self.stats.n_prefix_hits += 1
            self.stats.prefix_hit_tokens += k * self.ps
            for p in pages:
                self.ref[p] += 1
            self.bt[slot, :k] = pages
        self.n_blocks[slot] = k
        self.lengths[slot] = k * self.ps
        self._tokens[slot] = toks
        self._dirty = True
        self.cache = {**self.cache,
                      "length": self.cache["length"].at[slot].set(k * self.ps)}
        return k * self.ps

    def reserve(self, slot: int, n: int) -> int:
        if n <= 0:
            return 0
        start = int(self.lengths[slot])
        need = -(-(start + n) // self.ps)          # blocks incl. existing
        while self.n_blocks[slot] < need:
            p = self._alloc_page()
            if p is None:
                break
            self.bt[slot, self.n_blocks[slot]] = p
            self.ref[p] = 1
            self.n_blocks[slot] += 1
            self._dirty = True
        cap = int(self.n_blocks[slot]) * self.ps - start
        return max(0, min(n, cap))

    def advance(self, q_lens) -> None:
        self.lengths += np.asarray(q_lens, np.int64)

    def rollback(self, slot: int, n: int) -> int:
        """Shrink the slot by its rejected speculative tail: drop the last
        ``n`` tokens and return pages past the new length to the pool.

        Tail-only by construction: shared prefix pages occupy the FRONT of
        the block table (``begin`` places the matched pages first), and a
        draft tail starts past the prompt, so the released blocks are
        always the slot's exclusively-owned newest pages — an indexed or
        still-referenced page is never handed to the free list (same guard
        as ``free``)."""
        if n <= 0:
            return 0
        new_len = max(0, int(self.lengths[slot]) - int(n))
        keep = -(-new_len // self.ps)              # ceil: partial page stays
        freed = 0
        for b in range(keep, int(self.n_blocks[slot])):
            p = int(self.bt[slot, b])
            if p < 0:
                continue
            self.ref[p] -= 1
            if self.ref[p] == 0 and p not in self._node_of_page:
                self._free.append(p)
                freed += 1
            self.bt[slot, b] = -1
        self.n_blocks[slot] = keep
        self.lengths[slot] = new_len
        self._dirty = True
        return freed

    def free(self, slot: int, keep_prefix: bool = True) -> None:
        n = int(self.n_blocks[slot])
        pages = [int(p) for p in self.bt[slot, :n]]
        for p in pages:
            self.ref[p] -= 1
        toks = self._tokens[slot]
        if (keep_prefix and self.prefix_cache and toks is not None
                and len(toks) > 1):
            done = min(int(self.lengths[slot]), len(toks))
            self._insert(toks, pages[:done // self.ps])
        for p in pages:      # unreferenced, un-indexed pages -> free list
            if self.ref[p] == 0 and p not in self._node_of_page:
                self._free.append(p)
        self.bt[slot, :] = -1
        self.n_blocks[slot] = 0
        self.lengths[slot] = 0
        self._tokens[slot] = None
        self._dirty = True
        self.cache = {**self.cache,
                      "length": self.cache["length"].at[slot].set(0)}

    def flush(self) -> None:
        if self._dirty:
            self.cache = {**self.cache,
                          "block_tables": jnp.asarray(self.bt)}
            self._dirty = False

    def occupancy(self) -> float:
        return (self.n_pages - len(self._free)) / max(self.n_pages, 1)


def make_kv_cache(cfg: ModelConfig, kv: Optional[KVConfig], batch: int,
                  max_len: int, dtype=jnp.bfloat16) -> KVCache:
    """Construct the backend a resolved ``ServeSpec.kv`` asks for."""
    if kv is None or kv.backend == "dense":
        return DenseKVCache(cfg, batch, max_len, dtype)
    ps = kv.page_size           # resolver-matching fallback: halve until
    while ps > 1 and max_len % ps:     # the page divides max_len
        ps //= 2
    pool = kv.pool_pages or batch * (max_len // ps)
    return PagedKVCache(cfg, batch, max_len, page_size=ps,
                        pool_pages=pool, prefix_cache=kv.prefix_cache,
                        dtype=dtype)


# ---------------------------------------------------------------------------
# Legacy slot helpers (blocking-prefill fallback path)
# ---------------------------------------------------------------------------

def insert_slot(big, small, slot: int):
    """Insert a batch=1 cache pytree into batch slot ``slot`` of ``big``.

    Cache arrays are (layers, batch, ...) — insert along axis 1; ``kpos``
    ring-position arrays are (layers, batch, W); ``length`` is (batch,).
    """
    def one(b, s):
        if b.ndim == 1:                    # length vector (batch,)
            return b.at[slot].set(s[0] if s.ndim else s)
        return jax.lax.dynamic_update_slice_in_dim(b, s.astype(b.dtype),
                                                   slot, axis=1)
    return jax.tree.map(one, big, small)


def batched_lengths(cache) -> jax.Array:
    return cache["length"]


def with_lengths(cache, lengths):
    return {**cache, "length": lengths}


def make_batched_cache(cfg: ModelConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16):
    """A batch cache whose ``length`` is a per-slot vector (all zero)."""
    c = init_cache(cfg, batch, max_len, dtype)
    return with_lengths(c, jnp.zeros((batch,), jnp.int32))


__all__ = ["KVCache", "KVStats", "DenseKVCache", "PagedKVCache",
           "make_kv_cache", "init_cache", "init_paged_cache", "cache_axes",
           "insert_slot", "batched_lengths", "with_lengths",
           "make_batched_cache"]
