"""Slot-based KV-cache manager for continuous batching.

The cache pytree itself is defined by ``repro.models.model.init_cache`` (it
is family-shaped: K/V buffers for GQA, latent buffers for MLA, ring buffers
for local attention, recurrent state for SSM/hybrid).  This module adds the
*slot* view the engine needs: per-slot lengths, insertion of a freshly
prefilled single-request cache into a batch slot, and free-slot tracking.

Cache layout reminder (decode sharding): every (layers, batch, kv_seq, ...)
buffer is sharded batch->DP axes and kv_seq->TP ("model") axis, so per-chip
cache bytes scale 1/(d_DP * d_TP).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import cache_axes, init_cache  # re-export


def insert_slot(big, small, slot: int):
    """Insert a batch=1 cache pytree into batch slot ``slot`` of ``big``.

    Cache arrays are (layers, batch, ...) — insert along axis 1; ``kpos``
    ring-position arrays are (layers, batch, W); ``length`` is (batch,).
    """
    def one(b, s):
        if b.ndim == 1:                    # length vector (batch,)
            return b.at[slot].set(s[0] if s.ndim else s)
        return jax.lax.dynamic_update_slice_in_dim(b, s.astype(b.dtype),
                                                   slot, axis=1)
    return jax.tree.map(one, big, small)


def batched_lengths(cache) -> jax.Array:
    return cache["length"]


def with_lengths(cache, lengths):
    return {**cache, "length": lengths}


def make_batched_cache(cfg: ModelConfig, batch: int, max_len: int,
                       dtype=jnp.bfloat16):
    """A batch cache whose ``length`` is a per-slot vector (all zero)."""
    c = init_cache(cfg, batch, max_len, dtype)
    return with_lengths(c, jnp.zeros((batch,), jnp.int32))


__all__ = ["init_cache", "cache_axes", "insert_slot", "batched_lengths",
           "with_lengths", "make_batched_cache"]
