"""Token-budget request scheduler: FIFO admission + Sarathi-style mixed
continuous batching.

Implements the serving-side of the paper's §III-B4 latency model: requests
arrive stochastically (arrival_rate), queue (the W_q term), are admitted into
engine slots, and per-request TTFT / ITL / throughput are measured — the same
indicators Eqs. 9-11 estimate theoretically.  ``summarize`` reports both so
benchmarks can compare measured vs modeled.

Each iteration of ``run`` admits due arrivals into free slots (admission is
pure bookkeeping on the unified engine — no blocking prefill) and then runs
ONE engine step under a token budget (from the engine's resolved
``ServeSpec.token_budget`` — the cost model's decode-first budget, or the
old ``B * chunk`` for legacy-kwarg engines): every decoding slot
contributes its 1 token first,
and the remaining budget is filled with prefill chunks in admission order.
Long prompts therefore stream through in chunks co-scheduled WITH the
decode traffic instead of stalling it — the TTFT/ITL trade the paper's
headline metrics measure.  Engines on the internal legacy fallback
(``unified_supported(cfg)`` False: ssm/hybrid/frontend families) get the
old loop: blocking prefill inside admission + decode-only steps.

``run(max_steps=...)`` no longer drops in-flight work silently: requests
still queued or mid-generation at exit are counted in
``ServeMetrics.n_incomplete``, and ``metrics()`` is well-defined with zero
finished requests.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Iterable, Optional

import numpy as np

from repro.serving.engine import Engine, Request


@dataclasses.dataclass
class ServeMetrics:
    n_requests: int
    ttft_mean: float
    ttft_p99: float
    itl_mean: float
    itl_p99: float
    throughput_tok_s: float      # total tokens (in+out) / wall time
    queue_wait_mean: float
    wall_time: float
    n_incomplete: int = 0        # admitted-or-queued but unfinished at exit

    def row(self) -> str:
        r = (f"n={self.n_requests} ttft={self.ttft_mean*1e3:.1f}ms "
             f"(p99 {self.ttft_p99*1e3:.1f}) itl={self.itl_mean*1e3:.2f}ms "
             f"(p99 {self.itl_p99*1e3:.2f}) thr={self.throughput_tok_s:.1f}tok/s "
             f"wq={self.queue_wait_mean*1e3:.1f}ms")
        if self.n_incomplete:
            r += f" INCOMPLETE={self.n_incomplete}"
        return r


class Scheduler:
    def __init__(self, engine: Engine, token_budget: Optional[int] = None):
        self.engine = engine
        if token_budget is not None:
            warnings.warn(
                "Scheduler(token_budget=...) is deprecated: set "
                "ServeSpec.token_budget (default 'auto' -> the cost "
                "model's decode-first budget) and build the engine from "
                "the resolved spec — see docs/api.md",
                DeprecationWarning, stacklevel=2)
        # the budget rides on the engine's resolved spec (the cost-model
        # choice, or B*chunk for legacy-kwarg engines); the deprecated
        # kwarg still wins for its one-release window
        self.token_budget = int(token_budget) if token_budget \
            else engine.spec.token_budget
        self.waiting: deque[Request] = deque()
        self.finished: list[Request] = []
        self.wall = 0.0
        self.n_incomplete = 0

    def submit(self, req: Request):
        self.engine.validate(req)          # raises PromptTooLongError early
        self.waiting.append(req)

    def run(self, *, max_steps: int = 100000) -> list:
        """Drain the queue: admit when slots free, step otherwise.

        Request ``arrival`` fields are *relative* offsets (seconds from run
        start) — an open-loop Poisson workload replays in real time.
        """
        t0 = time.perf_counter()
        for r in self.waiting:                 # rebase to absolute wall time
            r.arrival += t0
        steps = 0
        while (self.waiting or self.engine.n_active) and steps < max_steps:
            now = time.perf_counter()
            while (self.waiting and self.engine.free_slots()
                   and self.waiting[0].arrival <= now):
                req = self.waiting[0]
                if not self.engine.admit(req):
                    break
                self.waiting.popleft()
            if self.engine.n_active:
                self.finished.extend(self.engine.step(self.token_budget))
            else:                              # idle: wait for next arrival
                time.sleep(max(0.0, min(self.waiting[0].arrival - now, 1e-3)))
            steps += 1
        self.wall = time.perf_counter() - t0
        # max_steps can exit with work in flight — surface it, don't drop it
        self.n_incomplete = self.engine.n_active + len(self.waiting)
        return self.finished

    def metrics(self) -> ServeMetrics:
        rs = self.finished
        ttfts = np.array([r.ttft for r in rs])
        itls = np.array([r.itl for r in rs if len(r.out_tokens) > 1])
        waits = np.array([r.t_admitted - r.arrival for r in rs])
        total_toks = sum(len(r.prompt) + len(r.out_tokens) for r in rs)
        return ServeMetrics(
            n_requests=len(rs),
            ttft_mean=float(ttfts.mean()) if len(rs) else 0.0,
            ttft_p99=float(np.percentile(ttfts, 99)) if len(rs) else 0.0,
            itl_mean=float(itls.mean()) if len(itls) else 0.0,
            itl_p99=float(np.percentile(itls, 99)) if len(itls) else 0.0,
            throughput_tok_s=total_toks / max(self.wall, 1e-9),
            queue_wait_mean=float(waits.mean()) if len(rs) else 0.0,
            wall_time=self.wall,
            n_incomplete=self.n_incomplete,
        )


def synthetic_workload(n_requests: int, *, prompt_len: int = 64,
                       max_new_tokens: int = 16, vocab: int = 256,
                       arrival_rate: float = 0.0, seed: int = 0
                       ) -> Iterable[Request]:
    """Deterministic ShareGPT-stand-in workload (seeded, poisson arrivals)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    for rid in range(n_requests):
        if arrival_rate > 0:
            t += rng.exponential(1.0 / arrival_rate)
        s = max(4, int(rng.integers(prompt_len // 2, prompt_len + 1)))
        yield Request(rid=rid,
                      prompt=rng.integers(0, vocab, size=s).astype(np.int32),
                      max_new_tokens=max_new_tokens, arrival=t)


def mixed_workload(n_short: int = 8, *, short_len: int = 12,
                   n_long: int = 2, long_len: int = 96,
                   max_new_tokens: int = 8, vocab: int = 256,
                   arrival_rate: float = 16.0, seed: int = 0
                   ) -> Iterable[Request]:
    """Short decode-heavy stream with long prompts landing mid-decode — the
    workload where blocking prefill spikes every active slot's ITL and
    queued TTFTs, and the unified mixed step should not."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for rid in range(n_short):
        t += rng.exponential(1.0 / arrival_rate)
        s = max(4, int(rng.integers(short_len // 2, short_len + 1)))
        reqs.append(Request(
            rid=rid, prompt=rng.integers(0, vocab, size=s).astype(np.int32),
            max_new_tokens=max_new_tokens, arrival=t))
    # long prompts arrive in the thick of the short stream
    mid = reqs[n_short // 3].arrival if reqs else 0.0
    for j in range(n_long):
        reqs.append(Request(
            rid=n_short + j,
            prompt=rng.integers(0, vocab, size=long_len).astype(np.int32),
            max_new_tokens=max_new_tokens,
            arrival=mid + 1e-3 * j))
    return sorted(reqs, key=lambda r: r.arrival)


__all__ = ["Scheduler", "ServeMetrics", "synthetic_workload",
           "mixed_workload"]
