"""Request scheduler: FIFO admission + iteration-level continuous batching.

Implements the serving-side of the paper's §III-B4 latency model: requests
arrive stochastically (arrival_rate), queue (the W_q term), are admitted into
engine slots, and per-request TTFT / ITL / throughput are measured — the same
indicators Eqs. 9-11 estimate theoretically.  ``summarize`` reports both so
benchmarks can compare measured vs modeled.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterable, Optional

import numpy as np

from repro.serving.engine import Engine, Request


@dataclasses.dataclass
class ServeMetrics:
    n_requests: int
    ttft_mean: float
    ttft_p99: float
    itl_mean: float
    itl_p99: float
    throughput_tok_s: float      # total tokens (in+out) / wall time
    queue_wait_mean: float
    wall_time: float

    def row(self) -> str:
        return (f"n={self.n_requests} ttft={self.ttft_mean*1e3:.1f}ms "
                f"(p99 {self.ttft_p99*1e3:.1f}) itl={self.itl_mean*1e3:.2f}ms "
                f"(p99 {self.itl_p99*1e3:.2f}) thr={self.throughput_tok_s:.1f}tok/s "
                f"wq={self.queue_wait_mean*1e3:.1f}ms")


class Scheduler:
    def __init__(self, engine: Engine):
        self.engine = engine
        self.waiting: deque[Request] = deque()
        self.finished: list[Request] = []

    def submit(self, req: Request):
        self.waiting.append(req)

    def run(self, *, max_steps: int = 100000) -> list:
        """Drain the queue: admit when slots free, decode-step otherwise.

        Request ``arrival`` fields are *relative* offsets (seconds from run
        start) — an open-loop Poisson workload replays in real time.
        """
        t0 = time.perf_counter()
        for r in self.waiting:                 # rebase to absolute wall time
            r.arrival += t0
        steps = 0
        while (self.waiting or self.engine.n_active) and steps < max_steps:
            now = time.perf_counter()
            while (self.waiting and self.engine.free_slots()
                   and self.waiting[0].arrival <= now):
                req = self.waiting[0]
                if not self.engine.admit(req):
                    break
                self.waiting.popleft()
            if self.engine.n_active:
                self.finished.extend(self.engine.step())
            else:                              # idle: wait for next arrival
                time.sleep(max(0.0, min(self.waiting[0].arrival - now, 1e-3)))
            steps += 1
        self.wall = time.perf_counter() - t0
        return self.finished

    def metrics(self) -> ServeMetrics:
        rs = self.finished
        ttfts = np.array([r.ttft for r in rs])
        itls = np.array([r.itl for r in rs if len(r.out_tokens) > 1])
        waits = np.array([r.t_admitted - r.arrival for r in rs])
        total_toks = sum(len(r.prompt) + len(r.out_tokens) for r in rs)
        return ServeMetrics(
            n_requests=len(rs),
            ttft_mean=float(ttfts.mean()) if len(rs) else 0.0,
            ttft_p99=float(np.percentile(ttfts, 99)) if len(rs) else 0.0,
            itl_mean=float(itls.mean()) if len(itls) else 0.0,
            itl_p99=float(np.percentile(itls, 99)) if len(itls) else 0.0,
            throughput_tok_s=total_toks / max(self.wall, 1e-9),
            queue_wait_mean=float(waits.mean()) if len(rs) else 0.0,
            wall_time=self.wall,
        )


def synthetic_workload(n_requests: int, *, prompt_len: int = 64,
                       max_new_tokens: int = 16, vocab: int = 256,
                       arrival_rate: float = 0.0, seed: int = 0
                       ) -> Iterable[Request]:
    """Deterministic ShareGPT-stand-in workload (seeded, poisson arrivals)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    for rid in range(n_requests):
        if arrival_rate > 0:
            t += rng.exponential(1.0 / arrival_rate)
        s = max(4, int(rng.integers(prompt_len // 2, prompt_len + 1)))
        yield Request(rid=rid,
                      prompt=rng.integers(0, vocab, size=s).astype(np.int32),
                      max_new_tokens=max_new_tokens, arrival=t)


__all__ = ["Scheduler", "ServeMetrics", "synthetic_workload"]
