"""Token-budget request scheduler: priority admission + Sarathi-style mixed
continuous batching, with bounded-latency degradation under pressure.

Implements the serving-side of the paper's §III-B4 latency model: requests
arrive stochastically (arrival_rate), queue (the W_q term), are admitted into
engine slots, and per-request TTFT / ITL / throughput are measured — the same
indicators Eqs. 9-11 estimate theoretically.  ``summarize`` reports both so
benchmarks can compare measured vs modeled.

Each iteration of ``run``:

1. **deadline enforcement** — RUNNING slots whose deadline passed are freed
   mid-decode (state CANCELLED, counted as deadline misses); queued
   requests whose deadline already expired are shed before wasting a slot.
2. **admission** — due arrivals are admitted highest-priority-first (FIFO
   within a priority).  The queue is *bounded* by the resolved
   ``ServeSpec.overload`` policy (queue cap + shed rule priced by the cost
   model's Eq. 4-6 token-time estimates): when it overflows, the engine
   degrades to bounded-latency shedding instead of unbounded queueing.
   When a due request outranks every free slot, the lowest-priority slot
   is **preempted** (recompute-on-resume: its generated tokens become a
   prompt suffix and it re-queues — ``Engine.preempt``).
3. **one engine step** under the resolved token budget (decode-first).

A **no-progress watchdog** turns silent busy-spins (an un-admittable
request, an engine returning empty q_lens forever) into a diagnosable
``StalledEngineError`` instead of looping to ``max_steps``.  Injected
faults (``ServeSpec.faults``) surface here too: admission faults shed
exactly the targeted request, clock-skew faults shift this scheduler's
clock, latency spikes ride the engine step.

``run(max_steps=...)`` never drops in-flight work silently: requests still
queued or mid-generation at exit are counted in
``ServeMetrics.n_incomplete``, and ``metrics()`` is well-defined with zero
finished requests.  ``ServeMetrics`` carries the robustness counters
(shed/preempt/cancel/deadline-miss/fault) surfaced in ``row()`` and the
``BENCH_serve_mixed`` meta.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterable, Optional

import numpy as np

from repro.core.resolve import OverloadPolicy
from repro.serving.engine import Engine, Request, RequestState
from repro.serving.faults import InjectedFault

# pure-host iterations with zero progress before the watchdog trips — far
# above anything a healthy loop produces, cheap to reach when truly stuck
WATCHDOG_STALL_STEPS = 256


class StalledEngineError(RuntimeError):
    """The serving loop made no progress for WATCHDOG_STALL_STEPS
    iterations: nothing admitted, no tokens processed, nothing retired."""


@dataclasses.dataclass
class ServeMetrics:
    n_requests: int
    ttft_mean: float
    ttft_p99: float
    itl_mean: float
    itl_p99: float
    throughput_tok_s: float      # total tokens (in+out) / wall time
    queue_wait_mean: float
    wall_time: float
    n_incomplete: int = 0        # admitted-or-queued but unfinished at exit
    # robustness counters (graceful-degradation bookkeeping)
    n_shed: int = 0              # rejected by the bounded admission queue
    n_preempted: int = 0         # priority evictions (recompute-on-resume)
    n_cancelled: int = 0         # user cancellations
    n_deadline_miss: int = 0     # deadline-expired kills (queued + running)
    n_faults: int = 0            # NaN/Inf-quarantined slots
    deadline_miss_p99: float = 0.0   # p99 lateness of deadline-carrying
    #                                  requests (0 = every deadline met)
    # KV-cache efficiency (docs/kv_cache.md; dense backend: hits stay 0)
    kv_occupancy: float = 0.0    # PEAK fraction of KV capacity in use
    n_prefix_hits: int = 0       # admissions that reused shared pages
    prefix_hit_tokens: int = 0   # prompt tokens served from shared pages
    n_evictions: int = 0         # prefix-index pages evicted under pressure
    # expert-load skew + EP-exchange ledger (MoE only; docs/dispatch.md).
    # max/mean is the skew the count-bounded A2A buffers must absorb; the
    # byte ledger compares the resolved micro-chunked extent against the
    # monolithic worst case (Engine.ep_load_stats)
    ep_rank_max_tokens: int = 0      # routed slots on the hottest EP rank
    ep_rank_mean_tokens: float = 0.0  # routed slots per EP rank, mean
    a2a_bytes_moved: int = 0         # priced bytes under the resolved extent
    a2a_bytes_worst: int = 0         # priced bytes at worst-case extent
    # speculative decoding (Engine.spec_stats; zero when speculation off)
    n_spec_steps: int = 0            # slot-steps that carried draft rows
    n_spec_drafted: int = 0          # draft tokens proposed to the verifier
    n_spec_accepted: int = 0         # draft tokens accepted
    spec_accept_rate: float = 0.0    # accepted / drafted
    spec_tokens_per_step: float = 0.0  # committed tokens per verify step

    def row(self) -> str:
        r = (f"n={self.n_requests} ttft={self.ttft_mean*1e3:.1f}ms "
             f"(p99 {self.ttft_p99*1e3:.1f}) itl={self.itl_mean*1e3:.2f}ms "
             f"(p99 {self.itl_p99*1e3:.2f}) thr={self.throughput_tok_s:.1f}tok/s "
             f"wq={self.queue_wait_mean*1e3:.1f}ms "
             f"shed={self.n_shed} preempt={self.n_preempted} "
             f"cancel={self.n_cancelled} dmiss={self.n_deadline_miss} "
             f"fault={self.n_faults} "
             f"kv={self.kv_occupancy*100:.0f}% "
             f"pfxhit={self.n_prefix_hits}({self.prefix_hit_tokens}tok) "
             f"evict={self.n_evictions}")
        if self.ep_rank_mean_tokens > 0:
            skew = self.ep_rank_max_tokens / self.ep_rank_mean_tokens
            saved = 1.0 - self.a2a_bytes_moved / max(self.a2a_bytes_worst, 1)
            r += (f" epskew={skew:.2f} "
                  f"a2a={self.a2a_bytes_moved}/{self.a2a_bytes_worst}B "
                  f"(-{saved*100:.0f}%)")
        if self.n_spec_steps:
            r += (f" spec={self.spec_tokens_per_step:.2f}tok/step"
                  f"(acc {self.spec_accept_rate*100:.0f}%)")
        if self.n_incomplete:
            r += f" INCOMPLETE={self.n_incomplete}"
        return r

    def robustness(self) -> dict:
        """The degradation counters as a JSON-able block (bench meta)."""
        return {"n_shed": self.n_shed, "n_preempted": self.n_preempted,
                "n_cancelled": self.n_cancelled,
                "n_deadline_miss": self.n_deadline_miss,
                "n_faults": self.n_faults,
                "deadline_miss_p99": self.deadline_miss_p99,
                "kv_occupancy": self.kv_occupancy,
                "n_prefix_hits": self.n_prefix_hits,
                "prefix_hit_tokens": self.prefix_hit_tokens,
                "n_evictions": self.n_evictions,
                "ep_rank_max_tokens": self.ep_rank_max_tokens,
                "ep_rank_mean_tokens": self.ep_rank_mean_tokens,
                "a2a_bytes_moved": self.a2a_bytes_moved,
                "a2a_bytes_worst": self.a2a_bytes_worst,
                "n_spec_steps": self.n_spec_steps,
                "n_spec_drafted": self.n_spec_drafted,
                "n_spec_accepted": self.n_spec_accepted,
                "spec_accept_rate": self.spec_accept_rate,
                "spec_tokens_per_step": self.spec_tokens_per_step}


class Scheduler:
    def __init__(self, engine: Engine, *,
                 watchdog_steps: int = WATCHDOG_STALL_STEPS):
        self.engine = engine
        # budget + overload policy ride the engine's resolved spec (the
        # deprecated Scheduler(token_budget=) kwarg was removed after its
        # one-release window)
        self.token_budget = engine.spec.token_budget
        self.overload: OverloadPolicy = engine.spec.overload
        self.watchdog_steps = int(watchdog_steps)
        self.waiting: deque[Request] = deque()
        self.finished: list[Request] = []
        self.shed: list[Request] = []
        self.failed: list[Request] = []
        self.cancelled: list[Request] = []
        self.wall = 0.0
        self.n_incomplete = 0
        self.kv_peak = 0.0           # peak KV occupancy over the run

    # -- admission-side bookkeeping --------------------------------------
    def _shed_req(self, req: Request, error: str) -> None:
        req.state = RequestState.SHED
        req.error = error
        req.t_done = time.perf_counter()
        self.shed.append(req)

    def submit(self, req: Request) -> bool:
        """Queue a request; returns False when the bounded admission queue
        sheds one instead (``req`` itself under reject-newest, possibly a
        queued deadline-infeasible victim under deadline-first).

        Raises ``PromptTooLongError`` early for never-admittable prompts.
        """
        self.engine.validate(req)
        req.state = RequestState.QUEUED
        if len(self.waiting) >= self.overload.queue_cap:
            victim = self._shed_victim(req)
            if victim is req:
                self._shed_req(req, f"admission queue full "
                               f"(cap {self.overload.queue_cap})")
                return False
            self.waiting.remove(victim)
            self._shed_req(victim, "shed for an infeasible deadline "
                           f"(queue cap {self.overload.queue_cap})")
            self.engine.events["deadline_miss"] += 1
        self.waiting.append(req)
        return True

    def _shed_victim(self, incoming: Request) -> Request:
        """Overflow victim.  deadline-first: the queued request whose
        deadline is least feasible (most negative slack against the
        resolver's predicted per-request service time) — degrade by
        dropping work that cannot meet its SLO anyway; falls back to
        reject-newest when no queued deadline is infeasible."""
        if self.overload.shed == "deadline-first":
            now = time.perf_counter()
            est = self.overload.est_request_s
            worst, worst_slack = None, 0.0
            for r in self.waiting:
                if r.deadline_s is None:
                    continue
                slack = r.deadline - (now + est)
                if slack < worst_slack:
                    worst, worst_slack = r, slack
            if worst is not None:
                return worst
        return incoming

    def cancel(self, rid: int) -> Optional[Request]:
        """Cancel a queued or running request by id."""
        for r in self.waiting:
            if r.rid == rid:
                self.waiting.remove(r)
                r.state = RequestState.CANCELLED
                r.error = "cancelled"
                r.t_done = time.perf_counter()
                self.engine.events["cancel"] += 1
                self.cancelled.append(r)
                return r
        req = self.engine.cancel(rid)
        if req is not None:
            self.cancelled.append(req)
        return req

    # -- the serving loop ------------------------------------------------
    def _now(self) -> float:
        skew = self.engine.faults.advance_clock(self.engine._step_idx) \
            if self.engine.faults else 0.0
        return time.perf_counter() + skew

    def _enforce_deadlines(self, now: float) -> int:
        """Free deadline-expired RUNNING slots mid-decode; shed queued
        requests whose deadline already passed.  Returns #released."""
        released = 0
        for i, r in enumerate(self.engine.slots):
            if r is not None and not r.done and now > r.deadline:
                self.engine.release(i, RequestState.CANCELLED,
                                    error="deadline expired mid-flight",
                                    reason="deadline_miss")
                self.cancelled.append(r)
                released += 1
        for r in [r for r in self.waiting if now > r.deadline]:
            self.waiting.remove(r)
            self._shed_req(r, "deadline expired in queue")
            self.engine.events["deadline_miss"] += 1
            released += 1
        return released

    def _admit_due(self, now: float) -> int:
        """Admit due requests highest-priority-first; preempt the
        lowest-priority slot when a due request strictly outranks it."""
        admitted = 0
        while self.waiting:
            due = [r for r in self.waiting if r.arrival <= now]
            if not due:
                break
            req = min(due, key=lambda r: (-r.priority, r.arrival, r.rid))
            if not self.engine.free_slots():
                victim_slot = self.engine.victim_slot(req.priority)
                if victim_slot is None:
                    break                  # nothing outranked — wait
                victim = self.engine.preempt(victim_slot)
                # recompute-on-resume: generated tokens ride back as a
                # prompt suffix; re-queued at its original arrival so it
                # re-admits as soon as capacity frees
                self.waiting.append(victim)
            try:
                if not self.engine.admit(req):
                    break
            except InjectedFault as e:
                self.waiting.remove(req)
                self._shed_req(req, str(e))
                continue
            self.waiting.remove(req)
            admitted += 1
        return admitted

    def run(self, *, max_steps: int = 100000) -> list:
        """Drain the queue: admit when slots free, step otherwise.

        Request ``arrival`` fields are *relative* offsets (seconds from run
        start) — an open-loop Poisson workload replays in real time.
        Raises ``StalledEngineError`` when the loop stops making progress
        (the watchdog) instead of spinning silently to ``max_steps``.
        """
        t0 = time.perf_counter()
        for r in self.waiting:                 # rebase to absolute wall time
            r.arrival += t0
        steps = 0
        stall = 0
        while (self.waiting or self.engine.n_active) and steps < max_steps:
            now = self._now()
            progress = self._enforce_deadlines(now) > 0
            progress |= self._admit_due(now) > 0
            if self.engine.n_active:
                self.kv_peak = max(self.kv_peak, self.engine.kv.occupancy())
                retired = self.engine.step(self.token_budget)
                self._classify(retired)
                progress |= bool(retired) or self.engine.last_step_tokens > 0
            elif self.waiting:
                next_arrival = min(r.arrival for r in self.waiting)
                if next_arrival > now:         # idle: wait for next arrival
                    time.sleep(max(0.0, min(next_arrival - now, 1e-3)))
                    progress = True
            stall = 0 if progress else stall + 1
            if stall >= self.watchdog_steps:
                head = min(self.waiting, key=lambda r: r.arrival) \
                    if self.waiting else None
                raise StalledEngineError(
                    f"no progress for {stall} iterations: "
                    f"{self.engine.n_active} active slots, "
                    f"{len(self.waiting)} queued"
                    + (f" (head rid={head.rid} prompt={len(head.prompt)} "
                       f"state={head.state})" if head else "")
                    + f", free={len(self.engine.free_slots())}, "
                    f"last_step_tokens={self.engine.last_step_tokens} — "
                    "the engine cannot admit or advance any request")
            steps += 1
        self.wall = time.perf_counter() - t0
        # max_steps can exit with work in flight — surface it, don't drop it
        self.n_incomplete = self.engine.n_active + len(self.waiting)
        return self.finished

    def _classify(self, retired: list) -> None:
        for r in retired:
            if r.state == RequestState.DONE:
                self.finished.append(r)
            elif r.state == RequestState.FAILED:
                self.failed.append(r)
            elif r.state == RequestState.PREEMPTED:
                # pool-pressure eviction from Engine.step: recompute-on-
                # resume — back into the queue at its original arrival
                self.waiting.append(r)
            elif r not in self.cancelled:
                self.cancelled.append(r)

    def metrics(self) -> ServeMetrics:
        rs = self.finished
        ttfts = np.array([r.ttft for r in rs])
        itls = np.array([r.itl for r in rs if len(r.out_tokens) > 1])
        waits = np.array([r.t_admitted - r.arrival for r in rs])
        total_toks = sum(len(r.prompt) + len(r.out_tokens) for r in rs)
        ev = self.engine.events
        # deadline lateness over every terminal deadline-carrying request:
        # finished late, killed mid-flight, or shed in the queue
        late = [max(0.0, r.t_done - r.deadline)
                for pool in (rs, self.cancelled, self.shed, self.failed)
                for r in pool if r.deadline_s is not None and r.t_done]
        return ServeMetrics(
            n_requests=len(rs),
            ttft_mean=float(ttfts.mean()) if len(rs) else 0.0,
            ttft_p99=float(np.percentile(ttfts, 99)) if len(rs) else 0.0,
            itl_mean=float(itls.mean()) if len(itls) else 0.0,
            itl_p99=float(np.percentile(itls, 99)) if len(itls) else 0.0,
            throughput_tok_s=total_toks / max(self.wall, 1e-9),
            queue_wait_mean=float(waits.mean()) if len(rs) else 0.0,
            wall_time=self.wall,
            n_incomplete=self.n_incomplete,
            n_shed=len(self.shed),
            n_preempted=ev.get("preempt", 0),
            n_cancelled=ev.get("cancel", 0),
            n_deadline_miss=ev.get("deadline_miss", 0),
            n_faults=ev.get("fault", 0),
            deadline_miss_p99=float(np.percentile(late, 99)) if late else 0.0,
            kv_occupancy=self.kv_peak,
            n_prefix_hits=self.engine.kv.stats.n_prefix_hits,
            prefix_hit_tokens=self.engine.kv.stats.prefix_hit_tokens,
            n_evictions=self.engine.kv.stats.n_evictions,
            **self.engine.ep_load_stats(),
            **self.engine.spec_stats(),
        )


def synthetic_workload(n_requests: int, *, prompt_len: int = 64,
                       max_new_tokens: int = 16, vocab: int = 256,
                       arrival_rate: float = 0.0, seed: int = 0,
                       priority: int = 0, deadline_s: Optional[float] = None
                       ) -> Iterable[Request]:
    """Deterministic ShareGPT-stand-in workload (seeded, poisson arrivals)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    for rid in range(n_requests):
        if arrival_rate > 0:
            t += rng.exponential(1.0 / arrival_rate)
        s = max(4, int(rng.integers(prompt_len // 2, prompt_len + 1)))
        yield Request(rid=rid,
                      prompt=rng.integers(0, vocab, size=s).astype(np.int32),
                      max_new_tokens=max_new_tokens, arrival=t,
                      priority=priority, deadline_s=deadline_s)


def mixed_workload(n_short: int = 8, *, short_len: int = 12,
                   n_long: int = 2, long_len: int = 96,
                   max_new_tokens: int = 8, vocab: int = 256,
                   arrival_rate: float = 16.0, seed: int = 0
                   ) -> Iterable[Request]:
    """Short decode-heavy stream with long prompts landing mid-decode — the
    workload where blocking prefill spikes every active slot's ITL and
    queued TTFTs, and the unified mixed step should not."""
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for rid in range(n_short):
        t += rng.exponential(1.0 / arrival_rate)
        s = max(4, int(rng.integers(short_len // 2, short_len + 1)))
        reqs.append(Request(
            rid=rid, prompt=rng.integers(0, vocab, size=s).astype(np.int32),
            max_new_tokens=max_new_tokens, arrival=t))
    # long prompts arrive in the thick of the short stream
    mid = reqs[n_short // 3].arrival if reqs else 0.0
    for j in range(n_long):
        reqs.append(Request(
            rid=n_short + j,
            prompt=rng.integers(0, vocab, size=long_len).astype(np.int32),
            max_new_tokens=max_new_tokens,
            arrival=mid + 1e-3 * j))
    return sorted(reqs, key=lambda r: r.arrival)


def tiered_workload(n_requests: int, *, prompt_len: int = 24,
                    max_new_tokens: int = 8, vocab: int = 256,
                    arrival_rate: float = 16.0, seed: int = 0,
                    hi_every: int = 3, hi_priority: int = 10,
                    hi_deadline_s: Optional[float] = 2.0,
                    system: Optional[np.ndarray] = None
                    ) -> Iterable[Request]:
    """Two-tier traffic: every ``hi_every``-th request is a high-priority,
    deadline-bound "interactive" request riding a best-effort background
    stream — the mix where priority preemption + deadline enforcement earn
    their keep (examples/serve_moe.py, chaos tests).

    ``system`` prepends a shared system prompt to every request — the
    paged KV backend serves it once and re-matches its full pages from
    the prefix index on every later admission (docs/kv_cache.md)."""
    rng = np.random.default_rng(seed)
    t = 0.0
    for rid in range(n_requests):
        if arrival_rate > 0:
            t += rng.exponential(1.0 / arrival_rate)
        s = max(4, int(rng.integers(prompt_len // 2, prompt_len + 1)))
        hi = hi_every > 0 and rid % hi_every == 0
        tail = rng.integers(0, vocab, size=s).astype(np.int32)
        prompt = (tail if system is None
                  else np.concatenate([np.asarray(system, np.int32), tail]))
        yield Request(rid=rid, prompt=prompt,
                      max_new_tokens=max_new_tokens, arrival=t,
                      priority=hi_priority if hi else 0,
                      deadline_s=hi_deadline_s if hi else None)


__all__ = ["Scheduler", "ServeMetrics", "StalledEngineError",
           "WATCHDOG_STALL_STEPS", "synthetic_workload", "mixed_workload",
           "tiered_workload"]
