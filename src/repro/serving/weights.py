"""Weight loader/exporter — the paper's online-stage "weight loader &
partitioner" (§III-A).

Converts between a flat HF-transformers-style checkpoint dict
(``model.layers.{i}.self_attn.q_proj.weight`` with (out, in)-major Linear
layout) and this framework's stacked-scan pytree for the llama-family
architectures (smollm, minitron, gemma*, qwen2-vl text stack).

The loader is where the partitioner's NamedShardings would be applied on a
real cluster: ``load_llama_style(..., plan=...)`` device_puts each stacked
tensor with its plan sharding, so weights stream from host to their shards
without ever materializing replicated.

*gemma's tied embedding + GeGLU map 1:1; MLA/MoE archs have their own
native checkpoint layouts and are out of scope for this HF mapping.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.partitioner import NULL_PLAN, ShardingPlan
from repro.models.model import model_spec, param_axes
from repro.models.param import P, is_p


def _llama_names(cfg: ModelConfig, i: int) -> dict:
    base = f"model.layers.{i}."
    names = {
        "attn.wq": base + "self_attn.q_proj.weight",
        "attn.wk": base + "self_attn.k_proj.weight",
        "attn.wv": base + "self_attn.v_proj.weight",
        "attn.wo": base + "self_attn.o_proj.weight",
        "attn.norm": base + "input_layernorm.weight",
        "mlp.w_in": base + "mlp.up_proj.weight",
        "mlp.w_out": base + "mlp.down_proj.weight",
        "mlp.norm": base + "post_attention_layernorm.weight",
    }
    if cfg.activation in ("swiglu", "geglu"):      # gated MLPs only
        names["mlp.w_gate"] = base + "mlp.gate_proj.weight"
    return names


def _to_ours(key: str, arr: np.ndarray, cfg: ModelConfig) -> np.ndarray:
    """HF Linear (out, in) -> our einsum layouts."""
    h, nq, nkv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    if key == "attn.wq":
        return arr.reshape(nq, hd, h).transpose(2, 0, 1)       # (h, nq, hd)
    if key in ("attn.wk", "attn.wv"):
        return arr.reshape(nkv, hd, h).transpose(2, 0, 1)      # (h, nkv, hd)
    if key == "attn.wo":
        return arr.reshape(h, nq, hd).transpose(1, 2, 0)       # (nq, hd, h)
    if key in ("mlp.w_gate", "mlp.w_in"):
        return arr.T                                           # (h, f)
    if key == "mlp.w_out":
        return arr.T                                           # (f, h)
    return arr   # norms map 1:1 (our rms_norm uses the (1 + w) convention
                 # with zero-init, matching HF weights shifted by the loader
                 # caller when needed)


def _from_ours(key: str, arr: np.ndarray, cfg: ModelConfig) -> np.ndarray:
    h, nq, nkv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)
    if key == "attn.wq":
        return arr.transpose(1, 2, 0).reshape(nq * hd, h)
    if key in ("attn.wk", "attn.wv"):
        return arr.transpose(1, 2, 0).reshape(nkv * hd, h)
    if key == "attn.wo":
        return arr.transpose(2, 0, 1).reshape(h, nq * hd)
    if key in ("mlp.w_gate", "mlp.w_in", "mlp.w_out"):
        return arr.T
    return arr


def _supported(cfg: ModelConfig) -> bool:
    return (cfg.family in ("dense", "vlm") and cfg.attention == "gqa"
            and not cfg.is_moe)


def export_llama_style(params, cfg: ModelConfig) -> dict:
    """Our pytree -> flat HF-style dict (llama-family dense archs)."""
    assert _supported(cfg), f"{cfg.name}: not a llama-family dense arch"
    flat = {}
    v = cfg.vocab_size
    flat["model.embed_tokens.weight"] = np.asarray(params["embed"])[:v]
    flat["model.norm.weight"] = np.asarray(params["final_norm"])
    if not cfg.tie_embeddings:
        flat["lm_head.weight"] = np.asarray(params["lm_head"]).T[:v]
    stack = params["groups"][0]
    for i in range(cfg.n_layers):
        for key, name in _llama_names(cfg, i).items():
            sub, leaf = key.split(".")
            arr = np.asarray(stack[sub][leaf][i])
            flat[name] = _from_ours(key, arr, cfg)
    return flat


def load_llama_style(flat: dict, cfg: ModelConfig,
                     plan: ShardingPlan = NULL_PLAN,
                     dtype=jnp.float32) -> dict:
    """Flat HF-style dict -> our pytree (optionally sharded via the plan)."""
    assert _supported(cfg), f"{cfg.name}: not a llama-family dense arch"
    spec = model_spec(cfg)
    axes = param_axes(cfg)

    def put(arr, p_decl: P, ax):
        arr = np.asarray(arr, dtype=jnp.dtype(dtype).name)
        if tuple(arr.shape) != tuple(p_decl.shape):     # vocab padding
            padded = np.zeros(p_decl.shape, arr.dtype)
            padded[tuple(slice(0, s) for s in arr.shape)] = arr
            arr = padded
        sh = plan.sharding_for(arr.shape, ax) if plan.enabled else None
        return jax.device_put(arr, sh) if sh is not None else jnp.asarray(arr)

    out = {"embed": put(flat["model.embed_tokens.weight"], spec["embed"],
                        axes["embed"]),
           "final_norm": put(flat["model.norm.weight"], spec["final_norm"],
                             axes["final_norm"])}
    if not cfg.tie_embeddings:
        out["lm_head"] = put(np.asarray(flat["lm_head.weight"]).T,
                             spec["lm_head"], axes["lm_head"])

    layer_spec = spec["groups"][0]
    layer_axes = axes["groups"][0]
    stacked: dict = {"attn": {}, "mlp": {}}
    for key in _llama_names(cfg, 0):
        sub, leaf = key.split(".")
        per_layer = [
            _to_ours(key, np.asarray(flat[_llama_names(cfg, i)[key]]), cfg)
            for i in range(cfg.n_layers)]
        stacked[sub][leaf] = put(np.stack(per_layer),
                                 layer_spec[sub][leaf],
                                 layer_axes[sub][leaf])
    out["groups"] = [stacked]
    return out


__all__ = ["export_llama_style", "load_llama_style"]
