"""Flat-npz pytree checkpointing (no orbax in the offline container).

Pytree leaves are flattened with '/'-joined key paths; restore rebuilds into
a reference pytree structure, validating shapes/dtypes.
"""

from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> dict:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree: Any) -> None:
    tmp = path + ".tmp"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp + ".npz" if not tmp.endswith(".npz") else tmp, path)


def restore(path: str, like: Any) -> Any:
    with np.load(path) as data:
        flat = dict(data)
    leaves_ref, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for p, ref in leaves_ref:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q)))
                       for q in p)
        arr = flat[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {ref.shape}")
        leaves.append(arr.astype(ref.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), leaves)


__all__ = ["save", "restore"]
