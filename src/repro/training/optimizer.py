"""AdamW with cosine schedule — minimal, optax-free (offline container).

Optimizer state is a pytree of (m, v) in float32 plus a step counter, so it
shards exactly like the parameters (the dry-run's in_shardings map m/v with
the same PartitionSpecs as the weights they track).
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    m: dict                  # pytree like params, f32
    v: dict                  # pytree like params, f32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0
    # Moment storage dtype.  f32 moments for a 236B model cost 8 bytes/param
    # = 9.2 GB/chip even fully sharded on a 256-chip v5e pod — bf16 moments
    # are the production choice there (update math still runs in f32).
    moment_dtype: str = "float32"


def cosine_lr(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params, dtype=jnp.float32) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def abstract_opt_state(abstract_params, dtype=jnp.float32) -> AdamWState:
    mv = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, dtype),
                      abstract_params)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), m=mv, v=mv)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    b1c = 1 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        mdt = m.dtype
        g = g.astype(jnp.float32) * scale
        m = cfg.beta1 * m.astype(jnp.float32) + (1 - cfg.beta1) * g
        v = cfg.beta2 * v.astype(jnp.float32) + (1 - cfg.beta2) * jnp.square(g)
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * u).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    flat_g, td = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_p = jax.tree.leaves(params)
    # Chain leaf updates with optimization_barrier: without an ordering edge
    # XLA schedules every leaf's f32 working set (g, m, v, u, p casts)
    # concurrently — ~5 f32 copies x N big tensors of peak memory.  The
    # barrier serializes them so the peak is ONE leaf's working set.
    out = []
    gate = None
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        if gate is not None:
            g, _ = jax.lax.optimization_barrier((g, gate))
        new_p, new_m, new_v = upd(g, m, v, p)
        gate = new_p
        out.append((new_p, new_m, new_v))
    new_p = td.unflatten([o[0] for o in out])
    new_m = td.unflatten([o[1] for o in out])
    new_v = td.unflatten([o[2] for o in out])
    stats = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step=step, m=new_m, v=new_v), stats


__all__ = ["AdamWConfig", "AdamWState", "init_opt_state",
           "abstract_opt_state", "adamw_update", "cosine_lr", "global_norm"]
