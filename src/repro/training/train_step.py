"""Training step: CE loss (+ MoE router aux), grads, AdamW — sharding-aware.

The loss is computed with a streamed log-softmax over the (possibly
vocab-sharded) logits; XLA inserts the cross-shard reductions.  ``remat=True``
checkpoints each scanned layer group (required for the 4k x 256 train shape
on the big configs).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.partitioner import NULL_PLAN, ShardingPlan
from repro.models.model import forward
from repro.training.optimizer import AdamWConfig, adamw_update


def cross_entropy(logits, labels, mask=None):
    """logits (b, s, v) f32-accumulated CE with integer labels (b, s).

    The gold logit is extracted with a one-hot masked SUM (not
    take_along_axis): a gather along a vocab-sharded axis would force GSPMD
    to all-gather the full logits; the one-hot sum stays sharded and reduces
    with a tiny psum.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    v = logits.shape[-1]
    onehot = labels[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, v), 2)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def loss_fn(params, batch, cfg: ModelConfig, plan: ShardingPlan = NULL_PLAN,
            *, remat: bool = True):
    """batch: {tokens, labels[, mask, embeds, frames]}.

    Training pins the MoE *capacity* dispatch: the fixed (E, C, h) buffers
    are the load-balancing contract (dropped slots are what the router aux
    loss pushes against, and per-expert compute stays bounded).  A plan
    that explicitly says "dropless" is honored; the "auto" default — which
    resolves to dropless for inference — is not."""
    import dataclasses as _dc
    if plan.dispatch_mode == "auto":
        plan = _dc.replace(plan, dispatch_mode="capacity")
    out = forward(params, cfg, plan,
                  tokens=batch["tokens"],
                  embeds=batch.get("embeds"),
                  frames=batch.get("frames"),
                  remat=remat)
    ce = cross_entropy(out.logits, batch["labels"], batch.get("mask"))
    loss = ce + cfg.router_aux_coef * out.aux
    return loss, {"ce": ce, "aux": out.aux}


def train_step(params, opt_state, batch, *, cfg: ModelConfig,
               plan: ShardingPlan = NULL_PLAN,
               opt_cfg: Optional[AdamWConfig] = None, remat: bool = True,
               microbatches: int = 1, accum_dtype=jnp.float32):
    """One optimizer step.  jit this with in/out shardings from the plan.

    ``microbatches > 1`` scans gradient accumulation over batch slices
    (standard grad-accum): live activation memory scales 1/M while the token
    budget per optimizer step is unchanged.  The grad accumulator costs one
    param-sized buffer in ``accum_dtype`` (bf16 halves it; fine for <=16
    accumulation steps at LLM grad scales).
    """
    opt_cfg = opt_cfg or AdamWConfig()
    if microbatches <= 1:
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg, plan, remat=remat)
    else:
        mbs = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)

        def acc(carry, mb):
            gsum, lsum, csum, asum = carry
            (l, parts), g = jax.value_and_grad(loss_fn, has_aux=True)(
                params, mb, cfg, plan, remat=remat)
            gsum = jax.tree.map(
                lambda a, b: (a.astype(jnp.float32)
                              + b.astype(jnp.float32)).astype(a.dtype),
                gsum, g)
            return (gsum, lsum + l, csum + parts["ce"],
                    asum + parts["aux"]), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
        zero = jnp.zeros((), jnp.float32)
        (grads, loss, ce, aux), _ = jax.lax.scan(
            acc, (g0, zero, zero, zero), mbs)
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * inv), grads)
        loss, parts = loss * inv, {"ce": ce * inv, "aux": aux * inv}
    new_params, new_state, stats = adamw_update(opt_cfg, grads, opt_state,
                                                params)
    metrics = {"loss": loss, **parts, **stats}
    return new_params, new_state, metrics


def make_train_step(cfg: ModelConfig, plan: ShardingPlan = NULL_PLAN,
                    opt_cfg: Optional[AdamWConfig] = None,
                    remat: bool = True, microbatches: int = 1,
                    accum_dtype=jnp.float32):
    return functools.partial(train_step, cfg=cfg, plan=plan, opt_cfg=opt_cfg,
                             remat=remat, microbatches=microbatches,
                             accum_dtype=accum_dtype)


__all__ = ["cross_entropy", "loss_fn", "train_step", "make_train_step"]
