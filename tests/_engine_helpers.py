"""Shared test helper: build an Engine through the one public surface.

The PR 5 per-knob ``Engine(cfg, params, max_batch=...)`` kwargs are gone;
every test that wants a small hand-tuned engine now routes through
``ServeSpec(...).resolve(cfg)`` like production callers do.  The defaults
here reproduce the old engine-kwarg defaults (budget ``max_batch * chunk``)
so ported tests keep their original scheduling behavior.
"""

from repro.core.resolve import AUTO
from repro.serving.api import ServeSpec
from repro.serving.engine import Engine


def make_spec(cfg, *, max_batch=8, max_len=512, chunk=16, token_budget=0,
              kernels=AUTO, dispatch=AUTO, debug_logits=False,
              temperature=0.0, seed=0, faults=(), overload=AUTO, **kw):
    return ServeSpec(
        max_batch=max_batch, max_len=max_len, chunk=chunk,
        token_budget=token_budget or max_batch * min(chunk, max_len),
        kernels=kernels, dispatch=dispatch, debug_logits=debug_logits,
        temperature=temperature, seed=seed, faults=faults,
        overload=overload, **kw).resolve(cfg)


def make_engine(cfg, params, **knobs):
    return Engine(cfg, params, spec=make_spec(cfg, **knobs))
