"""Test-suite isolation: never read or write the developer's real autotune
persistence file (~/.cache/repro/autotune.json).  Tests that exercise the
persistent cache monkeypatch REPRO_AUTOTUNE_CACHE to a tmp path."""

import os

os.environ.setdefault("REPRO_AUTOTUNE_CACHE", "")   # "" disables persistence
