"""Subprocess script: MoE block numerical equivalence across all plans/algos
AND both dispatch modes on CPU meshes (fused RS-A2A-AG must be exact, not
approximate).

capacity runs with cf=8.0 (ample — no drops — so the distributed and local
paths see identical slot sets); dropless has no capacity to trip over and
must match the local oracle under every layout by construction."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.partitioner import make_plan
from repro.models import moe as M
from repro.models.param import init_tree


def main():
    cfg = ModelConfig(name="tiny-moe", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      n_experts=8, top_k=2, d_expert=96, n_shared_experts=1)
    key = jax.random.PRNGKey(0)
    params = init_tree(key, M.moe_spec(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64), jnp.float32)

    meshes = {
        "2x2": jax.make_mesh((2, 2), ("data", "model")),
        "2x4": jax.make_mesh((2, 4), ("data", "model")),
        "4x2": jax.make_mesh((4, 2), ("data", "model")),
        "pod2x2x2": jax.make_mesh((2, 2, 2), ("pod", "data", "model")),
    }
    cases = [("mixserve", "fused"), ("mixserve", "sync"),
             ("mixserve", "unfused"), ("dp_ep", "unfused"),
             ("pure_tp", "unfused")]
    for mode in ("capacity", "dropless"):
        out_local, _ = M.moe_local(params, x, cfg, cf=8.0, dispatch=mode)
        for mesh_name, mesh in meshes.items():
            for strat, algo in cases:
                plan = make_plan(strat, mesh, comm_algo=algo, dispatch=mode)
                out, _ = jax.jit(
                    lambda p, xx: M.moe_block(p, xx, cfg, plan, cf=8.0))(
                        params, x)
                err = float(jnp.max(jnp.abs(out - out_local)))
                print(f"{mode:8s} {mesh_name:9s} {strat:9s} {algo:8s} "
                      f"err={err:.2e}")
                assert err < 1e-4, (mode, mesh_name, strat, algo, err)

    # Mixed-batch safety under shard_map (the unified serving step's (B,
    # chunk) buffers): dropless rows must be invariant to extra pad slots
    # riding in the same batch — count-independence must survive the EP
    # counts A2A / ragged exchange / closed-form regroup, fused and unfused.
    x_mixed = x[:2, :4]                                     # (2, 4, 64)
    garbage = jax.random.normal(jax.random.PRNGKey(9), (2, 4, 64),
                                jnp.float32) * 3.0
    x_padded = jnp.concatenate([x_mixed, garbage], axis=0)  # (4, 4, 64)
    for strat, algo in [("mixserve", "fused"), ("mixserve", "unfused"),
                        ("dp_ep", "unfused")]:
        plan = make_plan(strat, meshes["2x2"], comm_algo=algo,
                         dispatch="dropless")
        fn = jax.jit(lambda p, xx, _plan=plan:
                     M.moe_block(p, xx, cfg, _plan)[0])
        real = fn(params, x_mixed)
        padded = fn(params, x_padded)[:2]
        err = float(jnp.max(jnp.abs(padded - real)))
        print(f"mixed-batch dropless {strat:9s} {algo:8s} err={err:.2e}")
        assert err < 1e-5, ("mixed", strat, algo, err)
    print("MOE_EQUIVALENCE_OK")


if __name__ == "__main__":
    main()
