"""Subprocess script: distributed-path kernel equivalence on a CPU mesh.

``moe_block`` under every plan/comm-algo with ``KernelPolicy.all_on()`` must
match (a) the same plan with kernels off and (b) the local oracle — AND the
kernelized jitted graph must actually trace topk_gate, moe_gemm and the
fused permute/unpermute kernels (ops.counters, incremented at trace time)."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.partitioner import make_plan
from repro.kernels import ops
from repro.kernels.policy import KernelPolicy
from repro.models import moe as M
from repro.models.param import init_tree

REQUIRED = ("topk_gate", "moe_gemm", "permute_tokens", "unpermute_tokens")


def main():
    cfg = ModelConfig(name="tiny-moe", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      n_experts=8, top_k=2, d_expert=96, n_shared_experts=1)
    params = init_tree(jax.random.PRNGKey(0), M.moe_spec(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64), jnp.float32)
    out_local, _ = M.moe_local(params, x, cfg, cf=8.0)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cases = [("mixserve", "fused"), ("mixserve", "unfused"),
             ("dp_ep", "unfused"), ("pure_tp", "unfused")]
    for strat, algo in cases:
        p_off = make_plan(strat, mesh, comm_algo=algo,
                          kernels=KernelPolicy.off())
        p_on = make_plan(strat, mesh, comm_algo=algo,
                         kernels=KernelPolicy.all_on())
        off, _ = jax.jit(
            lambda p, xx: M.moe_block(p, xx, cfg, p_off, cf=8.0))(params, x)
        ops.reset_counters()
        on, _ = jax.jit(
            lambda p, xx: M.moe_block(p, xx, cfg, p_on, cf=8.0))(params, x)
        missing = [k for k in REQUIRED if ops.counters[k] == 0]
        assert not missing, (strat, algo, missing, dict(ops.counters))
        err = float(jnp.max(jnp.abs(on - off)))
        err_l = float(jnp.max(jnp.abs(on - out_local)))
        print(f"{strat:9s} {algo:8s} on-vs-off={err:.2e} "
              f"vs-local={err_l:.2e} counters={dict(ops.counters)}")
        assert err < 1e-4 and err_l < 1e-4, (strat, algo, err, err_l)
    print("MOE_KERNEL_EQUIVALENCE_OK")


if __name__ == "__main__":
    main()
