"""Subprocess script: distributed-path kernel equivalence on a CPU mesh.

``moe_block`` under every plan/comm-algo with ``KernelPolicy.all_on()`` must
match (a) the same plan with kernels off and (b) the local oracle — AND the
kernelized jitted graph must actually trace the kernels (ops.counters,
incremented at trace time).  Both dispatch modes are covered: capacity
traces moe_gemm + the fused permute/unpermute pair; dropless traces
grouped_gemm + the segment-aware ragged permute + unpermute."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.partitioner import make_plan
from repro.kernels import ops
from repro.kernels.policy import KernelPolicy
from repro.models import moe as M
from repro.models.param import init_tree

REQUIRED = {
    "capacity": ("topk_gate", "moe_gemm", "permute_tokens",
                 "unpermute_tokens"),
    "dropless": ("topk_gate", "grouped_gemm", "unpermute_tokens"),
}


def required(mode: str, strat: str) -> tuple:
    req = REQUIRED[mode]
    if mode == "dropless" and strat != "pure_tp":
        # the segment-aware ragged permute only runs on the EP exchange
        # paths (pure_tp has no EP: the local gather is a plain permute)
        req = req + ("permute_tokens_ragged",)
    return req


def main():
    cfg = ModelConfig(name="tiny-moe", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      n_experts=8, top_k=2, d_expert=96, n_shared_experts=1)
    params = init_tree(jax.random.PRNGKey(0), M.moe_spec(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64), jnp.float32)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cases = [("mixserve", "fused"), ("mixserve", "unfused"),
             ("dp_ep", "unfused"), ("pure_tp", "unfused")]
    for mode in ("capacity", "dropless"):
        out_local, _ = M.moe_local(params, x, cfg, cf=8.0, dispatch=mode)
        for strat, algo in cases:
            p_off = make_plan(strat, mesh, comm_algo=algo,
                              kernels=KernelPolicy.off(), dispatch=mode)
            p_on = make_plan(strat, mesh, comm_algo=algo,
                             kernels=KernelPolicy.all_on(), dispatch=mode)
            off, _ = jax.jit(
                lambda p, xx: M.moe_block(p, xx, cfg, p_off, cf=8.0))(
                    params, x)
            ops.reset_counters()
            on, _ = jax.jit(
                lambda p, xx: M.moe_block(p, xx, cfg, p_on, cf=8.0))(
                    params, x)
            missing = [k for k in required(mode, strat)
                       if ops.counters[k] == 0]
            assert not missing, (mode, strat, algo, missing,
                                 dict(ops.counters))
            err = float(jnp.max(jnp.abs(on - off)))
            err_l = float(jnp.max(jnp.abs(on - out_local)))
            print(f"{mode:8s} {strat:9s} {algo:8s} on-vs-off={err:.2e} "
                  f"vs-local={err_l:.2e} counters={dict(ops.counters)}")
            assert err < 1e-4 and err_l < 1e-4, (mode, strat, algo, err,
                                                 err_l)

    # Regression: force tiny permute tiles (bn=8 << the 128-row exchange
    # buffers) so the ragged kernels actually elide tiles.  At default
    # block sizes one tile covers the whole buffer and elision never
    # fires — which masked a bug where the EP send gather's validity was
    # described as one contiguous prefix instead of per-destination-rank
    # prefixes, zeroing every row bound for ranks >= 1.
    from repro.kernels import autotune
    autotune.clear_cache()
    autotune.register("permute", (128, 64), jnp.float32, {"bn": 8},
                      persist=False)
    out_local, _ = M.moe_local(params, x, cfg, dispatch="dropless")
    for strat, algo in (("mixserve", "fused"), ("mixserve", "unfused"),
                        ("dp_ep", "unfused")):
        p_on = make_plan(strat, mesh, comm_algo=algo,
                         kernels=KernelPolicy.all_on(), dispatch="dropless")
        on, _ = jax.jit(
            lambda p, xx: M.moe_block(p, xx, cfg, p_on))(params, x)
        err = float(jnp.max(jnp.abs(on - out_local)))
        print(f"small-bn {strat:9s} {algo:8s} vs-local={err:.2e}")
        assert err < 1e-4, ("small-bn", strat, algo, err)
    autotune.clear_cache()
    print("MOE_KERNEL_EQUIVALENCE_OK")


if __name__ == "__main__":
    main()
