"""Subprocess script: the micro-chunked, count-bounded EP exchange is
BIT-IDENTICAL to the monolithic dropless exchange on CPU meshes.

Covers C in {1, 2, 4} x {fused, unfused} x {pure chunking (cap=worst-case),
auto cap, tight explicit cap}, the token-sliced dp_ep layout, a kernels-on
lane (interpret-mode Pallas), and an adversarial all-tokens-to-one-rank
skew that overflows a tight cap and must take the worst-case-extent
fallback (rank-uniform lax.cond) without changing a single bit."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.cost_model import EpOverlap, cap_rows_for
from repro.core.partitioner import make_plan
from repro.kernels.policy import KernelPolicy
from repro.models import moe as M
from repro.models.param import init_tree


def main():
    cfg = ModelConfig(name="tiny-moe", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      n_experts=8, top_k=2, d_expert=96, n_shared_experts=1)
    key = jax.random.PRNGKey(0)
    params = init_tree(key, M.moe_spec(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 64), jnp.float32)

    meshes = {
        "2x4": jax.make_mesh((2, 4), ("data", "model")),   # ep=2, tp=4
        "4x2": jax.make_mesh((4, 2), ("data", "model")),   # ep=4, tp=2
    }

    def run(mesh, strat, algo, ovl, p=params, xx=x, kernels=None):
        plan = make_plan(strat, mesh, comm_algo=algo, dispatch="dropless",
                         kernels=kernels, ep_overlap=ovl)
        return jax.jit(lambda pp, xv: M.moe_block(pp, xv, cfg, plan)[0])(
            p, xx)

    overlaps = [
        EpOverlap(chunks=1, cap_rows=0),    # count-bounding alone
        EpOverlap(chunks=2, cap_rows=-1),   # chunking alone (worst-case cap)
        EpOverlap(chunks=2, cap_rows=0),    # chunk + auto cap
        EpOverlap(chunks=4, cap_rows=0),
        EpOverlap(chunks=2, cap_rows=8),    # tight explicit cap
        EpOverlap(chunks=4, cap_rows=8),
    ]
    for mesh_name, mesh in meshes.items():
        for strat, algo in [("mixserve", "fused"), ("mixserve", "unfused")]:
            base = run(mesh, strat, algo, None)
            for ovl in overlaps:
                out = run(mesh, strat, algo, ovl)
                err = float(jnp.max(jnp.abs(out - base)))
                print(f"{mesh_name:5s} {strat:9s} {algo:8s} "
                      f"{ovl.describe():28s} err={err:.1e}")
                assert err == 0.0, (mesh_name, strat, algo, ovl, err)

    # token-sliced pure-EP layout (every device its own EP rank)
    base = run(meshes["2x4"], "dp_ep", "unfused", None)
    for ovl in (EpOverlap(chunks=2, cap_rows=0),
                EpOverlap(chunks=2, cap_rows=4)):
        out = run(meshes["2x4"], "dp_ep", "unfused", ovl)
        err = float(jnp.max(jnp.abs(out - base)))
        print(f"dp_ep  token-sliced {ovl.describe():28s} err={err:.1e}")
        assert err == 0.0, ("dp_ep", ovl, err)

    # kernels-on lane: interpret-mode Pallas permute/gemm under chunking
    kp = KernelPolicy.all_on()
    base = run(meshes["2x4"], "mixserve", "fused", None, kernels=kp)
    out = run(meshes["2x4"], "mixserve", "fused",
              EpOverlap(chunks=2, cap_rows=8), kernels=kp)
    err = float(jnp.max(jnp.abs(out - base)))
    print(f"kernels-on fused C=2 cap=8            err={err:.1e}")
    assert err == 0.0, ("kernels", err)

    # ---- adversarial skew: every token routes to rank 0's experts, so the
    # per-(source, dest) segment count == n_chunk >> cap -> the overflow
    # fallback must fire on every rank and stay bit-identical ----
    router_skew = jnp.zeros_like(params["router"])
    router_skew = router_skew.at[:, 0].set(10.0).at[:, 1].set(9.0)
    p_skew = {**params, "router": router_skew}
    # confirm the cap really is exceeded (fallback genuinely exercised):
    # ep=2 -> experts 0,1 live on rank 0; all t*k slots target rank 0.
    t_local, k = (x.shape[0] // 2) * x.shape[1], cfg.top_k
    n_c = t_local * k // 2                                    # C=2
    ovl = EpOverlap(chunks=2, cap_rows=8)
    assert cap_rows_for(n_c, 2, ovl) < n_c, "skew case must overflow the cap"
    for algo in ("fused", "unfused"):
        base = run(meshes["2x4"], "mixserve", algo, None, p=p_skew)
        out = run(meshes["2x4"], "mixserve", algo, ovl, p=p_skew)
        err = float(jnp.max(jnp.abs(out - base)))
        print(f"skew-overflow {algo:8s} C=2 cap=8       err={err:.1e}")
        assert err == 0.0, ("skew", algo, err)

    print("OVERLAP_EQUIVALENCE_OK")


if __name__ == "__main__":
    main()
