"""Subprocess script: full-model forward/train/decode under a sharding plan
equals the unsharded reference, on a (2 data x 2 model) CPU mesh.

Covers: dense GQA (smollm), MLA+MoE (deepseek reduced), hybrid (rgemma),
decode with kv_seq sharding over the model axis, per-slot lengths.
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.core.partitioner import make_plan
from repro.models import model as M

# Per-arch max-abs-error gates.  The default is the strict 2e-4 every arch
# held at the seed; whisper-tiny sits at ~3e-3 for a ROOT-CAUSED reason
# (investigated PR 7, see ROADMAP): the sharded encoder itself is clean
# (1.2e-5), but the reduced random-init config is intrinsically
# ill-conditioned at this seed — the UNSHARDED forward amplifies a 1e-7
# input perturbation into ~9e-2 logits at the same worst token position
# (local amplification ~1e5-1e6), so the sharded run's different fp32
# reduction order alone explains 3e-3 (greedy argmax is unaffected).
# The loose gate stays as the regression tripwire: green at the
# conditioning-limited error, red only if the sharded path regresses.
DEFAULT_TOL = 2e-4
TOLERANCES = {
    "whisper-tiny": 5e-3,    # conditioning-limited, not a sharding bug
}


def check(arch, mesh, plan_name="mixserve"):
    import dataclasses
    cfg = C.get_reduced(arch)
    if cfg.is_moe:
        # ample capacity: sharded routing computes per-DP-rank capacities, so
        # with the default factor token DROPS differ from the global oracle
        # (the paper's Fig. 6c trade-off) — equivalence needs no drops.
        # (Moot under the default dropless dispatch, but the capacity knob
        # stays pinned so a dispatch="capacity" A/B keeps passing too.)
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = M.init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    plan = make_plan(plan_name, mesh)
    b, s_pre, n_dec, max_len = 4, 16, 3, 64
    kw = {}
    if cfg.frontend == "vision_stub":
        kw["embeds"] = jax.random.normal(
            jax.random.PRNGKey(9), (b, cfg.n_frontend_tokens, cfg.d_model)
        ) * 0.02
    if cfg.frontend == "audio_stub":
        e = cfg.encoder
        kw["frames"] = jax.random.normal(
            jax.random.PRNGKey(9), (b, e.n_frames, e.d_model)) * 0.02
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s_pre + n_dec),
                                0, cfg.vocab_size)

    # ---- unsharded reference ----
    ref = M.forward(params, cfg, tokens=tokens, **kw)

    # ---- sharded train-mode forward ----
    with mesh:
        got = jax.jit(lambda p, t: M.forward(p, cfg, plan, tokens=t, **kw))(
            params, tokens)
    err_f = float(jnp.max(jnp.abs(got.logits - ref.logits)))

    # ---- sharded prefill + decode with per-slot lengths ----
    with mesh:
        cache = M.init_cache(cfg, b, max_len, jnp.float32)
        pre = jax.jit(lambda p, t, c: M.forward(p, cfg, plan, tokens=t,
                                                cache=c, **kw))(
            params, tokens[:, :s_pre], cache)
        cache = pre.cache
        # switch to per-slot vector lengths (continuous-batching form)
        cache = {**cache,
                 "length": jnp.full((b,), int(cache["length"]), jnp.int32)}
        errs = []
        dec = jax.jit(lambda p, t, c: M.forward(p, cfg, plan, tokens=t,
                                                cache=c))
        front = cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0
        for i in range(n_dec):
            out = dec(params, tokens[:, s_pre + i:s_pre + i + 1], cache)
            cache = out.cache
            errs.append(float(jnp.max(jnp.abs(
                out.logits[:, 0] - ref.logits[:, front + s_pre + i]))))
    tol = TOLERANCES.get(arch, DEFAULT_TOL)
    note = "" if tol == DEFAULT_TOL else f"  (known-loose tol={tol:g})"
    print(f"{arch:22s} fwd_err={err_f:.2e} decode_errs="
          f"{['%.1e' % e for e in errs]}{note}")
    assert err_f < tol, (arch, err_f, tol)
    assert max(errs) < tol, (arch, errs, tol)


def main():
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    for arch in ("smollm-360m", "deepseek-v2-236b", "phi3.5-moe-42b",
                 "recurrentgemma-9b", "rwkv6-1.6b", "qwen2-vl-7b",
                 "whisper-tiny", "minicpm3-4b"):
        check(arch, mesh)
    # multi-pod mini mesh on the paper-representative arch
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    check("deepseek-v2-236b", mesh3)
    check("phi3.5-moe-42b", mesh3, plan_name="dp_ep")
    print("SHARDED_MODEL_OK")


if __name__ == "__main__":
    main()
