"""Subprocess script: the trace contract on real CPU meshes.

For each strategy/algorithm/overlap the collective census of the traced
``moe_block`` must equal ``cost_model.comm_census`` exactly — and a
deliberately sabotaged block (one extra all-reduce) must FAIL, so a pass
is never vacuous."""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax

from repro.analysis import trace_contract as TC
from repro.configs.base import ModelConfig
from repro.core.cost_model import EpOverlap
from repro.core.partitioner import make_plan
from repro.models import moe as M


def main():
    cfg = ModelConfig(name="tiny-moe", family="moe", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      n_experts=8, top_k=2, d_expert=96, n_shared_experts=1)
    meshes = {
        "2x4": jax.make_mesh((2, 4), ("data", "model")),
        "4x2": jax.make_mesh((4, 2), ("data", "model")),
    }
    cases = [("mixserve", "fused"), ("mixserve", "sync"),
             ("mixserve", "unfused"), ("dp_ep", "unfused"),
             ("pure_tp", "unfused")]
    overlaps = {"mono": None, "chunks2": EpOverlap(chunks=2),
                # explicit small cap: exercises the overflow guard and the
                # conditional worst-case fallback inside lax.cond
                "cap8": EpOverlap(chunks=2, cap_rows=8)}

    n_checked = n_conditional = 0
    for mesh_name, mesh in meshes.items():
        for strat, algo in cases:
            for ovl_name, ovl in overlaps.items():
                plan = make_plan(strat, mesh, comm_algo=algo,
                                 dispatch="dropless", ep_overlap=ovl)
                tag = f"{mesh_name}/{strat}/{algo}/{ovl_name}"
                r = TC.check_moe_census(cfg, plan, name=tag)
                print(r)
                assert r.ok, r
                n_checked += 1
                if r.expected["conditional"]:
                    n_conditional += 1
    assert n_conditional > 0, \
        "no case exercised the conditional overflow fallback"

    # purity of the lowered hybrid program: no host callbacks, no
    # dynamic shapes
    plan = make_plan("mixserve", meshes["2x4"], comm_algo="fused",
                     dispatch="dropless")
    r = TC.check_moe_purity(cfg, plan)
    print(r)
    assert r.ok, r

    # negative control: one extra all-reduce smuggled into the block must
    # produce a census mismatch (the acceptance criterion's scratch test)
    orig = M.moe_block
    mesh = meshes["2x4"]

    def sabotaged(p, x, cfg, plan, **kw):
        from jax.sharding import PartitionSpec as P
        out = orig(p, x, cfg, plan, **kw)
        extra = M._shard_map(lambda y: jax.lax.psum(y, "model"), mesh=mesh,
                             in_specs=P(), out_specs=P(),
                             **M._SHARD_MAP_KW)(out[0].sum())
        return (out[0] + 0 * extra,) + tuple(out[1:])

    M.moe_block = sabotaged
    try:
        r = TC.check_moe_census(cfg, plan, name="sabotaged")
    finally:
        M.moe_block = orig
    print(r)
    assert not r.ok, "extra all-reduce was NOT detected"
    assert any("all_reduce(model)" in m for m in r.mismatches), r.mismatches

    print(f"checked={n_checked} conditional_cases={n_conditional}")
    print("TRACE_CONTRACT_OK")


if __name__ == "__main__":
    main()
