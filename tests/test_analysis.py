"""Tier-1 tests for the static-analysis subsystem (repro.analysis).

The linter's per-rule fixtures run through the real pipeline
(``lint_sources``), the shipped tree must stay clean, and the trace
contract's walker/census plumbing is exercised on a degenerate 1x1 mesh
(the multi-device census checks live in tests/sharded/run_trace_contract.py
behind the subprocess isolation rule)."""

import os
from pathlib import Path

import pytest

from repro.analysis.lint import (all_rules, lint_paths, lint_sources,
                                 self_test)

SRC = Path(__file__).resolve().parent.parent / "src"


# ---------------------------------------------------------------------------
# repro-lint: one positive + one negative fixture per rule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", all_rules(), ids=lambda r: r.id)
def test_rule_catches_bad_fixture(rule):
    found = lint_sources({f"fixture_bad_{rule.id.lower()}": rule.FIXTURE_BAD},
                         rule_ids={rule.id})
    assert found, f"{rule.id} missed its seeded violation"
    assert all(f.rule == rule.id for f in found)


@pytest.mark.parametrize("rule", all_rules(), ids=lambda r: r.id)
def test_rule_passes_good_fixture(rule):
    found = lint_sources(
        {f"fixture_good_{rule.id.lower()}": rule.FIXTURE_GOOD},
        rule_ids={rule.id})
    assert not found, [str(f) for f in found]


def test_self_test_green():
    assert self_test() == 0


# the ROADMAP incident, verbatim shape: a stable argsort inside a
# shard_map body whose consumers include an interpret-mode pallas call —
# XLA:CPU duplicated the sort into both consumers and miscompiled one copy
_HISTORICAL_ARGSORT = '''
import functools
import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map


def _moe_shard_fn(p, x, *, cfg, tp_axes, ep_axes):
    flat_e = x.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    return sorted_e


def moe_block(p, x, cfg, plan):
    fn = functools.partial(_moe_shard_fn, cfg=cfg, tp_axes=plan.tp_axes,
                           ep_axes=plan.ep_axes)
    return shard_map(fn, mesh=plan.mesh, in_specs=(None, None),
                     out_specs=None, check_rep=False)(p, x)
'''


def test_r1_historical_argsort_pattern():
    found = lint_sources({"histmod": _HISTORICAL_ARGSORT}, rule_ids={"R1"})
    assert len(found) == 1
    assert found[0].rule == "R1"
    assert "argsort" in found[0].message


def test_disable_comment_suppresses_and_no_disables_reveals():
    src = _HISTORICAL_ARGSORT.replace(
        "order = jnp.argsort(flat_e, stable=True)",
        "order = jnp.argsort(flat_e, stable=True)  # repro-lint: disable=R1")
    assert not lint_sources({"histmod": src}, rule_ids={"R1"})
    revealed = lint_sources({"histmod": src}, rule_ids={"R1"},
                            respect_disables=False)
    assert len(revealed) == 1


def test_r4_flags_missing_counter_and_oracle():
    from repro.analysis.rules.r4_kernel_contract import RULE as R4
    found = lint_sources({"kernels.ops2": R4.FIXTURE_BAD}, rule_ids={"R4"})
    msgs = " ".join(f.message for f in found)
    assert "counters" in msgs and "oracle" in msgs


def test_shipped_tree_is_clean():
    """Satellite 1, made permanent: `repro-lint src/` exits clean."""
    findings = lint_paths([SRC / "repro"])
    assert not findings, "\n".join(str(f) for f in findings)


# ---------------------------------------------------------------------------
# comm_census structure (no mesh needed)
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="tiny-moe", family="moe", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab_size=256, n_experts=8, top_k=2, d_expert=96,
                       n_shared_experts=1)


def test_census_layouts_and_counts():
    from repro.core.cost_model import Strategy, Workload, comm_census
    cfg, work = _tiny_cfg(), None
    from repro.core.cost_model import Workload
    work = Workload(batch=4, seq_len=8)

    hybrid = comm_census(cfg, Strategy(attn_tp=4, attn_dp=2, moe_tp=4,
                                       moe_ep=2, comm_algo="fused"), work)
    assert hybrid.layout == "mixserve" and hybrid.fused
    counts = hybrid.counts()   # traceable, unconditional
    assert counts[("all_to_all", "ep")] == 3     # counts + dispatch + combine
    assert counts[("all_gather", "tp")] == 2     # dispatch AG + epilogue AG
    assert counts[("reduce_scatter", "tp")] == 2  # combine RS + shared RS

    tp = comm_census(cfg, Strategy(attn_tp=8, attn_dp=1, moe_tp=8,
                                   moe_ep=1), work)
    assert tp.layout == "pure_tp"
    assert ("all_to_all", "ep") not in tp.counts()

    ep = comm_census(cfg, Strategy(attn_tp=4, attn_dp=2, moe_tp=1,
                                   moe_ep=8, comm_algo="fused"), work)
    assert ep.layout == "dp_ep" and ep.token_sliced and not ep.fused


def test_census_cap_bounded_adds_guard_and_conditional():
    from repro.core.cost_model import (EpOverlap, Strategy, Workload,
                                      comm_census)
    cfg = _tiny_cfg()
    strat = Strategy(attn_tp=4, attn_dp=2, moe_tp=4, moe_ep=2,
                     comm_algo="fused")
    work = Workload(batch=4, seq_len=8)
    census = comm_census(cfg, strat, work,
                         ep_overlap=EpOverlap(chunks=2, cap_rows=8),
                         tokens_local=16)
    assert census.cap_bounded and census.chunks == 2
    assert census.counts()[("all_reduce", "guard")] == 2   # pmax per chunk
    cond = census.counts(conditional=True)
    assert cond[("all_to_all", "ep")] == 4     # dispatch + combine, 2 chunks
    # monolithic census has neither
    mono = comm_census(cfg, strat, work)
    assert not mono.cap_bounded
    assert not mono.counts(conditional=True)
    assert ("all_reduce", "guard") not in mono.counts()


def test_census_priced_entries_feed_comm_latency():
    """comm_latency must price exactly the census's priced entries —
    the single-source-of-truth refactor (satellite 2)."""
    from repro.core.cost_model import (Strategy, Workload, comm_census,
                                      comm_latency)
    from repro.core.topology import H20_CLUSTER
    cfg = _tiny_cfg()
    work = Workload(batch=8, seq_len=16)
    s_fused = Strategy(attn_tp=4, attn_dp=2, moe_tp=4, moe_ep=2,
                       comm_algo="fused")
    s_unfused = Strategy(attn_tp=4, attn_dp=2, moe_tp=4, moe_ep=2,
                         comm_algo="unfused")
    t_fused = comm_latency(cfg, s_fused, work, H20_CLUSTER)
    t_unfused = comm_latency(cfg, s_unfused, work, H20_CLUSTER)
    assert 0 < t_fused < t_unfused   # the paper's fused < unfused ordering
    # every priced entry carries pricing inputs
    for census in (comm_census(cfg, s_fused, work),
                   comm_census(cfg, s_unfused, work)):
        for e in census.select(priced=True):
            assert e.bytes > 0 and e.degree > 1, e


# ---------------------------------------------------------------------------
# trace-contract plumbing on a 1x1 mesh (multi-device in tests/sharded/)
# ---------------------------------------------------------------------------

def test_jaxpr_census_walker_counts_and_cond():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.analysis.trace_contract import jaxpr_census
    from repro.models.moe import _SHARD_MAP_KW, _shard_map

    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def body(x):
        y = jax.lax.psum(x, "model")
        y = jax.lax.all_to_all(y, "data", 0, 0, tiled=True)
        y = jax.lax.all_gather(y, "model", axis=0, tiled=True)
        y = jax.lax.psum_scatter(y, "model", scatter_dimension=0, tiled=True)

        def hot(z):
            return z

        def fallback(z):
            return jax.lax.psum(z, "data")

        return jax.lax.cond(y.sum() > 0, hot, fallback, y)

    f = _shard_map(body, mesh=mesh, in_specs=P("data", "model"),
                   out_specs=P("data", "model"), **_SHARD_MAP_KW)
    firm, cond = jaxpr_census(jax.make_jaxpr(f)(jnp.ones((2, 2))))
    m = frozenset({"model"})
    d = frozenset({"data"})
    assert firm[("all_reduce", m)] == 1
    assert firm[("all_to_all", d)] == 1
    assert firm[("all_gather", m)] == 1
    assert firm[("reduce_scatter", m)] == 1
    assert firm[("all_reduce", d)] == 0
    assert cond[("all_reduce", d)] == 1    # only on the fallback branch


def test_check_moe_census_degenerate_mesh():
    import jax

    from repro.analysis.trace_contract import check_moe_census
    from repro.core.partitioner import make_plan

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    plan = make_plan("mixserve", mesh, comm_algo="fused",
                     dispatch="dropless")
    r = check_moe_census(_tiny_cfg(), plan)
    assert r.ok, r


def test_purity_issues_detects_callbacks_and_dynamic_shapes():
    from repro.analysis.trace_contract import purity_issues
    clean = "func.func @main(%arg0: tensor<4x8xf32>) { stablehlo.add }"
    assert not purity_issues(clean)
    cb = ('stablehlo.custom_call @xla_python_cpu_callback(%arg0) '
          '{api_version = 2} : (tensor<4xf32>) -> tensor<4xf32>')
    dyn = "func.func @main(%arg0: tensor<?x8xf32>)"
    assert purity_issues(cb) and purity_issues(dyn)


def test_compile_watch_counts_compiles():
    import jax
    import jax.numpy as jnp

    from repro.analysis.compile_watch import CompileWatch

    def step_fn_probe(x):
        return x * 2.0

    f = jax.jit(step_fn_probe)
    with CompileWatch(match="step_fn_probe") as w:
        f(jnp.ones((4,)))
        f(jnp.ones((4,)))            # cache hit: no new compile
    assert w.count == 1, w.events
    with CompileWatch(match="step_fn_probe") as w2:
        f(jnp.ones((8,)))            # new shape signature
    assert w2.count == 1


def test_check_retrace_reports():
    import jax
    import jax.numpy as jnp

    from repro.analysis.trace_contract import check_retrace

    f = jax.jit(lambda x: x + 1.0)
    r = check_retrace(lambda x: f(x),
                      [(jnp.ones((2,)),), (jnp.ones((3,)),)],
                      match="")
    assert r.ok
