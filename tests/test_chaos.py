"""Chaos suite: deterministic fault injection through the full serving stack.

Acceptance criteria of ISSUE 6 (run in tier-1 AND as the CI ``chaos`` lane
with a fixed seed):

  - under injected NaN-logit and admit-failure faults, ONLY the targeted
    requests end FAILED/SHED — every other request finishes with tokens
    identical to a fault-free run;
  - a preempted-then-resumed request's final output matches its
    uninterrupted output (covered in test_robustness; re-checked here
    under a concurrent latency fault);
  - probabilistic faults replay bit-for-bit: same (faults, seed), same
    firing steps;
  - a saturating deadline-bound workload completes with zero watchdog
    stalls and bounded deadline-miss lateness.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from _engine_helpers import make_engine, make_spec
from repro.models.model import init_params
from repro.serving.api import LLM
from repro.serving.engine import Request, RequestState
from repro.serving.faults import Fault, FaultInjector
from repro.serving.scheduler import Scheduler, tiered_workload

pytestmark = pytest.mark.chaos

KEY = jax.random.PRNGKey(0)
SEED = 7                       # the fixed chaos seed (CI lane)


@pytest.fixture(scope="module")
def smollm():
    cfg = C.get_reduced("smollm-360m")
    params = init_params(KEY, cfg, jnp.float32)
    return cfg, params


def _stream_all(cfg, params, faults=(), n=4, max_new=4):
    spec = make_spec(cfg, max_batch=2, max_len=64, chunk=4, faults=faults,
                     seed=SEED)
    llm = LLM(cfg, params, spec)
    rids = [llm.submit(np.arange(4 + i, dtype=np.int32), max_new)
            for i in range(n)]
    got = {r: [] for r in rids}
    for rid, tok in llm.stream():
        got[rid].append(tok)
    return got, llm


def test_nan_fault_quarantines_only_target(smollm):
    """A NaN-logits fault on one request FAILs exactly that request; every
    other request's tokens are identical to the fault-free run."""
    cfg, params = smollm
    clean, _ = _stream_all(cfg, params)
    fault = Fault(kind="nan", rid=1, at=(2,))
    faulty, llm = _stream_all(cfg, params, faults=(fault,))
    assert len(faulty[1]) < len(clean[1])        # target cut short
    for rid in clean:
        if rid != 1:
            assert faulty[rid] == clean[rid], rid
    assert llm.engine.events["fault"] == 1
    assert llm.engine.faults.log == [(2, "nan", "slots=[1]")]
    assert llm.engine.n_active == 0


def test_admit_fault_sheds_only_target(smollm):
    """An injected admission failure sheds exactly the targeted request;
    the rest complete with unchanged tokens."""
    cfg, params = smollm
    clean, _ = _stream_all(cfg, params)
    fault = Fault(kind="admit", rid=2, every=1, n_max=1)
    shed, llm = _stream_all(cfg, params, faults=(fault,))
    assert shed[2] == []
    for rid in clean:
        if rid != 2:
            assert shed[rid] == clean[rid], rid
    assert llm.engine.n_active == 0


def test_nan_fault_scheduler_path_marks_failed(smollm):
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=2, max_len=64, chunk=4,
                      faults=(Fault(kind="nan", rid=0, at=(2,)),), seed=SEED)
    sched = Scheduler(eng)
    reqs = [Request(rid=i, prompt=np.arange(5 + i, dtype=np.int32),
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    assert reqs[0].state == RequestState.FAILED
    assert "non-finite" in reqs[0].error
    assert {r.rid for r in done} == {1, 2}
    assert sched.failed == [reqs[0]]
    m = sched.metrics()
    assert m.n_faults == 1 and m.n_incomplete == 0


def test_probabilistic_faults_replay_bit_for_bit():
    """Same (faults, seed) -> the same firing steps, independent of wall
    time or call interleaving (counter-based RNG)."""
    faults = (Fault(kind="latency", p=0.3, ms=0.01),
              Fault(kind="nan", p=0.2, rid=0),
              Fault(kind="clock_skew", p=0.1, ms=1.0))

    def replay():
        inj = FaultInjector(faults, seed=SEED)
        for step in range(50):
            inj.step_latency_s(step)
            inj.nan_slots(step, {0: 0, 1: 1})
            inj.advance_clock(step)
        return inj.log

    a, b = replay(), replay()
    assert a == b and len(a) > 0
    # a different seed fires a different schedule
    inj2 = FaultInjector(faults, seed=SEED + 1)
    for step in range(50):
        inj2.step_latency_s(step)
        inj2.nan_slots(step, {0: 0, 1: 1})
        inj2.advance_clock(step)
    assert inj2.log != a


def test_latency_fault_slows_exactly_the_targeted_step(smollm):
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=1, max_len=64, chunk=4,
                      faults=(Fault(kind="latency", at=(3,), ms=80.0),),
                      seed=SEED)
    req = Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                  max_new_tokens=8)
    assert eng.admit(req)
    eng.step()                                   # step 0: compile + prefill
    times = []
    while not req.done:
        t0 = time.perf_counter()
        eng.step()
        times.append(time.perf_counter() - t0)
    assert eng.faults.log == [(3, "latency", "latency(at=[3] ms=80)")]
    spiked = times[2]                            # engine step index 3
    others = times[:2] + times[3:]
    assert spiked >= 0.08
    assert spiked > 4 * max(others)


def test_clock_skew_fault_expires_deadlines_early(smollm):
    """A +10s clock jump makes the scheduler see every deadline as expired
    — the workload degrades to deadline-miss shedding, not a hang."""
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=1, max_len=64, chunk=4,
                      faults=(Fault(kind="clock_skew", at=(1,), ms=10_000.0),),
                      seed=SEED)
    sched = Scheduler(eng)
    reqs = [Request(rid=i, prompt=np.arange(5, dtype=np.int32),
                    max_new_tokens=4, deadline_s=5.0, arrival=0.0)
            for i in range(3)]
    for r in reqs:
        sched.submit(r)
    sched.run()                                  # must terminate
    assert eng.faults.skew_s == 10.0
    m = sched.metrics()
    # every request hit the (skewed) deadline wall
    assert m.n_deadline_miss + m.n_requests == 3
    assert m.n_deadline_miss >= 1
    assert m.n_incomplete == 0


def test_preempt_resume_exact_under_latency_fault(smollm):
    """Recompute-on-resume stays bit-exact even with a straggler fault
    firing during the resumed run."""
    cfg, params = smollm
    spec = make_spec(cfg, max_batch=1, max_len=64, chunk=4)
    llm = LLM(cfg, params, spec)
    base = llm.generate([np.arange(5, dtype=np.int32)], max_new_tokens=6)[0]

    eng = make_engine(cfg, params, max_batch=1, max_len=64, chunk=4,
                      faults=(Fault(kind="latency", every=3, ms=5.0),),
                      seed=SEED)
    req = Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                  max_new_tokens=6)
    assert eng.admit(req)
    for _ in range(4):
        eng.step()
    eng.preempt(0)
    assert eng.admit(req)
    while not req.done:
        eng.step()
    assert req.out_tokens == base


def test_saturating_deadline_workload_zero_stalls(smollm):
    """Acceptance: a saturating two-tier deadline workload completes with
    zero watchdog stalls, every request reaching a terminal state, and
    deadline-miss lateness bounded (kills land within a step or two of
    expiry, not whole requests late)."""
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=2, max_len=64, chunk=4)
    # warm the jitted step so lateness measures steps, not compilation
    warm = Scheduler(eng)
    warm.submit(Request(rid=999, prompt=np.arange(5, dtype=np.int32),
                        max_new_tokens=2))
    warm.run()

    sched = Scheduler(eng, watchdog_steps=64)
    reqs = list(tiered_workload(12, prompt_len=12, max_new_tokens=6,
                                vocab=cfg.vocab_size, arrival_rate=500.0,
                                seed=SEED, hi_every=3, hi_priority=5,
                                hi_deadline_s=0.25))
    for r in reqs:
        sched.submit(r)
    done = sched.run()                  # raises StalledEngineError on stall
    assert all(r.terminal for r in reqs)
    m = sched.metrics()
    assert m.n_incomplete == 0
    assert len(done) + m.n_deadline_miss + m.n_shed >= len(reqs) - m.n_faults
    # lateness bound: a deadline kill lands within ~one engine iteration of
    # expiry (the loop checks deadlines every step); allow generous CPU
    # scheduling noise but far less than a whole request's service time
    assert m.deadline_miss_p99 < 0.25


def test_latency_fault_with_ep_overlap_keeps_moe_observability():
    """Chaos lane x the micro-chunked EP exchange: a MoE engine resolved
    with ``ep_overlap`` pinned serves through an injected straggler fault
    and still reports coherent expert-load skew + A2A-ledger counters
    (the overlap plumbing must not disturb fault accounting, and vice
    versa)."""
    cfg = C.get_reduced("phi3.5-moe-42b")
    params = init_params(KEY, cfg, jnp.float32)
    eng = make_engine(cfg, params, max_batch=2, max_len=64, chunk=4,
                      faults=(Fault(kind="latency", at=(2,), ms=10.0),),
                      seed=SEED, ep_overlap=2)
    assert eng.spec.ep_overlap is not None
    assert eng.spec.ep_overlap.chunks == 2
    sched = Scheduler(eng)
    reqs = [Request(rid=i, prompt=np.arange(6 + i, dtype=np.int32),
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    assert {r.rid for r in done} == {0, 1, 2}
    assert eng.faults.log == [(2, "latency", "latency(at=[2] ms=10)")]
    m = sched.metrics()
    # expert-load skew: every routed slot landed on some EP rank bucket
    assert m.ep_rank_max_tokens >= m.ep_rank_mean_tokens > 0
    total = int(eng.expert_counts.sum())
    ep = max(1, eng.spec.moe_ep) if cfg.n_experts % max(1, eng.spec.moe_ep) == 0 \
        else 1
    assert m.ep_rank_mean_tokens * ep == pytest.approx(total)
    # the ledger priced the exchange and never exceeds worst case
    if eng.spec.moe_ep > 1:
        assert 0 < m.a2a_bytes_moved <= m.a2a_bytes_worst
    for k in ("ep_rank_max_tokens", "a2a_bytes_moved", "a2a_bytes_worst"):
        assert k in m.robustness()
