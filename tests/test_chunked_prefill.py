"""Chunked (Sarathi-style) prefill equals one-shot prefill for every family.

Chunk i must attend to the cache of chunks 0..i: this exercises the
cache-continuation paths (GQA buffers, MLA latent re-expansion, local-attn
ring carry, recurrent state carry)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["smollm-360m", "deepseek-v2-236b",
                                  "phi3.5-moe-42b", "rwkv6-1.6b",
                                  "recurrentgemma-9b", "minicpm3-4b"])
def test_chunked_prefill_matches_oneshot(arch):
    cfg = C.get_reduced(arch)
    if cfg.family == "hybrid":
        # chunk >= window required for the ring rebuild
        cfg = dataclasses.replace(cfg, window_size=8)
    if cfg.is_moe:
        # ample capacity: chunked routing computes per-chunk capacities, so
        # with the default factor token DROPS differ from one-shot prefill
        # (the same Fig. 6c trade-off as sharded-vs-oracle routing)
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    params = M.init_params(KEY, cfg, jnp.float32)
    b, s, n_chunks, max_len = 2, 32, 2, 64
    tokens = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)

    one = M.forward(params, cfg, tokens=tokens,
                    cache=M.init_cache(cfg, b, max_len, jnp.float32))

    cache = M.init_cache(cfg, b, max_len, jnp.float32)
    chunk = s // n_chunks
    outs = []
    for i in range(n_chunks):
        o = M.forward(params, cfg,
                      tokens=tokens[:, i * chunk:(i + 1) * chunk],
                      cache=cache)
        cache = o.cache
        outs.append(o.logits)
    got = jnp.concatenate(outs, axis=1)
    err = float(jnp.max(jnp.abs(got - one.logits)))
    assert err < 2e-4, (arch, err)
    # and decode continues correctly off the chunked cache
    nxt = jax.random.randint(KEY, (b, 1), 0, cfg.vocab_size)
    d_chunked = M.forward(params, cfg, tokens=nxt, cache=cache)
    d_oneshot = M.forward(params, cfg, tokens=nxt, cache=one.cache)
    err_d = float(jnp.max(jnp.abs(d_chunked.logits - d_oneshot.logits)))
    assert err_d < 2e-4, (arch, err_d)
