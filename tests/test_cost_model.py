"""Unit tests for the theoretical cost model (paper Eqs. 1-13)."""

import math

import pytest

from repro.configs import get
from repro.core import cost_model as cm
from repro.core.topology import (ASCEND_910B_CLUSTER, H20_CLUSTER,
                                 TPU_V5E_POD, pow2_divisors)


def test_rs_ag_symmetry_eq1():
    # Eq. 1: RS(size, d) == AG(size, d)
    assert cm.rs_cost(1e6, 8, 1e9, 0) == cm.ag_cost(1e6, 8, 1e9, 0)


def test_ar_decomposition_eq2():
    # Eq. 2: AR = RS + AG
    ar = cm.ar_cost(1e6, 8, 1e9, 1e-6)
    assert ar == pytest.approx(cm.rs_cost(1e6, 8, 1e9, 1e-6)
                               + cm.ag_cost(1e6, 8, 1e9, 1e-6))


def test_a2a_pairwise_scaling_eq3():
    # Eq. 3: A2A ∝ (size/d) * (d-1); degree 1 is free
    assert cm.a2a_cost(1e6, 1, 1e9, 0) == 0.0
    t2 = cm.a2a_cost(1e6, 2, 1e9, 0)
    t8 = cm.a2a_cost(1e6, 8, 1e9, 0)
    assert t2 == pytest.approx(1e6 / 2 * 1 / 1e9)
    assert t8 == pytest.approx(1e6 / 8 * 7 / 1e9)


def test_collectives_monotone_in_size():
    for f in (cm.rs_cost, cm.ag_cost, cm.ar_cost, cm.a2a_cost):
        assert f(2e6, 4, 1e9, 1e-6) > f(1e6, 4, 1e9, 1e-6)


def test_fig3_tp_worse_than_ep_at_d32():
    """The paper's Fig. 3 observation: AR-based TP loses to A2A-based EP when
    the communication group spans nodes (d=32 on the 910B cluster)."""
    model = get("deepseek-v2-236b")
    cl = ASCEND_910B_CLUSTER
    work = cm.Workload(batch=16, seq_len=1024)
    size = work.batch * work.seq_len * model.d_model * cm.BYTES
    # d=32 spans 4 nodes -> AR rides inter-node links
    bw, a = cm.tp_link(cl, 32)
    ar32 = cm.ar_cost(size, 32, bw, a)
    a2a32 = cm.a2a_cost(size * model.top_k, 32, cl.bw(True), cl.latency(True))
    assert bw == cl.inter_node_bw        # TP at d=32 is inter-node
    assert ar32 > cm.ar_cost(size, 8, cl.intra_node_bw, cl.intra_node_latency)


def test_eq13_beats_eq12_on_910b():
    """The hybrid TP-EP (Eq. 13) must beat pure EP (Eq. 12) on the paper's
    clusters: the inter-node A2A volume drops by 1/n_proc."""
    model = get("deepseek-v2-236b")
    for cl in (ASCEND_910B_CLUSTER, H20_CLUSTER):
        work = cm.Workload(batch=16, seq_len=1024)
        pure = cm.Strategy(attn_tp=cl.n_proc, attn_dp=cl.n_node,
                           moe_tp=1, moe_ep=cl.n_devices,
                           comm_algo="unfused", ep_inter_node=True)
        mix = cm.Strategy(attn_tp=cl.n_proc, attn_dp=cl.n_node,
                          moe_tp=cl.n_proc, moe_ep=cl.n_node,
                          comm_algo="fused", ep_inter_node=True)
        lam_pure = cm.comm_latency(model, pure, work, cl)
        lam_mix = cm.comm_latency(model, mix, work, cl)
        assert lam_mix < lam_pure, (cl.name, lam_mix, lam_pure)


def test_fused_beats_sync_beats_unfused():
    """Fig. 12 ablation ordering: fused (overlapped) < sync < unfused."""
    model = get("deepseek-v2-236b")
    cl = ASCEND_910B_CLUSTER
    work = cm.Workload(batch=16, seq_len=1024)
    def lam(algo):
        s = cm.Strategy(attn_tp=8, attn_dp=4, moe_tp=8, moe_ep=4,
                        comm_algo=algo, ep_inter_node=True)
        return cm.comm_latency(model, s, work, cl)
    assert lam("fused") < lam("sync")
    assert lam("sync") < lam("unfused")


def test_queuing_delay_eq7():
    # Eq. 7: W_q = rho / (mu (1 - rho)); unstable -> inf
    svc = 0.01
    assert cm.queuing_delay(svc, 0.0) == 0.0
    w = cm.queuing_delay(svc, 50.0)       # mu=100, rho=0.5 -> 0.01
    assert w == pytest.approx(50.0 / (100.0 * 50.0))
    assert math.isinf(cm.queuing_delay(svc, 100.0))
    assert math.isinf(cm.queuing_delay(svc, 200.0))


def test_ttft_itl_eq9_eq10():
    model = get("phi3.5-moe-42b")
    strat = cm.Strategy(attn_tp=8, attn_dp=2, moe_tp=8, moe_ep=2)
    ind = cm.indicators(model, strat, H20_CLUSTER, batch=16, l_in=1024,
                        l_out=128)
    # prefill processes 1024x the tokens of one decode step
    assert ind.ttft > ind.itl
    assert ind.throughput > 0
    assert ind.stable


def test_memory_constraint_eq8():
    model = get("deepseek-v2-236b")
    tiny = cm.Strategy(attn_tp=1, attn_dp=1, moe_tp=1, moe_ep=1)
    big = cm.Strategy(attn_tp=16, attn_dp=16, moe_tp=16, moe_ep=16)
    # 236B params cannot fit one 16GB chip; must fit 256 chips
    assert not cm.fits_memory(model, tiny, TPU_V5E_POD, batch=1, seq_len=128)
    m = cm.memory_per_device(model, big, batch=32, seq_len=4096)
    assert m < cm.memory_per_device(model, tiny, batch=32, seq_len=4096)


def test_memory_sharding_consistency():
    """Total memory across devices >= unsharded footprint (replication only
    ever adds)."""
    model = get("phi3.5-moe-42b")
    base = cm.memory_per_device(
        model, cm.Strategy(), batch=8, seq_len=1024)
    strat = cm.Strategy(attn_tp=4, attn_dp=4, moe_tp=4, moe_ep=4)
    sharded = cm.memory_per_device(model, strat, batch=8, seq_len=1024)
    assert sharded * 16 >= base * 0.99


def test_pow2_divisors():
    assert pow2_divisors(16) == [1, 2, 4, 8, 16]
    assert pow2_divisors(12) == [1, 2, 4]


def test_strategy_validation():
    with pytest.raises(ValueError):
        cm.Strategy(attn_tp=4, attn_dp=2, moe_tp=2, moe_ep=2).validate()
    with pytest.raises(ValueError):
        cm.Strategy(attn_tp=3, attn_dp=1, moe_tp=3, moe_ep=1).validate()
    cm.Strategy(attn_tp=4, attn_dp=4, moe_tp=2, moe_ep=8).validate()
