"""Property-based tests (hypothesis) on the cost model's invariants."""

import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't die at collection
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get
from repro.core import cost_model as cm
from repro.core.topology import ASCEND_910B_CLUSTER, H20_CLUSTER

POW2 = st.sampled_from([1, 2, 4, 8, 16, 32])
SIZE = st.floats(1e3, 1e10)
BW = st.floats(1e8, 1e12)


@settings(max_examples=50, deadline=None)
@given(size=SIZE, d=POW2, bw=BW)
def test_collectives_nonnegative_and_free_at_degree_1(size, d, bw):
    for f in (cm.rs_cost, cm.ag_cost, cm.ar_cost, cm.a2a_cost):
        t = f(size, d, bw, 1e-6)
        assert t >= 0.0
        assert f(size, 1, bw, 1e-6) == 0.0


@settings(max_examples=50, deadline=None)
@given(size=SIZE, d=POW2, bw=BW)
def test_collectives_monotone_in_size_and_bandwidth(size, d, bw):
    for f in (cm.rs_cost, cm.ar_cost, cm.a2a_cost):
        assert f(2 * size, d, bw, 0.0) >= f(size, d, bw, 0.0)
        assert f(size, d, 2 * bw, 0.0) <= f(size, d, bw, 0.0)


@settings(max_examples=30, deadline=None)
@given(b=st.integers(1, 64), s=st.integers(1, 4096))
def test_service_latency_monotone_in_tokens(b, s):
    model = get("phi3.5-moe-42b")
    strat = cm.Strategy(attn_tp=8, attn_dp=2, moe_tp=8, moe_ep=2)
    t1 = cm.service_latency(model, strat,
                            cm.Workload(batch=b, seq_len=s), H20_CLUSTER)
    t2 = cm.service_latency(model, strat,
                            cm.Workload(batch=b, seq_len=2 * s), H20_CLUSTER)
    assert t2 >= t1 > 0


@settings(max_examples=30, deadline=None)
@given(rate=st.floats(0.0, 1e4), svc=st.floats(1e-4, 1.0))
def test_queuing_delay_monotone_in_rate(rate, svc):
    w1 = cm.queuing_delay(svc, rate)
    w2 = cm.queuing_delay(svc, rate * 1.5 + 1e-6)
    assert w2 >= w1 >= 0.0


@settings(max_examples=20, deadline=None)
@given(batch=st.integers(1, 64), l_in=st.integers(16, 4096),
       l_out=st.integers(1, 512))
def test_indicators_internally_consistent(batch, l_in, l_out):
    model = get("deepseek-v2-236b")
    strat = cm.Strategy(attn_tp=8, attn_dp=4, moe_tp=8, moe_ep=4)
    ind = cm.indicators(model, strat, ASCEND_910B_CLUSTER, batch=batch,
                        l_in=l_in, l_out=l_out)
    assert ind.ttft >= 0 and ind.itl > 0 and ind.throughput > 0
    # TTFT includes the whole prefill; must exceed one decode step's latency
    # whenever the prompt is at least a decode step's worth of work
    assert ind.ttft >= ind.w_q
    # throughput can never exceed tokens/itl at perfect prefill
    assert ind.throughput <= batch * (l_in + l_out) / (l_out * ind.itl) + 1e-6


@settings(max_examples=30, deadline=None)
@given(b=st.integers(1, 32), s=st.integers(16, 2048))
def test_memory_monotone_in_batch_and_seq(b, s):
    model = get("minitron-8b")
    strat = cm.Strategy(attn_tp=8, attn_dp=2, moe_tp=8, moe_ep=2)
    m1 = cm.memory_per_device(model, strat, batch=b, seq_len=s)
    m2 = cm.memory_per_device(model, strat, batch=b + 1, seq_len=s)
    m3 = cm.memory_per_device(model, strat, batch=b, seq_len=s * 2)
    assert m2 >= m1 and m3 >= m1


@settings(max_examples=25, deadline=None)
@given(d1=st.sampled_from([2, 4, 8]), d2=st.sampled_from([2, 4, 8]))
def test_sharding_reduces_memory(d1, d2):
    model = get("phi3.5-moe-42b")
    small = cm.Strategy(attn_tp=d1, attn_dp=d2, moe_tp=d1, moe_ep=d2)
    base = cm.Strategy()
    assert cm.memory_per_device(model, small, batch=8, seq_len=512) <= \
        cm.memory_per_device(model, base, batch=8, seq_len=512)
