"""Prefill + step-by-step decode must reproduce the full-forward logits for
every architecture family (KV caches, latent caches, ring buffers, recurrent
state are all exercised)."""

import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = C.get_reduced(arch)
    params = M.init_params(KEY, cfg, jnp.float32)
    b, s_pre, n_dec, max_len = 2, 16, 4, 64
    kw = {}
    if cfg.frontend == "vision_stub":
        kw["embeds"] = jax.random.normal(
            KEY, (b, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    if cfg.frontend == "audio_stub":
        e = cfg.encoder
        kw["frames"] = jax.random.normal(KEY, (b, e.n_frames, e.d_model)) * 0.02
    tokens = jax.random.randint(KEY, (b, s_pre + n_dec), 0, cfg.vocab_size)

    full = M.forward(params, cfg, tokens=tokens, **kw)

    cache = M.init_cache(cfg, b, max_len, jnp.float32)
    pre = M.forward(params, cfg, tokens=tokens[:, :s_pre], cache=cache, **kw)
    cache = pre.cache
    front = cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0

    err_p = float(jnp.max(jnp.abs(
        pre.logits - full.logits[:, :front + s_pre])))
    assert err_p < 2e-4, (arch, err_p)

    for i in range(n_dec):
        out = M.forward(params, cfg,
                        tokens=tokens[:, s_pre + i:s_pre + i + 1],
                        cache=cache)
        cache = out.cache
        err = float(jnp.max(jnp.abs(
            out.logits[:, 0] - full.logits[:, front + s_pre + i])))
        assert err < 2e-4, (arch, i, err)


def test_ring_buffer_wraps_correctly():
    """Decode far past the window: ring overwrites oldest slots and logits
    keep matching the full forward (window masks identically)."""
    cfg = C.get_reduced("recurrentgemma-9b")
    # tiny window so the ring wraps during the test
    import dataclasses
    cfg = dataclasses.replace(cfg, window_size=8)
    params = M.init_params(KEY, cfg, jnp.float32)
    b, s_pre, n_dec = 1, 6, 12       # crosses the window twice
    tokens = jax.random.randint(KEY, (b, s_pre + n_dec), 0, cfg.vocab_size)
    full = M.forward(params, cfg, tokens=tokens)
    cache = M.init_cache(cfg, b, 64, jnp.float32)
    out = M.forward(params, cfg, tokens=tokens[:, :s_pre], cache=cache)
    cache = out.cache
    for i in range(n_dec):
        out = M.forward(params, cfg,
                        tokens=tokens[:, s_pre + i:s_pre + i + 1],
                        cache=cache)
        cache = out.cache
        err = float(jnp.max(jnp.abs(out.logits[:, 0]
                                    - full.logits[:, s_pre + i])))
        assert err < 2e-4, (i, err)


@pytest.mark.parametrize("arch", ["smollm-360m", "minicpm3-4b"])
def test_decode_with_flash_decode_kernel_matches_jnp(arch):
    """KernelPolicy(flash_decode) on vs off: identical decode logits through
    the full model forward (GQA and MLA-absorbed decode paths), with the
    kernel asserted traced."""
    import dataclasses

    from repro.core.partitioner import NULL_PLAN
    from repro.kernels import ops
    from repro.kernels.policy import KernelPolicy

    cfg = C.get_reduced(arch)
    params = M.init_params(KEY, cfg, jnp.float32)
    toks = jax.random.randint(KEY, (2, 10), 0, cfg.vocab_size)
    cache = M.init_cache(cfg, 2, 32, jnp.float32)
    pre = M.forward(params, cfg, tokens=toks[:, :8], cache=cache)
    plan_k = dataclasses.replace(NULL_PLAN,
                                 kernels=KernelPolicy(flash_decode=True))
    c_off, c_on = pre.cache, pre.cache
    for i in range(2):
        off = M.forward(params, cfg, tokens=toks[:, 8 + i:9 + i], cache=c_off)
        ops.reset_counters()
        on = M.forward(params, cfg, plan_k, tokens=toks[:, 8 + i:9 + i],
                       cache=c_on)
        assert ops.counters["flash_decode"] > 0, dict(ops.counters)
        c_off, c_on = off.cache, on.cache
        err = float(jnp.max(jnp.abs(on.logits - off.logits)))
        assert err < 2e-4, (arch, i, err)


def test_engine_decode_with_flash_decode_kernel():
    """Continuous-batching engine with flash_decode enabled generates the
    exact same tokens as the jnp decode path, and the jitted step contains
    the kernel.  chunk=1 makes the unified program decode-shaped (sq == 1),
    which is the flash_decode specialization — prefill then streams one
    token per iteration through the same program."""
    import numpy as np

    from _engine_helpers import make_engine
    from repro.kernels import ops
    from repro.kernels.policy import KernelPolicy
    from repro.serving.engine import Request

    cfg = C.get_reduced("smollm-360m")
    params = M.init_params(KEY, cfg, jnp.float32)
    prompts = [np.asarray([3, 1, 4, 1, 5, 9, 2, 6], np.int32),
               np.asarray([2, 7, 1, 8, 2, 8], np.int32)]

    def run_collect(policy):
        eng = make_engine(cfg, params, max_batch=2, max_len=64,
                          kernels=policy, chunk=1)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]
        for r in reqs:
            assert eng.admit(r)
        while eng.n_active:
            eng.step()
        return [r.out_tokens for r in reqs]

    base = run_collect(KernelPolicy.off())
    ops.reset_counters()
    kern = run_collect(KernelPolicy(flash_decode=True))
    assert ops.counters["flash_decode"] > 0, dict(ops.counters)
    assert kern == base


def test_decode_attention_idle_slot_rows_finite():
    """kv_len == 0 (idle slots of the unified mixed step) through the flash
    path: no caller-side length floor anymore — the kernel masks len==0
    natively, idle rows are garbage-but-finite (exact zeros) and discarded,
    and live slots are untouched by their idle neighbours."""
    from repro.kernels.policy import KernelPolicy
    from repro.models.layers import decode_attention

    b, nq, nkv, hd, s = 3, 8, 4, 32, 64
    q = jax.random.normal(KEY, (b, 1, nq, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hd))
    lens = jnp.asarray([0, 17, 64], jnp.int32)          # slot 0 is idle
    pol = KernelPolicy(flash_decode=True)
    out = decode_attention(q, k, v, kv_len=lens,
                           q_positions=(jnp.maximum(lens, 1) - 1)[:, None],
                           policy=pol)
    assert bool(jnp.isfinite(out).all())
    assert float(jnp.max(jnp.abs(out[0]))) == 0.0       # discarded row
    # live slots: identical to the jnp body computed without the idle slot
    want = decode_attention(q[1:], k[1:], v[1:], kv_len=lens[1:],
                            q_positions=(lens[1:] - 1)[:, None])
    err = float(jnp.max(jnp.abs(out[1:] - want)))
    assert err < 1e-4, err


def test_engine_respects_spec_kernel_policy():
    """An explicit KernelPolicy on the spec survives resolution onto the
    engine's plan — not clobbered by auto(); an explicit "off" disables."""
    from _engine_helpers import make_engine
    from repro.kernels.policy import KernelPolicy

    cfg = C.get_reduced("smollm-360m")
    params = M.init_params(KEY, cfg, jnp.float32)
    eng = make_engine(cfg, params, max_batch=1, max_len=32,
                      kernels=KernelPolicy.all_on())
    assert eng.plan.kernels == KernelPolicy.all_on()
    assert eng.spec.provenance["kernels"] == "explicit"
    eng2 = make_engine(cfg, params, max_batch=1, max_len=32, kernels="off")
    assert eng2.plan.kernels == KernelPolicy.off()


def test_per_slot_vector_lengths_decode():
    """Vector cache lengths: staggered slots decode exactly like uniform."""
    cfg = C.get_reduced("smollm-360m")
    params = M.init_params(KEY, cfg, jnp.float32)
    max_len = 64
    toks = jax.random.randint(KEY, (1, 12), 0, cfg.vocab_size)

    # reference: single-request prefill+decode
    c1 = M.init_cache(cfg, 1, max_len, jnp.float32)
    o1 = M.forward(params, cfg, tokens=toks[:, :8], cache=c1)
    ref = M.forward(params, cfg, tokens=toks[:, 8:9], cache=o1.cache)

    # batched cache: slot 0 holds the same request, slot 1 holds noise of a
    # DIFFERENT length; per-slot lengths isolate them
    c2 = M.init_cache(cfg, 2, max_len, jnp.float32)
    big = jax.tree.map(
        lambda a: (jnp.concatenate([a, a], axis=1)
                   if a.ndim >= 2 and a.shape[1] == 1 else a),
        o1.cache)
    noise = jax.random.randint(jax.random.PRNGKey(7), (1, 5), 0,
                               cfg.vocab_size)
    o_noise = M.forward(params, cfg, tokens=noise,
                        cache=M.init_cache(cfg, 1, max_len, jnp.float32))
    big = jax.tree.map(
        lambda a, nz: a.at[:, 1:2].set(nz) if a.ndim >= 2 else a,
        big, jax.tree.map(lambda x: x, o_noise.cache))
    big = {**big, "length": jnp.asarray([8, 5], jnp.int32)}
    out = M.forward(params, cfg,
                    tokens=jnp.concatenate([toks[:, 8:9], noise[:, -1:]]),
                    cache=big)
    err = float(jnp.max(jnp.abs(out.logits[0] - ref.logits[0])))
    assert err < 2e-4, err
