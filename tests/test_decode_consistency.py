"""Prefill + step-by-step decode must reproduce the full-forward logits for
every architecture family (KV caches, latent caches, ring buffers, recurrent
state are all exercised)."""

import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = C.get_reduced(arch)
    params = M.init_params(KEY, cfg, jnp.float32)
    b, s_pre, n_dec, max_len = 2, 16, 4, 64
    kw = {}
    if cfg.frontend == "vision_stub":
        kw["embeds"] = jax.random.normal(
            KEY, (b, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    if cfg.frontend == "audio_stub":
        e = cfg.encoder
        kw["frames"] = jax.random.normal(KEY, (b, e.n_frames, e.d_model)) * 0.02
    tokens = jax.random.randint(KEY, (b, s_pre + n_dec), 0, cfg.vocab_size)

    full = M.forward(params, cfg, tokens=tokens, **kw)

    cache = M.init_cache(cfg, b, max_len, jnp.float32)
    pre = M.forward(params, cfg, tokens=tokens[:, :s_pre], cache=cache, **kw)
    cache = pre.cache
    front = cfg.n_frontend_tokens if cfg.frontend == "vision_stub" else 0

    err_p = float(jnp.max(jnp.abs(
        pre.logits - full.logits[:, :front + s_pre])))
    assert err_p < 2e-4, (arch, err_p)

    for i in range(n_dec):
        out = M.forward(params, cfg,
                        tokens=tokens[:, s_pre + i:s_pre + i + 1],
                        cache=cache)
        cache = out.cache
        err = float(jnp.max(jnp.abs(
            out.logits[:, 0] - full.logits[:, front + s_pre + i])))
        assert err < 2e-4, (arch, i, err)


def test_ring_buffer_wraps_correctly():
    """Decode far past the window: ring overwrites oldest slots and logits
    keep matching the full forward (window masks identically)."""
    cfg = C.get_reduced("recurrentgemma-9b")
    # tiny window so the ring wraps during the test
    import dataclasses
    cfg = dataclasses.replace(cfg, window_size=8)
    params = M.init_params(KEY, cfg, jnp.float32)
    b, s_pre, n_dec = 1, 6, 12       # crosses the window twice
    tokens = jax.random.randint(KEY, (b, s_pre + n_dec), 0, cfg.vocab_size)
    full = M.forward(params, cfg, tokens=tokens)
    cache = M.init_cache(cfg, b, 64, jnp.float32)
    out = M.forward(params, cfg, tokens=tokens[:, :s_pre], cache=cache)
    cache = out.cache
    for i in range(n_dec):
        out = M.forward(params, cfg,
                        tokens=tokens[:, s_pre + i:s_pre + i + 1],
                        cache=cache)
        cache = out.cache
        err = float(jnp.max(jnp.abs(out.logits[:, 0]
                                    - full.logits[:, s_pre + i])))
        assert err < 2e-4, (i, err)


def test_per_slot_vector_lengths_decode():
    """Vector cache lengths: staggered slots decode exactly like uniform."""
    cfg = C.get_reduced("smollm-360m")
    params = M.init_params(KEY, cfg, jnp.float32)
    max_len = 64
    toks = jax.random.randint(KEY, (1, 12), 0, cfg.vocab_size)

    # reference: single-request prefill+decode
    c1 = M.init_cache(cfg, 1, max_len, jnp.float32)
    o1 = M.forward(params, cfg, tokens=toks[:, :8], cache=c1)
    ref = M.forward(params, cfg, tokens=toks[:, 8:9], cache=o1.cache)

    # batched cache: slot 0 holds the same request, slot 1 holds noise of a
    # DIFFERENT length; per-slot lengths isolate them
    c2 = M.init_cache(cfg, 2, max_len, jnp.float32)
    big = jax.tree.map(
        lambda a: (jnp.concatenate([a, a], axis=1)
                   if a.ndim >= 2 and a.shape[1] == 1 else a),
        o1.cache)
    noise = jax.random.randint(jax.random.PRNGKey(7), (1, 5), 0,
                               cfg.vocab_size)
    o_noise = M.forward(params, cfg, tokens=noise,
                        cache=M.init_cache(cfg, 1, max_len, jnp.float32))
    big = jax.tree.map(
        lambda a, nz: a.at[:, 1:2].set(nz) if a.ndim >= 2 else a,
        big, jax.tree.map(lambda x: x, o_noise.cache))
    big = {**big, "length": jnp.asarray([8, 5], jnp.int32)}
    out = M.forward(params, cfg,
                    tokens=jnp.concatenate([toks[:, 8:9], noise[:, -1:]]),
                    cache=big)
    err = float(jnp.max(jnp.abs(out.logits[0] - ref.logits[0])))
    assert err < 2e-4, err
