"""Dispatch-mode semantics: dropless MoE outputs are count-independent —
a token's output depends only on (token, routing), never on how many other
tokens share the batch.  Capacity mode provably violates this (drops depend
on the total count through capacity_for), which is exactly the bucketed
prefill (T=32) vs full forward (T=40) divergence the dropless default fixes
(ROADMAP "known seed failure" #1)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.partitioner import NULL_PLAN, make_plan
from repro.kernels.policy import KernelPolicy
from repro.models import model as MM
from repro.models import moe as M
from repro.models.param import init_tree

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    from repro.configs.base import ModelConfig
    base = dict(name="d-moe", family="moe", n_layers=1, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                n_experts=8, top_k=2, d_expert=96, n_shared_experts=1,
                capacity_factor=1.0)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("t_sub", [1, 5, 16])
@pytest.mark.parametrize("use_kernels", [False, True])
def test_dropless_moe_is_count_independent(t_sub, use_kernels):
    """moe_local under dropless: the outputs for a token subset equal the
    corresponding rows of the full batch — exactly (same-path float ops)."""
    cfg = _cfg()
    params = init_tree(KEY, M.moe_spec(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model),
                          jnp.float32)
    policy = KernelPolicy.all_on() if use_kernels else KernelPolicy.off()
    full, _ = M.moe_local(params, x, cfg, policy=policy, dispatch="dropless")
    sub, _ = M.moe_local(params, x[:, :t_sub], cfg, policy=policy,
                         dispatch="dropless")
    err = float(jnp.max(jnp.abs(sub - full[:, :t_sub])))
    assert err < 1e-6, (t_sub, use_kernels, err)


def test_capacity_moe_violates_count_independence():
    """The property the dropless default exists to restore: with a tight
    capacity factor, shrinking the batch changes which slots are dropped, so
    the shared prefix's outputs change."""
    cfg = _cfg(top_k=2, n_experts=8)
    params = init_tree(KEY, M.moe_spec(cfg), jnp.float32)
    # degenerate router (uniform logits): every token ties onto experts 0,1,
    # so per-expert load is T while capacity_for gives T*k/E = T/4 — the
    # drop cliff sits at a different slot for every batch size.
    params = {**params, "router": jnp.zeros_like(params["router"])}
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 80, cfg.d_model),
                          jnp.float32)
    full, _ = M.moe_local(params, x, cfg, dispatch="capacity")
    sub, _ = M.moe_local(params, x[:, :16], cfg, dispatch="capacity")
    err = float(jnp.max(jnp.abs(sub - full[:, :16])))
    assert err > 1e-4, f"expected capacity drops to differ, err={err}"
    # and the same routing under dropless is subset-invariant
    fd, _ = M.moe_local(params, x, cfg, dispatch="dropless")
    sd, _ = M.moe_local(params, x[:, :16], cfg, dispatch="dropless")
    assert float(jnp.max(jnp.abs(sd - fd[:, :16]))) < 1e-6


def test_dropless_matches_capacity_with_ample_headroom():
    """With cf high enough that nothing drops, the two dispatch schemes are
    the same mathematical function (different summation trees: allclose)."""
    cfg = _cfg()
    params = init_tree(KEY, M.moe_spec(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    drop, _ = M.moe_local(params, x, cfg, dispatch="dropless")
    cap, _ = M.moe_local(params, x, cfg, cf=8.0, dispatch="capacity")
    np.testing.assert_allclose(np.asarray(drop), np.asarray(cap), atol=2e-5)


def test_moe_block_default_is_dropless():
    """NULL_PLAN carries dispatch_mode="auto" which must resolve to dropless
    for inference — the moe_block default equals the explicit dropless call
    and ignores cf."""
    cfg = _cfg()
    params = init_tree(KEY, M.moe_spec(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    assert NULL_PLAN.dispatch_mode == "auto"
    assert M.resolve_dispatch("auto") == "dropless"
    default, _ = M.moe_block(params, x, cfg)
    explicit, _ = M.moe_block(params, x, cfg, dispatch="dropless")
    np.testing.assert_array_equal(np.asarray(default), np.asarray(explicit))
    tight, _ = M.moe_block(params, x, cfg, cf=0.0)      # cf inert: no drops
    np.testing.assert_array_equal(np.asarray(default), np.asarray(tight))
    with pytest.raises(ValueError):
        M.resolve_dispatch("bogus")


def test_training_loss_pins_capacity_dispatch():
    """train_step.loss_fn maps the "auto" default to capacity (the training
    load-balancing contract) but honors an explicit dropless plan."""
    from repro.training.train_step import loss_fn

    cfg = C.get_reduced("phi3.5-moe-42b")
    # tight capacity so the capacity path genuinely drops slots
    cfg = dataclasses.replace(cfg, capacity_factor=0.25)
    params = MM.init_params(KEY, cfg, jnp.float32)
    toks = jax.random.randint(KEY, (2, 12), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    l_auto, _ = loss_fn(params, batch, cfg, NULL_PLAN, remat=False)
    l_cap, _ = loss_fn(
        params, batch, cfg,
        dataclasses.replace(NULL_PLAN, dispatch_mode="capacity"),
        remat=False)
    l_drop, _ = loss_fn(
        params, batch, cfg,
        dataclasses.replace(NULL_PLAN, dispatch_mode="dropless"),
        remat=False)
    assert float(l_auto) == float(l_cap)
    # the reduced config routes with collisions at its default cf, so the
    # dropless loss is genuinely different from the capacity loss
    assert abs(float(l_drop) - float(l_cap)) > 1e-7


def test_dropless_grads_flow():
    """An explicit dropless plan is trainable: finite grads through the
    sort/gather/segment-GEMM pipeline."""
    cfg = _cfg()
    params = init_tree(KEY, M.moe_spec(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model),
                          jnp.float32)

    def loss(p):
        out, aux = M.moe_local(p, x, cfg, dispatch="dropless")
        return (out ** 2).mean() + 0.01 * aux

    grads = jax.grad(loss)(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in flat)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat)


def test_engine_dispatch_mode_plumbs_to_plan():
    from _engine_helpers import make_engine

    cfg = C.get_reduced("smollm-360m")
    params = MM.init_params(KEY, cfg, jnp.float32)
    eng = make_engine(cfg, params, max_batch=1, max_len=32)
    # resolution pins the inference default: count-independent dropless
    assert eng.plan.dispatch_mode == eng.spec.dispatch == "dropless"
    eng2 = make_engine(cfg, params, max_batch=1, max_len=32,
                       dispatch="capacity")
    assert eng2.plan.dispatch_mode == "capacity"


def test_make_plan_carries_dispatch():
    import jax as _jax
    mesh = _jax.sharding.Mesh(np.array(_jax.devices()[:1]).reshape(1, 1),
                              ("data", "model"))
    for d in ("auto", "capacity", "dropless"):
        assert make_plan("mixserve", mesh, dispatch=d).dispatch_mode == d
        assert make_plan("mixserve", None, dispatch=d).dispatch_mode == d


def test_prefill_bucket_vs_full_forward_phi35():
    """The ROADMAP seed failure, as a focused regression: bucketed prefill
    logits equal the full forward's prefix for the MoE arch under the
    default (dropless) plan."""
    cfg = C.get_reduced("phi3.5-moe-42b")
    params = MM.init_params(KEY, cfg, jnp.float32)
    toks = jax.random.randint(KEY, (2, 20), 0, cfg.vocab_size)
    full = MM.forward(params, cfg, tokens=toks)
    cache = MM.init_cache(cfg, 2, 64, jnp.float32)
    pre = MM.forward(params, cfg, tokens=toks[:, :16], cache=cache)
    err = float(jnp.max(jnp.abs(pre.logits - full.logits[:, :16])))
    assert err < 2e-4, err
