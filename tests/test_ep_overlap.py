"""Unit tests for the micro-chunked EP-exchange overlap stack.

Covers the cost-model primitives (``EpOverlap``, ``cap_rows_for``,
``moe_overlap_lambda``), the resolver knob (``auto_ep_overlap``, the
``kv_page`` autotune hook), the ``ServeSpec`` surface (validation,
resolution, provenance, meta), the acceptance-criterion analyzer flip,
and the engine-level bit-identity oracle.  The sharded bit-identity of
the chunked pipeline itself runs in tests/sharded (subprocess CPU mesh).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core import analyzer
from repro.core import cost_model as cm
from repro.core import resolve as R
from repro.core.topology import CLUSTERS
from repro.kernels import autotune
from repro.models import model as M
from repro.serving.api import LLM, ServeSpec

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# cost-model primitives
# ---------------------------------------------------------------------------

def test_ep_overlap_validation_and_describe():
    with pytest.raises(ValueError):
        cm.EpOverlap(chunks=0)
    with pytest.raises(ValueError):
        cm.EpOverlap(chunks=2, cap_rows=-2)
    with pytest.raises(ValueError):
        cm.EpOverlap(chunks=2, cap_sigma=0.0)
    assert cm.EP_OVERLAP_OFF.off
    assert not cm.EpOverlap(chunks=1, cap_rows=0).off   # count-bounding on
    assert "C=4" in cm.EpOverlap(chunks=4).describe()
    assert "worst-case" in cm.EpOverlap(chunks=2, cap_rows=-1).describe()
    assert "cap=8 rows" in cm.EpOverlap(chunks=2, cap_rows=8).describe()


def test_cap_rows_for_rules():
    ovl = cm.EpOverlap(chunks=2)
    # no EP / worst-case cap: the full chunk extent
    assert cm.cap_rows_for(128, 1, ovl) == 128
    assert cm.cap_rows_for(128, 4, cm.EpOverlap(chunks=2, cap_rows=-1)) == 128
    # explicit cap clamps to [1, n_chunk]
    assert cm.cap_rows_for(128, 4, cm.EpOverlap(chunks=2, cap_rows=48)) == 48
    assert cm.cap_rows_for(16, 4, cm.EpOverlap(chunks=2, cap_rows=48)) == 16
    # auto rule: >= the routing mean, multiple of 8, <= worst case; at
    # realistic sizes strictly below worst case (that's the point)
    cap = cm.cap_rows_for(512, 4, ovl)
    assert cap % 8 == 0 and 512 // 4 <= cap < 512
    # more sigma headroom -> never a smaller cap
    cap_hi = cm.cap_rows_for(512, 4, cm.EpOverlap(chunks=2, cap_sigma=6.0))
    assert cap_hi >= cap


def test_moe_overlap_lambda_shapes_of_the_estimate():
    lam, tau = 10e-3, 4e-3
    # C=1 is the serial sum (identity on the comm term)
    assert cm.moe_overlap_lambda(lam, tau, cm.EpOverlap(chunks=1)) == lam
    # comm-bound: hides up to tau of wire time, never more
    eff = cm.moe_overlap_lambda(lam, tau, cm.EpOverlap(chunks=4))
    assert lam - tau <= eff < lam
    # compute-bound: hides (almost) all of lam
    eff2 = cm.moe_overlap_lambda(tau, lam, cm.EpOverlap(chunks=4))
    assert eff2 < tau and eff2 == pytest.approx(tau / 4)
    # per-chunk alpha rounds grow linearly with C and bound useful C
    a = 1e-4
    e2 = cm.moe_overlap_lambda(lam, tau, cm.EpOverlap(chunks=2), a)
    e8 = cm.moe_overlap_lambda(lam, tau, cm.EpOverlap(chunks=8), a)
    assert e8 - e2 == pytest.approx(
        (6 * a) - (1.0 / 2 - 1.0 / 8) * min(lam, tau))


# ---------------------------------------------------------------------------
# resolver knob
# ---------------------------------------------------------------------------

@pytest.fixture
def moe_pick():
    cfg = C.get("phi3.5-moe-42b")
    cluster = CLUSTERS["v5e-pod-256"]
    strat = analyzer.select(cfg, cluster, batch=16, l_in=1024,
                            l_out=256).best.strategy
    return cfg, cluster, strat


def test_auto_ep_overlap_explicit_paths(moe_pick):
    cfg, cluster, strat = moe_pick
    kw = dict(batch=16, l_in=1024, l_out=256)
    ovl, prov = R.auto_ep_overlap(cfg, strat, cluster, value="off", **kw)
    assert ovl is None and prov.startswith("explicit:off")
    ovl, prov = R.auto_ep_overlap(cfg, strat, cluster, value=3, **kw)
    assert ovl == cm.EpOverlap(chunks=3) and prov == "explicit(C=3, auto cap)"
    pinned = cm.EpOverlap(chunks=2, cap_rows=64)
    ovl, prov = R.auto_ep_overlap(cfg, strat, cluster, value=pinned, **kw)
    assert ovl is pinned and "cap=64 rows" in prov
    with pytest.raises(ValueError):
        R.auto_ep_overlap(cfg, strat, cluster, value=0, **kw)


def test_auto_ep_overlap_degenerate_paths(moe_pick):
    cfg, cluster, strat = moe_pick
    kw = dict(batch=16, l_in=1024, l_out=256)
    dense = C.get("smollm-360m")
    ovl, prov = R.auto_ep_overlap(dense, strat, cluster, **kw)
    assert ovl is None and "dense" in prov
    ep1 = cm.Strategy(attn_tp=4, attn_dp=1, moe_tp=4, moe_ep=1)
    ovl, prov = R.auto_ep_overlap(cfg, ep1, cluster, value="auto", **kw)
    assert ovl is None and "ep=1" in prov


def test_auto_ep_overlap_auto_prices_candidates(moe_pick):
    cfg, cluster, strat = moe_pick
    kw = dict(batch=16, l_in=1024, l_out=256)
    ovl, prov = R.auto_ep_overlap(cfg, strat, cluster, value="auto", **kw)
    assert ovl is not None and ovl.chunks in R.EP_OVERLAP_CANDIDATES
    assert prov.startswith("auto:cost-model(") and cluster.name in prov
    # the auto pick never prices above the monolithic C=1 schedule
    def step(o):
        return (cm.service_latency(cfg, strat,
                                   cm.Workload(batch=16, seq_len=1024),
                                   cluster, ep_overlap=o)
                + 256 * cm.service_latency(
                    cfg, strat,
                    cm.Workload(batch=16, seq_len=1, kv_len=1280),
                    cluster, ep_overlap=o))
    assert step(ovl) <= step(cm.EpOverlap(chunks=1)) + 1e-12


def test_overlap_pricing_flips_analyzer_pick():
    """Acceptance criterion: the overlapped exchange estimate changes the
    automatic analyzer's preferred strategy on a paper cluster config
    (phi3.5-MoE @ v5e-pod-256, the Fig. 10 workload shape)."""
    cfg = C.get("phi3.5-moe-42b")
    cluster = CLUSTERS["v5e-pod-256"]
    kw = dict(batch=16, l_in=1024, l_out=256)
    base = analyzer.select(cfg, cluster, **kw)
    ovl = analyzer.select(cfg, cluster,
                          ep_overlap=cm.EpOverlap(chunks=4), **kw)
    assert base.best.strategy != ovl.best.strategy
    # direction: hiding exchange time behind expert compute stops
    # penalizing configs whose A2A the pipeline can hide — the overlapped
    # pick keeps more compute per EP rank (lower or equal EP degree)
    assert ovl.best.strategy.moe_ep <= base.best.strategy.moe_ep


def test_kv_page_autotune_entry_reaches_auto_kv(monkeypatch):
    """A measured ``kv_page`` registration (kernel_bench sweep) must win
    over the analytic page constant — and carry measured provenance."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "")   # process-local cache
    autotune.clear_cache()
    cfg = C.get_reduced("smollm-360m")
    kw = dict(max_batch=4, max_len=256, l_in=96, l_out=32)
    kv, prov = R.auto_kv(cfg, **kw)
    assert kv.page_size == R.KV_PAGE_SIZE
    assert f"autotune:default({R.KV_PAGE_SIZE})" in prov
    autotune.register("kv_page", R.kv_page_key(cfg, 256), "bfloat16",
                      {"page": 64})
    kv, prov = R.auto_kv(cfg, **kw)
    assert kv.page_size == 64 and "autotune:measured" in prov
    # a tuned page that does not divide max_len degrades, never orphans
    autotune.register("kv_page", R.kv_page_key(cfg, 256), "bfloat16",
                      {"page": 48})
    kv, _ = R.auto_kv(cfg, **kw)
    assert 256 % kv.page_size == 0
    # explicit page beats the registration
    kv, prov = R.auto_kv(cfg, page_size=32, **kw)
    assert kv.page_size == 32 and "page 32 from explicit" in prov
    autotune.clear_cache()


# ---------------------------------------------------------------------------
# ServeSpec surface
# ---------------------------------------------------------------------------

def test_serve_spec_ep_overlap_validation():
    for bad in ("weird", 0, -3, True):
        with pytest.raises(ValueError):
            ServeSpec(ep_overlap=bad)
    for ok in ("auto", "off", 1, 4, cm.EpOverlap(chunks=2)):
        ServeSpec(ep_overlap=ok)


def test_serve_spec_resolves_ep_overlap_to_plan_and_meta():
    cfg = C.get("phi3.5-moe-42b")
    r = ServeSpec(arch="phi3.5-moe-42b", max_batch=4, max_len=64,
                  chunk=8, prompt_len=8, max_new_tokens=4,
                  ep_overlap=2).resolve(cfg)
    assert r.ep_overlap == cm.EpOverlap(chunks=2)
    assert r.plan.ep_overlap == r.ep_overlap
    assert r.moe_ep >= 1 and r.moe_tp >= 1
    assert "C=2" in r.describe()
    meta = r.as_meta()
    assert meta["resolved"]["ep_overlap"] == cm.EpOverlap(chunks=2).describe()
    assert meta["provenance"]["ep_overlap"] == "explicit(C=2, auto cap)"
    off = ServeSpec(arch="phi3.5-moe-42b", max_batch=4, max_len=64,
                    chunk=8, prompt_len=8, max_new_tokens=4,
                    ep_overlap="off").resolve(cfg)
    assert off.ep_overlap is None and off.plan.ep_overlap is None
    assert off.as_meta()["resolved"]["ep_overlap"] == "off"


def test_engine_streams_bit_identical_across_ep_overlap():
    """Engine-level oracle: the resolved ``ep_overlap`` knob must not
    change a single sampled token on this host (the single-device engine
    runs the monolithic local dispatch either way; the knob only changes
    the sharded plan + the pricing)."""
    cfg = C.get_reduced("phi3.5-moe-42b")
    params = M.init_params(KEY, cfg, jnp.float32)
    outs = {}
    for mode in ("off", 2):
        spec = ServeSpec(arch="phi3.5-moe-42b", max_batch=2, max_len=64,
                         chunk=4, prompt_len=8, max_new_tokens=6,
                         ep_overlap=mode).resolve(cfg)
        llm = LLM.from_spec(spec, cfg=cfg, params=params)
        outs[mode] = llm.generate(
            [np.arange(7, dtype=np.int32), np.arange(5, dtype=np.int32)],
            max_new_tokens=6)
    assert outs["off"] == outs[2]


def test_forward_expert_stats_counts_and_identity():
    cfg = C.get_reduced("phi3.5-moe-42b")
    params = M.init_params(KEY, cfg, jnp.float32)
    tokens = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    base = M.forward(params, cfg, tokens=tokens)
    out = M.forward(params, cfg, tokens=tokens, expert_stats=True)
    assert base.expert_counts is None
    counts = np.asarray(out.expert_counts)
    assert counts.shape == (cfg.n_experts,)
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers
    assert counts.sum() == 2 * 16 * cfg.top_k * n_moe_layers
    assert jnp.array_equal(base.logits, out.logits)
    # dense models: stats stay a zero vector, logits untouched
    dense = C.get_reduced("smollm-360m")
    dp = M.init_params(KEY, dense, jnp.float32)
    dt = jax.random.randint(KEY, (1, 8), 0, dense.vocab_size)
    dout = M.forward(dp, dense, tokens=dt, expert_stats=True)
    assert np.asarray(dout.expert_counts).sum() == 0
