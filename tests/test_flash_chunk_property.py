"""Property sweep of the ragged mixed-chunk flash-attention kernel.

Random per-slot ``q_len``/``q_offset``/``kv_len`` mixes — all-idle,
single-slot, full-chunk, ragged-tail — against the ``chunked_attention``
jnp oracle (the masked chunked-softmax body the kernel replaces on the
unified serving hot path), for GQA and MLA-absorbed head shapes.

``hypothesis`` is not in the base container image; CI installs it (the
module skips cleanly without it).  Runs in the fast ``-m kernels`` lane.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import example, given, settings, strategies as st  # noqa: E402

from repro.kernels import ops  # noqa: E402
from repro.models.layers import chunked_attention  # noqa: E402

pytestmark = pytest.mark.kernels

KEY = jax.random.PRNGKey(0)

# (nq, nkv, hd, hdv): GQA, and MLA-absorbed (single latent kv head, latent
# keys wider than the bare-latent values)
HEAD_SHAPES = {"gqa": (8, 2, 32, 32), "mla": (4, 1, 40, 24)}


@st.composite
def ragged_batches(draw):
    b = draw(st.integers(1, 4))
    sq = draw(st.integers(1, 8))
    q_lens = draw(st.lists(st.integers(0, sq), min_size=b, max_size=b))
    margin = draw(st.integers(0, 24))        # cache slack past the frontier
    offsets = [draw(st.integers(0, 24)) if ql else 0 for ql in q_lens]
    skv = max(o + ql for o, ql in zip(offsets, q_lens)) + margin
    skv = max(skv, sq, 1)
    return b, sq, q_lens, offsets, skv


@pytest.mark.parametrize("head", sorted(HEAD_SHAPES))
@settings(max_examples=25, deadline=None)
@example(batch=(1, 4, [0], [0], 8))                       # all-idle
@example(batch=(1, 6, [6], [5], 16))                      # single full slot
@example(batch=(3, 4, [4, 1, 0], [0, 9, 0], 16))          # mixed step
@example(batch=(2, 8, [3, 8], [13, 0], 24))               # ragged tails
@given(batch=ragged_batches())
def test_flash_chunk_matches_oracle(head, batch):
    b, sq, q_lens, offsets, skv = batch
    nq, nkv, hd, hdv = HEAD_SHAPES[head]
    q = jax.random.normal(KEY, (b, sq, nq, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, skv, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, skv, nkv, hdv))
    off = jnp.asarray(offsets, jnp.int32)
    qlen = jnp.asarray(q_lens, jnp.int32)
    kvlen = off + qlen

    got = ops.flash_chunk(q, k, v, off, qlen, kvlen, bq=4, bs=16)
    assert bool(jnp.isfinite(got).all())
    want = chunked_attention(q, k, v, q_offset=off, kv_len=kvlen,
                             causal=True)
    for i in range(b):                 # oracle tail rows are garbage
        ql = int(qlen[i])
        np.testing.assert_allclose(np.asarray(got[i, :ql]),
                                   np.asarray(want[i, :ql]),
                                   atol=2e-5, rtol=2e-5)
        # kernel tail rows are exact zeros
        assert float(jnp.max(jnp.abs(got[i, ql:]), initial=0.0)) == 0.0


@pytest.mark.parametrize("head", sorted(HEAD_SHAPES))
@settings(max_examples=25, deadline=None)
@example(batch=(1, 4, [0], [0], 8))                       # all-idle
@example(batch=(1, 6, [6], [5], 16))                      # single full slot
@example(batch=(3, 4, [4, 1, 0], [0, 9, 0], 16))          # mixed step
@example(batch=(2, 8, [3, 8], [13, 0], 24))               # ragged tails
@given(batch=ragged_batches(), seed=st.integers(0, 2 ** 16))
def test_flash_chunk_paged_bit_identical_to_dense(head, batch, seed=0):
    """Page indirection through a PERMUTED block table never changes the
    bits: flash_chunk_paged == flash_chunk at the same bq/bs, for any
    ragged slot mix (the shared-prefix serving invariant)."""
    b, sq, q_lens, offsets, skv = batch
    nq, nkv, hd, hdv = HEAD_SHAPES[head]
    page = 8
    nb = -(-max(skv, 1) // page)
    s = nb * page                       # pad the cache to whole pages
    q = jax.random.normal(KEY, (b, sq, nq, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hdv))
    off = jnp.asarray(offsets, jnp.int32)
    qlen = jnp.asarray(q_lens, jnp.int32)
    kvlen = off + qlen

    # scatter each slot's logical blocks to permuted pool pages
    rng = np.random.default_rng(seed)
    perm = rng.permutation(b * nb).reshape(b, nb).astype(np.int32)
    kp = jnp.zeros((b * nb, page, nkv, hd), jnp.float32)
    vp = jnp.zeros((b * nb, page, nkv, hdv), jnp.float32)
    for i in range(b):
        for j in range(nb):
            kp = kp.at[perm[i, j]].set(k[i, j * page:(j + 1) * page])
            vp = vp.at[perm[i, j]].set(v[i, j * page:(j + 1) * page])
    bt = jnp.asarray(perm)

    dense = ops.flash_chunk(q, k, v, off, qlen, kvlen, bq=4, bs=page)
    paged = ops.flash_chunk_paged(q, kp, vp, bt, off, qlen, kvlen,
                                  bq=4, bs=page)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(paged))
