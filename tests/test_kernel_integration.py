"""Pallas kernels integrated into the MoE block: kernel path == jnp path,
locally (in-process) and on a CPU mesh (subprocess, fake devices)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.kernels.policy import KernelPolicy
from repro.models import moe as M
from repro.models.param import init_tree

CFG = ModelConfig(name="k-moe", family="moe", n_layers=1, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  n_experts=4, top_k=2, d_expert=96, n_shared_experts=1)

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


@pytest.mark.kernels
@pytest.mark.parametrize("dispatch", ["capacity", "dropless"])
def test_moe_local_kernel_path_matches_jnp(dispatch):
    params = init_tree(jax.random.PRNGKey(0), M.moe_spec(CFG), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.float32)
    out_jnp, aux_jnp = M.moe_local(params, x, CFG, cf=8.0, use_kernels=False,
                                   dispatch=dispatch)
    out_krn, aux_krn = M.moe_local(params, x, CFG, cf=8.0, use_kernels=True,
                                   dispatch=dispatch)
    np.testing.assert_allclose(np.asarray(out_krn), np.asarray(out_jnp),
                               atol=2e-5)
    assert abs(float(aux_krn) - float(aux_jnp)) < 1e-5


@pytest.mark.kernels
@pytest.mark.parametrize("dispatch,required", [
    ("capacity", ("topk_gate", "moe_gemm", "permute_tokens",
                  "unpermute_tokens")),
    ("dropless", ("topk_gate", "grouped_gemm", "permute_tokens",
                  "unpermute_tokens")),
])
def test_moe_local_policy_traces_all_kernels(dispatch, required):
    """KernelPolicy.all_on() must actually put every hot-path kernel into the
    jitted MoE graph (trace-time counters), and match jnp to allclose."""
    params = init_tree(jax.random.PRNGKey(0), M.moe_spec(CFG), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.float32)
    fn_off = jax.jit(lambda p, xx: M.moe_local(p, xx, CFG, cf=8.0,
                                               dispatch=dispatch))
    fn_on = jax.jit(lambda p, xx: M.moe_local(
        p, xx, CFG, cf=8.0, policy=KernelPolicy.all_on(), dispatch=dispatch))
    out_off, _ = fn_off(params, x)
    ops.reset_counters()
    out_on, _ = fn_on(params, x)
    for k in required:
        assert ops.counters[k] > 0, (k, dict(ops.counters))
    np.testing.assert_allclose(np.asarray(out_on), np.asarray(out_off),
                               atol=2e-5)


@pytest.mark.kernels
def test_moe_capacity_factor_zero_not_silently_replaced():
    """cf=0.0 is a real (degenerate) capacity factor: capacity clamps to 1
    and must NOT fall back to cfg.capacity_factor (the old `cf or ...` bug).
    Capacity dispatch is pinned — the dropless default has no capacity."""
    params = init_tree(jax.random.PRNGKey(0), M.moe_spec(CFG), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.float32)
    out_zero, _ = M.moe_local(params, x, CFG, cf=0.0, dispatch="capacity")
    out_eps, _ = M.moe_local(params, x, CFG, cf=1e-9,  # same capacity (1)
                             dispatch="capacity")
    out_default, _ = M.moe_local(params, x, CFG,       # cfg.capacity_factor
                                 dispatch="capacity")
    np.testing.assert_allclose(np.asarray(out_zero), np.asarray(out_eps),
                               atol=1e-6)
    assert float(jnp.max(jnp.abs(out_zero - out_default))) > 1e-4


@pytest.mark.parametrize("via_block", [False, True])
def test_moe_block_cf_zero(via_block):
    params = init_tree(jax.random.PRNGKey(0), M.moe_spec(CFG), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.float32)
    if via_block:
        out, _ = M.moe_block(params, x, CFG, cf=0.0, dispatch="capacity")
    else:
        out, _ = M.moe_local(params, x, CFG, cf=0.0, dispatch="capacity")
    ref, _ = M.moe_local(params, x, CFG, cf=1e-9, dispatch="capacity")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


def test_moe_block_distributed_kernel_equivalence():
    """moe_block on a CPU mesh: KernelPolicy on vs off allclose across the
    mixserve/dp_ep/pure_tp plans, with the kernels asserted traced.  Runs in
    a subprocess with its own fake-device count (dry-run isolation rule)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable,
         os.path.join(HERE, "sharded", "run_moe_kernel_equivalence.py")],
        capture_output=True, text=True, timeout=1200, env=env)
    if r.returncode != 0:
        pytest.fail(f"run_moe_kernel_equivalence.py failed:\n"
                    f"{r.stdout[-2000:]}\n{r.stderr[-3000:]}")
    assert "MOE_KERNEL_EQUIVALENCE_OK" in r.stdout


@pytest.mark.kernels
def test_route_topk_kernel_matches_jnp():
    logits = jax.random.normal(jax.random.PRNGKey(2), (64, 8), jnp.float32)
    i1, w1, a1 = M.route_topk(logits, 3, use_kernel=False)
    i2, w2, a2 = M.route_topk(logits, 3, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-6)
    assert abs(float(a1) - float(a2)) < 1e-6


@pytest.mark.kernels
def test_expert_ffn_kernel_matches_jnp():
    params = init_tree(jax.random.PRNGKey(0), M.moe_spec(CFG), jnp.float32)
    buf = jax.random.normal(jax.random.PRNGKey(3), (4, 24, 64), jnp.float32)
    a = M.expert_ffn(params, buf, CFG, use_kernel=False)
    b = M.expert_ffn(params, buf, CFG, use_kernel=True)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=2e-5)
