"""Pallas kernels integrated into the MoE block: kernel path == jnp path."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import moe as M
from repro.models.param import init_tree

CFG = ModelConfig(name="k-moe", family="moe", n_layers=1, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                  n_experts=4, top_k=2, d_expert=96, n_shared_experts=1)


def test_moe_local_kernel_path_matches_jnp():
    params = init_tree(jax.random.PRNGKey(0), M.moe_spec(CFG), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64), jnp.float32)
    out_jnp, aux_jnp = M.moe_local(params, x, CFG, cf=8.0, use_kernels=False)
    out_krn, aux_krn = M.moe_local(params, x, CFG, cf=8.0, use_kernels=True)
    np.testing.assert_allclose(np.asarray(out_krn), np.asarray(out_jnp),
                               atol=2e-5)
    assert abs(float(aux_krn) - float(aux_jnp)) < 1e-5


def test_route_topk_kernel_matches_jnp():
    logits = jax.random.normal(jax.random.PRNGKey(2), (64, 8), jnp.float32)
    i1, w1, a1 = M.route_topk(logits, 3, use_kernel=False)
    i2, w2, a2 = M.route_topk(logits, 3, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2), atol=1e-6)
    assert abs(float(a1) - float(a2)) < 1e-6


def test_expert_ffn_kernel_matches_jnp():
    params = init_tree(jax.random.PRNGKey(0), M.moe_spec(CFG), jnp.float32)
    buf = jax.random.normal(jax.random.PRNGKey(3), (4, 24, 64), jnp.float32)
    a = M.expert_ffn(params, buf, CFG, use_kernel=False)
    b = M.expert_ffn(params, buf, CFG, use_kernel=True)
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=2e-5)
