"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs the jnp oracle.

Kernels run in interpret mode on CPU (the kernel body executes in Python);
on a TPU backend the same wrappers lower natively.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops

pytestmark = pytest.mark.kernels

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,h,d", [
    (2, 64, 64, 64), (4, 128, 128, 128), (1, 100, 96, 200),
    (8, 48, 256, 128), (3, 130, 70, 90),
])
def test_moe_gemm_sweep(e, c, h, d, dtype):
    x = jax.random.normal(KEY, (e, c, h), dtype)
    w = (jax.random.normal(jax.random.PRNGKey(1), (e, h, d), jnp.float32)
         / np.sqrt(h)).astype(dtype)
    got = ops.moe_gemm(x, w)
    want = ops.moe_gemm_ref(x, w)
    assert got.shape == (e, c, d) and got.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("t,e,k", [
    (64, 8, 2), (256, 16, 2), (100, 160, 6), (17, 4, 3), (512, 64, 8),
])
def test_topk_gate_sweep(t, e, k):
    logits = jax.random.normal(KEY, (t, e), jnp.float32)
    gw, gi = ops.topk_gate(logits, k)
    ww, wi = ops.topk_gate_ref(logits, k)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_allclose(np.asarray(gw), np.asarray(ww), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,nq,nkv,hd,s", [
    (2, 8, 2, 64, 256), (1, 4, 1, 32, 77), (4, 16, 16, 64, 512),
    (2, 28, 4, 128, 300),
])
def test_flash_decode_sweep(b, nq, nkv, hd, s, dtype):
    q = jax.random.normal(KEY, (b, nq, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hd), dtype)
    lens = jnp.asarray(
        np.random.default_rng(0).integers(1, s + 1, b), jnp.int32)
    got = ops.flash_decode(q, k, v, lens, bs=128)
    want = ops.flash_decode_ref(q, k, v, lens)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_decode_matches_model_decode_attention():
    """Kernel == the model's pure-JAX decode path (same masking semantics)."""
    from repro.models.layers import decode_attention
    b, nq, nkv, hd, s = 2, 8, 4, 32, 128
    q = jax.random.normal(KEY, (b, 1, nq, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hd))
    lens = jnp.asarray([50, 128], jnp.int32)
    jnp_out = decode_attention(q, k, v, kv_len=lens,
                               q_positions=(lens - 1)[:, None])
    krn_out = ops.flash_decode(q[:, 0], k, v, lens)
    np.testing.assert_allclose(np.asarray(jnp_out[:, 0]),
                               np.asarray(krn_out), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,nq,nkv,hd,hdv,s", [
    (3, 8, 8, 2, 64, 64, 256),      # GQA, mixed chunk
    (2, 16, 4, 1, 40, 24, 96),      # MLA-absorbed-like: nkv=1, hdv != hd
    (4, 1, 15, 5, 16, 16, 77),      # decode shape (sq == 1, ragged heads)
    (2, 5, 6, 6, 32, 32, 33),       # MHA, nothing tile-aligned
])
def test_flash_chunk_sweep(b, sq, nq, nkv, hd, hdv, s, dtype):
    """Ragged mixed-chunk kernel == the jnp oracle across slot mixes:
    idle (q_len 0), decode (1), short chunk, full chunk, at random cache
    offsets — GQA and MLA-absorbed (hdv != hd) head shapes."""
    rng = np.random.default_rng(b * sq + s)
    q = jax.random.normal(KEY, (b, sq, nq, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hdv), dtype)
    qlen = rng.integers(0, sq + 1, b).astype(np.int32)
    qlen[0] = 0                                     # always one idle slot
    off = np.asarray([rng.integers(0, s - ql + 1) for ql in qlen], np.int32)
    kvlen = off + qlen
    got = ops.flash_chunk(q, k, v, jnp.asarray(off), jnp.asarray(qlen),
                          jnp.asarray(kvlen), bq=4, bs=32)
    want = ops.flash_chunk_ref(q, k, v, jnp.asarray(off), jnp.asarray(qlen),
                               jnp.asarray(kvlen))
    assert got.shape == (b, sq, nq, hdv) and got.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    # ragged tails / idle slots are exact zeros, never NaN
    tail = np.asarray(got, np.float32)[np.arange(sq)[None] >= qlen[:, None]]
    assert np.all(tail == 0.0)


def test_flash_chunk_all_idle_zero_work():
    """An all-idle batch (every q_len == 0) writes finite exact-zero output
    — the case the old flash_decode needed a caller-side length floor for."""
    b, sq, nq, nkv, hd, s = 2, 4, 8, 2, 32, 64
    q = jax.random.normal(KEY, (b, sq, nq, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hd))
    zero = jnp.zeros((b,), jnp.int32)
    got = ops.flash_chunk(q, k, v, zero, zero, zero, bq=4, bs=32)
    assert float(jnp.max(jnp.abs(got))) == 0.0
    assert not bool(jnp.isnan(got).any())


def test_flash_decode_is_flash_chunk_sq1_specialization():
    """flash_decode == flash_chunk at sq == 1 with the decode invariant
    (q_offset = len-1, q_len = 1, kv_len = len), incl. a len == 0 slot."""
    b, nq, nkv, hd, s = 3, 8, 4, 32, 128
    q = jax.random.normal(KEY, (b, nq, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hd))
    lens = jnp.asarray([0, 50, 128], jnp.int32)
    dec = ops.flash_decode(q, k, v, lens, bs=64)
    chk = ops.flash_chunk(q[:, None], k, v, jnp.maximum(lens - 1, 0),
                          jnp.minimum(lens, 1), lens, bq=1, bs=64)[:, 0]
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(chk))
    # live slots match the decode oracle; the len==0 slot is exact zeros
    want = ops.flash_decode_ref(q[1:], k[1:], v[1:], lens[1:])
    np.testing.assert_allclose(np.asarray(dec[1:]), np.asarray(want),
                               atol=1e-4)
    assert float(jnp.max(jnp.abs(dec[0]))) == 0.0


def test_flash_chunk_matches_chunked_attention_oracle():
    """Kernel == the model's masked chunked-softmax body over valid rows
    (the unified step's vector q_offset/kv_len semantics)."""
    from repro.models.layers import chunked_attention
    b, sq, nq, nkv, hd, s = 3, 8, 8, 2, 32, 64
    q = jax.random.normal(KEY, (b, sq, nq, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hd))
    off = jnp.asarray([0, 13, 40], jnp.int32)
    qlen = jnp.asarray([8, 3, 0], jnp.int32)
    jnp_out = chunked_attention(q, k, v, q_offset=off, kv_len=off + qlen,
                                causal=True)
    krn_out = ops.flash_chunk(q, k, v, off, qlen, off + qlen, bq=4, bs=32)
    for i, ql in enumerate([8, 3, 0]):       # jnp tail rows are garbage
        np.testing.assert_allclose(np.asarray(krn_out[i, :ql]),
                                   np.asarray(jnp_out[i, :ql]), atol=2e-5)


def _paged_case(rng, b, sq, nkv, hd, hdv, page, nb, pool, dtype,
                *, scatter=True):
    """Random paged-attention instance: dense k/v plus an equivalent page
    pool reached through a (scattered) block table."""
    q = jax.random.normal(KEY, (b, sq, nkv * 2, hd), dtype)
    s = nb * page
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hdv), dtype)
    qlen = rng.integers(0, sq + 1, b).astype(np.int32)
    qlen[0] = 0                                     # always one idle slot
    off = np.asarray([rng.integers(0, s - ql + 1) for ql in qlen], np.int32)
    kvlen = off + qlen
    # scatter each slot's nb logical blocks to distinct pool pages
    perm = (rng.permutation(pool) if scatter
            else np.arange(pool))[:b * nb].reshape(b, nb).astype(np.int32)
    kp = jnp.zeros((pool, page, nkv, hd), dtype)
    vp = jnp.zeros((pool, page, nkv, hdv), dtype)
    for i in range(b):
        for j in range(nb):
            kp = kp.at[perm[i, j]].set(k[i, j * page:(j + 1) * page])
            vp = vp.at[perm[i, j]].set(v[i, j * page:(j + 1) * page])
    # unallocated blocks past the frontier are -1 in the real table
    bt = np.where(np.arange(nb)[None] * page < np.maximum(kvlen, 1)[:, None],
                  perm, -1).astype(np.int32)
    return (q, k, v, kp, vp, jnp.asarray(bt), jnp.asarray(off),
            jnp.asarray(qlen), jnp.asarray(kvlen))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,nkv,hd,hdv,page,nb,pool", [
    (3, 8, 2, 64, 64, 32, 4, 16),    # GQA, scattered pages + slack pool
    (2, 16, 1, 40, 24, 16, 4, 8),    # MLA-absorbed-like: nkv=1, hdv != hd
    (4, 1, 4, 32, 32, 16, 3, 12),    # decode shape (sq == 1)
    (2, 5, 3, 32, 32, 16, 2, 4),     # exactly-full pool, ragged heads
])
def test_flash_chunk_paged_sweep(b, sq, nkv, hd, hdv, page, nb, pool, dtype):
    """Paged kernel == paged jnp oracle across slot mixes and SCATTERED
    (permuted) block tables, GQA and MLA-absorbed head shapes."""
    rng = np.random.default_rng(b * sq + page)
    (q, _k, _v, kp, vp, bt, off, qlen, kvlen) = _paged_case(
        rng, b, sq, nkv, hd, hdv, page, nb, pool, dtype)
    got = ops.flash_chunk_paged(q, kp, vp, bt, off, qlen, kvlen,
                                bq=4, bs=page)
    want = ops.flash_chunk_paged_ref(q, kp, vp, bt, off, qlen, kvlen)
    assert got.shape == (b, sq, nkv * 2, hdv) and got.dtype == dtype
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    tail = np.asarray(got, np.float32)[
        np.arange(sq)[None] >= np.asarray(qlen)[:, None]]
    assert np.all(tail == 0.0)


@pytest.mark.parametrize("scatter", [False, True])
def test_flash_chunk_paged_bit_identical_to_dense(scatter):
    """THE paging correctness bar: page indirection (contiguous AND
    permuted tables) changes where tiles are read, never the arithmetic —
    outputs are bitwise equal to dense flash_chunk at the same bq/bs."""
    b, sq, nkv, hd, page, nb, pool = 3, 8, 2, 32, 32, 3, 12
    rng = np.random.default_rng(7 if scatter else 8)
    (q, k, v, kp, vp, bt, off, qlen, kvlen) = _paged_case(
        rng, b, sq, nkv, hd, hd, page, nb, pool, jnp.float32,
        scatter=scatter)
    dense = ops.flash_chunk(q, k, v, off, qlen, kvlen, bq=4, bs=page)
    paged = ops.flash_chunk_paged(q, kp, vp, bt, off, qlen, kvlen,
                                  bq=4, bs=page)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(paged))
    # sub-page KV tiles route through the same table: still bitwise equal
    half = ops.flash_chunk_paged(q, kp, vp, bt, off, qlen, kvlen,
                                 bq=4, bs=page // 2)
    dense_half = ops.flash_chunk(q, k, v, off, qlen, kvlen,
                                 bq=4, bs=page // 2)
    np.testing.assert_array_equal(np.asarray(dense_half), np.asarray(half))


def test_flash_chunk_paged_shared_prefix_page():
    """Two slots whose block tables alias the SAME pool page (a shared
    prompt prefix) both read it correctly — no copy, same bits."""
    b, sq, nkv, hd, page, nb, pool = 2, 4, 2, 32, 16, 2, 4
    q = jax.random.normal(KEY, (b, sq, nkv * 2, hd), jnp.float32)
    kp = jax.random.normal(jax.random.PRNGKey(1), (pool, page, nkv, hd))
    vp = jax.random.normal(jax.random.PRNGKey(2), (pool, page, nkv, hd))
    bt = jnp.asarray([[3, 1], [3, 2]], jnp.int32)   # page 3 shared
    off = jnp.asarray([page + 2, page + 5], jnp.int32)
    qlen = jnp.asarray([sq, sq], jnp.int32)
    kvlen = off + qlen
    got = ops.flash_chunk_paged(q, kp, vp, bt, off, qlen, kvlen,
                                bq=4, bs=page)
    want = ops.flash_chunk_paged_ref(q, kp, vp, bt, off, qlen, kvlen)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    # each slot == its own dense gather (prefix aliasing is transparent)
    for i in range(b):
        ki = kp[bt[i]].reshape(1, nb * page, nkv, hd)
        vi = vp[bt[i]].reshape(1, nb * page, nkv, hd)
        solo = ops.flash_chunk(q[i:i + 1], ki, vi, off[i:i + 1],
                               qlen[i:i + 1], kvlen[i:i + 1],
                               bq=4, bs=page)
        np.testing.assert_array_equal(np.asarray(solo[0]),
                                      np.asarray(got[i]))


def test_flash_chunk_paged_rejects_non_dividing_bs():
    q = jnp.zeros((1, 4, 2, 16), jnp.float32)
    kp = jnp.zeros((2, 16, 1, 16), jnp.float32)
    vp = jnp.zeros((2, 16, 1, 16), jnp.float32)
    bt = jnp.zeros((1, 2), jnp.int32)
    z = jnp.zeros((1,), jnp.int32)
    with pytest.raises(ValueError, match="divide"):
        ops.flash_chunk_paged(q, kp, vp, bt, z, z, z, bq=4, bs=12)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,h,n,frac", [
    (16, 32, 40, 0.5), (100, 64, 256, 0.9), (7, 48, 7, 1.0),
    (256, 128, 300, 0.0),
])
def test_permute_tokens_sweep(t, h, n, frac, dtype):
    """Fused permute == gather oracle across fill fractions (frac = share of
    output rows that carry a token; the rest are -1 -> zero rows)."""
    x = jax.random.normal(KEY, (t, h), dtype)
    rng = np.random.default_rng(0)
    src = np.full((n,), -1, np.int32)
    fill = rng.choice(n, size=int(n * frac), replace=False)
    src[fill] = rng.integers(0, t, size=fill.size)
    got = ops.permute_tokens(x, jnp.asarray(src))
    want = ops.permute_tokens_ref(x, jnp.asarray(src))
    assert got.shape == (n, h) and got.dtype == dtype
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,k,h,m", [
    (16, 2, 32, 64), (100, 6, 64, 320), (7, 3, 48, 21), (256, 8, 128, 512),
])
def test_unpermute_tokens_sweep(t, k, h, m, dtype):
    buf = jax.random.normal(KEY, (m, h), dtype)
    slot = jax.random.randint(jax.random.PRNGKey(1), (t, k), -1, m)
    w = jax.random.uniform(jax.random.PRNGKey(2), (t, k), jnp.float32)
    got = ops.unpermute_tokens(buf, slot, w)
    want = ops.unpermute_tokens_ref(buf, slot, w)
    assert got.shape == (t, h) and got.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,h,d,e,skew", [
    (64, 64, 64, 4, "uniform"), (100, 96, 200, 8, "uniform"),
    (128, 64, 96, 16, "one_expert"),      # full skew: every row one expert
    (96, 48, 64, 6, "empty_experts"),     # half the experts get nothing
    (33, 40, 56, 5, "uniform"),           # ragged, nothing tile-aligned
])
def test_grouped_gemm_sweep(n, h, d, e, skew, dtype):
    """Dropless segment GEMM == ragged_dot oracle across skews, including
    empty experts and segment boundaries inside row tiles."""
    rng = np.random.default_rng(0)
    if skew == "one_expert":
        counts = np.zeros(e, np.int64)
        counts[e // 2] = n
    elif skew == "empty_experts":
        counts = rng.multinomial(n, [2 / e if i % 2 else 0.0
                                     for i in range(e)])
    else:
        counts = rng.multinomial(n, np.ones(e) / e)
    offs = jnp.asarray(np.concatenate([[0], np.cumsum(counts)]), jnp.int32)
    x = jax.random.normal(KEY, (n, h), dtype)
    w = (jax.random.normal(jax.random.PRNGKey(1), (e, h, d), jnp.float32)
         / np.sqrt(h)).astype(dtype)
    got = ops.grouped_gemm(x, w, offs, bn=16, bd=32, bh=32)
    want = ops.grouped_gemm_ref(x, w, offs)
    assert got.shape == (n, d) and got.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_grouped_gemm_partial_buffer_zero_tail():
    """Rows at/after offsets[-1] (the unused tail of a worst-case-sized EP
    exchange buffer) come back as exact zeros — never uninitialized."""
    n, h, d, e, m_real = 96, 32, 48, 4, 21
    rng = np.random.default_rng(1)
    counts = rng.multinomial(m_real, np.ones(e) / e)
    offs = jnp.asarray(np.concatenate([[0], np.cumsum(counts)]), jnp.int32)
    x = np.zeros((n, h), np.float32)
    x[:m_real] = rng.standard_normal((m_real, h))
    w = jnp.asarray(rng.standard_normal((e, h, d)) / np.sqrt(h), jnp.float32)
    got = ops.grouped_gemm(jnp.asarray(x), w, offs, bn=8, bd=16, bh=16)
    want = ops.grouped_gemm_ref(jnp.asarray(x), w, offs)
    np.testing.assert_allclose(np.asarray(got[:m_real]),
                               np.asarray(want[:m_real]), atol=1e-5)
    assert float(jnp.max(jnp.abs(got[m_real:]))) == 0.0
    assert not bool(jnp.isnan(got).any())


@pytest.mark.parametrize("t,h,n,fill", [
    (16, 32, 64, 0), (32, 64, 256, 100), (20, 48, 48, 48), (8, 16, 128, 3),
])
def test_permute_tokens_ragged_sweep(t, h, n, fill):
    """Segment-aware ragged permute == gather oracle: rows past the dynamic
    ``total`` are -1 (zero rows) and whole empty tiles are skipped."""
    rng = np.random.default_rng(0)
    x = jax.random.normal(KEY, (t, h), jnp.float32)
    src = np.full((n,), -1, np.int32)
    src[:fill] = rng.integers(0, t, size=fill)
    got = ops.permute_tokens_ragged(x, jnp.asarray(src), fill, bn=8)
    want = ops.permute_tokens_ref(x, jnp.asarray(src))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("n_seg,stride,bn", [
    (4, 32, 8), (8, 16, 8), (3, 40, 16), (2, 64, 128),
])
def test_permute_tokens_ragged_per_segment(n_seg, stride, bn):
    """The EP send layout: per-destination-rank prefixes at fixed stride,
    NOT one contiguous prefix.  Valid rows of every segment — including
    the last ranks' — must survive tile elision."""
    rng = np.random.default_rng(1)
    t, h = 24, 32
    x = jax.random.normal(KEY, (t, h), jnp.float32)
    n = n_seg * stride
    counts = rng.integers(0, stride + 1, n_seg).astype(np.int32)
    src = np.full((n,), -1, np.int32)
    for s in range(n_seg):
        src[s * stride:s * stride + counts[s]] = rng.integers(
            0, t, size=counts[s])
    got = ops.permute_tokens_ragged(x, jnp.asarray(src),
                                    jnp.asarray(counts),
                                    seg_stride=stride, bn=bn)
    want = ops.permute_tokens_ref(x, jnp.asarray(src))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_permute_matches_moe_dispatch():
    """Kernel round trip == the jnp scatter/gather dispatch path, including
    capacity-dropped slots (cf tight enough to drop with random routing)."""
    from repro.models import moe as M
    t, h, e, k = 64, 32, 8, 2
    x = jax.random.normal(KEY, (t, h), jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(1), (t, k), 0, e)
    w = jax.random.uniform(jax.random.PRNGKey(2), (t, k), jnp.float32)
    cap = M.capacity_for(t, k, e, 1.0)
    d = M.make_dispatch(idx, w, e, cap)
    assert not bool(d.keep.all()), "want dropped slots in this scenario"

    buf_jnp = M.scatter_to_buffers(x, d, e)
    buf_krn = M.scatter_to_buffers(x, d, e, use_kernel=True)
    np.testing.assert_array_equal(np.asarray(buf_krn), np.asarray(buf_jnp))

    out_jnp = M.gather_from_buffers(buf_jnp, d, t)
    out_krn = M.gather_from_buffers(buf_jnp, d, t, use_kernel=True)
    np.testing.assert_allclose(np.asarray(out_krn), np.asarray(out_jnp),
                               atol=1e-5)


def test_autotune_selection_cached_and_divisible():
    autotune.clear_cache()
    blocks = autotune.select_blocks("moe_gemm", (4, 300, 512, 640),
                                    jnp.float32)
    assert set(blocks) == {"bc", "bd", "bh"}
    # VMEM working set respects the budget
    assert (blocks["bc"] * blocks["bh"] * 4 + blocks["bh"] * blocks["bd"] * 4
            + blocks["bc"] * blocks["bd"] * 4) <= autotune.VMEM_BUDGET_BYTES
    # cached: same key -> identical selection, one entry
    again = autotune.select_blocks("moe_gemm", (4, 300, 512, 640),
                                   jnp.float32)
    assert again == blocks and len(autotune.cache_info()) == 1
    # registered overrides (a measured tune result) win over the default
    autotune.register("moe_gemm", (4, 300, 512, 640), jnp.float32,
                      {"bc": 64, "bd": 64, "bh": 64})
    assert autotune.select_blocks("moe_gemm", (4, 300, 512, 640),
                                  jnp.float32) == {"bc": 64, "bd": 64,
                                                   "bh": 64}
    autotune.clear_cache()


def test_autotune_tune_measures_and_registers():
    """tune() without an explicit shape must register under the SAME key the
    ops wrapper builds, so the measured override is actually reachable."""
    autotune.clear_cache()
    x = jax.random.normal(KEY, (2, 64, 64), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64), jnp.float32)
    cands = [{"bc": 32, "bd": 32, "bh": 32}, {"bc": 64, "bd": 64, "bh": 64}]
    best = autotune.tune("moe_gemm", ops.moe_gemm, cands, x, w)
    assert best in cands
    assert autotune.select_blocks("moe_gemm", (2, 64, 64, 64),
                                  x.dtype) == best
    autotune.clear_cache()


def test_autotune_flash_decode_bs_tracks_kv_len():
    """The flash tile grows with the cache length S (k.shape[1]), not with
    the head dim."""
    autotune.clear_cache()
    short = autotune.select_blocks("flash_decode", (4, 256, 8, 64),
                                   jnp.float32)
    long = autotune.select_blocks("flash_decode", (4, 4096, 8, 64),
                                  jnp.float32)
    assert short["bs"] == 256 and long["bs"] == 2048
    autotune.clear_cache()


def test_autotune_flash_chunk_blocks():
    """The flash_chunk default: bq covers the (small) chunk, bs tracks the
    cache length like flash_decode's tile."""
    autotune.clear_cache()
    small = autotune.select_blocks("flash_chunk", (4, 8, 16, 64, 256),
                                   jnp.float32)
    assert small == {"bq": 8, "bs": 256}
    long = autotune.select_blocks("flash_chunk", (4, 256, 16, 64, 4096),
                                  jnp.float32)
    assert long["bq"] == 128 and long["bs"] == 2048
    autotune.clear_cache()


def test_autotune_flash_chunk_paged_bs_divides_page():
    """The paged flash default: the KV tile is the largest power-of-two
    divisor of the page (the block table routes whole tiles), the q tile
    tracks the chunk like flash_chunk's."""
    autotune.clear_cache()
    got = autotune.select_blocks("flash_chunk_paged", (4, 8, 16, 64, 28, 16),
                                 jnp.float32)
    assert got == {"bq": 8, "bs": 16}
    # big pages cap the KV tile at a power-of-two divisor
    big = autotune.select_blocks("flash_chunk_paged",
                                 (4, 128, 16, 64, 64, 4096), jnp.float32)
    assert big["bs"] <= 2048 and 4096 % big["bs"] == 0
    # odd page sizes degrade to one tile per page
    odd = autotune.select_blocks("flash_chunk_paged", (2, 8, 8, 64, 8, 24),
                                 jnp.float32)
    assert odd["bs"] in (8, 24) and 24 % odd["bs"] == 0
    autotune.clear_cache()


def test_autotune_flash_chunk_persistent_roundtrip(tmp_path, monkeypatch):
    """A persisted flash_chunk registration survives reload under the
    CURRENT cache version, and a stale-version file is invalidated
    wholesale (the version was bumped for this op's key)."""
    import json

    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.clear_cache()
    shape = (2, 16, 8, 32, 512)
    autotune.register("flash_chunk", shape, jnp.float32,
                      {"bq": 16, "bs": 64})
    assert json.loads(path.read_text())["version"] == autotune.CACHE_VERSION
    autotune.clear_cache()              # "new process"
    assert autotune.select_blocks("flash_chunk", shape,
                                  jnp.float32) == {"bq": 16, "bs": 64}
    # rewrite the same entry under the PREVIOUS schema version: ignored
    payload = json.loads(path.read_text())
    payload["version"] = autotune.CACHE_VERSION - 1
    path.write_text(json.dumps(payload))
    autotune.clear_cache()
    got = autotune.select_blocks("flash_chunk", shape, jnp.float32)
    assert got != {"bq": 16, "bs": 64}      # analytic default instead
    autotune.clear_cache()


def test_autotune_persistent_roundtrip(tmp_path, monkeypatch):
    """register() writes through to the JSON cache; a fresh process (simulated
    by clearing the in-memory cache) lazily adopts it in select_blocks."""
    import json

    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.clear_cache()
    # a measured override no analytic default would produce
    autotune.register("moe_gemm", (4, 300, 512, 640), jnp.float32,
                      {"bc": 32, "bd": 16, "bh": 8})
    payload = json.loads(path.read_text())
    assert payload["version"] == autotune.CACHE_VERSION
    assert len(payload["entries"]) == 1

    autotune.clear_cache()              # "new process": in-memory gone
    assert autotune.cache_info() == {}
    got = autotune.select_blocks("moe_gemm", (4, 300, 512, 640), jnp.float32)
    assert got == {"bc": 32, "bd": 16, "bh": 8}    # adopted from disk
    # a key NOT on disk still falls through to the analytic default
    other = autotune.select_blocks("moe_gemm", (2, 64, 64, 64), jnp.float32)
    assert other != got
    autotune.clear_cache(persistent=True)
    assert not path.exists()


def test_autotune_persistent_stale_version_ignored(tmp_path, monkeypatch):
    """A version-skewed cache file is invalidated wholesale: stale block
    schemas must not leak into selection."""
    import json

    path = tmp_path / "autotune.json"
    key = autotune.cache_key("moe_gemm", (4, 300, 512, 640), jnp.float32)
    path.write_text(json.dumps({
        "version": autotune.CACHE_VERSION + 999,
        "entries": {f"moe_gemm|4,300,512,640|{key.dtype}":
                    {"bc": 8, "bd": 8, "bh": 8}},
    }))
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.clear_cache()
    got = autotune.select_blocks("moe_gemm", (4, 300, 512, 640), jnp.float32)
    assert got != {"bc": 8, "bd": 8, "bh": 8}      # analytic default instead
    # a corrupt file is equally ignored (best-effort persistence)
    path.write_text("{not json")
    autotune.clear_cache()
    assert autotune.select_blocks("moe_gemm", (4, 300, 512, 640),
                                  jnp.float32) == got
    autotune.clear_cache()


def test_autotune_persistence_disabled_by_empty_env(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "")
    autotune.clear_cache()
    assert autotune.cache_path() is None
    autotune.register("moe_gemm", (2, 64, 64, 64), jnp.float32,
                      {"bc": 32, "bd": 32, "bh": 32})   # no crash, no file
    assert list(tmp_path.iterdir()) == []
    autotune.clear_cache()


def test_moe_gemm_grad_matches_ref():
    """The pallas_call is differentiable in interpret mode? No — but the ops
    wrapper is only used in inference paths; verify the forward at bf16
    accumulates in f32 (no catastrophic error vs f32 oracle)."""
    e, c, h, d = 2, 64, 128, 64
    x32 = jax.random.normal(KEY, (e, c, h), jnp.float32)
    w32 = jax.random.normal(jax.random.PRNGKey(1), (e, h, d)) / np.sqrt(h)
    ref32 = ops.moe_gemm_ref(x32, w32)
    got16 = ops.moe_gemm(x32.astype(jnp.bfloat16), w32.astype(jnp.bfloat16))
    err = float(jnp.max(jnp.abs(got16.astype(jnp.float32) - ref32)))
    assert err < 0.15   # bf16 inputs, f32 accumulation
