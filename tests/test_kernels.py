"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs the jnp oracle.

Kernels run in interpret mode on CPU (the kernel body executes in Python);
on a TPU backend the same wrappers lower natively.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,h,d", [
    (2, 64, 64, 64), (4, 128, 128, 128), (1, 100, 96, 200),
    (8, 48, 256, 128), (3, 130, 70, 90),
])
def test_moe_gemm_sweep(e, c, h, d, dtype):
    x = jax.random.normal(KEY, (e, c, h), dtype)
    w = (jax.random.normal(jax.random.PRNGKey(1), (e, h, d), jnp.float32)
         / np.sqrt(h)).astype(dtype)
    got = ops.moe_gemm(x, w)
    want = ops.moe_gemm_ref(x, w)
    assert got.shape == (e, c, d) and got.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("t,e,k", [
    (64, 8, 2), (256, 16, 2), (100, 160, 6), (17, 4, 3), (512, 64, 8),
])
def test_topk_gate_sweep(t, e, k):
    logits = jax.random.normal(KEY, (t, e), jnp.float32)
    gw, gi = ops.topk_gate(logits, k)
    ww, wi = ops.topk_gate_ref(logits, k)
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    np.testing.assert_allclose(np.asarray(gw), np.asarray(ww), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,nq,nkv,hd,s", [
    (2, 8, 2, 64, 256), (1, 4, 1, 32, 77), (4, 16, 16, 64, 512),
    (2, 28, 4, 128, 300),
])
def test_flash_decode_sweep(b, nq, nkv, hd, s, dtype):
    q = jax.random.normal(KEY, (b, nq, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hd), dtype)
    lens = jnp.asarray(
        np.random.default_rng(0).integers(1, s + 1, b), jnp.int32)
    got = ops.flash_decode(q, k, v, lens, bs=128)
    want = ops.flash_decode_ref(q, k, v, lens)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_decode_matches_model_decode_attention():
    """Kernel == the model's pure-JAX decode path (same masking semantics)."""
    from repro.models.layers import decode_attention
    b, nq, nkv, hd, s = 2, 8, 4, 32, 128
    q = jax.random.normal(KEY, (b, 1, nq, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, nkv, hd))
    lens = jnp.asarray([50, 128], jnp.int32)
    jnp_out = decode_attention(q, k, v, kv_len=lens,
                               q_positions=(lens - 1)[:, None])
    krn_out = ops.flash_decode(q[:, 0], k, v, lens)
    np.testing.assert_allclose(np.asarray(jnp_out[:, 0]),
                               np.asarray(krn_out), atol=1e-4)


def test_moe_gemm_grad_matches_ref():
    """The pallas_call is differentiable in interpret mode? No — but the ops
    wrapper is only used in inference paths; verify the forward at bf16
    accumulates in f32 (no catastrophic error vs f32 oracle)."""
    e, c, h, d = 2, 64, 128, 64
    x32 = jax.random.normal(KEY, (e, c, h), jnp.float32)
    w32 = jax.random.normal(jax.random.PRNGKey(1), (e, h, d)) / np.sqrt(h)
    ref32 = ops.moe_gemm_ref(x32, w32)
    got16 = ops.moe_gemm(x32.astype(jnp.bfloat16), w32.astype(jnp.bfloat16))
    err = float(jnp.max(jnp.abs(got16.astype(jnp.float32) - ref32)))
    assert err < 0.15   # bf16 inputs, f32 accumulation
