"""serving.kv_cache unit tests: the legacy slot helpers and the paged
allocator (free list, refcounts, radix prefix index, LRU eviction)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.resolve import KVConfig, auto_kv, kv_bytes_per_token
from repro.serving import kv_cache as KV


@pytest.fixture(scope="module")
def gqa_cfg():
    return C.get_reduced("smollm-360m")


# ---------------------------------------------------------------------------
# legacy slot helpers
# ---------------------------------------------------------------------------

def _fill(cache, value):
    return jax.tree.map(lambda x: jnp.full_like(x, value), cache)


def test_insert_slot_gqa_shapes(gqa_cfg):
    big = KV.make_batched_cache(gqa_cfg, 3, 32, jnp.float32)
    small = _fill(KV.make_batched_cache(gqa_cfg, 1, 32, jnp.float32), 7)
    out = KV.insert_slot(big, small, 1)
    k = out["groups"][0]["k"]
    assert k.shape == big["groups"][0]["k"].shape
    assert bool((k[:, 1] == 7).all()) and bool((k[:, 0] == 0).all())
    assert out["length"].shape == (3,) and int(out["length"][1]) == 7


def test_insert_slot_ring_shapes():
    """Hybrid ring-buffer caches (kpos position arrays) insert along the
    batch axis too — the legacy path serves this family."""
    cfg = C.get_reduced("recurrentgemma-9b")
    big = KV.make_batched_cache(cfg, 2, 32, jnp.float32)
    small = _fill(KV.make_batched_cache(cfg, 1, 32, jnp.float32), 3)
    out = KV.insert_slot(big, small, 0)
    for b, o in zip(jax.tree.leaves(big), jax.tree.leaves(out)):
        assert b.shape == o.shape
    assert int(out["length"][0]) == 3 and int(out["length"][1]) == 0


def test_insert_slot_latent_shapes():
    """MLA caches carry latent (c) + rope-key (kr) buffers per group."""
    cfg = C.get_reduced("minicpm3-4b")
    big = KV.make_batched_cache(cfg, 2, 32, jnp.float32)
    small = _fill(KV.make_batched_cache(cfg, 1, 32, jnp.float32), 5)
    out = KV.insert_slot(big, small, 1)
    g = out["groups"][0]
    assert {"c", "kr"} <= set(g)
    assert bool((g["c"][:, 1] == 5).all()) and bool((g["c"][:, 0] == 0).all())


def test_with_lengths_and_batched_lengths(gqa_cfg):
    c = KV.make_batched_cache(gqa_cfg, 2, 16, jnp.float32)
    c2 = KV.with_lengths(c, jnp.asarray([3, 9], jnp.int32))
    assert list(np.asarray(KV.batched_lengths(c2))) == [3, 9]
    assert c2["groups"] is c["groups"]          # only length replaced


# ---------------------------------------------------------------------------
# paged allocator
# ---------------------------------------------------------------------------

def _paged(cfg, *, batch=2, max_len=64, pool=6, prefix=True):
    return KV.PagedKVCache(cfg, batch, max_len, page_size=16,
                           pool_pages=pool, prefix_cache=prefix,
                           dtype=jnp.float32)


def _toks(seed, n):
    return np.random.default_rng(seed).integers(0, 99, n).astype(np.int32)


def test_begin_reserve_advance_free_cycle(gqa_cfg):
    kv = _paged(gqa_cfg)
    t = _toks(0, 20)
    assert kv.begin(0, t) == 0                  # cold: nothing cached
    assert kv.reserve(0, 20) == 20              # 2 pages
    assert int(kv.n_blocks[0]) == 2
    assert kv.occupancy() == pytest.approx(2 / 6)
    kv.advance(np.asarray([20, 0]))
    assert int(kv.lengths[0]) == 20
    kv.free(0)
    # 1 full prompt page enters the index (held), the partial page frees
    assert kv.occupancy() == pytest.approx(1 / 6)
    assert int(kv.ref.sum()) == 0
    assert int(kv.n_blocks[0]) == 0 and int(kv.lengths[0]) == 0


def test_prefix_match_refcounts_shared_page(gqa_cfg):
    kv = _paged(gqa_cfg)
    t = _toks(0, 20)
    kv.begin(0, t), kv.reserve(0, 20), kv.advance(np.asarray([20, 0]))
    kv.free(0)
    cached = kv.begin(1, t)                     # same prompt: full-page hit
    assert cached == 16
    assert kv.stats.n_prefix_hits == 1
    assert kv.stats.prefix_hit_tokens == 16
    shared = int(kv.bt[1, 0])
    assert int(kv.ref[shared]) == 1             # slot 1 references it
    kv.free(1)
    assert int(kv.ref[shared]) == 0             # back to index-held only


def test_prefix_never_serves_the_last_token(gqa_cfg):
    """A prompt that is exactly one page long still computes its last token
    (its logits sample the first output) — match caps at len(tokens)-1."""
    kv = _paged(gqa_cfg)
    t = _toks(1, 16)
    kv.begin(0, t), kv.reserve(0, 16), kv.advance(np.asarray([16, 0]))
    kv.free(0)                                  # full page IS indexed...
    assert kv.begin(1, t) == 0                  # ...but never fully served
    assert kv.stats.n_prefix_hits == 0


def test_identical_free_dedupes_into_one_chain(gqa_cfg):
    kv = _paged(gqa_cfg)
    t = _toks(2, 20)
    # both slots admitted cold (no index yet): private pages each
    kv.begin(0, t), kv.reserve(0, 20)
    kv.begin(1, t), kv.reserve(1, 20)
    kv.advance(np.asarray([20, 20]))
    assert kv.occupancy() == pytest.approx(4 / 6)
    kv.free(0)                                  # seeds the chain
    kv.free(1)                                  # dedupes: same key bytes
    assert len(kv._node_of_page) == 1           # ONE indexed page survives
    assert kv.occupancy() == pytest.approx(1 / 6)


def test_lru_leaf_eviction_under_pressure(gqa_cfg):
    kv = _paged(gqa_cfg)
    for seed in (10, 11, 12):                   # three 2-page chains
        t = _toks(seed, 32)
        kv.begin(0, t), kv.reserve(0, 32), kv.advance(np.asarray([32, 0]))
        kv.free(0)
    assert len(kv._free) == 0 and len(kv._node_of_page) == 6
    # a cold request must steal pages: LRU leaves go first, then their
    # parents (which become leaves) — oldest chain drains before newer ones
    kv.begin(0, _toks(13, 40))
    assert kv.reserve(0, 40) == 40
    assert kv.stats.n_evictions == 3
    assert len(kv._node_of_page) == 3
    # the freshest chain (seed 12) must have survived intact
    survivor = kv._match(np.concatenate([_toks(12, 32), _toks(99, 1)]))
    assert len(survivor) == 2


def test_reserve_exhaustion_grants_partial_then_zero(gqa_cfg):
    kv = _paged(gqa_cfg, pool=2)
    kv.begin(0, _toks(3, 40))
    assert kv.reserve(0, 40) == 32              # pool is only 2 pages
    kv.advance(np.asarray([32, 0]))
    assert kv.reserve(0, 8) == 0                # and now it is exhausted
    assert kv.reserve(1, 1) == 0
    kv.free(0, keep_prefix=False)
    assert kv.reserve(1, 1) == 1                # freed pages recirculate


def test_free_without_prefix_returns_every_page(gqa_cfg):
    kv = _paged(gqa_cfg)
    kv.begin(0, _toks(4, 32)), kv.reserve(0, 32)
    kv.advance(np.asarray([32, 0]))
    kv.free(0, keep_prefix=False)
    assert len(kv._free) == 6 and not kv._node_of_page


def test_prefix_cache_off_never_matches(gqa_cfg):
    kv = _paged(gqa_cfg, prefix=False)
    t = _toks(5, 32)
    kv.begin(0, t), kv.reserve(0, 32), kv.advance(np.asarray([32, 0]))
    kv.free(0)
    assert kv.begin(1, t) == 0 and kv.stats.n_prefix_hits == 0


def test_flush_pushes_block_tables_to_device(gqa_cfg):
    kv = _paged(gqa_cfg)
    kv.begin(0, _toks(6, 20)), kv.reserve(0, 20)
    kv.flush()
    assert np.array_equal(np.asarray(kv.cache["block_tables"]), kv.bt)
    assert not kv._dirty


def test_page_size_must_divide_max_len(gqa_cfg):
    with pytest.raises(ValueError):
        KV.PagedKVCache(gqa_cfg, 2, 60, page_size=16, pool_pages=4)


def test_dense_backend_interface(gqa_cfg):
    kv = KV.DenseKVCache(gqa_cfg, 2, 32, jnp.float32)
    assert kv.begin(0, _toks(7, 8)) == 0
    assert kv.reserve(0, 999) == 999            # a slot owns its rows
    assert kv.pool_tokens is None
    kv.cache = KV.with_lengths(kv.cache, jnp.asarray([16, 16], jnp.int32))
    assert kv.occupancy() == pytest.approx(0.5)
    kv.free(0)
    assert int(kv.cache["length"][0]) == 0


def test_make_kv_cache_factory(gqa_cfg):
    assert KV.make_kv_cache(gqa_cfg, None, 2, 32).backend == "dense"
    assert KV.make_kv_cache(gqa_cfg, KVConfig(backend="dense"),
                            2, 32).backend == "dense"
    kv = KV.make_kv_cache(gqa_cfg, KVConfig(), 2, 64)
    assert kv.backend == "paged" and kv.pool_tokens == 2 * 64
    # non-dividing page size degrades like the resolver: halve until it fits
    kv = KV.make_kv_cache(gqa_cfg, KVConfig(page_size=16), 2, 40)
    assert kv.ps == 8


# ---------------------------------------------------------------------------
# speculative rollback (rejected draft tails; docs/serving.md)
# ---------------------------------------------------------------------------

def test_rollback_frees_tail_pages(gqa_cfg):
    kv = _paged(gqa_cfg)
    kv.begin(0, _toks(20, 20)), kv.reserve(0, 20)
    kv.advance(np.asarray([20, 0]))
    assert kv.reserve(0, 13) == 13              # speculative tail: page 3
    kv.advance(np.asarray([13, 0]))
    assert int(kv.n_blocks[0]) == 3
    assert kv.rollback(0, 13) == 1              # page 3 back to the pool...
    assert int(kv.lengths[0]) == 20 and int(kv.n_blocks[0]) == 2
    assert len(kv._free) == 4                   # ...immediately reusable
    assert int(kv.ref.sum()) == 2               # surviving pages only


def test_rollback_within_partial_page_frees_nothing(gqa_cfg):
    kv = _paged(gqa_cfg)
    kv.begin(0, _toks(21, 20)), kv.reserve(0, 20)
    kv.advance(np.asarray([20, 0]))
    bt_before = kv.bt.copy()
    assert kv.rollback(0, 2) == 0               # stays inside page 2
    assert int(kv.lengths[0]) == 18 and int(kv.n_blocks[0]) == 2
    assert np.array_equal(kv.bt, bt_before)
    assert kv.rollback(0, 0) == 0               # no-op guard


def test_rollback_never_frees_shared_prefix_pages(gqa_cfg):
    kv = KV.PagedKVCache(gqa_cfg, 3, 64, page_size=16, pool_pages=8,
                         dtype=jnp.float32)
    t = _toks(22, 33)                           # 2 full pages + 1 token
    kv.begin(0, t), kv.reserve(0, 33), kv.advance(np.asarray([33, 0, 0]))
    kv.free(0)                                  # indexes the 2 full pages
    assert kv.begin(1, t) == 32                 # warm admit: both shared
    shared = [int(p) for p in kv.bt[1, :2]]
    assert all(int(kv.ref[p]) == 1 for p in shared)
    # the warm slot computes its last prompt token and speculates k=5 past
    # it — the draft tail lands on a fresh exclusively-owned page
    assert kv.reserve(1, 6) == 6
    kv.advance(np.asarray([0, 6, 0]))
    assert int(kv.n_blocks[1]) == 3
    # full rejection: roll the tail back past the page boundary
    assert kv.rollback(1, 6) == 1
    assert int(kv.lengths[1]) == 32 and int(kv.n_blocks[1]) == 2
    # the shared pages are untouched: still referenced, still indexed,
    # still at the front of the block table
    assert [int(p) for p in kv.bt[1, :2]] == shared
    assert all(int(kv.ref[p]) == 1 for p in shared)
    assert all(p in kv._node_of_page for p in shared)
    # and the prefix chain still serves the NEXT request after release
    kv.free(1)
    assert kv.begin(2, t) == 32
    assert kv.stats.n_prefix_hits == 2


def test_rollback_preserves_prefix_index_lru_order(gqa_cfg):
    kv = _paged(gqa_cfg, pool=8)
    for seed in (30, 31):                       # two indexed chains
        kv.begin(0, _toks(seed, 32)), kv.reserve(0, 32)
        kv.advance(np.asarray([32, 0]))
        kv.free(0)
    ticks = {p: n.tick for p, n in kv._node_of_page.items()}
    # an unrelated slot speculates and rejects — the index must not notice
    kv.begin(1, _toks(32, 20)), kv.reserve(1, 20)
    kv.advance(np.asarray([0, 20]))
    kv.reserve(1, 13), kv.advance(np.asarray([0, 13]))
    kv.rollback(1, 13)
    assert {p: n.tick for p, n in kv._node_of_page.items()} == ticks


def test_dense_rollback_is_device_side(gqa_cfg):
    """The dense backend's device length vector is authoritative — the
    jitted step already subtracted the rejected tail, so the host-side
    rollback frees nothing and succeeds."""
    kv = KV.DenseKVCache(gqa_cfg, 2, 32, jnp.float32)
    assert kv.rollback(0, 4) == 0


# ---------------------------------------------------------------------------
# resolution (core.resolve.auto_kv)
# ---------------------------------------------------------------------------

def test_auto_kv_pool_from_envelope(gqa_cfg):
    kv, src = auto_kv(gqa_cfg, max_batch=4, max_len=192, l_in=96, l_out=8)
    assert kv.backend == "paged" and kv.page_size == 16
    assert kv.pool_pages == 4 * 7               # ceil(104/16) pages x slots
    assert kv.pool_pages < 4 * (192 // 16)      # strictly below dense
    assert "Eq. 8" in src


def test_auto_kv_dense_for_legacy_families(gqa_cfg):
    kv, src = auto_kv(gqa_cfg, max_batch=2, max_len=64, l_in=8, l_out=4,
                      paged_ok=False)
    assert kv.backend == "dense" and "legacy" in src


def test_auto_kv_page_halves_to_divide(gqa_cfg):
    kv, _ = auto_kv(gqa_cfg, max_batch=2, max_len=72, l_in=8, l_out=4)
    assert kv.page_size == 8 and 72 % kv.page_size == 0


def test_auto_kv_pool_capped_at_dense(gqa_cfg):
    kv, _ = auto_kv(gqa_cfg, max_batch=2, max_len=32, l_in=400, l_out=400)
    assert kv.pool_pages <= 2 * (32 // kv.page_size)


def test_kv_bytes_per_token_mla_vs_gqa(gqa_cfg):
    mla = C.get_reduced("minicpm3-4b")
    per_mla = kv_bytes_per_token(mla)
    assert per_mla == mla.n_layers * (mla.kv_lora_rank
                                      + mla.rope_head_dim) * 2
    per_gqa = kv_bytes_per_token(gqa_cfg)
    assert per_gqa == gqa_cfg.n_layers * 2 * gqa_cfg.n_kv_heads \
        * gqa_cfg.head_dim * 2


def test_kvconfig_validation_and_describe():
    assert KVConfig(backend="dense").describe() == "dense"
    assert "page=16" in KVConfig(pool_pages=8).describe()
    with pytest.raises(ValueError):
        KVConfig(backend="ring")
    with pytest.raises(ValueError):
        KVConfig(page_size=0)
