"""Attention / RoPE / norm layer unit tests against naive references."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import init_tree


def naive_attention(q, k, v, *, causal=True, window=0, kv_len=None,
                    q_offset=0, scale=None):
    b, sq, nq, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    scale = scale or hd ** -0.5
    qg = q.reshape(b, sq, nkv, g, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    q_pos = q_offset + np.arange(sq)
    k_pos = np.arange(skv)
    mask = np.ones((sq, skv), bool)
    if kv_len is not None:
        mask &= k_pos[None] < kv_len
    if causal:
        mask &= k_pos[None] <= q_pos[:, None]
    if window:
        mask &= k_pos[None] > q_pos[:, None] - window
    s = jnp.where(jnp.asarray(mask)[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, nq, v.shape[-1])


@pytest.mark.parametrize("sq,skv,nq,nkv,window", [
    (16, 16, 4, 4, 0), (33, 33, 8, 2, 0), (64, 64, 6, 1, 0),
    (32, 32, 4, 2, 8), (17, 40, 4, 4, 0),
])
def test_chunked_attention_matches_naive(sq, skv, nq, nkv, window):
    key = jax.random.PRNGKey(0)
    b, hd = 2, 16
    q = jax.random.normal(key, (b, sq, nq, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, skv, nkv, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, skv, nkv, hd))
    off = skv - sq
    got = L.chunked_attention(q, k, v, q_offset=off, causal=True,
                              window=window, chunk_size=8)
    want = naive_attention(q, k, v, causal=True, window=window, q_offset=off)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_chunked_attention_kv_len_mask():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 4, 2, 8))
    k = jax.random.normal(key, (1, 20, 2, 8))
    v = jax.random.normal(key, (1, 20, 2, 8))
    got = L.chunked_attention(q, k, v, q_offset=6, kv_len=10, chunk_size=4)
    want = naive_attention(q, k, v, kv_len=10, q_offset=6)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_decode_attention_matches_naive():
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (3, 1, 8, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (3, 32, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (3, 32, 2, 16))
    got = L.decode_attention(q, k, v, kv_len=20,
                             q_positions=jnp.asarray([19]))
    want = naive_attention(q, k, v, kv_len=20, q_offset=19)[:, :1]
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_decode_attention_per_slot_lengths():
    """Vector kv_len: each batch row masks its own cache tail."""
    key = jax.random.PRNGKey(0)
    b = 4
    q = jax.random.normal(key, (b, 1, 4, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, 16, 4, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, 16, 4, 8))
    lens = jnp.asarray([3, 7, 16, 1])
    got = L.decode_attention(q, k, v, kv_len=lens,
                             q_positions=(lens - 1)[:, None])
    for i in range(b):
        want = naive_attention(q[i:i+1], k[i:i+1], v[i:i+1],
                               kv_len=int(lens[i]),
                               q_offset=int(lens[i]) - 1)[:, :1]
        np.testing.assert_allclose(got[i:i+1], want, atol=2e-5)


def test_decode_attention_ring_positions():
    """k_positions drives causal/window tests for ring-buffer caches."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, 2, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 2, 8))
    # ring holding positions 8..15 permuted, current q at 15, window 4
    kpos = jnp.asarray([8, 9, 10, 11, 12, 13, 14, 15])
    perm = jnp.asarray([3, 0, 6, 1, 7, 2, 5, 4])
    got = L.decode_attention(q, k[:, perm], v[:, perm],
                             q_positions=jnp.asarray([15]),
                             k_positions=kpos[perm], window=4)
    # reference: unpermuted k holds positions 8..15 at slots 0..7, so in the
    # naive index space the query sits at slot 7
    want = naive_attention(q, k, v, window=4, q_offset=7, kv_len=8)[:, :1]
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_write_cache_scalar_and_vector():
    buf = jnp.zeros((3, 8, 2))
    new = jnp.ones((3, 2, 2))
    got = L.write_cache(buf, new, 4)
    assert float(got[:, 4:6].sum()) == 12.0 and float(got.sum()) == 12.0
    new1 = jnp.ones((3, 1, 2)) * jnp.asarray([1., 2., 3.])[:, None, None]
    got = L.write_cache(buf, new1, jnp.asarray([0, 3, 7]))
    assert got[0, 0, 0] == 1 and got[1, 3, 0] == 2 and got[2, 7, 0] == 3
    assert float(got.sum()) == 12.0


def test_rope_rotation_invariant():
    """RoPE preserves norms and relative-position dot products."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 6, 2, 16))
    pos = jnp.arange(6)[None]
    r = L.apply_rope(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(r, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    def dot_at(p, d):
        rq = L.apply_rope(q, jnp.asarray([[p]]))
        rk = L.apply_rope(k, jnp.asarray([[p + d]]))
        return float(jnp.sum(rq * rk))
    assert abs(dot_at(0, 3) - dot_at(11, 3)) < 1e-4


def test_mrope_sections_match_rope_when_uniform():
    """M-RoPE with identical t/h/w position streams == plain RoPE."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 5, 3, 32))
    pos = jnp.arange(5)
    p3 = jnp.broadcast_to(pos, (2, 3, 5))
    got = L.apply_mrope(x, p3, (4, 6, 6))
    want = L.apply_rope(x, pos[None])
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_rms_norm():
    x = jnp.asarray([[1.0, 2.0, 3.0, 4.0]])
    w = jnp.zeros(4)
    out = L.rms_norm(x, w)
    rms = np.sqrt(np.mean(np.square([1, 2, 3, 4])) + 1e-6)
    np.testing.assert_allclose(out, np.asarray([[1, 2, 3, 4]]) / rms,
                               rtol=1e-5)


def test_mla_absorbed_equals_expanded():
    """The absorbed (latent-space) MLA path must equal head-expanded MLA."""
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64,
                      head_dim=16, attention="mla", kv_lora_rank=24,
                      q_lora_rank=32, rope_head_dim=8, v_head_dim=16)
    p = init_tree(jax.random.PRNGKey(0), L.mla_spec(cfg), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 64))
    out_a, _ = L.mla_attention(p, x, cfg, absorb=True)
    out_e, _ = L.mla_attention(p, x, cfg, absorb=False)
    np.testing.assert_allclose(out_a, out_e, atol=2e-5)
