"""Per-architecture smoke tests: REDUCED variants of every assigned arch run
one forward + one train step on CPU, asserting output shapes and no NaNs
(deliverable f)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import model as M
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import make_train_step

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, b, s):
    kw, s_text = {}, s
    if cfg.frontend == "vision_stub":
        s_text = s - cfg.n_frontend_tokens
        kw["embeds"] = jax.random.normal(
            KEY, (b, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
    if cfg.frontend == "audio_stub":
        e = cfg.encoder
        kw["frames"] = jax.random.normal(KEY, (b, e.n_frames, e.d_model)) * 0.02
    tokens = jax.random.randint(KEY, (b, s_text), 0, cfg.vocab_size)
    return tokens, kw


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = C.get_reduced(arch)
    assert cfg.n_layers <= 3 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    params = M.init_params(KEY, cfg, jnp.float32)
    b, s = 2, 32
    tokens, kw = _inputs(cfg, b, s)
    out = jax.jit(lambda p, t: M.forward(p, cfg, tokens=t, **kw))(
        params, tokens)
    assert out.logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out.logits)))


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = C.get_reduced(arch)
    params = M.init_params(KEY, cfg, jnp.float32)
    opt = init_opt_state(params)
    b, s = 2, 32
    tokens, kw = _inputs(cfg, b, s)
    batch = {"tokens": tokens,
             "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
             **kw}
    step = jax.jit(make_train_step(cfg, opt_cfg=AdamWConfig(lr=1e-3),
                                   remat=False))
    new_params, new_opt, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert int(new_opt.step) == 1
    # parameters actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b_))) > 0
        for a, b_ in zip(jax.tree.leaves(params)[:5],
                         jax.tree.leaves(new_params)[:5]))
    assert moved


def test_full_config_exactness():
    """The FULL configs carry the exact assigned hyperparameters."""
    rows = {
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
        "phi3.5-moe-42b": (32, 4096, 32, 8, 6400, 32064),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    }
    for arch, (nl, dm, nh, nkv, dff, v) in rows.items():
        cfg = C.get(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (nl, dm, nh, nkv, dff, v), arch
        assert cfg.source, arch
    d = C.get("deepseek-v2-236b")
    assert (d.n_experts, d.top_k, d.n_shared_experts, d.kv_lora_rank,
            d.d_expert) == (160, 6, 2, 512, 1536)
    p = C.get("phi3.5-moe-42b")
    assert (p.n_experts, p.top_k) == (16, 2)
    g = C.get("gemma-2b")
    assert (g.head_dim, g.activation) == (256, "geglu")
    r = C.get("recurrentgemma-9b")
    assert r.block_pattern == ("rec", "rec", "attn")
    q = C.get("qwen2-vl-7b")
    assert q.mrope and q.frontend == "vision_stub"
    w = C.get("whisper-tiny")
    assert w.encoder is not None and w.frontend == "audio_stub"


def test_param_counts_in_expected_range():
    """Total parameter counts land near the names' advertised sizes."""
    expect = {
        "smollm-360m": (0.30e9, 0.45e9),
        "gemma-2b": (2.0e9, 3.2e9),
        "minicpm3-4b": (3.3e9, 5.0e9),
        "qwen2-vl-7b": (6.5e9, 9.0e9),
        "minitron-8b": (7.0e9, 10.0e9),
        "rwkv6-1.6b": (1.3e9, 2.1e9),
        "recurrentgemma-9b": (7.5e9, 11.0e9),
        "phi3.5-moe-42b": (38e9, 46e9),
        "deepseek-v2-236b": (210e9, 250e9),
        "whisper-tiny": (0.02e9, 0.08e9),
    }
    for arch, (lo, hi) in expect.items():
        n = M.count_params(C.get(arch))
        assert lo <= n <= hi, f"{arch}: {n:,} not in [{lo:.1e}, {hi:.1e}]"


def test_layer_plan_grouping():
    assert [g.kind for g in M.layer_plan(C.get("deepseek-v2-236b"))] == \
        ["dense", "moe"]
    hybrid = M.layer_plan(C.get("recurrentgemma-9b"))
    assert hybrid[0].kind == "pattern" and hybrid[0].count == 12
    assert hybrid[1].kind == "rec" and hybrid[1].count == 2
    assert sum(g.count * (len(g.sub) or 1) for g in hybrid) == 38
