"""Paged-KV engine integration: the bit-identity oracle, prefix reuse,
cache-preserving preemption, and pool-exhaustion progress.

THE correctness property of the paged backend: under greedy decoding the
token streams must be bit-identical to the dense backend's — page
indirection, prefix sharing and pool-pressure preemption are allowed to
change WHEN work happens, never WHAT comes out (docs/kv_cache.md)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from _engine_helpers import make_engine
from repro.core.resolve import KVConfig
from repro.models.model import init_params
from repro.serving.engine import PromptTooLongError, Request
from repro.serving.scheduler import Scheduler, synthetic_workload

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smollm():
    cfg = C.get_reduced("smollm-360m")
    return cfg, init_params(KEY, cfg, jnp.float32)


@pytest.fixture(scope="module")
def mla():
    cfg = C.get_reduced("minicpm3-4b")
    return cfg, init_params(KEY, cfg, jnp.float32)


@pytest.fixture(scope="module")
def moe():
    cfg = C.get_reduced("phi3.5-moe-42b")
    return cfg, init_params(KEY, cfg, jnp.float32)


def _streams(cfg, params, kv, *, n=5, prompt_len=16, out=5, batch=2,
             max_len=64, chunk=8, **kw):
    # declare the workload envelope so kv="auto" sizes its pool from Eq. 8
    eng = make_engine(cfg, params, max_batch=batch, max_len=max_len,
                      chunk=chunk, kv=kv, prompt_len=prompt_len,
                      max_new_tokens=out, **kw)
    assert eng.kv.backend == ("dense" if kv == "dense" else "paged")
    sched = Scheduler(eng)
    for r in synthetic_workload(n, prompt_len=prompt_len,
                                max_new_tokens=out, vocab=cfg.vocab_size):
        sched.submit(r)
    done = sched.run()
    assert len(done) == n
    return {r.rid: list(r.out_tokens) for r in done}, eng


@pytest.mark.parametrize("fixture", ["smollm", "mla", "moe"])
def test_paged_streams_bit_identical_to_dense(fixture, request):
    """GQA, MLA (latent cache) and MoE engines: paged == dense, greedy."""
    cfg, params = request.getfixturevalue(fixture)
    kw = dict(n=4, prompt_len=12, out=4) if fixture != "smollm" else {}
    dense, _ = _streams(cfg, params, "dense", **kw)
    paged, eng = _streams(cfg, params, "auto", **kw)
    assert eng.kv.backend == "paged"
    assert paged == dense


def test_paged_pool_strictly_below_dense_footprint(smollm):
    """With a declared workload envelope well under max_len, the resolved
    pool (Eq. 8) allocates strictly fewer KV bytes than the dense cache."""
    cfg, params = smollm
    kw = dict(n=2, out=2, prompt_len=16)
    d, dense_eng = _streams(cfg, params, "dense", **kw)
    p, paged_eng = _streams(cfg, params, "auto", **kw)
    assert paged_eng.kv.kv_bytes() < dense_eng.kv.kv_bytes()
    assert p == d


def test_shared_prefix_reuse_is_exact(smollm):
    """Warm requests sharing a system prompt skip its full pages at
    admission AND still produce the dense engine's exact tokens."""
    cfg, params = smollm
    rng = np.random.default_rng(3)
    system = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    prompts = [np.concatenate(
        [system, rng.integers(0, cfg.vocab_size, 5).astype(np.int32)])
        for _ in range(3)]

    outs = {}
    for kv in ("auto", "dense"):
        eng = make_engine(cfg, params, max_batch=2, max_len=64, chunk=8,
                          kv=kv)
        sched = Scheduler(eng)
        sched.submit(Request(rid=0, prompt=prompts[0], max_new_tokens=4))
        sched.run()                              # cold: seeds the index
        sched2 = Scheduler(eng)
        for i, p in enumerate(prompts[1:], 1):
            sched2.submit(Request(rid=i, prompt=p, max_new_tokens=4))
        done = sched2.run()
        assert len(done) == 2
        outs[kv] = {r.rid: list(r.out_tokens) for r in done}
        if kv == "auto":
            assert eng.kv.stats.n_prefix_hits == 2
            assert eng.kv.stats.prefix_hit_tokens == 2 * 32
    assert outs["auto"] == outs["dense"]


def test_cache_preserving_preemption_resumes_from_prefix(smollm):
    """A preempted slot's computed prompt pages park in the prefix index;
    its resume re-matches them (cache-preserving) and the final stream
    equals an uninterrupted run."""
    cfg, params = smollm
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, cfg.vocab_size, 40).astype(np.int32)

    eng = make_engine(cfg, params, max_batch=1, max_len=128, chunk=8,
                      kv="auto")
    r = Request(rid=0, prompt=prompt, max_new_tokens=8)
    assert eng.admit(r)
    for _ in range(7):                           # 5 prefill + 2 decode steps
        eng.step()
    assert 1 <= len(r.out_tokens) < 8
    assert eng.preempt(0) is r
    assert eng.admit(r)                          # recompute-on-resume
    # the 2 full prompt pages (32 tokens) came back from the prefix index
    assert eng._prompt_pos[0] == 32
    assert eng.kv.stats.n_prefix_hits == 1
    while not r.done:
        eng.step()

    fresh = make_engine(cfg, params, max_batch=1, max_len=128, chunk=8,
                        kv="auto")
    r2 = Request(rid=1, prompt=prompt, max_new_tokens=8)
    assert fresh.admit(r2)
    while not r2.done:
        fresh.step()
    assert list(r.out_tokens) == list(r2.out_tokens)


def test_pool_exhaustion_preempts_and_completes(smollm):
    """A pool too small for both slots' decode growth must not deadlock:
    the engine preempts a victim (recompute-on-resume via the scheduler)
    and every request still completes with the dense engine's tokens."""
    cfg, params = smollm
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
               for _ in range(2)]

    def run(kv):
        eng = make_engine(cfg, params, max_batch=2, max_len=64, chunk=16,
                          kv=kv)
        sched = Scheduler(eng)
        for i, p in enumerate(prompts):
            sched.submit(Request(rid=i, prompt=p, max_new_tokens=20))
        done = sched.run()
        assert len(done) == 2
        return {r.rid: list(r.out_tokens) for r in done}, eng

    # 3 pages x 16 = 48 pooled tokens < 2 slots x 36-token envelope
    tight = KVConfig(page_size=16, pool_pages=3)
    paged, eng = run(tight)
    assert eng.events["preempt"] >= 1
    dense, _ = run("dense")
    assert paged == dense


def test_validate_rejects_request_bigger_than_pool(smollm):
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=2, max_len=64, chunk=8,
                      kv=KVConfig(page_size=16, pool_pages=2))
    with pytest.raises(PromptTooLongError, match="pool"):
        eng.validate(Request(rid=0, prompt=np.arange(30, dtype=np.int32),
                             max_new_tokens=8))


def test_paged_kernel_policy_traces_paged_kernel(smollm):
    """With kernels on, the paged engine's jitted step must contain
    flash_chunk_paged (no silent jnp fallback on the hot path)."""
    from repro.kernels import ops
    from repro.kernels.policy import KernelPolicy
    cfg, params = smollm

    def run(kv):
        eng = make_engine(cfg, params, max_batch=2, max_len=64, chunk=8,
                          kv=kv, kernels=KernelPolicy.all_on())
        sched = Scheduler(eng)
        for r in synthetic_workload(2, prompt_len=12, max_new_tokens=3,
                                    vocab=cfg.vocab_size):
            sched.submit(r)
        done = sched.run()
        assert len(done) == 2
        return {r.rid: list(r.out_tokens) for r in done}

    ops.reset_counters()
    dense = run("dense")
    assert ops.counters["flash_chunk_paged"] == 0
    assert ops.counters["flash_chunk"] > 0
    ops.reset_counters()
    paged = run("auto")
    assert ops.counters["flash_chunk_paged"] > 0
    # both kernelized: the paged kernel reuses the dense body, so the
    # streams match bitwise (the paper's correctness bar for paging)
    assert paged == dense
