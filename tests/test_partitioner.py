"""Partitioner / sharding-plan unit tests (no multi-device needed)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.partitioner import NULL_PLAN, ShardingPlan, make_plan


class FakeMesh:
    """Duck-typed mesh exposing .shape and .axis_names only."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


def plan_with(rules, shape):
    return ShardingPlan(mesh=FakeMesh(shape), rules=rules)


def test_null_plan_identity():
    assert not NULL_PLAN.enabled
    assert NULL_PLAN.spec(("batch", "embed")) == P()
    x = object()
    assert NULL_PLAN.constrain(x, "batch") is x


def test_spec_mapping():
    p = plan_with({"heads": ("model",), "batch": ("data",)},
                  {"data": 4, "model": 2})
    assert p.spec(("batch", None, "heads")) == P("data", None, "model")
    # trailing Nones trimmed
    assert p.spec(("heads", "nope")) == P("model")


def test_spec_no_duplicate_mesh_axes():
    """A mesh axis may appear at most once in a PartitionSpec."""
    p = plan_with({"a": ("model",), "b": ("model",)}, {"model": 2})
    spec = p.spec(("a", "b"))
    assert spec == P("model")       # second use dropped


def test_spec_for_shape_divisibility():
    p = plan_with({"heads": ("model",), "batch": ("data",)},
                  {"data": 4, "model": 16})
    # 15 heads % 16 != 0 -> replicated; 32 batch % 4 == 0 -> sharded
    assert p.spec_for_shape((32, 15), ("batch", "heads")) == P("data")
    assert p.spec_for_shape((32, 32), ("batch", "heads")) == P("data", "model")


def test_make_plan_layouts():
    mesh = FakeMesh({"data": 4, "model": 2})
    mix = make_plan("mixserve", mesh)
    assert mix.rules["expert"] == ("data",)
    assert mix.rules["expert_ffn"] == ("model",)
    assert mix.tp == 2 and mix.ep == 4 and mix.dp == 4
    assert mix.comm_algo == "fused"

    ep = make_plan("dp_ep", mesh)
    assert set(ep.rules["expert"]) == {"data", "model"}
    assert ep.comm_algo == "unfused"

    tp = make_plan("pure_tp", mesh)
    assert tp.rules["expert"] is None
    assert tp.ep == 1

    with pytest.raises(KeyError):
        make_plan("nope", mesh)


def test_make_plan_multipod_axes():
    mesh = FakeMesh({"pod": 2, "data": 4, "model": 2})
    mix = make_plan("mixserve", mesh)
    # batch spans pod+data; experts only data (pod replicates EP groups —
    # no A2A ever rides the DCN "pod" axis)
    assert mix.rules["batch"] == ("pod", "data")
    assert mix.rules["expert"] == ("data",)
    assert mix.dp == 8


def test_kv_seq_rule_present_in_all_plans():
    mesh = FakeMesh({"data": 4, "model": 2})
    for name in ("mixserve", "dp_ep", "pure_tp"):
        p = make_plan(name, mesh)
        assert p.rules["kv_seq"] == ("model",), name
