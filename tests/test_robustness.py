"""Request lifecycle, overload shedding, preemption, and the watchdog.

The serving tier's graceful-degradation contract (ISSUE 6 tentpole):

  - requests carry a terminal lifecycle state and can be cancelled
    mid-stream without corrupting neighbours;
  - per-request deadlines are enforced (queued AND mid-decode);
  - the bounded admission queue sheds instead of queueing unboundedly;
  - priority preemption evicts the lowest-priority slot and the resumed
    request's output is bit-identical to its uninterrupted run
    (recompute-on-resume on the dense per-slot cache);
  - a stalled serving loop raises ``StalledEngineError`` instead of
    silently busy-spinning to ``max_steps``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from _engine_helpers import make_engine, make_spec
from repro.core.resolve import OverloadPolicy
from repro.models.model import init_params
from repro.serving.api import LLM, ServeSpec
from repro.serving.engine import (Engine, PromptTooLongError, Request,
                                  RequestState)
from repro.serving.scheduler import (Scheduler, StalledEngineError,
                                     synthetic_workload)

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def smollm():
    cfg = C.get_reduced("smollm-360m")
    params = init_params(KEY, cfg, jnp.float32)
    return cfg, params


def _llm(cfg, params, **kw):
    spec = make_spec(cfg, max_batch=2, max_len=64, chunk=4,
                     prompt_len=16, max_new_tokens=4, **kw)
    return LLM(cfg, params, spec)


# -- lifecycle ----------------------------------------------------------


def test_request_lifecycle_states(smollm):
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=1, max_len=64, chunk=4)
    req = Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                  max_new_tokens=3)
    assert req.state == RequestState.QUEUED and not req.terminal
    assert eng.admit(req)
    assert req.state == RequestState.RUNNING
    while not req.done:
        eng.step()
    eng.step()                                   # reap sweep
    assert req.state == RequestState.DONE and req.terminal
    assert eng.n_active == 0


def test_cancel_mid_stream_frees_slot_later_rids_complete(smollm):
    """Cancel one of three requests mid-stream: its tokens stop, its slot
    frees, and the remaining rids finish with unchanged tokens."""
    cfg, params = smollm
    prompts = [np.arange(4 + i, dtype=np.int32) for i in range(3)]

    # clean reference run
    llm0 = _llm(cfg, params)
    rids0 = [llm0.submit(p, 6) for p in prompts]
    clean = {r: [] for r in rids0}
    for rid, tok in llm0.stream():
        clean[rid].append(tok)

    llm1 = _llm(cfg, params)
    rids1 = [llm1.submit(p, 6) for p in prompts]
    got = {r: [] for r in rids1}
    cancelled = rids1[1]
    stream = llm1.stream()
    for rid, tok in stream:
        got[rid].append(tok)
        if rid == cancelled and len(got[cancelled]) == 2:
            assert llm1.cancel(cancelled)
    assert len(got[cancelled]) == 2              # stopped early
    # the OTHER requests are unperturbed (slot isolation + dropless MoE)
    assert got[rids1[0]] == clean[rids0[0]]
    assert got[rids1[2]] == clean[rids0[2]]
    assert llm1.engine.n_active == 0
    assert llm1.engine.events["cancel"] == 1


def test_cancel_queued_and_unknown_rid(smollm):
    cfg, params = smollm
    llm = _llm(cfg, params)
    rid = llm.submit(np.arange(5, dtype=np.int32), 4)
    assert llm.cancel(rid) is True               # still queued
    assert llm.cancel(rid) is False              # already gone
    assert llm.cancel(999) is False
    assert not llm._queue


def test_overlong_submit_raises_without_corrupting_queue(smollm):
    """PromptTooLongError from submit() must not disturb queued rids."""
    cfg, params = smollm
    llm = _llm(cfg, params)
    ok = [llm.submit(np.arange(5, dtype=np.int32), 3),
          llm.submit(np.arange(7, dtype=np.int32), 3)]
    with pytest.raises(PromptTooLongError):
        llm.submit(np.zeros(80, np.int32), 4)    # 80 + 4 > max_len=64
    got = {r: [] for r in ok}
    for rid, tok in llm.stream():
        got[rid].append(tok)
    assert all(len(got[r]) == 3 for r in ok)
    assert llm.engine.n_active == 0


def test_zero_token_request_completes(smollm):
    """max_new_tokens=0 retires instead of pinning its slot forever
    (the old busy-spin)."""
    cfg, params = smollm
    llm = _llm(cfg, params)
    rid0 = llm.submit(np.arange(5, dtype=np.int32), 0)
    rid1 = llm.submit(np.arange(6, dtype=np.int32), 3)
    got = {rid0: [], rid1: []}
    for rid, tok in llm.stream():
        got[rid].append(tok)
    assert got[rid0] == [] and len(got[rid1]) == 3
    assert llm.engine.n_active == 0


# -- deadlines ----------------------------------------------------------


def test_deadline_expired_in_queue_is_shed(smollm):
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=1, max_len=64, chunk=4)
    sched = Scheduler(eng)
    # an already-expired deadline: arrival offset 0, deadline_s negative
    dead = Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                   max_new_tokens=4, deadline_s=-1.0)
    live = Request(rid=1, prompt=np.arange(6, dtype=np.int32),
                   max_new_tokens=4)
    sched.submit(dead)
    sched.submit(live)
    done = sched.run()
    assert [r.rid for r in done] == [1]
    assert dead.state == RequestState.SHED
    m = sched.metrics()
    assert m.n_shed == 1 and m.n_deadline_miss == 1
    assert m.deadline_miss_p99 > 0


def test_deadline_expired_mid_decode_frees_slot(smollm):
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=1, max_len=64, chunk=4)
    # warm the jitted step so the deadline clock isn't eaten by compile
    warm = Scheduler(eng)
    warm.submit(Request(rid=99, prompt=np.arange(5, dtype=np.int32),
                        max_new_tokens=2))
    warm.run()
    sched = Scheduler(eng)
    req = Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                  max_new_tokens=50, deadline_s=0.02)
    follow = Request(rid=1, prompt=np.arange(6, dtype=np.int32),
                     max_new_tokens=3)
    sched.submit(req)
    sched.submit(follow)
    done = sched.run()
    # the long request was killed at its deadline, the follower ran
    assert req.state == RequestState.CANCELLED
    assert "deadline" in req.error
    assert len(req.out_tokens) < 50
    assert [r.rid for r in done] == [1]
    assert sched.metrics().n_deadline_miss == 1


def test_llm_stream_enforces_deadlines(smollm):
    cfg, params = smollm
    llm = _llm(cfg, params)
    llm.generate([np.arange(5, dtype=np.int32)], max_new_tokens=2)  # warm jit
    rid = llm.submit(np.arange(5, dtype=np.int32), 50, deadline_s=0.02)
    toks = [t for r, t in llm.stream() if r == rid]
    assert len(toks) < 50
    assert llm.engine.n_active == 0
    assert llm.engine.events["deadline_miss"] == 1


# -- overload shedding --------------------------------------------------


def test_bounded_queue_sheds_reject_newest(smollm):
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=1, max_len=64, chunk=4,
                      overload=OverloadPolicy(queue_cap=2,
                                              shed="reject-newest"))
    sched = Scheduler(eng)
    reqs = list(synthetic_workload(4, prompt_len=8, max_new_tokens=2,
                                   vocab=cfg.vocab_size))
    accepted = [sched.submit(r) for r in reqs]
    assert accepted == [True, True, False, False]
    assert [r.state for r in reqs[2:]] == [RequestState.SHED] * 2
    done = sched.run()
    assert len(done) == 2
    assert sched.metrics().n_shed == 2


def test_bounded_queue_sheds_deadline_infeasible_first(smollm):
    """deadline-first: the overflow victim is the queued request whose
    deadline cannot be met anyway, not the incoming one."""
    cfg, params = smollm
    pol = OverloadPolicy(queue_cap=2, shed="deadline-first",
                         est_request_s=10.0)   # everything looks slow
    eng = make_engine(cfg, params, max_batch=1, max_len=64, chunk=4,
                      overload=pol)
    sched = Scheduler(eng)
    infeasible = Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                         max_new_tokens=2, deadline_s=0.5)   # < est 10s
    r1 = Request(rid=1, prompt=np.arange(5, dtype=np.int32), max_new_tokens=2)
    r2 = Request(rid=2, prompt=np.arange(5, dtype=np.int32), max_new_tokens=2)
    assert sched.submit(infeasible) and sched.submit(r1)
    assert sched.submit(r2)            # r2 accepted; infeasible shed instead
    assert infeasible.state == RequestState.SHED
    assert "deadline" in infeasible.error
    assert [r.rid for r in sched.waiting] == [1, 2]


# -- priority preemption ------------------------------------------------


def test_preempt_resume_output_exact(smollm):
    """THE recompute-on-resume property: preempted mid-decode, re-admitted,
    the final output equals the uninterrupted run bit-for-bit."""
    cfg, params = smollm
    spec = make_spec(cfg, max_batch=1, max_len=64, chunk=4)

    eng0 = Engine(cfg, params, spec=spec)
    base = Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                   max_new_tokens=6)
    assert eng0.admit(base)
    while not base.done:
        eng0.step()

    eng = Engine(cfg, params, spec=spec)
    req = Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                  max_new_tokens=6)
    assert eng.admit(req)
    for _ in range(4):                           # prefill + a few decodes
        eng.step()
    assert 0 < len(req.out_tokens) < 6
    victim = eng.preempt(0)
    assert victim is req and req.state == RequestState.PREEMPTED
    assert req.n_preempted == 1 and eng.n_active == 0
    assert eng.admit(req)                        # recompute-on-resume
    assert req.state == RequestState.RUNNING
    while not req.done:
        eng.step()
    assert req.out_tokens == base.out_tokens
    assert eng.events["preempt"] == 1


def test_scheduler_preempts_lower_priority_for_higher(smollm):
    """A high-priority arrival evicts the lowest-priority running slot;
    the victim re-queues and still completes with full output."""
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=1, max_len=64, chunk=4)
    sched = Scheduler(eng)
    lo = Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                 max_new_tokens=40, priority=0)
    hi = Request(rid=1, prompt=np.arange(6, dtype=np.int32),
                 max_new_tokens=3, priority=5, arrival=0.05)
    sched.submit(lo)
    sched.submit(hi)
    done = sched.run()
    assert {r.rid for r in done} == {0, 1}
    assert lo.n_preempted >= 1                  # evicted at least once
    assert len(lo.out_tokens) == 40 and len(hi.out_tokens) == 3
    # hi finished BEFORE the (longer) low-priority request
    assert hi.t_done < lo.t_done
    m = sched.metrics()
    assert m.n_preempted >= 1 and m.n_incomplete == 0


def test_victim_slot_picks_strictly_lower_priority(smollm):
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=2, max_len=64, chunk=4)
    a = Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                max_new_tokens=4, priority=1)
    b = Request(rid=1, prompt=np.arange(5, dtype=np.int32),
                max_new_tokens=4, priority=3)
    assert eng.admit(a) and eng.admit(b)
    assert eng.victim_slot(2) == 0              # only a (prio 1) outranked
    assert eng.victim_slot(1) is None           # nothing STRICTLY below 1
    assert eng.victim_slot(5) == 0              # lowest priority first


# -- watchdog -----------------------------------------------------------


def test_watchdog_raises_on_stalled_engine(smollm, monkeypatch):
    """An engine that stops planning work (q_lens always zero — the old
    silent busy-spin) now raises StalledEngineError with a diagnosis."""
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=1, max_len=64, chunk=4)
    monkeypatch.setattr(
        eng, "plan_q_lens",
        lambda budget=None: np.zeros((eng.max_batch,), np.int32))
    sched = Scheduler(eng, watchdog_steps=16)
    sched.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                         max_new_tokens=4))
    with pytest.raises(StalledEngineError, match="no progress"):
        sched.run()


def test_watchdog_quiet_on_healthy_idle_arrivals(smollm):
    """Waiting for a future arrival is NOT a stall: a sparse open-loop
    workload (gaps far longer than a step) completes without tripping."""
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=1, max_len=64, chunk=4)
    sched = Scheduler(eng, watchdog_steps=16)
    for r in synthetic_workload(3, prompt_len=8, max_new_tokens=2,
                                vocab=cfg.vocab_size, arrival_rate=8.0,
                                seed=5):
        sched.submit(r)
    done = sched.run()
    assert len(done) == 3


def test_metrics_row_includes_robustness_counters(smollm):
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=1, max_len=64, chunk=4)
    sched = Scheduler(eng)
    sched.submit(Request(rid=0, prompt=np.arange(5, dtype=np.int32),
                         max_new_tokens=2))
    sched.run()
    m = sched.metrics()
    row = m.row()
    for k in ("shed=", "preempt=", "cancel=", "dmiss=", "fault=", "kv=",
              "pfxhit="):
        assert k in row, row
    rb = m.robustness()
    assert set(rb) == {"n_shed", "n_preempted", "n_cancelled",
                       "n_deadline_miss", "n_faults", "deadline_miss_p99",
                       "kv_occupancy", "n_prefix_hits", "prefix_hit_tokens",
                       "n_evictions", "ep_rank_max_tokens",
                       "ep_rank_mean_tokens", "a2a_bytes_moved",
                       "a2a_bytes_worst", "n_spec_steps", "n_spec_drafted",
                       "n_spec_accepted", "spec_accept_rate",
                       "spec_tokens_per_step"}
