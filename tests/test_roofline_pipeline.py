"""Regression guard on the dry-run -> roofline analysis pipeline."""

import json
import os

import pytest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")


def _records():
    if not os.path.isdir(RESULTS):
        return []
    out = []
    for fn in sorted(os.listdir(RESULTS)):
        if fn.endswith("_mixserve.json"):
            with open(os.path.join(RESULTS, fn)) as f:
                r = json.load(f)
            if r.get("status") == "ok":
                out.append(r)
    return out


@pytest.mark.skipif(not _records(), reason="no dry-run records generated")
def test_dryrun_records_complete_and_analyzable():
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.roofline import analyze

    recs = _records()
    # 10 archs x {4,3} shapes x 2 meshes: 64 ok records expected
    assert len(recs) == 64, len(recs)
    seen = set()
    for r in recs:
        seen.add((r["arch"], r["shape"], r["mesh"]))
        a = analyze(r)
        assert a["t_compute"] > 0 and a["t_memory"] > 0
        assert a["dominant"] in ("compute", "memory", "collective")
        assert 0 < a["useful_ratio"] <= 1.0
        # decode must never be collective-dominant post pair-2 fix
        if r["shape"] in ("decode_32k", "long_500k"):
            assert a["dominant"] == "memory", (r["arch"], r["shape"],
                                               a["dominant"])
        # memory proof: everything except the documented deepseek train cell
        over = (r["memory"]["argument_bytes"]
                + r["memory"]["temp_bytes"]) / 1e9 > 16.5
        if over:
            assert (r["arch"], r["shape"]) == ("deepseek-v2-236b",
                                               "train_4k"), r["arch"]
    assert len(seen) == 64


@pytest.mark.skipif(not _records(), reason="no dry-run records generated")
def test_collective_parser_scales_with_layers():
    """Sanity: a deeper arch's per-step collective traffic in the same
    family/shape should not be smaller than a much shallower one (trip-count
    accounting actually multiplies)."""
    recs = {(r["arch"], r["shape"], r["mesh"]): r for r in _records()}
    deep = recs[("minicpm3-4b", "prefill_32k", "single")]  # 62 layers
    shallow = recs[("whisper-tiny", "prefill_32k", "single")]  # 4 layers
    d = deep["costs"]["collectives"]["bytes"]["total"]
    s = shallow["costs"]["collectives"]["bytes"]["total"]
    assert d > 5 * s
