"""Property-based tests (hypothesis) for MoE routing invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dep: skip, don't die at collection
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models import moe as M


@settings(max_examples=25, deadline=None)
@given(t=st.integers(1, 64), e=st.integers(2, 16), seed=st.integers(0, 999))
def test_positions_in_expert_are_dense_ranks(t, e, seed):
    """Within each expert, positions are exactly 0..count-1 (no gaps)."""
    rng = np.random.default_rng(seed)
    flat_e = jnp.asarray(rng.integers(0, e, size=t), jnp.int32)
    pos = np.asarray(M.positions_in_expert(flat_e, e))
    for ex in range(e):
        got = sorted(pos[np.asarray(flat_e) == ex])
        assert got == list(range(len(got)))


@settings(max_examples=25, deadline=None)
@given(t=st.integers(1, 48), e=st.integers(2, 12),
       k=st.integers(1, 4), seed=st.integers(0, 999))
def test_route_topk_invariants(t, e, k, seed):
    k = min(k, e)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (t, e))
    idx, w, aux = M.route_topk(logits, k)
    idx, w = np.asarray(idx), np.asarray(w, np.float64)
    assert idx.shape == (t, k) and w.shape == (t, k)
    # indices valid and distinct per token
    assert (idx >= 0).all() and (idx < e).all()
    for row in idx:
        assert len(set(row.tolist())) == k
    # renormalized weights sum to 1, are positive, sorted descending
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
    assert (w >= 0).all()
    assert (np.diff(w, axis=-1) <= 1e-6).all()
    # aux loss >= 1 (perfectly balanced) for any routing
    assert float(aux) >= 0.99


@settings(max_examples=20, deadline=None)
@given(t=st.integers(4, 64), e=st.integers(2, 8), k=st.integers(1, 2),
       cap=st.integers(1, 16), seed=st.integers(0, 99))
def test_dispatch_capacity_clipping(t, e, k, cap, seed):
    k = min(k, e)
    logits = jax.random.normal(jax.random.PRNGKey(seed), (t, e))
    idx, w, _ = M.route_topk(logits, k)
    d = M.make_dispatch(idx, w, e, cap)
    pos, keep, fe = (np.asarray(d.pos), np.asarray(d.keep),
                     np.asarray(d.flat_e))
    # kept slots sit strictly inside capacity, each (expert, pos) unique
    assert (pos[keep] < cap).all()
    pairs = set()
    for ex, p_, kp in zip(fe, pos, keep):
        if kp:
            assert (ex, p_) not in pairs
            pairs.add((ex, p_))
    # per-expert kept count == min(assigned, cap)
    for ex in range(e):
        assigned = int((fe == ex).sum())
        kept = int(keep[fe == ex].sum())
        assert kept == min(assigned, cap)


@settings(max_examples=10, deadline=None)
@given(t=st.integers(2, 32), seed=st.integers(0, 99))
def test_scatter_gather_roundtrip(t, seed):
    """With ample capacity, scatter->gather with weight 1 reproduces sums."""
    e, k, h = 4, 2, 8
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (t, h))
    logits = jax.random.normal(jax.random.PRNGKey(seed + 1), (t, e))
    idx, w, _ = M.route_topk(logits, k, renorm=True)
    d = M.make_dispatch(idx, w, e, capacity=t * k)
    buf = M.scatter_to_buffers(x, d, e)
    out = M.gather_from_buffers(buf, d, t)
    # identity experts: gather(scatter(x)) == sum_k w_k * x == x
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-5)


def test_capacity_for_padding():
    # aligned to 8 once past 8 ...
    assert M.capacity_for(100, 2, 8, 1.25) % 8 == 0
    # ... but NOT floored at 8: decode-time buffers stay exact (§Perf pair 2)
    assert M.capacity_for(1, 1, 64, 1.0) == 1
    assert M.capacity_for(8, 6, 160, 1.25) == 1
    assert M.capacity_for(0, 2, 8, 1.25) == 1
