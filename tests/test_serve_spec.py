"""ServeSpec resolution: the automatic loop's public API contract.

- resolution fills EVERY "auto" field with a concrete, deterministic value
  (arch sweep: MoE + dense + a legacy-fallback family);
- resolved specs round-trip through ``dataclasses.replace``;
- explicit overrides beat "auto" field by field;
- the resolved spec — not Engine flag defaults — is what reaches the
  engine (the acceptance criterion of the redesign);
- the old per-knob ``Engine(...)`` / ``Scheduler(token_budget=)`` kwargs
  and ``spec_from_engine_kwargs`` are fully retired (their one-release
  deprecation window closed) — construction without a spec is a TypeError.
"""

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core.topology import CLUSTERS
from repro.kernels.policy import KernelPolicy
from repro.models.model import init_params
from repro.serving.api import AUTO, LLM, ResolvedServeSpec, ServeSpec
from repro.serving.engine import Engine
from repro.serving.scheduler import Scheduler

# one MoE, one dense, one family the unified engine serves via the
# internal legacy fallback (ssm) — resolution must work for all of them
ARCH_SWEEP = ("phi3.5-moe-42b", "smollm-360m", "rwkv6-1.6b")


@pytest.fixture(scope="module")
def smollm():
    cfg = C.get_reduced("smollm-360m")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    return cfg, params


@pytest.mark.parametrize("arch", ARCH_SWEEP)
def test_resolution_concrete_and_deterministic(arch):
    spec = ServeSpec(arch=arch, prompt_len=32, max_new_tokens=8)
    r1 = spec.resolve()
    r2 = spec.resolve()
    for f in ("chunk", "token_budget", "max_batch", "max_len"):
        v = getattr(r1, f)
        assert isinstance(v, int) and v > 0, (f, v)
    assert r1.dispatch in ("dropless", "capacity")
    assert isinstance(r1.kernels, KernelPolicy)
    assert r1.strategy in ("mixserve", "dp_ep", "pure_ep", "pure_tp")
    assert r1.cluster in CLUSTERS
    assert r1 == r2                                 # deterministic
    # every knob has a provenance entry, and auto fields say so
    assert set(r1.provenance) >= set(ResolvedServeSpec._KNOBS)
    for f in ("strategy", "chunk", "token_budget", "max_batch", "max_len",
              "kernels", "dispatch"):
        assert r1.provenance[f].startswith("auto:"), (f, r1.provenance[f])
    assert arch in r1.describe()                    # printable report


@pytest.mark.parametrize("arch", ARCH_SWEEP)
def test_resolved_spec_roundtrips_replace(arch):
    r = ServeSpec(arch=arch).resolve()
    assert dataclasses.replace(r) == r
    bumped = dataclasses.replace(r, chunk=r.chunk + 1)
    assert bumped.chunk == r.chunk + 1
    assert dataclasses.replace(bumped, chunk=r.chunk) == r


def test_explicit_overrides_beat_auto():
    spec = ServeSpec(arch="phi3.5-moe-42b", strategy="mixserve",
                     kernels="off", dispatch="capacity", chunk=5,
                     token_budget=11, max_batch=3, max_len=80)
    r = spec.resolve()
    assert (r.chunk, r.token_budget, r.max_batch, r.max_len) == (5, 11, 3, 80)
    assert r.dispatch == "capacity" and not r.kernels.any_enabled
    assert r.strategy == "mixserve"
    for f in ("strategy", "kernels", "dispatch", "chunk", "token_budget",
              "max_batch", "max_len"):
        assert r.provenance[f].startswith("explicit"), f
    # the plan carries the explicit kernel/dispatch choice
    assert r.plan.dispatch_mode == "capacity"
    assert r.plan.kernels == KernelPolicy.off()


def test_spec_field_validation():
    with pytest.raises(ValueError):
        ServeSpec(arch="smollm-360m", dispatch="bogus")
    with pytest.raises(ValueError):
        ServeSpec(arch="smollm-360m", strategy="bogus")
    with pytest.raises(ValueError):
        ServeSpec(arch="smollm-360m", chunk="sixteen")
    with pytest.raises(ValueError):
        ServeSpec().resolve()          # no arch, no cfg


def test_explicit_cluster_name_and_mismatch():
    r = ServeSpec(arch="smollm-360m", cluster="h20x16").resolve()
    assert r.cluster == "h20x16" and r.provenance["cluster"] == "explicit"
    with pytest.raises(KeyError):
        ServeSpec(arch="smollm-360m", cluster="nope").resolve()


def test_resolved_spec_reaches_engine(smollm):
    """Acceptance: the analyzer/cost-model-resolved knobs — not the old
    Engine flag defaults (max_batch=8/max_len=512/chunk=16, budget
    B*chunk) — are what configures the engine and scheduler."""
    cfg, params = smollm
    r = ServeSpec(arch="smollm-360m", prompt_len=16,
                  max_new_tokens=4).resolve()
    eng = Engine(cfg, params, spec=r)
    assert eng.spec is r
    assert eng.max_batch == r.max_batch and eng.max_len == r.max_len
    assert eng.chunk == r.chunk
    assert eng.plan is r.plan
    assert eng.plan.dispatch_mode == r.dispatch == "dropless"
    assert eng.plan.kernels == r.kernels
    assert eng.temperature == r.temperature
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # no deprecation on the new API
        sched = Scheduler(eng)
    assert sched.token_budget == r.token_budget
    # the resolved values are live, not the legacy defaults
    assert eng.max_len != 512
    assert sched.token_budget != eng.max_batch * eng.chunk


def test_engine_kwargs_shim_retired(smollm):
    """The PR 5 deprecation window is closed: per-knob Engine kwargs, the
    Scheduler(token_budget=) kwarg and spec_from_engine_kwargs are gone."""
    cfg, params = smollm
    with pytest.raises(TypeError):
        Engine(cfg, params, max_batch=3, max_len=48, chunk=4)
    with pytest.raises(TypeError):
        Engine(cfg, params)                      # a spec is mandatory
    r = ServeSpec(arch="smollm-360m", max_batch=2, max_len=64,
                  prompt_len=8, max_new_tokens=2).resolve()
    eng = Engine(cfg, params, spec=r)
    with pytest.raises(TypeError):
        Scheduler(eng, token_budget=7)
    import repro.serving.api as api
    assert not hasattr(api, "spec_from_engine_kwargs")


def test_overload_resolves_from_cost_model():
    """The "auto" overload knob becomes a concrete bounded-admission policy
    priced by the Eq. 4-6 token-time estimates, with provenance; an explicit
    OverloadPolicy passes through untouched."""
    from repro.core.resolve import OverloadPolicy

    r = ServeSpec(arch="smollm-360m", prompt_len=16, max_new_tokens=4).resolve()
    assert isinstance(r.overload, OverloadPolicy)
    assert r.overload.queue_cap >= 2 * r.max_batch
    assert r.overload.est_request_s > 0
    assert r.overload.shed == "deadline-first"
    assert r.provenance["overload"].startswith("auto:cost-model")
    assert "overload" in r.describe()

    pol = OverloadPolicy(queue_cap=3, shed="reject-newest")
    r2 = ServeSpec(arch="smollm-360m", overload=pol).resolve()
    assert r2.overload is pol
    assert r2.provenance["overload"] == "explicit"
    # validation
    with pytest.raises(ValueError):
        OverloadPolicy(queue_cap=0)
    with pytest.raises(ValueError):
        OverloadPolicy(queue_cap=4, shed="bogus")
    with pytest.raises(ValueError):
        ServeSpec(arch="smollm-360m", overload="bogus")


def test_faults_field_validated_and_in_meta():
    from repro.serving.faults import Fault

    f = Fault(kind="latency", at=(3,), ms=5.0)
    r = ServeSpec(arch="smollm-360m", faults=[f]).resolve()
    assert r.faults == (f,)                     # normalized to a tuple
    assert r.as_meta()["faults"] == [f.describe()]
    with pytest.raises(ValueError):
        ServeSpec(arch="smollm-360m", faults=("nan",))   # not a Fault
    with pytest.raises(ValueError):
        Fault(kind="bogus", at=(1,))
    with pytest.raises(ValueError):
        Fault(kind="nan")                       # never fires


def test_llm_generate_and_stream_agree(smollm):
    cfg, params = smollm
    r = ServeSpec(arch="smollm-360m", prompt_len=16, max_new_tokens=4,
                  max_batch=2, max_len=64, chunk=4).resolve()
    prompts = [np.arange(5, dtype=np.int32) % cfg.vocab_size,
               np.arange(7, dtype=np.int32) % cfg.vocab_size]

    llm = LLM.from_spec(r, cfg=cfg, params=params)
    outs = llm.generate(prompts, max_new_tokens=4)
    assert [len(o) for o in outs] == [4, 4]

    llm2 = LLM.from_spec(r, cfg=cfg, params=params)
    rids = [llm2.submit(p, 4) for p in prompts]
    got = {rid: [] for rid in rids}
    for rid, tok in llm2.stream():
        got[rid].append(tok)
    assert [got[rid] for rid in rids] == outs


def test_llm_generate_after_submit_does_not_crash(smollm):
    """generate() drains the queue: an earlier submit()'s request must not
    crash the bookkeeping (regression: KeyError on the foreign rid)."""
    cfg, params = smollm
    r = ServeSpec(arch="smollm-360m", prompt_len=16, max_new_tokens=4,
                  max_batch=2, max_len=64, chunk=4).resolve()
    llm = LLM.from_spec(r, cfg=cfg, params=params)
    llm.submit(np.arange(4, dtype=np.int32) % cfg.vocab_size, 3)
    outs = llm.generate([np.arange(6, dtype=np.int32) % cfg.vocab_size],
                        max_new_tokens=2)
    assert [len(o) for o in outs] == [2]
    assert llm.engine.n_active == 0 and not llm._queue


def test_llm_stream_terminates_on_legacy_fallback_single_token():
    """Legacy-fallback families (blocking prefill emits the first token in
    admit): a max_new_tokens=1 request must finish and free its slot
    instead of spinning stream() forever (regression)."""
    cfg = C.get_reduced("rwkv6-1.6b")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    r = ServeSpec(arch="rwkv6-1.6b", prompt_len=8, max_new_tokens=1,
                  max_batch=1, max_len=64).resolve()
    llm = LLM.from_spec(r, cfg=cfg, params=params)
    assert llm.engine.legacy
    outs = llm.generate([np.arange(5, dtype=np.int32) % cfg.vocab_size],
                        max_new_tokens=1)
    assert [len(o) for o in outs] == [1]
    assert llm.engine.n_active == 0


def test_llm_from_plain_spec_builds_reduced_engine():
    llm = LLM.from_spec(ServeSpec(arch="smollm-360m", prompt_len=8,
                                  max_new_tokens=2, max_batch=1,
                                  max_len=32))
    assert llm.cfg.name == C.get_reduced("smollm-360m").name
    assert llm.engine.max_batch == 1 and llm.engine.max_len == 32
    out = llm.generate([np.arange(4, dtype=np.int32)], max_new_tokens=2)
    assert len(out[0]) == 2


def test_serve_cli_builds_auto_spec_and_reduced_toggle():
    """serve.py flags land on the spec; --reduced is finally disableable."""
    from repro.launch import serve as S
    args = S.parse_args(["--arch", "phi3.5-moe-42b"])
    assert args.reduced is True
    spec = S.build_spec(args)
    for f in ("chunk", "token_budget", "max_batch", "max_len",
              "kernels", "dispatch", "strategy"):
        assert getattr(spec, f) == AUTO, f
    args2 = S.parse_args(["--arch", "phi3.5-moe-42b", "--no-reduced",
                          "--chunk", "8", "--dispatch", "capacity"])
    assert args2.reduced is False
    spec2 = S.build_spec(args2)
    assert spec2.reduced is False
    assert spec2.chunk == 8 and spec2.dispatch == "capacity"


def test_cluster_for_mesh_explicit_and_fallback():
    """launch.auto.cluster_for_mesh: explicit ClusterSpec/name wins and is
    validated against the mesh size; the v5e heuristic is the fallback."""
    from repro.core.topology import TPU_V5E_POD
    from repro.launch.auto import cluster_for_mesh

    class FakeDevices:
        def __init__(self, size):
            self.size = size

    class FakeMesh:
        def __init__(self, size):
            self.devices = FakeDevices(size)

    assert cluster_for_mesh(FakeMesh(256)) is TPU_V5E_POD
    assert cluster_for_mesh(FakeMesh(512)).name == "v5e-2pods-512"
    assert cluster_for_mesh(FakeMesh(16), "h20x16").name == "h20x16"
    assert cluster_for_mesh(FakeMesh(16), CLUSTERS["h20x16"]).name == "h20x16"
    with pytest.raises(ValueError):
        cluster_for_mesh(FakeMesh(8), "h20x16")     # 16 devices != 8
