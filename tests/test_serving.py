"""Serving engine + scheduler integration tests (continuous batching)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models.model import init_params
from _engine_helpers import make_engine
from repro.serving.engine import Request
from repro.serving.scheduler import Scheduler, synthetic_workload


@pytest.fixture(scope="module")
def smollm():
    cfg = C.get_reduced("smollm-360m")
    params = init_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    return cfg, params


def test_engine_completes_all_requests(smollm):
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=3, max_len=96)
    sched = Scheduler(eng)
    reqs = list(synthetic_workload(7, prompt_len=16, max_new_tokens=5,
                                   vocab=cfg.vocab_size))
    for r in reqs:
        sched.submit(r)
    done = sched.run()
    assert len(done) == 7
    assert all(len(r.out_tokens) == 5 for r in done)
    m = sched.metrics()
    assert m.n_requests == 7 and m.throughput_tok_s > 0


def test_continuous_batching_matches_isolated_generation(smollm):
    """Tokens generated under slot contention == tokens generated alone.

    This is THE correctness property of per-slot lengths: an occupied slot's
    generation must be unaffected by neighbours being admitted/retired."""
    cfg, params = smollm
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (9, 14, 5, 11, 7)]

    # isolated: one request at a time, fresh engine
    isolated = []
    for p in prompts:
        eng = make_engine(cfg, params, max_batch=1, max_len=64)
        s = Scheduler(eng)
        s.submit(Request(rid=0, prompt=p, max_new_tokens=6))
        done = s.run()
        isolated.append(done[0].out_tokens)

    # contended: all five through a 2-slot engine
    eng = make_engine(cfg, params, max_batch=2, max_len=64)
    s = Scheduler(eng)
    for i, p in enumerate(prompts):
        s.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    done = sorted(s.run(), key=lambda r: r.rid)
    contended = [r.out_tokens for r in done]

    assert contended == isolated


def test_slot_reuse_after_retirement(smollm):
    cfg, params = smollm
    eng = make_engine(cfg, params, max_batch=1, max_len=64)
    sched = Scheduler(eng)
    for r in synthetic_workload(3, prompt_len=8, max_new_tokens=3,
                                vocab=cfg.vocab_size):
        sched.submit(r)
    done = sched.run()
    assert len(done) == 3          # same slot served all three sequentially


def test_greedy_determinism(smollm):
    cfg, params = smollm
    p = np.arange(10, dtype=np.int32) % cfg.vocab_size
    outs = []
    for _ in range(2):
        eng = make_engine(cfg, params, max_batch=1, max_len=64)
        s = Scheduler(eng)
        s.submit(Request(rid=0, prompt=p, max_new_tokens=8))
        outs.append(s.run()[0].out_tokens)
    assert outs[0] == outs[1]
