"""Sharded integration tests, each in a subprocess with its own fake-device
count (the main pytest process keeps the default 1 CPU device, per the
dry-run isolation rule)."""

import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


def _run(script: str, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, os.path.join(HERE, "sharded", script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    if r.returncode != 0:
        pytest.fail(f"{script} failed:\n{r.stdout[-2000:]}\n{r.stderr[-3000:]}")
    return r.stdout


def test_moe_equivalence_across_plans():
    out = _run("run_moe_equivalence.py")
    assert "MOE_EQUIVALENCE_OK" in out


def test_sharded_model_matches_unsharded():
    out = _run("run_sharded_model.py")
    assert "SHARDED_MODEL_OK" in out


def test_overlap_exchange_bitwise_equivalence():
    """Micro-chunked count-bounded EP exchange == monolithic, bit for bit,
    incl. the overflow fallback under adversarial routing skew."""
    out = _run("run_overlap_equivalence.py")
    assert "OVERLAP_EQUIVALENCE_OK" in out


def test_trace_contract_census_matches_cost_model():
    """The traced moe_block's collective census == cost_model.comm_census
    for every strategy/algorithm/overlap, and a sabotaged block fails."""
    out = _run("run_trace_contract.py")
    assert "TRACE_CONTRACT_OK" in out
